//! Periodic live sampler: a background thread that sweeps the global
//! registry and prints a one-line progress report (cliques/sec, queue
//! depth, worker utilization) — the `--metrics-every` CLI surface for
//! watching long enumerations and replays in flight.
//!
//! The thread only *reads* the registry (snapshot sweeps), so it never
//! perturbs the hot paths beyond cache traffic.  It parks in short slices
//! to react to [`Sampler::stop`] promptly even with long periods.

use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;

use super::{names, snapshot, TelemetrySnapshot};

/// Handle to a running sampler thread; stops and joins on drop.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Sampler {
    /// Start sampling every `period` (clamped to ≥ 10ms), printing to
    /// stderr.
    pub fn start(period: Duration) -> Sampler {
        let period = period.max(Duration::from_millis(10));
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("parmce-telemetry-sampler".into())
            .spawn(move || run(&flag, period))
            .expect("spawn telemetry sampler");
        Sampler {
            stop,
            thread: Some(thread),
        }
    }

    /// Stop the thread and wait for it to exit.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run(stop: &AtomicBool, period: Duration) {
    let t0 = Instant::now();
    let mut prev = snapshot();
    let mut prev_at = t0;
    loop {
        // park in small slices so stop() returns quickly
        let wake = Instant::now() + period;
        while Instant::now() < wake {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(10).min(period));
        }
        let now = Instant::now();
        let snap = snapshot();
        eprintln!(
            "[telemetry] {}",
            format_tick(&snap, &prev, now - prev_at, now - t0)
        );
        prev = snap;
        prev_at = now;
    }
}

/// One progress line from two consecutive sweeps.  Public (crate-visible
/// via the module) so the unit tests can pin the arithmetic without a
/// real thread.
pub(crate) fn format_tick(
    snap: &TelemetrySnapshot,
    prev: &TelemetrySnapshot,
    dt: Duration,
    since_start: Duration,
) -> String {
    let dt_s = dt.as_secs_f64().max(1e-9);
    let cliques = snap.counter(names::CLIQUES_EMITTED).unwrap_or(0);
    let d_cliques = cliques.saturating_sub(prev.counter(names::CLIQUES_EMITTED).unwrap_or(0));
    let d_busy = snap
        .counter(names::POOL_WORKER_BUSY_NS)
        .unwrap_or(0)
        .saturating_sub(prev.counter(names::POOL_WORKER_BUSY_NS).unwrap_or(0));
    let depth = snap.gauge(names::POOL_QUEUE_DEPTH).unwrap_or(0);
    // worker-equivalents of CPU consumed over the window (4 workers fully
    // busy → 4.0)
    let utilization = d_busy as f64 / (dt_s * 1e9);
    format!(
        "t={:.1}s cliques={} (+{:.0}/s) queue_depth={} workers_busy={:.2}x",
        since_start.as_secs_f64(),
        cliques,
        d_cliques as f64 / dt_s,
        depth,
        utilization
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{CounterSample, GaugeSample};

    fn snap(cliques: u64, busy_ns: u64, depth: u64) -> TelemetrySnapshot {
        TelemetrySnapshot {
            counters: vec![
                CounterSample {
                    name: names::CLIQUES_EMITTED,
                    help: "",
                    per_worker: false,
                    total: cliques,
                    shards: vec![],
                },
                CounterSample {
                    name: names::POOL_WORKER_BUSY_NS,
                    help: "",
                    per_worker: true,
                    total: busy_ns,
                    shards: vec![],
                },
            ],
            gauges: vec![GaugeSample {
                name: names::POOL_QUEUE_DEPTH,
                help: "",
                value: depth,
            }],
            histograms: vec![],
        }
    }

    #[test]
    fn tick_line_reports_rates() {
        let line = format_tick(
            &snap(3000, 2_000_000_000, 7),
            &snap(1000, 0, 0),
            Duration::from_secs(1),
            Duration::from_secs(5),
        );
        assert!(line.contains("cliques=3000"), "{line}");
        assert!(line.contains("(+2000/s)"), "{line}");
        assert!(line.contains("queue_depth=7"), "{line}");
        assert!(line.contains("workers_busy=2.00x"), "{line}");
    }

    #[test]
    fn sampler_starts_and_stops_cleanly() {
        let s = Sampler::start(Duration::from_millis(20));
        std::thread::sleep(Duration::from_millis(5));
        s.stop(); // must join without hanging even mid-period
    }
}
