//! Live telemetry: sharded runtime metrics with a metrics export surface.
//!
//! The paper's own evidence for parallel MCE's hard problems — subproblem
//! skew (Fig. 2), scheduler load balance (§4.2) — is exactly what a
//! production clique service must see *while running*, not rebuild
//! offline.  This module is the always-on layer: a [`Registry`] of named
//! counters, gauges and histograms instrumenting the load-bearing seams
//! (pool scheduling, ParTTT spawn/cutover/kernel hand-off, IMCE batch
//! phases, service publish/read), exported three ways:
//!
//! * [`TelemetrySnapshot`] embedded in
//!   [`RunReport`](crate::session::RunReport) and the serve-replay
//!   [`DriverReport`](crate::service::DriverReport) (per-run deltas of
//!   the process-wide registry);
//! * Prometheus text exposition / JSON behind `--metrics-out` on the
//!   `enumerate` and `serve-replay` CLI commands;
//! * a periodic [`Sampler`] thread printing cliques/sec, queue depth and
//!   worker utilization during long runs (`--metrics-every`).
//!
//! **Cost contract.** Counters are cache-padded per-worker shards (the
//! [`crate::mce::sink::sharded`] pattern): enabled-but-unread cost on the
//! TTT hot path is one `Relaxed` `fetch_add` on a private cache line.
//! Snapshots sweep shards with `Acquire` loads — exact after a
//! happens-before point (scope join / run end), a monotone lower bound
//! while workers run; the loom model
//! `telemetry_counter_sweep_exact_after_join` pins the protocol.  The
//! `telemetry-off` cargo feature compiles every metric to a zero-sized
//! no-op for true zero cost (`benches/telemetry.rs` measures both).
//!
//! All synchronization goes through [`crate::util::sync`], so the loom
//! shim can perturb the shard-sweep protocol like every other concurrent
//! structure in the crate.

pub mod metrics;
pub mod sampler;
pub mod snapshot;
pub mod subprob;

pub use metrics::{Counter, Gauge, Histogram, SpanTimer, HIST_BUCKETS, WORKER_SHARDS};
pub use sampler::Sampler;
pub use snapshot::{CounterSample, GaugeSample, HistogramSample, TelemetrySnapshot};
pub use subprob::{SubCell, SubCellSink};

use crate::util::sync::OnceLock;

/// Canonical metric names (the README "Metric reference" table mirrors
/// this list) — use these for [`TelemetrySnapshot::counter`] /
/// [`TelemetrySnapshot::gauge`] lookups instead of string literals.
pub mod names {
    pub const POOL_JOBS_SPAWNED: &str = "parmce_pool_jobs_spawned_total";
    pub const POOL_JOBS_DEQUEUED: &str = "parmce_pool_jobs_dequeued_total";
    pub const POOL_WAKEUPS: &str = "parmce_pool_wakeups_total";
    pub const POOL_QUEUE_DEPTH: &str = "parmce_pool_queue_depth";
    pub const POOL_WORKER_BUSY_NS: &str = "parmce_pool_worker_busy_ns_total";
    pub const CLIQUES_EMITTED: &str = "parmce_cliques_emitted_total";
    pub const PARTTT_TASKS_SPAWNED: &str = "parmce_parttt_tasks_spawned_total";
    pub const PARTTT_SEQ_CUTOVERS: &str = "parmce_parttt_seq_cutovers_total";
    pub const PARTTT_PAR_PIVOTS: &str = "parmce_parttt_par_pivots_total";
    pub const BITKERNEL_HANDOFFS: &str = "parmce_bitkernel_handoffs_total";
    pub const DYNAMIC_BATCHES: &str = "parmce_dynamic_batches_total";
    pub const DYNAMIC_NEW_CLIQUES: &str = "parmce_dynamic_new_cliques_total";
    pub const DYNAMIC_SUBSUMED: &str = "parmce_dynamic_subsumed_cliques_total";
    pub const DYNAMIC_BATCH_NS: &str = "parmce_dynamic_batch_ns";
    pub const DYNAMIC_NEW_TASK_NS: &str = "parmce_dynamic_new_task_ns";
    pub const DYNAMIC_SUB_TASK_NS: &str = "parmce_dynamic_sub_task_ns";
    pub const SERVICE_PUBLISHES: &str = "parmce_service_publishes_total";
    pub const SERVICE_QUERIES: &str = "parmce_service_queries_total";
    pub const SERVICE_PUBLISHED_EPOCH: &str = "parmce_service_published_epoch";
    pub const SERVICE_EPOCH_LAG_SUM: &str = "parmce_service_epoch_lag_sum_total";
    pub const SERVICE_EPOCH_LAG_SAMPLES: &str = "parmce_service_epoch_lag_samples_total";
    pub const SERVICE_EPOCH_LAG_MAX: &str = "parmce_service_epoch_lag_max";
    pub const POOL_SPAWN_FAILURES: &str = "parmce_pool_spawn_failures_total";
    pub const POOL_JOBS_PANICKED: &str = "parmce_pool_jobs_panicked_total";
    pub const SERVICE_PUBLISH_FAILURES: &str = "parmce_service_publish_failures_total";
    pub const INGEST_EDGES_PARSED: &str = "parmce_ingest_edges_parsed_total";
    pub const INGEST_SELF_LOOPS: &str = "parmce_ingest_self_loops_total";
    pub const INGEST_PARSE_NS: &str = "parmce_ingest_parse_ns";
    pub const INGEST_CSR_BUILD_NS: &str = "parmce_ingest_csr_build_ns";
    pub const INGEST_RANK_NS: &str = "parmce_ingest_rank_ns";
}

/// The process-wide metric registry.  One instance lives behind
/// [`global`]; hot paths reach their metric as a direct field access, so
/// "registration" is compile-time and the emit path never hashes a name.
pub struct Registry {
    // --- pool scheduling (coordinator/pool.rs) ---
    pub pool_jobs_spawned: Counter,
    pub pool_jobs_dequeued: Counter,
    pub pool_wakeups: Counter,
    pub pool_queue_depth: Gauge,
    /// Exported per worker shard (`worker="i"` labels).
    pub pool_worker_busy_ns: Counter,
    /// Worker threads that failed to spawn (the pool degrades to fewer
    /// workers instead of aborting — ISSUE 9).
    pub pool_spawn_failures: Counter,
    /// Jobs whose closure panicked; the pool contains the unwind and the
    /// first payload per scope resurfaces at join (ISSUE 9).
    pub pool_jobs_panicked: Counter,
    // --- enumeration kernels (mce/) ---
    pub cliques_emitted: Counter,
    pub parttt_tasks_spawned: Counter,
    pub parttt_seq_cutovers: Counter,
    pub parttt_par_pivots: Counter,
    pub bitkernel_handoffs: Counter,
    // --- dynamic pipeline (dynamic/, session/dynamic.rs) ---
    pub dynamic_batches: Counter,
    pub dynamic_new_cliques: Counter,
    pub dynamic_subsumed_cliques: Counter,
    pub dynamic_batch_ns: Histogram,
    pub dynamic_new_task_ns: Histogram,
    pub dynamic_sub_task_ns: Histogram,
    // --- clique service (service/) ---
    pub service_publishes: Counter,
    pub service_queries: Counter,
    pub service_published_epoch: Gauge,
    pub service_epoch_lag_sum: Counter,
    pub service_epoch_lag_samples: Counter,
    pub service_epoch_lag_max: Gauge,
    /// Snapshot publishes skipped after exhausting freeze retries
    /// (readers stay on the previous epoch — ISSUE 9).
    pub service_publish_failures: Counter,
    // --- ingest & ranking pipeline (graph/, mce/ranking.rs) ---
    /// Edges accepted by edge-list parsing (either path; self-loops
    /// excluded).
    pub ingest_edges_parsed: Counter,
    /// Self-loop edges skipped by edge-list parsing.
    pub ingest_self_loops: Counter,
    /// Wall time per edge-list parse, nanoseconds.
    pub ingest_parse_ns: Histogram,
    /// Wall time per CSR construction, nanoseconds.
    pub ingest_csr_build_ns: Histogram,
    /// Wall time per vertex-ranking computation, nanoseconds.
    pub ingest_rank_ns: Histogram,
}

impl Registry {
    fn new() -> Self {
        Registry {
            pool_jobs_spawned: Counter::new(),
            pool_jobs_dequeued: Counter::new(),
            pool_wakeups: Counter::new(),
            pool_queue_depth: Gauge::new(),
            pool_worker_busy_ns: Counter::new(),
            pool_spawn_failures: Counter::new(),
            pool_jobs_panicked: Counter::new(),
            cliques_emitted: Counter::new(),
            parttt_tasks_spawned: Counter::new(),
            parttt_seq_cutovers: Counter::new(),
            parttt_par_pivots: Counter::new(),
            bitkernel_handoffs: Counter::new(),
            dynamic_batches: Counter::new(),
            dynamic_new_cliques: Counter::new(),
            dynamic_subsumed_cliques: Counter::new(),
            dynamic_batch_ns: Histogram::new(),
            dynamic_new_task_ns: Histogram::new(),
            dynamic_sub_task_ns: Histogram::new(),
            service_publishes: Counter::new(),
            service_queries: Counter::new(),
            service_published_epoch: Gauge::new(),
            service_epoch_lag_sum: Counter::new(),
            service_epoch_lag_samples: Counter::new(),
            service_epoch_lag_max: Gauge::new(),
            service_publish_failures: Counter::new(),
            ingest_edges_parsed: Counter::new(),
            ingest_self_loops: Counter::new(),
            ingest_parse_ns: Histogram::new(),
            ingest_csr_build_ns: Histogram::new(),
            ingest_rank_ns: Histogram::new(),
        }
    }

    /// Sweep every metric into an owned [`TelemetrySnapshot`].  Under
    /// `telemetry-off` every sample reads zero (and counter shard
    /// breakdowns are empty) — the export surface keeps working, it just
    /// has nothing to say.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let c = |name, help, per_worker, counter: &Counter| CounterSample {
            name,
            help,
            per_worker,
            total: counter.value(),
            shards: counter.per_shard(),
        };
        let g = |name, help, gauge: &Gauge| GaugeSample {
            name,
            help,
            value: gauge.get(),
        };
        TelemetrySnapshot {
            counters: vec![
                c(
                    names::POOL_JOBS_SPAWNED,
                    "Jobs submitted to the work-stealing pool.",
                    false,
                    &self.pool_jobs_spawned,
                ),
                c(
                    names::POOL_JOBS_DEQUEUED,
                    "Jobs taken off a deque or the injector (own pop, injector pop, or steal).",
                    false,
                    &self.pool_jobs_dequeued,
                ),
                c(
                    names::POOL_WAKEUPS,
                    "Parked worker wakeups (notify or park timeout).",
                    false,
                    &self.pool_wakeups,
                ),
                c(
                    names::POOL_WORKER_BUSY_NS,
                    "Nanoseconds each pool worker spent executing jobs.",
                    true,
                    &self.pool_worker_busy_ns,
                ),
                c(
                    names::POOL_SPAWN_FAILURES,
                    "Worker threads that failed to spawn (pool degraded to fewer workers).",
                    false,
                    &self.pool_spawn_failures,
                ),
                c(
                    names::POOL_JOBS_PANICKED,
                    "Jobs whose closure panicked (contained by the pool, resurfaced at scope join).",
                    false,
                    &self.pool_jobs_panicked,
                ),
                c(
                    names::CLIQUES_EMITTED,
                    "Maximal cliques emitted through counted session sinks.",
                    false,
                    &self.cliques_emitted,
                ),
                c(
                    names::PARTTT_TASKS_SPAWNED,
                    "ParTTT/ParMCE subtree tasks forked onto the pool.",
                    false,
                    &self.parttt_tasks_spawned,
                ),
                c(
                    names::PARTTT_SEQ_CUTOVERS,
                    "ParTTT tasks that fell below seq_cutoff and ran sequential TTT in-task.",
                    false,
                    &self.parttt_seq_cutovers,
                ),
                c(
                    names::PARTTT_PAR_PIVOTS,
                    "Pivot selections computed in parallel (ParPivot, above par_pivot_min).",
                    false,
                    &self.parttt_par_pivots,
                ),
                c(
                    names::BITKERNEL_HANDOFFS,
                    "Subproblems handed off to the dense bit-parallel kernel.",
                    false,
                    &self.bitkernel_handoffs,
                ),
                c(
                    names::DYNAMIC_BATCHES,
                    "Edge batches applied by IMCE/ParIMCE.",
                    false,
                    &self.dynamic_batches,
                ),
                c(
                    names::DYNAMIC_NEW_CLIQUES,
                    "Cliques added to the maintained set by dynamic batches.",
                    false,
                    &self.dynamic_new_cliques,
                ),
                c(
                    names::DYNAMIC_SUBSUMED,
                    "Cliques retired (subsumed or invalidated) by dynamic batches.",
                    false,
                    &self.dynamic_subsumed_cliques,
                ),
                c(
                    names::SERVICE_PUBLISHES,
                    "Snapshot publishes by the clique service (one per applied batch).",
                    false,
                    &self.service_publishes,
                ),
                c(
                    names::SERVICE_QUERIES,
                    "Queries answered by serve-replay readers.",
                    false,
                    &self.service_queries,
                ),
                c(
                    names::SERVICE_EPOCH_LAG_SUM,
                    "Sum of reader epoch-lag samples (published epoch minus reader epoch).",
                    false,
                    &self.service_epoch_lag_sum,
                ),
                c(
                    names::SERVICE_EPOCH_LAG_SAMPLES,
                    "Number of reader epoch-lag samples.",
                    false,
                    &self.service_epoch_lag_samples,
                ),
                c(
                    names::SERVICE_PUBLISH_FAILURES,
                    "Snapshot publishes skipped after exhausting freeze retries.",
                    false,
                    &self.service_publish_failures,
                ),
                c(
                    names::INGEST_EDGES_PARSED,
                    "Edges accepted by edge-list parsing (self-loops excluded).",
                    false,
                    &self.ingest_edges_parsed,
                ),
                c(
                    names::INGEST_SELF_LOOPS,
                    "Self-loop edges skipped by edge-list parsing.",
                    false,
                    &self.ingest_self_loops,
                ),
            ],
            gauges: vec![
                g(
                    names::POOL_QUEUE_DEPTH,
                    "Jobs currently queued (deques + injector) across live pools.",
                    &self.pool_queue_depth,
                ),
                g(
                    names::SERVICE_PUBLISHED_EPOCH,
                    "Latest epoch published by the clique service.",
                    &self.service_published_epoch,
                ),
                g(
                    names::SERVICE_EPOCH_LAG_MAX,
                    "Largest reader epoch lag observed.",
                    &self.service_epoch_lag_max,
                ),
            ],
            histograms: vec![
                snapshot::histogram_sample(
                    names::DYNAMIC_BATCH_NS,
                    "Wall time per dynamic batch (apply + maintain), nanoseconds.",
                    self.dynamic_batch_ns.sweep(),
                ),
                snapshot::histogram_sample(
                    names::DYNAMIC_NEW_TASK_NS,
                    "Per-task time in the new-clique phase of a dynamic batch, nanoseconds.",
                    self.dynamic_new_task_ns.sweep(),
                ),
                snapshot::histogram_sample(
                    names::DYNAMIC_SUB_TASK_NS,
                    "Per-task time in the subsumed-clique phase of a dynamic batch, nanoseconds.",
                    self.dynamic_sub_task_ns.sweep(),
                ),
                snapshot::histogram_sample(
                    names::INGEST_PARSE_NS,
                    "Wall time per edge-list parse, nanoseconds.",
                    self.ingest_parse_ns.sweep(),
                ),
                snapshot::histogram_sample(
                    names::INGEST_CSR_BUILD_NS,
                    "Wall time per CSR construction, nanoseconds.",
                    self.ingest_csr_build_ns.sweep(),
                ),
                snapshot::histogram_sample(
                    names::INGEST_RANK_NS,
                    "Wall time per vertex-ranking computation, nanoseconds.",
                    self.ingest_rank_ns.sweep(),
                ),
            ],
        }
    }
}

/// The process-wide registry (created on first touch).
pub fn global() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Sweep the global registry — shorthand for `global().snapshot()`.
pub fn snapshot() -> TelemetrySnapshot {
    global().snapshot()
}

/// Render a snapshot for a `--metrics-out` path: JSON when the path ends
/// in `.json`, Prometheus text exposition otherwise.
pub fn render_for_path(snap: &TelemetrySnapshot, path: &str) -> String {
    if path.ends_with(".json") {
        snap.to_json().to_string_pretty()
    } else {
        snap.to_prometheus()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_is_a_singleton() {
        let a = global() as *const Registry;
        let b = global() as *const Registry;
        assert_eq!(a, b);
    }

    #[test]
    fn snapshot_contains_every_named_metric() {
        let s = snapshot();
        for name in [
            names::POOL_JOBS_SPAWNED,
            names::POOL_JOBS_DEQUEUED,
            names::POOL_WAKEUPS,
            names::POOL_WORKER_BUSY_NS,
            names::POOL_SPAWN_FAILURES,
            names::POOL_JOBS_PANICKED,
            names::CLIQUES_EMITTED,
            names::PARTTT_TASKS_SPAWNED,
            names::PARTTT_SEQ_CUTOVERS,
            names::PARTTT_PAR_PIVOTS,
            names::BITKERNEL_HANDOFFS,
            names::DYNAMIC_BATCHES,
            names::DYNAMIC_NEW_CLIQUES,
            names::DYNAMIC_SUBSUMED,
            names::SERVICE_PUBLISHES,
            names::SERVICE_QUERIES,
            names::SERVICE_EPOCH_LAG_SUM,
            names::SERVICE_EPOCH_LAG_SAMPLES,
            names::SERVICE_PUBLISH_FAILURES,
            names::INGEST_EDGES_PARSED,
            names::INGEST_SELF_LOOPS,
        ] {
            assert!(s.counter(name).is_some(), "missing counter {name}");
        }
        for name in [
            names::POOL_QUEUE_DEPTH,
            names::SERVICE_PUBLISHED_EPOCH,
            names::SERVICE_EPOCH_LAG_MAX,
        ] {
            assert!(s.gauge(name).is_some(), "missing gauge {name}");
        }
        for name in [
            names::DYNAMIC_BATCH_NS,
            names::DYNAMIC_NEW_TASK_NS,
            names::DYNAMIC_SUB_TASK_NS,
            names::INGEST_PARSE_NS,
            names::INGEST_CSR_BUILD_NS,
            names::INGEST_RANK_NS,
        ] {
            assert!(s.histogram(name).is_some(), "missing histogram {name}");
        }
    }

    #[test]
    fn exports_render_without_panicking() {
        let s = snapshot();
        let prom = s.to_prometheus();
        assert!(prom.contains("# TYPE parmce_pool_jobs_spawned_total counter"));
        let json = render_for_path(&s, "metrics.json");
        assert!(crate::util::json::parse(&json).is_ok());
        let prom2 = render_for_path(&s, "metrics.prom");
        assert_eq!(prom, prom2);
    }

    #[cfg(not(feature = "telemetry-off"))]
    #[test]
    fn delta_isolates_a_window() {
        let before = snapshot();
        global().cliques_emitted.add(5);
        let after = snapshot();
        let d = after.delta(&before);
        // another test may add concurrently — the delta is at least ours
        assert!(d.counter(names::CLIQUES_EMITTED).unwrap() >= 5);
    }
}
