//! Metric primitives: sharded counters, gauges, bucketed histograms.
//!
//! The hot-path contract mirrors [`crate::mce::sink::sharded`]: each pool
//! worker owns a cache-padded shard (routed by
//! [`crate::coordinator::pool::current_worker_slot`]), increments are
//! `Relaxed` `fetch_add`s on a private cache line, and a snapshot *sweeps*
//! all shards with `Acquire` loads.  The sweep is a racy lower bound while
//! workers are still running; it is exact once the enumeration scope has
//! joined, because the pool's `WaitGroup` (`done` → `Release`, `wait` →
//! `Acquire`) orders every shard write before the sweeping thread's loads.
//! The loom model `telemetry_counter_sweep_exact_after_join` in
//! `rust/tests/loom_models.rs` pins exactly this protocol.
//!
//! Under the `telemetry-off` cargo feature every type here is a zero-sized
//! no-op with the identical API, so instrumentation call sites compile to
//! nothing — no shard arrays exist, no atomics are touched, and
//! [`SpanTimer`] never reads the clock.

#[cfg(not(feature = "telemetry-off"))]
use crate::mce::sink::CachePadded;
#[cfg(not(feature = "telemetry-off"))]
use crate::util::sync::atomic::{AtomicU64, Ordering};

/// Worker shards per metric.  Fixed (the global registry outlives any one
/// pool), sized to cover every realistic pool width; workers with a slot
/// at or beyond this route to the shared *external* shard — a routing
/// hint, never a correctness assumption, exactly like the sharded sinks.
pub const WORKER_SHARDS: usize = 32;

/// Total shards: one per worker slot plus the external shard that
/// non-pool threads (and out-of-range slots) fall back to.
pub const TOTAL_SHARDS: usize = WORKER_SHARDS + 1;

#[cfg(not(feature = "telemetry-off"))]
#[inline]
fn shard_index(n_shards: usize) -> usize {
    let external = n_shards - 1;
    match crate::coordinator::pool::current_worker_slot() {
        Some(i) if i < external => i,
        _ => external,
    }
}

// --- counter ---------------------------------------------------------------

/// Monotone counter, sharded per worker.  `add` is one `Relaxed`
/// `fetch_add` on the caller's own cache line.
pub struct Counter {
    #[cfg(not(feature = "telemetry-off"))]
    shards: Box<[CachePadded<AtomicU64>]>,
}

impl Counter {
    /// Registry-sized counter ([`TOTAL_SHARDS`] shards).
    pub fn new() -> Self {
        Self::with_shards(TOTAL_SHARDS)
    }

    /// Explicit shard count (tests and the loom sweep model). Must be ≥ 1;
    /// the last shard is the external fallback.
    pub fn with_shards(n: usize) -> Self {
        #[cfg(feature = "telemetry-off")]
        {
            let _ = n;
            Counter {}
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            assert!(n >= 1, "a sharded counter needs at least one shard");
            Counter {
                shards: (0..n).map(|_| CachePadded(AtomicU64::new(0))).collect(),
            }
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = n;
        #[cfg(not(feature = "telemetry-off"))]
        self.shards[shard_index(self.shards.len())]
            .0
            .fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Sweep all shards (`Acquire` loads) and return the total.  Exact
    /// after a happens-before point (scope join, run end); a monotone
    /// lower bound while writers are live.
    pub fn value(&self) -> u64 {
        self.per_shard().iter().sum()
    }

    /// Per-shard sweep — index `i < WORKER_SHARDS` is worker `i`'s shard,
    /// the last entry is the external shard.  Empty under `telemetry-off`.
    pub fn per_shard(&self) -> Vec<u64> {
        #[cfg(feature = "telemetry-off")]
        {
            Vec::new()
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            self.shards
                .iter()
                .map(|s| s.0.load(Ordering::Acquire))
                .collect()
        }
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

// --- gauge -----------------------------------------------------------------

/// Instantaneous value (queue depth, current epoch, max lag).  A single
/// atomic — gauges are read as often as written, so sharding would only
/// move the cost to the sweep.
pub struct Gauge {
    #[cfg(not(feature = "telemetry-off"))]
    value: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge {
            #[cfg(not(feature = "telemetry-off"))]
            value: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = n;
        #[cfg(not(feature = "telemetry-off"))]
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = n;
        #[cfg(not(feature = "telemetry-off"))]
        self.value.fetch_sub(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn set(&self, n: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = n;
        #[cfg(not(feature = "telemetry-off"))]
        self.value.store(n, Ordering::Relaxed);
    }

    /// Raise the gauge to `n` if `n` is larger (high-water marks).
    #[inline]
    pub fn set_max(&self, n: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = n;
        #[cfg(not(feature = "telemetry-off"))]
        self.value.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        #[cfg(feature = "telemetry-off")]
        {
            0
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            self.value.load(Ordering::Acquire)
        }
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

// --- histogram -------------------------------------------------------------

/// Power-of-two histogram buckets: bucket `i` counts values whose bit
/// length is `i` (bucket 0 holds zero), i.e. upper bound `2^i - 1`;
/// the last bucket absorbs everything larger (`+Inf`).
pub const HIST_BUCKETS: usize = 40;

/// Bucket index for a recorded value.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (`None` = `+Inf`).
pub fn bucket_bound(i: usize) -> Option<u64> {
    if i + 1 >= HIST_BUCKETS {
        None
    } else {
        Some((1u64 << i) - 1)
    }
}

#[cfg(not(feature = "telemetry-off"))]
struct HistShard {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

#[cfg(not(feature = "telemetry-off"))]
impl Default for HistShard {
    fn default() -> Self {
        HistShard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }
}

/// Distribution metric (batch latencies, task durations), sharded like
/// [`Counter`]: `record` is two `Relaxed` adds on the caller's own shard.
pub struct Histogram {
    #[cfg(not(feature = "telemetry-off"))]
    shards: Box<[CachePadded<HistShard>]>,
}

impl Histogram {
    pub fn new() -> Self {
        #[cfg(feature = "telemetry-off")]
        {
            Histogram {}
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            Histogram {
                shards: (0..TOTAL_SHARDS)
                    .map(|_| CachePadded(HistShard::default()))
                    .collect(),
            }
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        #[cfg(feature = "telemetry-off")]
        let _ = v;
        #[cfg(not(feature = "telemetry-off"))]
        {
            let shard = &self.shards[shard_index(self.shards.len())].0;
            shard.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
            shard.sum.fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Sweep: per-bucket counts (length [`HIST_BUCKETS`]) and the value
    /// sum, merged across shards with `Acquire` loads.
    pub fn sweep(&self) -> (Vec<u64>, u64) {
        #[cfg(feature = "telemetry-off")]
        {
            (vec![0; HIST_BUCKETS], 0)
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            let mut buckets = vec![0u64; HIST_BUCKETS];
            let mut sum = 0u64;
            for shard in self.shards.iter() {
                for (acc, b) in buckets.iter_mut().zip(shard.0.buckets.iter()) {
                    *acc += b.load(Ordering::Acquire);
                }
                // value sums wrap like the atomics they mirror
                sum = sum.wrapping_add(shard.0.sum.load(Ordering::Acquire));
            }
            (buckets, sum)
        }
    }

    pub fn count(&self) -> u64 {
        self.sweep().0.iter().sum()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

// --- span timer ------------------------------------------------------------

/// Lightweight span timer for busy-time attribution: start, do work, add
/// `elapsed_ns` to a counter.  Compiles to nothing (never reads the
/// clock) under `telemetry-off`.
pub struct SpanTimer {
    #[cfg(not(feature = "telemetry-off"))]
    start: std::time::Instant,
}

impl SpanTimer {
    #[inline]
    pub fn start() -> Self {
        SpanTimer {
            #[cfg(not(feature = "telemetry-off"))]
            start: std::time::Instant::now(),
        }
    }

    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        #[cfg(feature = "telemetry-off")]
        {
            0
        }
        #[cfg(not(feature = "telemetry-off"))]
        {
            self.start.elapsed().as_nanos() as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "telemetry-off"))]
    mod enabled {
        use super::*;
        use crate::coordinator::pool::ThreadPool;
        use crate::util::sync::Arc;

        #[test]
        #[cfg_attr(miri, ignore)] // spawns a real pool; loom owns this protocol
        fn counter_totals_are_exact_after_join() {
            let pool = ThreadPool::new(4);
            let c = Arc::new(Counter::new());
            pool.scope(|s| {
                for _ in 0..100 {
                    let c = Arc::clone(&c);
                    s.spawn(move |_| c.add(3));
                }
            });
            assert_eq!(c.value(), 300);
            assert_eq!(c.per_shard().iter().sum::<u64>(), 300);
        }

        #[test]
        fn external_threads_use_the_last_shard() {
            let c = Counter::new();
            c.add(7);
            let shards = c.per_shard();
            assert_eq!(shards[TOTAL_SHARDS - 1], 7);
            assert!(shards[..TOTAL_SHARDS - 1].iter().all(|&v| v == 0));
        }

        #[test]
        fn gauge_add_sub_set_max() {
            let g = Gauge::new();
            g.add(5);
            g.sub(2);
            assert_eq!(g.get(), 3);
            g.set(10);
            g.set_max(7);
            assert_eq!(g.get(), 10);
            g.set_max(12);
            assert_eq!(g.get(), 12);
        }

        #[test]
        fn histogram_buckets_and_sum() {
            let h = Histogram::new();
            for v in [0u64, 1, 2, 3, 4, 1000, u64::MAX] {
                h.record(v);
            }
            let (buckets, sum) = h.sweep();
            assert_eq!(buckets.iter().sum::<u64>(), 7);
            assert_eq!(sum, 0u64.wrapping_add(1 + 2 + 3 + 4 + 1000).wrapping_add(u64::MAX));
            assert_eq!(buckets[0], 1, "zero lands in bucket 0");
            assert_eq!(buckets[1], 1, "one lands in bucket 1");
            assert_eq!(buckets[HIST_BUCKETS - 1], 1, "u64::MAX lands in +Inf");
            assert_eq!(h.count(), 7);
        }

        #[test]
        fn bucket_bounds_cover_indices() {
            assert_eq!(bucket_bound(0), Some(0));
            assert_eq!(bucket_bound(1), Some(1));
            assert_eq!(bucket_bound(2), Some(3));
            assert_eq!(bucket_bound(HIST_BUCKETS - 1), None);
            // every value's bucket bound (when finite) is >= the value
            for v in [0u64, 1, 5, 1 << 20, (1 << 38) + 1] {
                let i = bucket_index(v);
                if let Some(b) = bucket_bound(i) {
                    assert!(b >= v, "v={v} bucket {i} bound {b}");
                }
            }
        }

        #[test]
        fn span_timer_measures_nonzero() {
            let t = SpanTimer::start();
            std::thread::sleep(std::time::Duration::from_millis(1));
            assert!(t.elapsed_ns() > 0);
        }
    }

    #[cfg(feature = "telemetry-off")]
    mod disabled {
        use super::*;

        #[test]
        fn metric_types_are_zero_sized_noops() {
            // true zero cost: no shard arrays exist, nothing to touch
            assert_eq!(std::mem::size_of::<Counter>(), 0);
            assert_eq!(std::mem::size_of::<Gauge>(), 0);
            assert_eq!(std::mem::size_of::<Histogram>(), 0);
            assert_eq!(std::mem::size_of::<SpanTimer>(), 0);
            let c = Counter::new();
            c.add(5);
            assert_eq!(c.value(), 0);
            assert!(c.per_shard().is_empty());
            let g = Gauge::new();
            g.add(3);
            g.set_max(9);
            assert_eq!(g.get(), 0);
            let h = Histogram::new();
            h.record(42);
            assert_eq!(h.count(), 0);
            assert_eq!(SpanTimer::start().elapsed_ns(), 0);
        }
    }
}
