//! Point-in-time metric snapshots and the two export encodings.
//!
//! A [`TelemetrySnapshot`] is plain owned data — taking one sweeps every
//! registered metric ([`super::Registry::snapshot`]) and detaches from the
//! live shards, so snapshots can be embedded in reports, diffed
//! ([`TelemetrySnapshot::delta`]) and serialized long after the run.
//!
//! Exports:
//! * [`to_prometheus`](TelemetrySnapshot::to_prometheus) — text exposition
//!   format (`# HELP` / `# TYPE` + samples; histograms as cumulative
//!   `_bucket{le=...}` series with `_sum`/`_count`), validated in CI by
//!   `cargo xtask check-prom`;
//! * [`to_json`](TelemetrySnapshot::to_json) — a [`crate::util::json`]
//!   dump with per-shard counter breakdowns.

use std::fmt::Write as _;

use crate::util::json::Json;

use super::metrics::{bucket_bound, HIST_BUCKETS};

/// One swept counter. `shards[i]` is worker `i`'s shard (last entry =
/// external threads); empty under `telemetry-off`.
#[derive(Clone, Debug)]
pub struct CounterSample {
    pub name: &'static str,
    pub help: &'static str,
    /// Export one labeled series per worker shard (busy-ns attribution)
    /// instead of a single total.
    pub per_worker: bool,
    pub total: u64,
    pub shards: Vec<u64>,
}

/// One swept gauge.
#[derive(Clone, Debug)]
pub struct GaugeSample {
    pub name: &'static str,
    pub help: &'static str,
    pub value: u64,
}

/// One swept histogram: per-bucket (non-cumulative) counts, value sum.
#[derive(Clone, Debug)]
pub struct HistogramSample {
    pub name: &'static str,
    pub help: &'static str,
    pub buckets: Vec<u64>,
    pub sum: u64,
}

impl HistogramSample {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }
}

/// A full sweep of the registry at one instant.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    pub counters: Vec<CounterSample>,
    pub gauges: Vec<GaugeSample>,
    pub histograms: Vec<HistogramSample>,
}

impl TelemetrySnapshot {
    /// Total of the named counter, if present.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|c| c.name == name).map(|c| c.total)
    }

    /// Value of the named gauge, if present.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|g| g.name == name).map(|g| g.value)
    }

    /// The named histogram sample, if present.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSample> {
        self.histograms.iter().find(|h| h.name == name)
    }

    /// What happened between `earlier` and `self`: counters and histogram
    /// buckets subtract (saturating — the registry is global, so an
    /// unrelated concurrent run can only make deltas larger, never
    /// negative); gauges keep the later instantaneous value.  This is how
    /// a per-run view is carved out of process-wide cumulative metrics.
    pub fn delta(&self, earlier: &TelemetrySnapshot) -> TelemetrySnapshot {
        let counters = self
            .counters
            .iter()
            .map(|c| {
                let prev = earlier.counters.iter().find(|p| p.name == c.name);
                let shards = match prev {
                    Some(p) if p.shards.len() == c.shards.len() => c
                        .shards
                        .iter()
                        .zip(p.shards.iter())
                        .map(|(now, was)| now.saturating_sub(*was))
                        .collect(),
                    _ => c.shards.clone(),
                };
                CounterSample {
                    total: c.total.saturating_sub(prev.map_or(0, |p| p.total)),
                    shards,
                    ..c.clone()
                }
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|h| {
                let prev = earlier.histograms.iter().find(|p| p.name == h.name);
                let buckets = match prev {
                    Some(p) if p.buckets.len() == h.buckets.len() => h
                        .buckets
                        .iter()
                        .zip(p.buckets.iter())
                        .map(|(now, was)| now.saturating_sub(*was))
                        .collect(),
                    _ => h.buckets.clone(),
                };
                HistogramSample {
                    buckets,
                    sum: h.sum.wrapping_sub(prev.map_or(0, |p| p.sum)),
                    ..h.clone()
                }
            })
            .collect();
        TelemetrySnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// Prometheus text exposition (version 0.0.4).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for c in &self.counters {
            let _ = writeln!(out, "# HELP {} {}", c.name, c.help);
            let _ = writeln!(out, "# TYPE {} counter", c.name);
            if c.per_worker && !c.shards.is_empty() {
                let external = c.shards.len() - 1;
                for (i, &v) in c.shards.iter().enumerate() {
                    if v == 0 {
                        continue; // idle worker slots would drown the dump
                    }
                    if i == external {
                        let _ = writeln!(out, "{}{{worker=\"external\"}} {v}", c.name);
                    } else {
                        let _ = writeln!(out, "{}{{worker=\"{i}\"}} {v}", c.name);
                    }
                }
            } else {
                let _ = writeln!(out, "{} {}", c.name, c.total);
            }
        }
        for g in &self.gauges {
            let _ = writeln!(out, "# HELP {} {}", g.name, g.help);
            let _ = writeln!(out, "# TYPE {} gauge", g.name);
            let _ = writeln!(out, "{} {}", g.name, g.value);
        }
        for h in &self.histograms {
            let _ = writeln!(out, "# HELP {} {}", h.name, h.help);
            let _ = writeln!(out, "# TYPE {} histogram", h.name);
            let mut cum = 0u64;
            for (i, &b) in h.buckets.iter().enumerate() {
                cum += b;
                match bucket_bound(i) {
                    // skip interior zero-count buckets: cumulative series
                    // stay correct, the dump stays readable
                    Some(le) if b > 0 => {
                        let _ = writeln!(out, "{}_bucket{{le=\"{le}\"}} {cum}", h.name);
                    }
                    _ => {}
                }
            }
            let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {cum}", h.name);
            let _ = writeln!(out, "{}_sum {}", h.name, h.sum);
            let _ = writeln!(out, "{}_count {cum}", h.name);
        }
        out
    }

    /// JSON dump (counter shard breakdowns included).  Values above 2^53
    /// lose precision — [`crate::util::json`] numbers are `f64`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            (
                "counters",
                Json::arr(self.counters.iter().map(|c| {
                    Json::obj([
                        ("name", Json::str(c.name)),
                        ("total", Json::num(c.total as f64)),
                        (
                            "shards",
                            Json::arr(c.shards.iter().map(|&v| Json::num(v as f64))),
                        ),
                    ])
                })),
            ),
            (
                "gauges",
                Json::arr(self.gauges.iter().map(|g| {
                    Json::obj([
                        ("name", Json::str(g.name)),
                        ("value", Json::num(g.value as f64)),
                    ])
                })),
            ),
            (
                "histograms",
                Json::arr(self.histograms.iter().map(|h| {
                    Json::obj([
                        ("name", Json::str(h.name)),
                        ("count", Json::num(h.count() as f64)),
                        ("sum", Json::num(h.sum as f64)),
                        (
                            "buckets",
                            Json::arr(h.buckets.iter().map(|&v| Json::num(v as f64))),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Build a histogram sample from a sweep (shared by the registry).
pub(super) fn histogram_sample(
    name: &'static str,
    help: &'static str,
    sweep: (Vec<u64>, u64),
) -> HistogramSample {
    debug_assert!(sweep.0.len() == HIST_BUCKETS || sweep.0.is_empty());
    HistogramSample {
        name,
        help,
        buckets: sweep.0,
        sum: sweep.1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TelemetrySnapshot {
        TelemetrySnapshot {
            counters: vec![
                CounterSample {
                    name: "parmce_test_total",
                    help: "a counter",
                    per_worker: false,
                    total: 10,
                    shards: vec![4, 6],
                },
                CounterSample {
                    name: "parmce_test_busy_ns_total",
                    help: "per-worker",
                    per_worker: true,
                    total: 9,
                    shards: vec![9, 0],
                },
            ],
            gauges: vec![GaugeSample {
                name: "parmce_test_depth",
                help: "a gauge",
                value: 3,
            }],
            histograms: vec![{
                let mut buckets = vec![0u64; HIST_BUCKETS];
                buckets[1] = 2;
                buckets[3] = 1;
                HistogramSample {
                    name: "parmce_test_ns",
                    help: "a histogram",
                    buckets,
                    sum: 9,
                }
            }],
        }
    }

    #[test]
    fn lookup_helpers() {
        let s = sample();
        assert_eq!(s.counter("parmce_test_total"), Some(10));
        assert_eq!(s.counter("missing"), None);
        assert_eq!(s.gauge("parmce_test_depth"), Some(3));
        assert_eq!(s.histogram("parmce_test_ns").unwrap().count(), 3);
    }

    #[test]
    fn delta_subtracts_counters_keeps_gauges() {
        let earlier = sample();
        let mut later = sample();
        later.counters[0].total = 25;
        later.counters[0].shards = vec![10, 15];
        later.gauges[0].value = 1;
        later.histograms[0].buckets[1] = 5;
        later.histograms[0].sum = 21;
        let d = later.delta(&earlier);
        assert_eq!(d.counter("parmce_test_total"), Some(15));
        assert_eq!(d.counters[0].shards, vec![6, 9]);
        assert_eq!(d.gauge("parmce_test_depth"), Some(1), "gauge keeps later value");
        assert_eq!(d.histogram("parmce_test_ns").unwrap().buckets[1], 3);
        assert_eq!(d.histogram("parmce_test_ns").unwrap().sum, 12);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = sample().to_prometheus();
        assert!(text.contains("# TYPE parmce_test_total counter"));
        assert!(text.contains("parmce_test_total 10"));
        // per-worker counter: labeled series, zero shards skipped
        assert!(text.contains("parmce_test_busy_ns_total{worker=\"0\"} 9"));
        assert!(!text.contains("worker=\"external\"} 0"));
        assert!(text.contains("# TYPE parmce_test_depth gauge"));
        // histogram: cumulative buckets + sum/count
        assert!(text.contains("parmce_test_ns_bucket{le=\"1\"} 2"));
        assert!(text.contains("parmce_test_ns_bucket{le=\"7\"} 3"));
        assert!(text.contains("parmce_test_ns_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("parmce_test_ns_sum 9"));
        assert!(text.contains("parmce_test_ns_count 3"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let j = sample().to_json();
        let back = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back, j);
        let counters = back.get("counters").unwrap().as_arr().unwrap();
        assert_eq!(counters.len(), 2);
        assert_eq!(counters[0].get("total").unwrap().as_f64(), Some(10.0));
    }
}
