//! Per-subproblem skew capture for *parallel* runs (paper Fig. 2).
//!
//! `parmce::subproblems_timed` measures per-vertex subproblem cost
//! sequentially; this module lets ParMCE attribute the same quantities —
//! cliques and nanoseconds per root vertex — while the real parallel
//! schedule runs.  Each root gets one [`SubCell`]: every ParTTT task
//! working under that root adds its own execution time (children time
//! themselves, so the sum is total CPU work for the root, not wall
//! clock), and a [`SubCellSink`] wrapper counts the root's emitted
//! cliques on the way into the real sink.
//!
//! Increments are `Relaxed`: cells are only read after the enumeration
//! scope joins, which orders every task's adds before the read (the same
//! sweep argument as [`super::metrics`]).  Not gated by `telemetry-off`:
//! capture is explicit opt-in (`MceSession::subproblems_parallel`), and
//! the un-instrumented path pays one `Option` branch per spawned task.

use crate::coordinator::stats::Subproblem;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Arc;

/// Accumulator for one root vertex's subproblem.
pub struct SubCell {
    vertex: Vertex,
    cliques: AtomicU64,
    ns: AtomicU64,
}

impl SubCell {
    pub fn new(vertex: Vertex) -> Self {
        SubCell {
            vertex,
            cliques: AtomicU64::new(0),
            ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn add_cliques(&self, n: u64) {
        self.cliques.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_ns(&self, n: u64) {
        self.ns.fetch_add(n, Ordering::Relaxed);
    }

    /// Read out the record. Exact once the enumeration scope has joined.
    pub fn to_subproblem(&self) -> Subproblem {
        Subproblem {
            vertex: self.vertex,
            cliques: self.cliques.load(Ordering::Acquire),
            ns: self.ns.load(Ordering::Acquire),
        }
    }
}

/// Sink wrapper that attributes every emitted clique to a root's
/// [`SubCell`] before forwarding to the real sink.  Created once per root
/// and cloned (as `Arc<dyn CliqueSink>`) into the root's whole task tree.
pub struct SubCellSink {
    inner: Arc<dyn CliqueSink>,
    cell: Arc<SubCell>,
}

impl SubCellSink {
    pub fn new(inner: Arc<dyn CliqueSink>, cell: Arc<SubCell>) -> Self {
        SubCellSink { inner, cell }
    }
}

impl CliqueSink for SubCellSink {
    #[inline]
    fn emit(&self, clique: &[Vertex]) {
        self.cell.add_cliques(1);
        self.inner.emit(clique);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mce::sink::CountSink;

    #[test]
    fn cell_accumulates_and_reads_back() {
        let cell = SubCell::new(7);
        cell.add_cliques(2);
        cell.add_cliques(1);
        cell.add_ns(500);
        let s = cell.to_subproblem();
        assert_eq!(s.vertex, 7);
        assert_eq!(s.cliques, 3);
        assert_eq!(s.ns, 500);
    }

    #[test]
    fn sink_counts_and_forwards() {
        let inner = Arc::new(CountSink::new());
        let cell = Arc::new(SubCell::new(0));
        let sink = SubCellSink::new(inner.clone(), cell.clone());
        sink.emit(&[0, 1, 2]);
        sink.emit(&[0, 3]);
        assert_eq!(inner.count(), 2, "cliques still reach the real sink");
        assert_eq!(cell.to_subproblem().cliques, 2);
    }
}
