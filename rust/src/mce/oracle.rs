//! Independent correctness oracle: plain Bron–Kerbosch (no pivot) plus an
//! explicit maximality validator.  Deliberately shares no code with the
//! TTT family so a bug cannot cancel itself out in tests.

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;

/// All maximal cliques, canonical form (each sorted; set sorted).
/// Exponential — use on small graphs only (tests).
pub fn maximal_cliques(g: &CsrGraph) -> Vec<Vec<Vertex>> {
    let mut out = Vec::new();
    let mut r: Vec<Vertex> = Vec::new();
    let p: Vec<Vertex> = (0..g.n() as Vertex).collect();
    bk(g, &mut r, p, Vec::new(), &mut out);
    for c in out.iter_mut() {
        c.sort_unstable();
    }
    out.sort();
    out
}

fn bk(g: &CsrGraph, r: &mut Vec<Vertex>, p: Vec<Vertex>, x: Vec<Vertex>, out: &mut Vec<Vec<Vertex>>) {
    if p.is_empty() && x.is_empty() {
        if !r.is_empty() {
            out.push(r.clone());
        }
        return;
    }
    let mut p_rest = p.clone();
    let mut x_rest = x;
    for v in p {
        let nbrs = g.neighbors(v);
        let p2: Vec<Vertex> = p_rest
            .iter()
            .copied()
            .filter(|u| nbrs.binary_search(u).is_ok())
            .collect();
        let x2: Vec<Vertex> = x_rest
            .iter()
            .copied()
            .filter(|u| nbrs.binary_search(u).is_ok())
            .collect();
        r.push(v);
        bk(g, r, p2, x2, out);
        r.pop();
        p_rest.retain(|&u| u != v);
        x_rest.push(v);
    }
}

/// Validate that `cliques` is exactly the set of maximal cliques of `g`:
/// each is a maximal clique, no duplicates, and none is missing (checked
/// against the oracle). Returns an error description on failure.
pub fn validate(g: &CsrGraph, cliques: &[Vec<Vertex>]) -> Result<(), String> {
    let mut seen = std::collections::HashSet::new();
    for c in cliques {
        let mut s = c.clone();
        s.sort_unstable();
        if !g.is_clique(&s) {
            return Err(format!("{s:?} is not a clique"));
        }
        if !g.is_maximal_clique(&s) {
            return Err(format!("{s:?} is not maximal"));
        }
        if !seen.insert(s.clone()) {
            return Err(format!("{s:?} emitted twice"));
        }
    }
    let want = maximal_cliques(g);
    if seen.len() != want.len() {
        return Err(format!(
            "count mismatch: got {} unique cliques, oracle has {}",
            seen.len(),
            want.len()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn oracle_on_triangle_tail() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(maximal_cliques(&g), vec![vec![0, 1, 2], vec![2, 3]]);
    }

    #[test]
    fn oracle_moon_moser() {
        let g = generators::moon_moser(3);
        assert_eq!(maximal_cliques(&g).len(), 27);
    }

    #[test]
    fn validate_catches_problems() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let good = vec![vec![0, 1, 2], vec![2, 3]];
        assert!(validate(&g, &good).is_ok());
        // non-maximal
        assert!(validate(&g, &[vec![0, 1], vec![2, 3]]).is_err());
        // duplicate
        assert!(validate(&g, &[vec![0, 1, 2], vec![0, 1, 2], vec![2, 3]]).is_err());
        // missing
        assert!(validate(&g, &[vec![0, 1, 2]]).is_err());
        // not a clique
        assert!(validate(&g, &[vec![0, 3], vec![0, 1, 2]]).is_err());
    }
}
