//! Static maximal clique enumeration: the sequential TTT baseline
//! (Tomita–Tanaka–Takahashi) and the paper's parallel algorithms
//! ParTTT (Alg. 3) and ParMCE (Alg. 4).

pub mod bitkernel;
pub mod oracle;
pub mod parmce;
pub mod parttt;
pub mod pivot;
pub mod ranking;
pub mod sink;
pub mod ttt;

pub use bitkernel::DEFAULT_BITSET_CUTOFF;
pub use parmce::{parmce, ParMceConfig};
pub use parttt::{parttt, ParTttConfig};
pub use ranking::{RankStrategy, Ranking};
pub use sink::{CliqueSink, CollectSink, CountSink};
pub use ttt::ttt;
