//! Sequential TTT (Tomita–Tanaka–Takahashi 2006) — paper Algorithm 1.
//!
//! The work-efficiency baseline every parallel algorithm is measured
//! against (Tables 4/5, Figures 6/7).  Worst-case O(3^{n/3}), optimal.
//!
//! Besides the plain enumerator this module provides:
//! * [`ttt_from`] — enumeration from an arbitrary (K, cand, fini) state,
//!   the subroutine ParMCE runs inside each per-vertex subproblem;
//! * [`ttt_traced`] — records a task tree (one node per recursive call,
//!   exclusive durations) for the trace-replay scheduler simulator;
//! * [`TttMetrics`] — pivot / set-update cost attribution (§6.3.1 quotes
//!   these overheads for DBLP: 248s pivot, 38s updates in ParTTT).

use std::time::Instant;

use crate::coordinator::sim::Trace;
use crate::graph::csr::CsrGraph;
use crate::graph::{AdjacencyGraph, Vertex};
use crate::mce::bitkernel::{self, DEFAULT_BITSET_CUTOFF};
use crate::mce::pivot::choose_pivot;
use crate::mce::sink::CliqueSink;
use crate::util::vset;

/// Cost attribution counters (nanoseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct TttMetrics {
    pub calls: u64,
    pub pivot_ns: u64,
    pub update_ns: u64,
    pub emitted: u64,
}

/// Enumerate all maximal cliques of `g` into `sink`.
pub fn ttt(g: &CsrGraph, sink: &dyn CliqueSink) {
    ttt_with_cutoff(g, sink, DEFAULT_BITSET_CUTOFF)
}

/// As [`ttt`] with an explicit bitset hand-off threshold: subproblems
/// whose `|cand| + |fini|` is at or below `bitset_cutoff` run in the
/// dense bit-parallel kernel ([`crate::mce::bitkernel`]); 0 keeps the
/// whole recursion on the sorted-slice path.
pub fn ttt_with_cutoff(g: &CsrGraph, sink: &dyn CliqueSink, bitset_cutoff: usize) {
    if g.n() == 0 {
        return;
    }
    let cand: Vec<Vertex> = (0..g.n() as Vertex).collect();
    let mut k = Vec::new();
    ttt_from_with_cutoff(g, &mut k, cand, Vec::new(), sink, bitset_cutoff);
}

/// Enumerate all maximal cliques containing `k`, extendable by `cand`,
/// excluding any vertex of `fini` (paper Algorithm 1 semantics).
/// `cand`/`fini` must be sorted and disjoint; all their members adjacent
/// to every vertex of `k`.
///
/// Hot path: recursion buffers (ext / cand_q / fini_q) come from a free
/// pool, so steady-state enumeration performs no allocation (§Perf
/// optimization 1 — see EXPERIMENTS.md for the before/after), and
/// subproblems at or below [`DEFAULT_BITSET_CUTOFF`] finish in the dense
/// bit-parallel kernel (§Perf optimization 3).
pub fn ttt_from<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
) {
    ttt_from_with_cutoff(g, k, cand, fini, sink, DEFAULT_BITSET_CUTOFF)
}

/// As [`ttt_from`] with an explicit bitset hand-off threshold
/// (0 = slice-only recursion).
pub fn ttt_from_with_cutoff<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    mut cand: Vec<Vertex>,
    mut fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
    bitset_cutoff: usize,
) {
    let mut pool: Vec<Vec<Vertex>> = Vec::new();
    rec_pooled(g, k, &mut cand, &mut fini, sink, &mut pool, bitset_cutoff);
}

fn rec_pooled<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: &mut Vec<Vertex>,
    fini: &mut Vec<Vertex>,
    sink: &dyn CliqueSink,
    pool: &mut Vec<Vec<Vertex>>,
    bitset_cutoff: usize,
) {
    // dense hand-off: finish small working sets in bitset space
    if bitset_cutoff > 0 && cand.len() + fini.len() <= bitset_cutoff {
        // one relaxed add against an entire kernel invocation — the
        // hand-off count is the number the cutoff-sweep recipe in
        // EXPERIMENTS.md tunes against
        crate::telemetry::global().bitkernel_handoffs.inc();
        bitkernel::enumerate_subproblem(g, k, cand, fini, sink);
        return;
    }
    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(k);
        }
        return;
    }
    let pivot = choose_pivot(g, cand, fini);
    let mut ext = pool.pop().unwrap_or_default();
    vset::difference_into(cand, g.neighbors(pivot), &mut ext);
    let mut cand_q = pool.pop().unwrap_or_default();
    let mut fini_q = pool.pop().unwrap_or_default();
    for i in 0..ext.len() {
        let q = ext[i];
        let nbrs = g.neighbors(q);
        // intersect_into clears its output first, so buffer state left by
        // the child recursion is irrelevant
        vset::intersect_into(cand, nbrs, &mut cand_q);
        vset::intersect_into(fini, nbrs, &mut fini_q);
        k.push(q);
        rec_pooled(g, k, &mut cand_q, &mut fini_q, sink, pool, bitset_cutoff);
        k.pop();
        vset::remove_sorted(cand, q);
        vset::insert_sorted(fini, q);
    }
    ext.clear();
    cand_q.clear();
    fini_q.clear();
    pool.push(ext);
    pool.push(cand_q);
    pool.push(fini_q);
}

/// As [`ttt_from`] but collecting metrics.  Stays on the slice path for
/// the whole recursion — the bitset kernel would hide the per-node
/// pivot/update attribution this exists to measure.
pub fn ttt_from_metered<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
    metrics: &mut TttMetrics,
) {
    rec(g, k, cand, fini, sink, Some(metrics));
}

fn rec<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    mut cand: Vec<Vertex>,
    mut fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
    mut metrics: Option<&mut TttMetrics>,
) {
    if let Some(m) = metrics.as_deref_mut() {
        m.calls += 1;
    }
    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(k);
            if let Some(m) = metrics.as_deref_mut() {
                m.emitted += 1;
            }
        }
        return;
    }

    // Line 3: pivot maximizing |cand ∩ Γ(u)| over u ∈ cand ∪ fini.
    let t0 = metrics.is_some().then(Instant::now);
    let pivot = choose_pivot(g, &cand, &fini);
    if let (Some(m), Some(t)) = (metrics.as_deref_mut(), t0) {
        m.pivot_ns += t.elapsed().as_nanos() as u64;
    }

    // Line 4: ext = cand − Γ(pivot) (sorted, since cand is sorted).
    let ext = vset::difference(&cand, g.neighbors(pivot));

    // Lines 5–11.
    let mut cand_q = Vec::new();
    let mut fini_q = Vec::new();
    for q in ext {
        let nbrs = g.neighbors(q);
        let t1 = metrics.is_some().then(Instant::now);
        vset::intersect_into(&cand, nbrs, &mut cand_q);
        vset::intersect_into(&fini, nbrs, &mut fini_q);
        if let (Some(m), Some(t)) = (metrics.as_deref_mut(), t1) {
            m.update_ns += t.elapsed().as_nanos() as u64;
        }
        k.push(q);
        rec(
            g,
            k,
            std::mem::take(&mut cand_q),
            std::mem::take(&mut fini_q),
            sink,
            metrics.as_deref_mut(),
        );
        k.pop();
        let t2 = metrics.is_some().then(Instant::now);
        vset::remove_sorted(&mut cand, q);
        vset::insert_sorted(&mut fini, q);
        if let (Some(m), Some(t)) = (metrics.as_deref_mut(), t2) {
            m.update_ns += t.elapsed().as_nanos() as u64;
        }
    }
}

/// Traced enumeration: one [`Trace`] node per recursive call with its
/// *exclusive* time (pivot + set updates + emit, excluding children).
/// This is the input to `coordinator::sim` for Figures 6/7.  Slice-only
/// (the kernel would collapse whole subtrees into one trace node).
pub fn ttt_traced<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
    trace: &mut Trace,
    parent: Option<u32>,
) {
    rec_traced(g, k, cand, fini, sink, trace, parent);
}

fn rec_traced<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    mut cand: Vec<Vertex>,
    mut fini: Vec<Vertex>,
    sink: &dyn CliqueSink,
    trace: &mut Trace,
    parent: Option<u32>,
) {
    let my_id = trace.push(parent, 0);
    let mut excl = 0u64;
    let t0 = Instant::now();

    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(k);
        }
        trace.tasks[my_id as usize].excl_ns = t0.elapsed().as_nanos() as u64;
        return;
    }

    let pivot = choose_pivot(g, &cand, &fini);
    let ext = vset::difference(&cand, g.neighbors(pivot));
    let mut cand_q = Vec::new();
    let mut fini_q = Vec::new();
    excl += t0.elapsed().as_nanos() as u64;

    for q in ext {
        let t1 = Instant::now();
        let nbrs = g.neighbors(q);
        vset::intersect_into(&cand, nbrs, &mut cand_q);
        vset::intersect_into(&fini, nbrs, &mut fini_q);
        excl += t1.elapsed().as_nanos() as u64;
        k.push(q);
        rec_traced(
            g,
            k,
            std::mem::take(&mut cand_q),
            std::mem::take(&mut fini_q),
            sink,
            trace,
            Some(my_id),
        );
        k.pop();
        let t2 = Instant::now();
        vset::remove_sorted(&mut cand, q);
        vset::insert_sorted(&mut fini, q);
        excl += t2.elapsed().as_nanos() as u64;
    }
    trace.tasks[my_id as usize].excl_ns = excl;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::{CollectSink, CountSink};

    fn enumerate(g: &CsrGraph) -> Vec<Vec<Vertex>> {
        let sink = CollectSink::new();
        ttt(g, &sink);
        sink.into_canonical()
    }

    #[test]
    fn triangle_with_tail() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(enumerate(&g), vec![vec![0, 1, 2], vec![2, 3]]);
    }

    #[test]
    fn empty_and_edgeless_graphs() {
        let g0 = CsrGraph::from_edges(0, &[]);
        assert!(enumerate(&g0).is_empty());
        // isolated vertices are themselves maximal cliques
        let g3 = CsrGraph::from_edges(3, &[]);
        assert_eq!(enumerate(&g3), vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn complete_graph_single_clique() {
        let g = generators::complete(7);
        assert_eq!(enumerate(&g), vec![(0..7).collect::<Vec<_>>()]);
    }

    #[test]
    fn moon_moser_count() {
        // 3^k maximal cliques on the complete k-partite graph with parts of 3
        for k in 2..=4 {
            let g = generators::moon_moser(k);
            let sink = CountSink::new();
            ttt(&g, &sink);
            assert_eq!(sink.count(), 3u64.pow(k as u32), "k={k}");
        }
    }

    #[test]
    fn bitset_cutoff_values_agree() {
        // 0 (disabled), tiny (hand-off mid-recursion), huge (whole graph
        // runs in the kernel) must all enumerate the same set.
        let g = generators::gnp(26, 0.45, 12);
        let want = {
            let sink = CollectSink::new();
            ttt_with_cutoff(&g, &sink, 0);
            sink.into_canonical()
        };
        for cutoff in [2, 5, 64, usize::MAX] {
            let sink = CollectSink::new();
            ttt_with_cutoff(&g, &sink, cutoff);
            assert_eq!(sink.into_canonical(), want, "cutoff {cutoff}");
        }
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 31, iters: 40 },
            |rng, level| {
                let n = 4 + rng.gen_usize(18 >> level.min(2));
                let p = 0.2 + 0.6 * rng.gen_f64();
                generators::gnp(n, p, rng.next_u64())
            },
            |g| {
                let got = enumerate(g);
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {} cliques, oracle {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn ttt_from_subproblem_semantics() {
        // G = triangle 0-1-2 plus edge 2-3. Subproblem rooted at K={2} with
        // cand={3}, fini={0,1} must yield only {2,3}: cliques through 0/1
        // are excluded.
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let sink = CollectSink::new();
        let mut k = vec![2];
        ttt_from(&g, &mut k, vec![3], vec![0, 1], &sink);
        assert_eq!(sink.into_canonical(), vec![vec![2, 3]]);
        assert_eq!(k, vec![2], "K restored after enumeration");
    }

    #[test]
    fn metrics_accumulate() {
        let g = generators::gnp(40, 0.3, 9);
        let sink = CountSink::new();
        let mut m = TttMetrics::default();
        let mut k = Vec::new();
        ttt_from_metered(
            &g,
            &mut k,
            (0..40).collect(),
            Vec::new(),
            &sink,
            &mut m,
        );
        assert!(m.calls > 0);
        assert_eq!(m.emitted, sink.count());
        assert!(m.pivot_ns > 0);
    }

    #[test]
    fn traced_run_matches_plain_and_trace_is_sane() {
        let g = generators::gnp(30, 0.35, 4);
        let plain = CountSink::new();
        ttt(&g, &plain);

        let sink = CountSink::new();
        let mut trace = Trace::new();
        let mut k = Vec::new();
        ttt_traced(
            &g,
            &mut k,
            (0..30).collect(),
            Vec::new(),
            &sink,
            &mut trace,
            None,
        );
        assert_eq!(sink.count(), plain.count());
        assert!(!trace.is_empty());
        assert!(trace.span_ns() <= trace.work_ns());
        // exactly one root
        assert_eq!(
            trace.tasks.iter().filter(|t| t.parent.is_none()).count(),
            1
        );
    }
}
