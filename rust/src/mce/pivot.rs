//! Pivot selection (paper Algorithm 2, `ParPivot`).
//!
//! TTT's pruning ingredient: pick u ∈ cand ∪ fini maximizing |cand ∩ Γ(u)|,
//! then only extend by cand \ Γ(u).  The sequential version carries a
//! best-so-far lower bound so candidates whose degree already loses are
//! skipped without touching their adjacency (this is the dominant cost of
//! TTT; see EXPERIMENTS.md §Perf).  The parallel version partitions the
//! score computation across pool workers (Lemma 1: work-efficient,
//! O(log n) depth).

use crate::coordinator::pool::ThreadPool;
use crate::graph::{AdjacencyGraph, Vertex};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{ScopeShare, ScopedPtr};
use crate::util::vset;

/// Sequential pivot choice over cand ∪ fini. Returns the pivot vertex.
/// Assumes `cand` is non-empty or `fini` is non-empty.
pub fn choose_pivot<G: AdjacencyGraph + ?Sized>(g: &G, cand: &[Vertex], fini: &[Vertex]) -> Vertex {
    debug_assert!(!cand.is_empty() || !fini.is_empty());
    // §Perf optimization 2: seed the scan with the vertex of maximal
    // upper bound min(deg(u), |cand|) — its (usually high) score makes the
    // early-exit bound below prune most of the remaining intersections.
    let seed = cand
        .iter()
        .chain(fini)
        .copied()
        .max_by_key(|&u| g.degree(u).min(cand.len()))
        .expect("cand ∪ fini must be non-empty");
    let mut best_v = seed;
    let mut best_score = vset::intersection_count(cand, g.neighbors(seed));
    let mut consider = |u: Vertex| {
        if u == seed {
            return;
        }
        let nbrs = g.neighbors(u);
        // upper bound: can't beat best_score → skip the intersection
        if nbrs.len().min(cand.len()) <= best_score {
            return;
        }
        let score = vset::intersection_count(cand, nbrs);
        if score > best_score {
            best_v = u;
            best_score = score;
        }
    };
    for &u in cand {
        consider(u);
    }
    for &u in fini {
        consider(u);
    }
    best_v
}

/// Parallel pivot (Algorithm 2): score all u ∈ cand ∪ fini on the pool,
/// then argmax.  Scores are packed into an AtomicU64 as (score << 32 | v̄)
/// so the argmax reduction is a lock-free `fetch_max`; ties resolve to the
/// *smallest* vertex id (v̄ = !v), matching the sequential tie-break of
/// first-in-iteration-order only up to ties — callers must not rely on a
/// specific pivot among equals, only on the score being maximal.
///
/// Borrows `cand`/`fini` as plain slices: ParTTT calls this once per
/// large recursion node, and cloning both sets into fresh `Arc`s each
/// call was pure allocation churn on the hot path.  Tasks reference the
/// borrowed data through [`ScopedPtr`]s; `pool.scope` blocks until
/// every task completes, so the pointees strictly outlive all
/// dereferences.
pub fn par_pivot<G: AdjacencyGraph + ?Sized + 'static>(
    pool: &ThreadPool,
    g: &G,
    cand: &[Vertex],
    fini: &[Vertex],
) -> Vertex {
    let best = AtomicU64::new(0);
    let total = cand.len() + fini.len();
    debug_assert!(total > 0);
    let chunk = total.div_ceil(pool.num_threads() * 4).max(16);
    // SAFETY: every reference shared below (`g`, `cand`, `fini`, `best`)
    // outlives the `pool.scope` call, which joins all spawned tasks before
    // returning — no task can hold a ScopedPtr past that join.
    #[allow(unsafe_code)]
    let share = unsafe { ScopeShare::new() };
    let ctx = PivotCtx {
        g: share.share(g),
        cand: share.share(cand),
        fini: share.share(fini),
        best: share.share(&best),
    };
    pool.scope(|s| {
        let mut start = 0;
        while start < total {
            let end = (start + chunk).min(total);
            s.spawn(move |_| {
                let g = ctx.g.get();
                let cand = ctx.cand.get();
                let fini = ctx.fini.get();
                let best = ctx.best.get();
                let mut local_best = 0u64;
                for i in start..end {
                    let u = if i < cand.len() {
                        cand[i]
                    } else {
                        fini[i - cand.len()]
                    };
                    let score = vset::intersection_count(cand, g.neighbors(u));
                    let packed = ((score as u64) << 32) | (!u as u64 & 0xFFFF_FFFF);
                    local_best = local_best.max(packed);
                }
                best.fetch_max(local_best, Ordering::Relaxed);
            });
            start = end;
        }
    });
    let packed = best.load(Ordering::Relaxed);
    !(packed as u32)
}

/// Scope-shared borrows handed to 'static pool tasks (same pattern as
/// `dynamic::par_imce`).  `Send` is derived from [`ScopedPtr`]'s audited
/// impls — no per-call-site `unsafe impl` needed; the liveness argument
/// lives at the single [`ScopeShare::new`] site in [`par_pivot`].
struct PivotCtx<G: ?Sized> {
    g: ScopedPtr<G>,
    cand: ScopedPtr<[Vertex]>,
    fini: ScopedPtr<[Vertex]>,
    best: ScopedPtr<AtomicU64>,
}

// manual impls: a derive would wrongly require `G: Clone`/`G: Copy`,
// but only the pointers are copied.
impl<G: ?Sized> Clone for PivotCtx<G> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<G: ?Sized> Copy for PivotCtx<G> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;

    /// Naive max score for cross-checking.
    fn max_score(g: &CsrGraph, cand: &[Vertex], fini: &[Vertex]) -> usize {
        cand.iter()
            .chain(fini)
            .map(|&u| vset::intersection_count(cand, g.neighbors(u)))
            .max()
            .unwrap()
    }

    #[test]
    fn pivot_maximizes_cand_coverage() {
        // star center covers all of cand
        let g = CsrGraph::from_edges(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        let cand: Vec<Vertex> = vec![1, 2, 3, 4, 5];
        let p = choose_pivot(&g, &cand, &[0]);
        // only vertex 0 has score 5; every leaf has score 0
        assert_eq!(p, 0);
    }

    #[test]
    fn seq_pivot_score_is_maximal_randomized() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 21, iters: 40 },
            |rng, level| {
                let n = 8 + rng.gen_usize(40 >> level);
                let g = generators::gnp(n, 0.3, rng.next_u64());
                let cand: Vec<Vertex> =
                    (0..n as Vertex).filter(|_| rng.gen_bool(0.5)).collect();
                let fini: Vec<Vertex> = (0..n as Vertex)
                    .filter(|v| !cand.contains(v))
                    .filter(|_| rng.gen_bool(0.3))
                    .collect();
                (g, cand, fini)
            },
            |(g, cand, fini)| {
                if cand.is_empty() && fini.is_empty() {
                    return Ok(());
                }
                let p = choose_pivot(g, cand, fini);
                let got = vset::intersection_count(cand, g.neighbors(p));
                let want = max_score(g, cand, fini);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("pivot score {got} < max {want}"))
                }
            },
        );
    }

    #[test]
    fn par_pivot_matches_seq_score() {
        let pool = ThreadPool::new(4);
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..20 {
            let n = 20 + rng.gen_usize(60);
            let g = generators::gnp(n, 0.25, rng.next_u64());
            let cand: Vec<Vertex> = (0..n as Vertex).filter(|_| rng.gen_bool(0.6)).collect();
            let fini: Vec<Vertex> = (0..n as Vertex)
                .filter(|v| !cand.contains(v))
                .filter(|_| rng.gen_bool(0.4))
                .collect();
            if cand.is_empty() && fini.is_empty() {
                continue;
            }
            let p = par_pivot(&pool, &g, &cand, &fini);
            let got = vset::intersection_count(&cand, g.neighbors(p));
            assert_eq!(got, max_score(&g, &cand, &fini));
        }
    }
}
