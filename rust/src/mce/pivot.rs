//! Pivot selection (paper Algorithm 2, `ParPivot`).
//!
//! TTT's pruning ingredient: pick u ∈ cand ∪ fini maximizing |cand ∩ Γ(u)|,
//! then only extend by cand \ Γ(u).  The sequential version carries a
//! best-so-far lower bound so candidates whose degree already loses are
//! skipped without touching their adjacency (this is the dominant cost of
//! TTT; see EXPERIMENTS.md §Perf).  The parallel version partitions the
//! score computation across pool workers (Lemma 1: work-efficient,
//! O(log n) depth).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::{AdjacencyGraph, Vertex};
use crate::util::vset;

/// Sequential pivot choice over cand ∪ fini. Returns the pivot vertex.
/// Assumes `cand` is non-empty or `fini` is non-empty.
pub fn choose_pivot<G: AdjacencyGraph + ?Sized>(g: &G, cand: &[Vertex], fini: &[Vertex]) -> Vertex {
    debug_assert!(!cand.is_empty() || !fini.is_empty());
    // §Perf optimization 2: seed the scan with the vertex of maximal
    // upper bound min(deg(u), |cand|) — its (usually high) score makes the
    // early-exit bound below prune most of the remaining intersections.
    let seed = cand
        .iter()
        .chain(fini)
        .copied()
        .max_by_key(|&u| g.degree(u).min(cand.len()))
        .expect("cand ∪ fini must be non-empty");
    let mut best_v = seed;
    let mut best_score = vset::intersection_count(cand, g.neighbors(seed));
    let mut consider = |u: Vertex| {
        if u == seed {
            return;
        }
        let nbrs = g.neighbors(u);
        // upper bound: can't beat best_score → skip the intersection
        if nbrs.len().min(cand.len()) <= best_score {
            return;
        }
        let score = vset::intersection_count(cand, nbrs);
        if score > best_score {
            best_v = u;
            best_score = score;
        }
    };
    for &u in cand {
        consider(u);
    }
    for &u in fini {
        consider(u);
    }
    best_v
}

/// Parallel pivot (Algorithm 2): score all u ∈ cand ∪ fini on the pool,
/// then argmax.  Scores are packed into an AtomicU64 as (score << 32 | v̄)
/// so the argmax reduction is a lock-free `fetch_max`; ties resolve to the
/// *smallest* vertex id (v̄ = !v), matching the sequential tie-break of
/// first-in-iteration-order only up to ties — callers must not rely on a
/// specific pivot among equals, only on the score being maximal.
///
/// Borrows `cand`/`fini` as plain slices: ParTTT calls this once per
/// large recursion node, and cloning both sets into fresh `Arc`s each
/// call was pure allocation churn on the hot path.  Tasks reference the
/// borrowed data through a raw-pointer shim; `pool.scope` blocks until
/// every task completes, so the pointees strictly outlive all
/// dereferences.
pub fn par_pivot(pool: &ThreadPool, g: &CsrGraph, cand: &[Vertex], fini: &[Vertex]) -> Vertex {
    let best = AtomicU64::new(0);
    let total = cand.len() + fini.len();
    debug_assert!(total > 0);
    let chunk = total.div_ceil(pool.num_threads() * 4).max(16);
    let shared = PivotCtx {
        g: g as *const CsrGraph,
        cand: cand as *const [Vertex],
        fini: fini as *const [Vertex],
        best: &best as *const AtomicU64,
    };
    pool.scope(|s| {
        let mut start = 0;
        while start < total {
            let end = (start + chunk).min(total);
            let ctx = shared.clone();
            s.spawn(move |_| {
                let ctx = ctx; // capture the whole Send shim, not fields
                // SAFETY: the enclosing scope blocks until this task
                // completes, so every pointee is still alive.
                let g = unsafe { &*ctx.g };
                let cand = unsafe { &*ctx.cand };
                let fini = unsafe { &*ctx.fini };
                let best = unsafe { &*ctx.best };
                let mut local_best = 0u64;
                for i in start..end {
                    let u = if i < cand.len() {
                        cand[i]
                    } else {
                        fini[i - cand.len()]
                    };
                    let score = vset::intersection_count(cand, g.neighbors(u));
                    let packed = ((score as u64) << 32) | (!u as u64 & 0xFFFF_FFFF);
                    local_best = local_best.max(packed);
                }
                best.fetch_max(local_best, Ordering::Relaxed);
            });
            start = end;
        }
    });
    let packed = best.load(Ordering::Relaxed);
    !(packed as u32)
}

/// Raw-pointer shim handing short-lived borrows to 'static pool tasks
/// (same pattern as `dynamic::par_imce`). SAFETY: see [`par_pivot`].
struct PivotCtx {
    g: *const CsrGraph,
    cand: *const [Vertex],
    fini: *const [Vertex],
    best: *const AtomicU64,
}

impl Clone for PivotCtx {
    fn clone(&self) -> Self {
        PivotCtx {
            g: self.g,
            cand: self.cand,
            fini: self.fini,
            best: self.best,
        }
    }
}

unsafe impl Send for PivotCtx {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    /// Naive max score for cross-checking.
    fn max_score(g: &CsrGraph, cand: &[Vertex], fini: &[Vertex]) -> usize {
        cand.iter()
            .chain(fini)
            .map(|&u| vset::intersection_count(cand, g.neighbors(u)))
            .max()
            .unwrap()
    }

    #[test]
    fn pivot_maximizes_cand_coverage() {
        // star center covers all of cand
        let g = CsrGraph::from_edges(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        let cand: Vec<Vertex> = vec![1, 2, 3, 4, 5];
        let p = choose_pivot(&g, &cand, &[0]);
        // only vertex 0 has score 5; every leaf has score 0
        assert_eq!(p, 0);
    }

    #[test]
    fn seq_pivot_score_is_maximal_randomized() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 21, iters: 40 },
            |rng, level| {
                let n = 8 + rng.gen_usize(40 >> level);
                let g = generators::gnp(n, 0.3, rng.next_u64());
                let cand: Vec<Vertex> =
                    (0..n as Vertex).filter(|_| rng.gen_bool(0.5)).collect();
                let fini: Vec<Vertex> = (0..n as Vertex)
                    .filter(|v| !cand.contains(v))
                    .filter(|_| rng.gen_bool(0.3))
                    .collect();
                (g, cand, fini)
            },
            |(g, cand, fini)| {
                if cand.is_empty() && fini.is_empty() {
                    return Ok(());
                }
                let p = choose_pivot(g, cand, fini);
                let got = vset::intersection_count(cand, g.neighbors(p));
                let want = max_score(g, cand, fini);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("pivot score {got} < max {want}"))
                }
            },
        );
    }

    #[test]
    fn par_pivot_matches_seq_score() {
        let pool = ThreadPool::new(4);
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..20 {
            let n = 20 + rng.gen_usize(60);
            let g = generators::gnp(n, 0.25, rng.next_u64());
            let cand: Vec<Vertex> = (0..n as Vertex).filter(|_| rng.gen_bool(0.6)).collect();
            let fini: Vec<Vertex> = (0..n as Vertex)
                .filter(|v| !cand.contains(v))
                .filter(|_| rng.gen_bool(0.4))
                .collect();
            if cand.is_empty() && fini.is_empty() {
                continue;
            }
            let p = par_pivot(&pool, &g, &cand, &fini);
            let got = vset::intersection_count(&cand, g.neighbors(p));
            assert_eq!(got, max_score(&g, &cand, &fini));
        }
    }
}
