//! Output statistics sinks: the clique-size histogram of Figure 5.

use crate::graph::Vertex;
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::core::CliqueSink;

/// Histogram of maximal clique sizes (Figure 5) + count + max size.
///
/// Cliques larger than the expected maximum land in an explicit
/// *overflow* bin ([`SizeHistogram::overflow`]) rather than being
/// silently clamped into the top size bin — so [`nonzero_bins`]
/// (true sizes only) and [`max_size`] never disagree about what was
/// actually seen.
///
/// [`nonzero_bins`]: SizeHistogram::nonzero_bins
/// [`max_size`]: SizeHistogram::max_size
pub struct SizeHistogram {
    bins: Vec<AtomicU64>,
    overflow: AtomicU64,
    max_size: AtomicUsize,
    count: AtomicU64,
    total_verts: AtomicU64,
}

impl SizeHistogram {
    pub fn new(max_expected_size: usize) -> Self {
        SizeHistogram {
            bins: (0..=max_expected_size).map(|_| AtomicU64::new(0)).collect(),
            overflow: AtomicU64::new(0),
            max_size: AtomicUsize::new(0),
            count: AtomicU64::new(0),
            total_verts: AtomicU64::new(0),
        }
    }

    /// Largest size with its own bin (the `max_expected_size` at
    /// construction); anything bigger counts into [`overflow`].
    ///
    /// [`overflow`]: SizeHistogram::overflow
    pub fn max_binned_size(&self) -> usize {
        self.bins.len() - 1
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max_size(&self) -> usize {
        self.max_size.load(Ordering::Relaxed)
    }

    /// Cliques whose size exceeded `max_expected_size`.
    pub fn overflow(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }

    pub fn avg_size(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.total_verts.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// (size, count) pairs for sizes that occur — true sizes only; the
    /// overflow bin is reported separately by [`SizeHistogram::overflow`].
    pub fn nonzero_bins(&self) -> Vec<(usize, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter_map(|(s, b)| {
                let v = b.load(Ordering::Relaxed);
                (v > 0).then_some((s, v))
            })
            .collect()
    }

    /// Record `n` cliques of size `size` at once — the merge path for
    /// sharded histogram shards.
    pub fn record_many(&self, size: usize, n: u64) {
        if n == 0 {
            return;
        }
        self.count.fetch_add(n, Ordering::Relaxed);
        self.total_verts.fetch_add(size as u64 * n, Ordering::Relaxed);
        self.max_size.fetch_max(size, Ordering::Relaxed);
        if size < self.bins.len() {
            self.bins[size].fetch_add(n, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(n, Ordering::Relaxed);
        }
    }
}

impl CliqueSink for SizeHistogram {
    fn emit(&self, clique: &[Vertex]) {
        self.record_many(clique.len(), 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_tracks_sizes() {
        let h = SizeHistogram::new(10);
        h.emit(&[1, 2, 3]);
        h.emit(&[1, 2, 3]);
        h.emit(&[7]);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_size(), 3);
        assert!((h.avg_size() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(h.nonzero_bins(), vec![(1, 1), (3, 2)]);
        assert_eq!(h.overflow(), 0);
    }

    #[test]
    fn histogram_overflow_is_explicit() {
        // a size-5 clique in a 2-bin histogram lands in the overflow bin:
        // no fabricated (2, 1) entry, and max_size still reports the truth
        let h = SizeHistogram::new(2);
        h.emit(&[1, 2, 3, 4, 5]);
        assert_eq!(h.nonzero_bins(), vec![]);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_size(), 5);
        assert_eq!(h.max_binned_size(), 2);
        // binned + overflow always reconciles with the total count
        let binned: u64 = h.nonzero_bins().iter().map(|&(_, c)| c).sum();
        assert_eq!(binned + h.overflow(), h.count());
    }

    #[test]
    fn record_many_merges_counts() {
        let h = SizeHistogram::new(8);
        h.record_many(3, 4);
        h.record_many(9, 2); // overflow
        h.record_many(5, 0); // no-op
        assert_eq!(h.count(), 6);
        assert_eq!(h.nonzero_bins(), vec![(3, 4)]);
        assert_eq!(h.overflow(), 2);
        assert_eq!(h.max_size(), 9);
        assert!((h.avg_size() - (3.0 * 4.0 + 9.0 * 2.0) / 6.0).abs() < 1e-12);
    }
}
