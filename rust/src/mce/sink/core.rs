//! The [`CliqueSink`] trait and the shared-state sinks.
//!
//! These sinks funnel every emit through one shared location (an atomic
//! counter, a mutex-guarded vector) — correct under concurrency, simple,
//! and the right tool for tests and sequential runs.  Parallel runs
//! should prefer the shard-per-worker adapters in
//! [`super::sharded`], which keep the emit hot path off shared cache
//! lines entirely.

use crate::graph::Vertex;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{plock, Mutex};

/// Receiver for enumerated maximal cliques. Implementations must tolerate
/// concurrent `emit` calls from multiple worker threads.
pub trait CliqueSink: Sync + Send {
    fn emit(&self, clique: &[Vertex]);
}

/// Counts cliques through one shared atomic (O(1) memory).  Under
/// multi-threaded emit storms the shared cache line serializes writers;
/// use [`super::ShardedCountSink`] on the parallel hot path.
#[derive(Default)]
pub struct CountSink {
    count: AtomicU64,
}

impl CountSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl CliqueSink for CountSink {
    #[inline]
    fn emit(&self, _clique: &[Vertex]) {
        self.count.fetch_add(1, Ordering::Relaxed);
    }
}

/// Discards every clique. Useful when the caller only wants the emitted
/// count that the session layer already tracks.
#[derive(Default)]
pub struct NullSink;

impl NullSink {
    pub fn new() -> Self {
        NullSink
    }
}

impl CliqueSink for NullSink {
    #[inline]
    fn emit(&self, _clique: &[Vertex]) {}
}

/// Collects every clique behind one mutex (tests / small graphs only).
#[derive(Default)]
pub struct CollectSink {
    cliques: Mutex<Vec<Vec<Vertex>>>,
}

impl CollectSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Canonical form: each clique sorted, the set of cliques sorted —
    /// so results from different algorithms/schedules compare equal.
    pub fn into_canonical(self) -> Vec<Vec<Vertex>> {
        let mut cliques = self.into_sorted_cliques();
        cliques.sort();
        cliques
    }

    /// Each clique sorted, collection order preserved — the cheap form
    /// for callers (e.g. the IMCE batch engines) that need per-clique
    /// canonical members now but canonicalize the full set later.
    pub fn into_sorted_cliques(self) -> Vec<Vec<Vertex>> {
        let mut cliques = self.cliques.into_inner().unwrap();
        for c in cliques.iter_mut() {
            c.sort_unstable();
        }
        cliques
    }

    pub fn len(&self) -> usize {
        plock(&self.cliques).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl CliqueSink for CollectSink {
    fn emit(&self, clique: &[Vertex]) {
        plock(&self.cliques).push(clique.to_vec());
    }
}

/// Forwards each clique to a closure.
pub struct CallbackSink<F: Fn(&[Vertex]) + Sync + Send> {
    f: F,
}

impl<F: Fn(&[Vertex]) + Sync + Send> CallbackSink<F> {
    pub fn new(f: F) -> Self {
        CallbackSink { f }
    }
}

impl<F: Fn(&[Vertex]) + Sync + Send> CliqueSink for CallbackSink<F> {
    fn emit(&self, clique: &[Vertex]) {
        (self.f)(clique)
    }
}

/// Tee: emit into two sinks at once (e.g. count + histogram).
pub struct TeeSink<'a> {
    pub a: &'a dyn CliqueSink,
    pub b: &'a dyn CliqueSink,
}

impl CliqueSink for TeeSink<'_> {
    fn emit(&self, clique: &[Vertex]) {
        self.a.emit(clique);
        self.b.emit(clique);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_sink_counts() {
        let s = CountSink::new();
        s.emit(&[1, 2, 3]);
        s.emit(&[4]);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let s = NullSink::new();
        s.emit(&[1, 2, 3]);
        s.emit(&[]);
    }

    #[test]
    fn collect_sink_canonicalizes() {
        let s = CollectSink::new();
        s.emit(&[3, 1, 2]);
        s.emit(&[0, 5]);
        let c = s.into_canonical();
        assert_eq!(c, vec![vec![0, 5], vec![1, 2, 3]]);
    }

    #[test]
    fn collect_sink_sorted_cliques_preserve_order() {
        let s = CollectSink::new();
        s.emit(&[5, 4]);
        s.emit(&[3, 1, 2]);
        // per-clique members sorted, emission order kept
        assert_eq!(
            s.into_sorted_cliques(),
            vec![vec![4, 5], vec![1, 2, 3]]
        );
    }

    #[test]
    fn tee_hits_both() {
        let a = CountSink::new();
        let b = CountSink::new();
        let t = TeeSink { a: &a, b: &b };
        t.emit(&[1]);
        assert_eq!(a.count(), 1);
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn concurrent_emits() {
        let s = crate::util::sync::Arc::new(CountSink::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        s.emit(&[1, 2]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 4000);
    }
}
