//! Clique sinks: where enumerated maximal cliques go.
//!
//! Enumeration is output-dominated (Orkut: 2.27 *billion* maximal cliques),
//! so algorithms never materialize the result set unless asked: they emit
//! each clique into a [`CliqueSink`].  The module is layered:
//!
//! * [`core`] — the [`CliqueSink`] trait and the shared-state sinks
//!   ([`CountSink`], [`CollectSink`], [`CallbackSink`], [`TeeSink`],
//!   [`NullSink`]).  Correct under concurrent emits, but every emit
//!   touches shared state — fine for tests and sequential runs.
//! * [`sharded`] — [`ShardedSink`]: one lock-free local shard per pool
//!   worker (plus one for external threads), merged after the scope
//!   joins.  The hot-path emit touches no shared cache line; this is
//!   what the session layer uses for parallel runs.
//! * [`writer`] — [`StreamWriterSink`]: buffered streaming of cliques to
//!   disk (ndjson / text / binary) with per-worker write buffers,
//!   periodic flush, and a byte/clique budget.
//! * [`stats`] — [`SizeHistogram`] (Figure 5) with an explicit overflow
//!   bin for cliques larger than the expected maximum.

pub mod core;
pub mod sharded;
pub mod stats;
pub mod writer;

pub use self::core::{CallbackSink, CliqueSink, CollectSink, CountSink, NullSink, TeeSink};
pub use self::sharded::{
    route_slot, shard_count, CachePadded, CollectShard, CountShard, HistShard, Shard,
    ShardedCollectSink, ShardedCountSink, ShardedHistogramSink, ShardedSink,
};
pub use self::stats::SizeHistogram;
pub use self::writer::{SinkError, StreamWriterSink, WriterConfig, WriterFormat, WriterStats};
