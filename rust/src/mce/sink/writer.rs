//! Streaming writer sink: materialize enumeration results to disk at
//! scale — the workload counting sinks cannot serve.
//!
//! Every pool worker encodes cliques into its own cache-padded write
//! buffer; a buffer that crosses the flush threshold is appended to the
//! shared output under a short-held lock.  So the per-emit hot path is
//! an uncontended buffer append, and the shared file lock is taken once
//! per ~64 KiB, not once per clique (Orkut: 2.27B cliques).
//!
//! Output is bounded: an optional byte and/or clique budget (the session
//! layer ties the byte budget to its memory limit) turns an oversized
//! enumeration into a truncated file plus an honest `dropped` count in
//! [`WriterStats`] instead of a filled disk.

use std::fmt;
use std::io::{self, Write};
use std::path::Path;

use crate::graph::Vertex;
use crate::util::failpoints;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{plock, Mutex};

use super::core::CliqueSink;
use super::sharded::{route_slot, shard_count, CachePadded};

/// On-disk encoding of one maximal clique.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriterFormat {
    /// One JSON array per line: `[0,4,17]\n`.
    Ndjson,
    /// Whitespace-separated vertex ids, one clique per line: `0 4 17\n`
    /// (the edge-list convention of [`crate::graph::edgelist`]).
    Text,
    /// Little-endian u32 length prefix followed by the member ids as
    /// little-endian u32s.
    Binary,
}

impl WriterFormat {
    pub fn name(&self) -> &'static str {
        match self {
            WriterFormat::Ndjson => "ndjson",
            WriterFormat::Text => "text",
            WriterFormat::Binary => "binary",
        }
    }

    /// CLI spelling → format.
    pub fn parse(s: &str) -> Option<WriterFormat> {
        Some(match s {
            "ndjson" | "json" => WriterFormat::Ndjson,
            "text" | "txt" => WriterFormat::Text,
            "binary" | "bin" => WriterFormat::Binary,
            _ => return None,
        })
    }
}

/// Knobs for [`StreamWriterSink`].
#[derive(Clone, Copy, Debug)]
pub struct WriterConfig {
    pub format: WriterFormat,
    /// Per-worker buffer size that triggers a flush to the shared output.
    pub buffer_bytes: usize,
    /// Stop writing once this many bytes were accepted (soft cap: emits
    /// racing the threshold may land a final buffered clique each).
    pub byte_budget: Option<u64>,
    /// Stop writing once this many cliques were accepted (soft cap).
    pub clique_budget: Option<u64>,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig {
            format: WriterFormat::Ndjson,
            buffer_bytes: 64 << 10,
            byte_budget: None,
            clique_budget: None,
        }
    }
}

/// What a [`StreamWriterSink`] did, readable at any quiescent point.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WriterStats {
    /// Cliques accepted (encoded into a buffer).
    pub cliques: u64,
    /// Bytes accepted. Equals bytes on disk after a full flush.
    pub bytes: u64,
    /// Buffer flushes to the shared output.
    pub flushes: u64,
    /// Cliques rejected by the byte/clique budget — or, after an I/O
    /// failure (which [`StreamWriterSink::flush_all`] keeps reporting),
    /// by the writer refusing to buffer into a dead output.
    pub dropped: u64,
}

/// Structured failure report for a [`StreamWriterSink`]: the I/O error
/// plus exactly how much output had already landed safely — overall and
/// per worker shard — so a mid-run disk failure degrades to accounted
/// partial output instead of a panic in a pool worker (ISSUE 9).
#[derive(Clone, Debug)]
pub struct SinkError {
    pub kind: io::ErrorKind,
    pub message: String,
    /// Writer counters at report time.
    pub stats: WriterStats,
    /// Bytes each shard had successfully flushed to the output before
    /// the failure (index = worker slot; last = the external shard for
    /// non-pool threads).
    pub per_worker_bytes: Vec<u64>,
}

impl fmt::Display for SinkError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let flushed: u64 = self.per_worker_bytes.iter().sum();
        write!(
            f,
            "clique writer failed ({:?}): {}; {} bytes flushed of {} accepted \
             ({} cliques, {} dropped)",
            self.kind, self.message, flushed, self.stats.bytes, self.stats.cliques,
            self.stats.dropped
        )
    }
}

impl std::error::Error for SinkError {}

impl From<SinkError> for io::Error {
    fn from(e: SinkError) -> io::Error {
        io::Error::new(e.kind, e.to_string())
    }
}

/// Buffered, sharded clique writer. See the module docs.
pub struct StreamWriterSink {
    shards: Box<[CachePadded<Mutex<Vec<u8>>>]>,
    /// Bytes each shard has successfully flushed to `out` (the
    /// per-worker accounting carried by [`SinkError`]).
    shard_flushed: Box<[CachePadded<AtomicU64>]>,
    out: Mutex<Box<dyn Write + Send>>,
    cfg: WriterConfig,
    cliques: AtomicU64,
    bytes: AtomicU64,
    flushes: AtomicU64,
    dropped: AtomicU64,
    /// First I/O failure; once set, emits are dropped (and counted).
    io_error: Mutex<Option<io::Error>>,
    failed: AtomicBool,
}

impl StreamWriterSink {
    /// Write to `path` (created/truncated), shard buffers sized for
    /// `workers` pool workers.
    pub fn create(
        path: impl AsRef<Path>,
        workers: usize,
        cfg: WriterConfig,
    ) -> io::Result<StreamWriterSink> {
        let file = std::fs::File::create(path)?;
        Ok(Self::from_writer(io::BufWriter::new(file), workers, cfg))
    }

    /// Write to an arbitrary sink (tests, pipes, compression adapters).
    pub fn from_writer(
        w: impl Write + Send + 'static,
        workers: usize,
        cfg: WriterConfig,
    ) -> StreamWriterSink {
        StreamWriterSink {
            shards: (0..shard_count(workers))
                .map(|_| CachePadded(Mutex::new(Vec::new())))
                .collect(),
            shard_flushed: (0..shard_count(workers))
                .map(|_| CachePadded(AtomicU64::new(0)))
                .collect(),
            out: Mutex::new(Box::new(w)),
            cfg,
            cliques: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            io_error: Mutex::new(None),
            failed: AtomicBool::new(false),
        }
    }

    pub fn config(&self) -> &WriterConfig {
        &self.cfg
    }

    /// Counters right now. Exact once emitting has quiesced (scope join).
    pub fn stats(&self) -> WriterStats {
        WriterStats {
            cliques: self.cliques.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }

    /// Bytes each shard has flushed to the output so far (index = worker
    /// slot; last = external shard).
    pub fn per_worker_bytes(&self) -> Vec<u64> {
        self.shard_flushed
            .iter()
            .map(|c| c.0.load(Ordering::Relaxed))
            .collect()
    }

    /// Drain every shard buffer to the output and flush it. Call after
    /// the enumeration scope has joined.
    ///
    /// An I/O failure is *sticky*: once any write fails, this (and
    /// [`finish`](Self::finish)) keep returning the error on every later
    /// call — a truncated file can never be mistaken for a clean run.
    /// The [`SinkError`] carries the stats and per-worker flushed bytes
    /// at report time, so callers can account the partial output.
    pub fn flush_all(&self) -> Result<(), SinkError> {
        for (slot, shard) in self.shards.iter().enumerate() {
            let mut buf = plock(&shard.0);
            self.write_out(slot, &mut buf);
        }
        if !self.failed.load(Ordering::Relaxed) {
            if let Err(e) = plock(&self.out).flush() {
                self.record_error(e);
            }
        }
        // report without consuming: io::Error is not Clone, so re-wrap
        // the stored failure each time
        match &*plock(&self.io_error) {
            Some(e) => Err(SinkError {
                kind: e.kind(),
                message: e.to_string(),
                stats: self.stats(),
                per_worker_bytes: self.per_worker_bytes(),
            }),
            None => Ok(()),
        }
    }

    /// Flush everything and return the final stats.
    pub fn finish(self) -> Result<WriterStats, SinkError> {
        self.flush_all()?;
        Ok(self.stats())
    }

    /// Append `buf` (shard `slot`'s buffer) to the shared output and
    /// clear it.
    fn write_out(&self, slot: usize, buf: &mut Vec<u8>) {
        if buf.is_empty() {
            return;
        }
        // `sink-flush` failpoint: `error` injects a sticky I/O failure
        // exactly where a full disk or closed pipe would surface one
        if failpoints::hit(failpoints::Site::SinkFlush) {
            self.record_error(io::Error::other(
                "failpoint sink-flush: injected I/O error",
            ));
        }
        if !self.failed.load(Ordering::Relaxed) {
            let n = buf.len() as u64;
            let result = plock(&self.out).write_all(buf);
            match result {
                Ok(()) => {
                    self.flushes.fetch_add(1, Ordering::Relaxed);
                    self.shard_flushed[slot].0.fetch_add(n, Ordering::Relaxed);
                }
                Err(e) => self.record_error(e),
            }
        }
        buf.clear();
    }

    fn record_error(&self, e: io::Error) {
        self.failed.store(true, Ordering::Relaxed);
        let mut slot = plock(&self.io_error);
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    fn over_budget(&self) -> bool {
        if self.failed.load(Ordering::Relaxed) {
            return true;
        }
        if let Some(cap) = self.cfg.clique_budget {
            if self.cliques.load(Ordering::Relaxed) >= cap {
                return true;
            }
        }
        if let Some(cap) = self.cfg.byte_budget {
            if self.bytes.load(Ordering::Relaxed) >= cap {
                return true;
            }
        }
        false
    }
}

impl CliqueSink for StreamWriterSink {
    fn emit(&self, clique: &[Vertex]) {
        if self.over_budget() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let slot = route_slot(self.shards.len());
        let mut buf = plock(&self.shards[slot].0);
        let before = buf.len();
        encode(self.cfg.format, clique, &mut buf);
        let n = (buf.len() - before) as u64;
        self.cliques.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(n, Ordering::Relaxed);
        if buf.len() >= self.cfg.buffer_bytes {
            self.write_out(slot, &mut buf);
        }
    }
}

/// Encode one clique into `buf` without allocating.
fn encode(format: WriterFormat, clique: &[Vertex], buf: &mut Vec<u8>) {
    match format {
        WriterFormat::Ndjson => {
            buf.push(b'[');
            for (i, &v) in clique.iter().enumerate() {
                if i > 0 {
                    buf.push(b',');
                }
                push_decimal(buf, v as u64);
            }
            buf.extend_from_slice(b"]\n");
        }
        WriterFormat::Text => {
            for (i, &v) in clique.iter().enumerate() {
                if i > 0 {
                    buf.push(b' ');
                }
                push_decimal(buf, v as u64);
            }
            buf.push(b'\n');
        }
        WriterFormat::Binary => {
            buf.extend_from_slice(&(clique.len() as u32).to_le_bytes());
            for &v in clique {
                buf.extend_from_slice(&(v as u32).to_le_bytes());
            }
        }
    }
}

/// ASCII decimal without going through `format!` (hot path).
fn push_decimal(buf: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    buf.extend_from_slice(&tmp[i..]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parmce_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ndjson_and_text_write_one_line_per_clique() {
        for (format, want_lines) in [
            (WriterFormat::Ndjson, vec!["[0,2,5]", "[7]"]),
            (WriterFormat::Text, vec!["0 2 5", "7"]),
        ] {
            let path = temp_path(&format!("out.{}", format.name()));
            let w = StreamWriterSink::create(
                &path,
                2,
                WriterConfig {
                    format,
                    ..WriterConfig::default()
                },
            )
            .unwrap();
            w.emit(&[0, 2, 5]);
            w.emit(&[7]);
            let stats = w.finish().unwrap();
            assert_eq!(stats.cliques, 2);
            assert_eq!(stats.dropped, 0);
            let text = std::fs::read_to_string(&path).unwrap();
            let lines: Vec<&str> = text.lines().collect();
            assert_eq!(lines, want_lines, "{}", format.name());
            assert_eq!(stats.bytes as usize, text.len());
        }
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("parmce_writer_test"));
    }

    #[test]
    fn binary_roundtrips() {
        let path = temp_path("out.bin");
        let w = StreamWriterSink::create(
            &path,
            1,
            WriterConfig {
                format: WriterFormat::Binary,
                ..WriterConfig::default()
            },
        )
        .unwrap();
        w.emit(&[3, 1, 4]);
        w.emit(&[u32::MAX]);
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut cliques = Vec::new();
        let mut i = 0;
        while i < bytes.len() {
            let len = u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap()) as usize;
            i += 4;
            let mut c = Vec::with_capacity(len);
            for _ in 0..len {
                c.push(u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap()));
                i += 4;
            }
            cliques.push(c);
        }
        assert_eq!(cliques, vec![vec![3, 1, 4], vec![u32::MAX]]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clique_budget_truncates_with_honest_dropped_count() {
        let path = temp_path("budget.ndjson");
        let w = StreamWriterSink::create(
            &path,
            1,
            WriterConfig {
                clique_budget: Some(2),
                ..WriterConfig::default()
            },
        )
        .unwrap();
        for i in 0..5u32 {
            w.emit(&[i]);
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.cliques, 2);
        assert_eq!(stats.dropped, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn byte_budget_truncates() {
        let w = StreamWriterSink::from_writer(
            Vec::new(),
            1,
            WriterConfig {
                byte_budget: Some(8),
                ..WriterConfig::default()
            },
        );
        // "[0]\n" = 4 bytes; two fit before the cap trips, the rest drop
        for _ in 0..10 {
            w.emit(&[0]);
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.bytes, 8);
        assert_eq!(stats.cliques, 2);
        assert_eq!(stats.dropped, 8);
    }

    #[test]
    fn small_buffers_force_incremental_flushes() {
        let path = temp_path("flushy.txt");
        let w = StreamWriterSink::create(
            &path,
            2,
            WriterConfig {
                format: WriterFormat::Text,
                buffer_bytes: 4, // every emit crosses the threshold
                ..WriterConfig::default()
            },
        )
        .unwrap();
        for i in 0..100u32 {
            w.emit(&[i, i + 1]);
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.cliques, 100);
        assert!(stats.flushes >= 100, "flushes: {}", stats.flushes);
        assert_eq!(
            std::fs::read_to_string(&path).unwrap().lines().count(),
            100
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_run_io_error_surfaces_structured_sink_error() {
        // a writer that dies after 10 bytes — the "disk full mid-run"
        // case that used to have no story beyond panicking in a worker
        struct FailingWriter {
            wrote: usize,
            cap: usize,
        }
        impl Write for FailingWriter {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.wrote + buf.len() > self.cap {
                    return Err(io::Error::other("disk full (simulated)"));
                }
                self.wrote += buf.len();
                Ok(buf.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let w = StreamWriterSink::from_writer(
            FailingWriter { wrote: 0, cap: 10 },
            2,
            WriterConfig {
                format: WriterFormat::Text,
                buffer_bytes: 4,
                ..WriterConfig::default()
            },
        );
        for i in 0..50u32 {
            w.emit(&[i, i + 1]); // must not panic, ever
        }
        let err = w.finish().expect_err("the write failure must surface");
        assert!(err.message.contains("disk full"), "{err}");
        assert_eq!(err.per_worker_bytes.len(), 3, "2 workers + external shard");
        let flushed: u64 = err.per_worker_bytes.iter().sum();
        assert!(flushed <= 10, "only pre-failure bytes count as flushed");
        assert!(err.stats.dropped > 0, "post-failure emits drop, counted");
        // sticky: a second report carries the same failure
        assert!(err.to_string().contains("clique writer failed"));
    }

    #[test]
    fn format_parse_round_trip() {
        for f in [WriterFormat::Ndjson, WriterFormat::Text, WriterFormat::Binary] {
            assert_eq!(WriterFormat::parse(f.name()), Some(f));
        }
        assert_eq!(WriterFormat::parse("csv"), None);
    }

    #[test]
    fn concurrent_emits_lose_nothing() {
        let w = crate::util::sync::Arc::new(StreamWriterSink::from_writer(
            Vec::new(),
            4,
            WriterConfig {
                format: WriterFormat::Text,
                buffer_bytes: 32,
                ..WriterConfig::default()
            },
        ));
        let hs: Vec<_> = (0..4u32)
            .map(|t| {
                let w = crate::util::sync::Arc::clone(&w);
                std::thread::spawn(move || {
                    for i in 0..500u32 {
                        w.emit(&[t, i]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.cliques, 2000);
        assert_eq!(stats.dropped, 0);
    }
}
