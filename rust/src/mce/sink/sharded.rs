//! Sharded, thread-local sinks: the parallel emit hot path.
//!
//! ParTTT/ParMCE emit from every pool worker at once; Orkut-scale graphs
//! emit billions of cliques.  A single shared counter or mutex serializes
//! exactly where the algorithms are supposed to scale.  [`ShardedSink`]
//! gives each pool worker its own cache-line-padded shard — the worker
//! index (exposed by [`crate::coordinator::pool::current_worker_slot`])
//! binds a thread to its shard, so `emit` touches no shared cache line.
//! Threads outside the pool (the scope caller helping out, tests, foreign
//! pools) fall back to one designated *external* shard, which every shard
//! type keeps thread-safe — sharding is a performance contract, never a
//! correctness assumption.
//!
//! Shards are merged after the enumeration scope joins (count / collect /
//! histogram accessors below), so readers never race writers.

use crate::coordinator::pool::{current_worker_slot, ThreadPool};
use crate::graph::Vertex;
use crate::util::failpoints;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{plock, Mutex};

use super::core::CliqueSink;
use super::stats::SizeHistogram;

/// Pads (and aligns) a value to its own cache line so neighbouring shards
/// never false-share. 128 bytes covers the common 64B line size plus
/// adjacent-line prefetchers.
#[repr(align(128))]
pub struct CachePadded<T>(pub T);

/// Shards needed for `workers` pool workers: one each plus the external
/// shard that non-pool threads (and out-of-range foreign-pool workers)
/// fall back to.
pub fn shard_count(workers: usize) -> usize {
    workers.max(1) + 1
}

/// Route the current thread to a shard index among `n_shards` — its own
/// worker slot on a pool thread, the last (*external*) shard otherwise.
/// The single routing rule shared by every sharded sink ([`ShardedSink`]
/// and [`super::StreamWriterSink`]), so they can never diverge.
///
/// `n_shards` must be ≥ 1 ([`shard_count`] always yields ≥ 2).
#[inline]
pub fn route_slot(n_shards: usize) -> usize {
    debug_assert!(n_shards >= 1, "a sharded sink needs at least one shard");
    let external = n_shards - 1;
    match current_worker_slot() {
        Some(i) if i < external => i,
        _ => external,
    }
}

/// Per-worker sink state. `absorb` is called through `&self` because the
/// external shard can be shared by several non-pool threads — every shard
/// must stay thread-safe (atomic or mutex), but on the worker-bound path
/// the state is effectively private, so those primitives are uncontended.
pub trait Shard: Send + Sync + Default {
    fn absorb(&self, clique: &[Vertex]);
}

/// The sharded sink adapter: `workers + 1` shards (one per pool worker,
/// one for external threads), routed by [`current_worker_slot`].
pub struct ShardedSink<S: Shard> {
    shards: Box<[CachePadded<S>]>,
}

impl<S: Shard> ShardedSink<S> {
    /// One shard per worker plus the external shard.
    pub fn new(workers: usize) -> Self {
        ShardedSink {
            shards: (0..shard_count(workers))
                .map(|_| CachePadded(S::default()))
                .collect(),
        }
    }

    /// Sized for `pool` (the usual construction in the session layer).
    pub fn for_pool(pool: &ThreadPool) -> Self {
        Self::new(pool.num_threads())
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn local(&self) -> &S {
        &self.shards[route_slot(self.shards.len())].0
    }

    /// Merge-time view of every shard (call after the scope has joined).
    pub fn shards(&self) -> impl Iterator<Item = &S> {
        self.shards.iter().map(|c| &c.0)
    }

    pub fn into_shards(self) -> Vec<S> {
        // `sink-merge` failpoint: merges run after the enumeration scope
        // joins, so an injected fault here models post-run aggregation
        // failures.  The `error` action is a no-op at this site (merging
        // is infallible); `panic`/`delay` apply.
        let _ = failpoints::hit(failpoints::Site::SinkMerge);
        self.shards.into_vec().into_iter().map(|c| c.0).collect()
    }
}

impl<S: Shard> CliqueSink for ShardedSink<S> {
    #[inline]
    fn emit(&self, clique: &[Vertex]) {
        self.local().absorb(clique);
    }
}

// --- counting --------------------------------------------------------------

/// Shard for clique counting: one padded atomic per worker. Relaxed
/// increments on a private cache line cost a plain add in steady state.
#[derive(Default)]
pub struct CountShard(AtomicU64);

impl Shard for CountShard {
    #[inline]
    fn absorb(&self, _clique: &[Vertex]) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
}

impl CountShard {
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sharded replacement for [`super::CountSink`] on parallel runs.
pub type ShardedCountSink = ShardedSink<CountShard>;

impl ShardedSink<CountShard> {
    /// Total across all shards. Exact once the enumeration scope has
    /// joined; a racy lower bound while workers are still emitting.
    pub fn count(&self) -> u64 {
        self.shards().map(CountShard::get).sum()
    }
}

// --- collecting ------------------------------------------------------------

/// Shard for clique collection: a per-worker buffer behind a mutex that
/// is uncontended on the worker-bound path.
#[derive(Default)]
pub struct CollectShard(Mutex<Vec<Vec<Vertex>>>);

impl Shard for CollectShard {
    fn absorb(&self, clique: &[Vertex]) {
        plock(&self.0).push(clique.to_vec());
    }
}

impl CollectShard {
    pub fn take(self) -> Vec<Vec<Vertex>> {
        self.0.into_inner().unwrap()
    }

    pub fn len(&self) -> usize {
        plock(&self.0).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Sharded replacement for [`super::CollectSink`] on parallel runs.
pub type ShardedCollectSink = ShardedSink<CollectShard>;

impl ShardedSink<CollectShard> {
    /// Merge all shards into the canonical form (each clique sorted, the
    /// set of cliques sorted) — schedule-independent, so results from
    /// different algorithms/thread counts compare equal.
    pub fn into_canonical(self) -> Vec<Vec<Vertex>> {
        let mut cliques: Vec<Vec<Vertex>> = Vec::new();
        for shard in self.into_shards() {
            cliques.extend(shard.take());
        }
        for c in cliques.iter_mut() {
            c.sort_unstable();
        }
        cliques.sort();
        cliques
    }

    pub fn len(&self) -> usize {
        self.shards().map(CollectShard::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// --- size histogram --------------------------------------------------------

#[derive(Default)]
struct LocalHist {
    /// bins[s] = cliques of size s; grows on demand, so shards need no
    /// up-front size bound.
    bins: Vec<u64>,
}

/// Shard for size-histogram accumulation.
#[derive(Default)]
pub struct HistShard(Mutex<LocalHist>);

impl Shard for HistShard {
    fn absorb(&self, clique: &[Vertex]) {
        let s = clique.len();
        let mut h = plock(&self.0);
        if s >= h.bins.len() {
            h.bins.resize(s + 1, 0);
        }
        h.bins[s] += 1;
    }
}

/// Sharded accumulation for [`SizeHistogram`] on parallel runs.
pub type ShardedHistogramSink = ShardedSink<HistShard>;

impl ShardedSink<HistShard> {
    /// Merge all shards into a [`SizeHistogram`] with `max_expected_size`
    /// regular bins (larger sizes land in its overflow bin).
    pub fn into_histogram(self, max_expected_size: usize) -> SizeHistogram {
        let hist = SizeHistogram::new(max_expected_size);
        for shard in self.into_shards() {
            let local = shard.0.into_inner().unwrap();
            for (size, &n) in local.bins.iter().enumerate() {
                hist.record_many(size, n);
            }
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::Arc;

    #[test]
    fn external_threads_share_the_external_shard() {
        // no pool: every emit routes to the last shard, still correct
        let s = Arc::new(ShardedCountSink::new(4));
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        s.emit(&[1, 2]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 4000);
        assert_eq!(s.num_shards(), 5);
    }

    #[test]
    fn pool_workers_bind_to_distinct_shards() {
        let pool = ThreadPool::new(4);
        let s = Arc::new(ShardedCountSink::for_pool(&pool));
        // record which worker slot each task emitted from, so we can pin
        // the binding property (worker i → shard i), not just the total
        let observed = Arc::new(Mutex::new(std::collections::BTreeMap::new()));
        pool.scope(|scope| {
            for _ in 0..200 {
                let s = Arc::clone(&s);
                let observed = Arc::clone(&observed);
                scope.spawn(move |_| {
                    // a task runs entirely on one thread, so all its
                    // emits land in the slot observed here (None = the
                    // scope caller helping out → external shard)
                    if let Some(slot) = current_worker_slot() {
                        *plock(&observed).entry(slot).or_insert(0u64) += 10;
                    }
                    for _ in 0..10 {
                        s.emit(&[7]);
                    }
                });
            }
        });
        assert_eq!(s.count(), 2000);
        let shards: Vec<u64> = s.shards().map(CountShard::get).collect();
        let observed = plock(&observed);
        // on a starved single-vCPU machine the scope caller's help loop
        // can drain every task before a worker wakes; `observed` is then
        // empty and the accounting below degenerates to "all external"
        let mut worker_total = 0u64;
        for (&slot, &emitted) in observed.iter() {
            assert!(slot < 4, "slot {slot} out of range");
            assert_eq!(
                shards[slot], emitted,
                "worker {slot}'s shard must hold exactly its own emits"
            );
            worker_total += emitted;
        }
        // everything else (tasks run by the blocked scope caller) must
        // have landed in the external shard — nothing leaks elsewhere
        assert_eq!(*shards.last().unwrap(), 2000 - worker_total);
    }

    #[test]
    fn sharded_collect_canonical_matches_shared_collect() {
        let pool = ThreadPool::new(3);
        let sharded = Arc::new(ShardedCollectSink::for_pool(&pool));
        let shared = Arc::new(crate::mce::sink::CollectSink::new());
        let cliques: Vec<Vec<Vertex>> =
            (0..50u32).map(|i| vec![i, i + 1, i + 2]).collect();
        pool.scope(|scope| {
            for c in cliques.clone() {
                let a = Arc::clone(&sharded);
                let b = Arc::clone(&shared);
                scope.spawn(move |_| {
                    a.emit(&c);
                    b.emit(&c);
                });
            }
        });
        assert_eq!(sharded.len(), 50);
        let a = Arc::try_unwrap(sharded).ok().unwrap().into_canonical();
        let b = Arc::try_unwrap(shared).ok().unwrap().into_canonical();
        assert_eq!(a, b);
    }

    #[test]
    fn sharded_histogram_merges_into_size_histogram() {
        let s = ShardedHistogramSink::new(2);
        s.emit(&[1, 2, 3]);
        s.emit(&[1, 2, 3]);
        s.emit(&[9]);
        s.emit(&[0; 12]); // will overflow a 10-bin histogram
        let h = s.into_histogram(10);
        assert_eq!(h.count(), 4);
        assert_eq!(h.nonzero_bins(), vec![(1, 1), (3, 2)]);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.max_size(), 12);
    }

    #[test]
    fn zero_worker_request_still_has_two_shards() {
        let s = ShardedCountSink::new(0);
        s.emit(&[1]);
        assert_eq!(s.num_shards(), 2);
        assert_eq!(s.count(), 1);
    }
}
