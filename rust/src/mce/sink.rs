//! Clique sinks: where enumerated maximal cliques go.
//!
//! Enumeration is output-dominated (Orkut: 2.27 *billion* maximal cliques),
//! so algorithms never materialize the result set unless asked: they emit
//! each clique into a `CliqueSink` that counts, histograms, collects, or
//! forwards — all thread-safe, since ParTTT/ParMCE emit from pool workers.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::graph::Vertex;

/// Receiver for enumerated maximal cliques. Implementations must tolerate
/// concurrent `emit` calls from multiple worker threads.
pub trait CliqueSink: Sync + Send {
    fn emit(&self, clique: &[Vertex]);
}

/// Counts cliques (the default for benchmarks — O(1) memory).
#[derive(Default)]
pub struct CountSink {
    count: AtomicU64,
}

impl CountSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl CliqueSink for CountSink {
    #[inline]
    fn emit(&self, _clique: &[Vertex]) {
        self.count.fetch_add(1, Ordering::Relaxed);
    }
}

/// Collects every clique (tests / small graphs only).
#[derive(Default)]
pub struct CollectSink {
    cliques: Mutex<Vec<Vec<Vertex>>>,
}

impl CollectSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Canonical form: each clique sorted, the set of cliques sorted —
    /// so results from different algorithms/schedules compare equal.
    pub fn into_canonical(self) -> Vec<Vec<Vertex>> {
        let mut cliques = self.cliques.into_inner().unwrap();
        for c in cliques.iter_mut() {
            c.sort_unstable();
        }
        cliques.sort();
        cliques
    }

    pub fn len(&self) -> usize {
        self.cliques.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl CliqueSink for CollectSink {
    fn emit(&self, clique: &[Vertex]) {
        self.cliques.lock().unwrap().push(clique.to_vec());
    }
}

/// Histogram of maximal clique sizes (Figure 5) + count + max size.
pub struct SizeHistogram {
    bins: Vec<AtomicU64>,
    max_size: AtomicUsize,
    count: AtomicU64,
    total_verts: AtomicU64,
}

impl SizeHistogram {
    pub fn new(max_expected_size: usize) -> Self {
        SizeHistogram {
            bins: (0..=max_expected_size).map(|_| AtomicU64::new(0)).collect(),
            max_size: AtomicUsize::new(0),
            count: AtomicU64::new(0),
            total_verts: AtomicU64::new(0),
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max_size(&self) -> usize {
        self.max_size.load(Ordering::Relaxed)
    }

    pub fn avg_size(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.total_verts.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// (size, count) pairs for sizes that occur.
    pub fn nonzero_bins(&self) -> Vec<(usize, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter_map(|(s, b)| {
                let v = b.load(Ordering::Relaxed);
                (v > 0).then_some((s, v))
            })
            .collect()
    }
}

impl CliqueSink for SizeHistogram {
    fn emit(&self, clique: &[Vertex]) {
        let s = clique.len();
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_verts.fetch_add(s as u64, Ordering::Relaxed);
        self.max_size.fetch_max(s, Ordering::Relaxed);
        let idx = s.min(self.bins.len() - 1);
        self.bins[idx].fetch_add(1, Ordering::Relaxed);
    }
}

/// Forwards each clique to a closure.
pub struct CallbackSink<F: Fn(&[Vertex]) + Sync + Send> {
    f: F,
}

impl<F: Fn(&[Vertex]) + Sync + Send> CallbackSink<F> {
    pub fn new(f: F) -> Self {
        CallbackSink { f }
    }
}

impl<F: Fn(&[Vertex]) + Sync + Send> CliqueSink for CallbackSink<F> {
    fn emit(&self, clique: &[Vertex]) {
        (self.f)(clique)
    }
}

/// Tee: emit into two sinks at once (e.g. count + histogram).
pub struct TeeSink<'a> {
    pub a: &'a dyn CliqueSink,
    pub b: &'a dyn CliqueSink,
}

impl CliqueSink for TeeSink<'_> {
    fn emit(&self, clique: &[Vertex]) {
        self.a.emit(clique);
        self.b.emit(clique);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_sink_counts() {
        let s = CountSink::new();
        s.emit(&[1, 2, 3]);
        s.emit(&[4]);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn collect_sink_canonicalizes() {
        let s = CollectSink::new();
        s.emit(&[3, 1, 2]);
        s.emit(&[0, 5]);
        let c = s.into_canonical();
        assert_eq!(c, vec![vec![0, 5], vec![1, 2, 3]]);
    }

    #[test]
    fn histogram_tracks_sizes() {
        let h = SizeHistogram::new(10);
        h.emit(&[1, 2, 3]);
        h.emit(&[1, 2, 3]);
        h.emit(&[7]);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_size(), 3);
        assert!((h.avg_size() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(h.nonzero_bins(), vec![(1, 1), (3, 2)]);
    }

    #[test]
    fn histogram_clamps_oversize() {
        let h = SizeHistogram::new(2);
        h.emit(&[1, 2, 3, 4, 5]);
        assert_eq!(h.nonzero_bins(), vec![(2, 1)]);
        assert_eq!(h.max_size(), 5);
    }

    #[test]
    fn tee_hits_both() {
        let a = CountSink::new();
        let b = CountSink::new();
        let t = TeeSink { a: &a, b: &b };
        t.emit(&[1]);
        assert_eq!(a.count(), 1);
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn concurrent_emits() {
        let s = std::sync::Arc::new(CountSink::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        s.emit(&[1, 2]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 4000);
    }
}
