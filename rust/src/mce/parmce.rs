//! ParMCE (paper Algorithm 4): rank-ordered per-vertex decomposition with
//! ParTTT inside each subproblem.
//!
//! For every vertex v a subproblem (K = {v}, cand = higher-ranked
//! neighbours, fini = lower-ranked neighbours) enumerates exactly the
//! maximal cliques whose lowest-ranked member is v — so the union over v is
//! exact and duplicate-free, and the rank function (degree / triangle /
//! degeneracy) shrinks the share of expensive vertices (load balancing à la
//! PECO, but with nested parallelism inside each subproblem).
//!
//! Every per-vertex subproblem inherits the [`ParTttConfig`] hand-offs:
//! tasks spawn until `seq_cutoff`, and working sets at or below
//! `bitset_cutoff` finish in the dense bit-parallel kernel
//! ([`crate::mce::bitkernel`]).  [`subproblems_timed`] measures with the
//! default hand-off (matching real execution); [`trace`] stays slice-only
//! because the kernel would collapse whole subtrees into one trace node.

use crate::util::sync::Arc;
use std::time::Instant;

use crate::coordinator::pool::ThreadPool;
use crate::coordinator::sim::Trace;
use crate::coordinator::stats::Subproblem;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::parttt::{spawn_subtree, ParTttConfig};
use crate::mce::ranking::Ranking;
use crate::mce::sink::{CliqueSink, CountSink};
use crate::mce::ttt;
use crate::telemetry::{SubCell, SubCellSink};

#[derive(Clone, Copy, Debug, Default)]
pub struct ParMceConfig {
    pub parttt: ParTttConfig,
}

/// Enumerate all maximal cliques of `g` into `sink` (Algorithm 4).
pub fn parmce(
    pool: &ThreadPool,
    g: &Arc<CsrGraph>,
    ranking: &Arc<Ranking>,
    sink: &Arc<dyn CliqueSink>,
    cfg: ParMceConfig,
) {
    pool.scope(|s| {
        for v in 0..g.n() as Vertex {
            let (cand, fini) = ranking.split_neighbors(g, v);
            spawn_subtree(
                s,
                Arc::clone(g),
                vec![v],
                cand,
                fini,
                Arc::clone(sink),
                cfg.parttt,
                None,
            );
        }
    });
}

/// As [`parmce`], but capture per-subproblem skew from the *parallel*
/// run: each per-vertex root gets a [`SubCell`] accumulating its
/// subtree's cliques (via a [`SubCellSink`] wrapper that rides the sink
/// Arc through every spawned task) and CPU nanoseconds (each task adds
/// its own exclusive time).  The result feeds
/// [`crate::coordinator::stats`] (`share_curve`, `summarize`) with
/// Figure-2 data measured under real scheduling instead of the
/// sequential [`subproblems_timed`] methodology.
pub fn parmce_with_subproblems(
    pool: &ThreadPool,
    g: &Arc<CsrGraph>,
    ranking: &Arc<Ranking>,
    sink: &Arc<dyn CliqueSink>,
    cfg: ParMceConfig,
) -> Vec<Subproblem> {
    let cells: Vec<Arc<SubCell>> = (0..g.n() as Vertex).map(|v| Arc::new(SubCell::new(v))).collect();
    pool.scope(|s| {
        for v in 0..g.n() as Vertex {
            let (cand, fini) = ranking.split_neighbors(g, v);
            let cell = Arc::clone(&cells[v as usize]);
            let counted: Arc<dyn CliqueSink> =
                Arc::new(SubCellSink::new(Arc::clone(sink), Arc::clone(&cell)));
            spawn_subtree(
                s,
                Arc::clone(g),
                vec![v],
                cand,
                fini,
                counted,
                cfg.parttt,
                Some(cell),
            );
        }
    });
    // scope join: every task's Relaxed adds happen-before these reads
    cells.iter().map(|c| c.to_subproblem()).collect()
}

/// Run every per-vertex subproblem *sequentially*, timing each — the
/// methodology behind Figure 2's imbalance data and the trace source for
/// the Figure 6/7 scheduler simulation.
pub fn subproblems_timed(g: &CsrGraph, ranking: &Ranking) -> Vec<Subproblem> {
    let mut out = Vec::with_capacity(g.n());
    for v in 0..g.n() as Vertex {
        let (cand, fini) = ranking.split_neighbors(g, v);
        let sink = CountSink::new();
        let mut k = vec![v];
        let t0 = Instant::now();
        ttt::ttt_from(g, &mut k, cand, fini, &sink);
        out.push(Subproblem {
            vertex: v,
            cliques: sink.count(),
            ns: t0.elapsed().as_nanos() as u64,
        });
    }
    out
}

/// Record the full ParMCE task tree (root → per-vertex subproblems → TTT
/// recursion) with measured exclusive durations, for `coordinator::sim`.
pub fn trace(g: &CsrGraph, ranking: &Ranking, sink: &dyn CliqueSink) -> Trace {
    let mut tr = Trace::new();
    let root = tr.push(None, 0);
    for v in 0..g.n() as Vertex {
        let (cand, fini) = ranking.split_neighbors(g, v);
        let mut k = vec![v];
        ttt::ttt_traced(g, &mut k, cand, fini, sink, &mut tr, Some(root));
    }
    tr
}

/// Record the ParTTT task tree (single root task over the whole graph).
pub fn trace_parttt(g: &CsrGraph, sink: &dyn CliqueSink) -> Trace {
    let mut tr = Trace::new();
    let cand: Vec<Vertex> = (0..g.n() as Vertex).collect();
    let mut k = Vec::new();
    ttt::ttt_traced(g, &mut k, cand, Vec::new(), sink, &mut tr, None);
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::ranking::RankStrategy;
    use crate::mce::sink::CollectSink;

    fn run_parmce(g: CsrGraph, strategy: RankStrategy, threads: usize) -> Vec<Vec<Vertex>> {
        let pool = ThreadPool::new(threads);
        let ranking = Arc::new(Ranking::compute(&g, strategy));
        let g = Arc::new(g);
        let sink = Arc::new(CollectSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        parmce(&pool, &g, &ranking, &dyn_sink, ParMceConfig::default());
        drop(dyn_sink);
        Arc::try_unwrap(sink).ok().unwrap().into_canonical()
    }

    #[test]
    fn triangle_tail_all_strategies() {
        for s in [
            RankStrategy::Id,
            RankStrategy::Degree,
            RankStrategy::Triangle,
            RankStrategy::Degeneracy,
        ] {
            let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
            assert_eq!(
                run_parmce(g, s, 3),
                vec![vec![0, 1, 2], vec![2, 3]],
                "{s:?}"
            );
        }
    }

    #[test]
    fn no_duplicates_across_subproblems() {
        // overlapping cliques are the dangerous case for per-vertex splits
        let g = generators::ring_of_cliques(6, 5, 2);
        let cliques = run_parmce(g.clone(), RankStrategy::Degree, 4);
        let mut dedup = cliques.clone();
        dedup.dedup();
        assert_eq!(cliques.len(), dedup.len(), "duplicate maximal cliques emitted");
        oracle::validate(&g, &cliques).unwrap();
    }

    #[test]
    fn matches_oracle_randomized_all_strategies() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 51, iters: 12 },
            |rng, level| {
                let n = 6 + rng.gen_usize(16 >> level.min(2));
                let g = generators::gnp(n, 0.5, rng.next_u64());
                let strat = match rng.gen_usize(4) {
                    0 => RankStrategy::Id,
                    1 => RankStrategy::Degree,
                    2 => RankStrategy::Triangle,
                    _ => RankStrategy::Degeneracy,
                };
                (g, strat)
            },
            |(g, strat)| {
                let got = run_parmce(g.clone(), *strat, 2);
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{strat:?}: got {}, want {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn isolated_vertices_are_cliques() {
        let g = CsrGraph::from_edges(3, &[(0, 1)]);
        assert_eq!(
            run_parmce(g, RankStrategy::Degree, 2),
            vec![vec![0, 1], vec![2]]
        );
    }

    #[test]
    fn subproblems_cover_all_cliques_exactly_once() {
        let g = generators::planted_cliques(150, 0.03, 5, 5, 8, 77);
        let ranking = Ranking::compute(&g, RankStrategy::Degree);
        let subs = subproblems_timed(&g, &ranking);
        let total: u64 = subs.iter().map(|s| s.cliques).sum();
        let seq = CountSink::new();
        ttt::ttt(&g, &seq);
        assert_eq!(total, seq.count());
        assert_eq!(subs.len(), g.n());
    }

    #[test]
    fn parallel_subproblems_match_sequential_attribution() {
        // the parallel skew capture must attribute exactly the cliques
        // the sequential Fig.-2 methodology does, per root vertex
        let g = generators::planted_cliques(150, 0.03, 5, 5, 8, 77);
        let ranking = Arc::new(Ranking::compute(&g, RankStrategy::Degree));
        let seq = subproblems_timed(&g, &ranking);

        let pool = ThreadPool::new(4);
        let g = Arc::new(g);
        let sink = Arc::new(CountSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        let par = parmce_with_subproblems(&pool, &g, &ranking, &dyn_sink, ParMceConfig::default());

        assert_eq!(par.len(), g.n());
        let total: u64 = par.iter().map(|s| s.cliques).sum();
        assert_eq!(sink.count(), total, "SubCellSink attribution is exact");
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.vertex, s.vertex);
            assert_eq!(p.cliques, s.cliques, "vertex {}", p.vertex);
        }
        // some root did measurable work (ns is cumulative over its subtree)
        assert!(par.iter().any(|s| s.ns > 0));
    }

    #[test]
    fn trace_covers_full_enumeration() {
        let g = generators::gnp(40, 0.3, 3);
        let ranking = Ranking::compute(&g, RankStrategy::Degree);
        let sink = CountSink::new();
        let tr = trace(&g, &ranking, &sink);
        let seq = CountSink::new();
        ttt::ttt(&g, &seq);
        assert_eq!(sink.count(), seq.count());
        assert!(tr.len() > g.n(), "trace has per-vertex tasks plus recursion");
        // replaying the trace on 1 worker is just the total work
        let r = crate::coordinator::sim::simulate(&tr, 1, 0);
        assert_eq!(r.makespan_ns, tr.work_ns());
    }
}
