//! Dense bit-parallel subproblem kernel — the word-level fast path under
//! every TTT-family recursion.
//!
//! Deep in the recursion `cand ∪ fini` has shrunk to a small *window* of
//! vertices whose induced subgraph is dense — exactly the regime where
//! sorted-slice merges lose to word-level AND/popcount (San Segundo et
//! al., arXiv:1801.00202; the GPU MCE encoding of arXiv:2212.01473).
//! When a subproblem's working set falls to `bitset_cutoff` or below,
//! the hand-off here:
//!
//! 1. relabels the window into a compact `0..w` id space (the sorted
//!    window itself is the local→global map; global→local is a binary
//!    search);
//! 2. materializes the induced adjacency as fixed-stride rows of a
//!    [`BitMatrix`] in a per-worker arena (`thread_local`, reused across
//!    invocations — steady state allocates nothing);
//! 3. runs the remaining recursion entirely in bitset space: pivot
//!    selection is a popcount of row ANDs, cand/fini push/pop are word
//!    copies, and `ext` is a single AND-NOT;
//! 4. translates emitted cliques back to global vertex ids before they
//!    hit the sink.
//!
//! The exclusion-aware variant serves the dynamic engines' TTT-exclude
//! recompute calls: excluded edges inside the window become a second bit
//! matrix (branch pruning = one row AND against the local-K bits), and
//! excluded edges between the window and the *outer* K collapse to one
//! per-vertex "blocked" row computed at entry.

use std::cell::RefCell;

use crate::dynamic::ttt_exclude::EdgeSet;
use crate::graph::{AdjacencyGraph, Vertex};
use crate::mce::sink::CliqueSink;
use crate::util::bitset::{row, BitMatrix};
use crate::util::vset;

/// Default `|cand| + |fini|` at or below which TTT-family recursions
/// hand off to this kernel; 0 disables the hand-off entirely.  128 keeps
/// the window within two cache lines per row while catching the dense
/// bottom of the recursion (see EXPERIMENTS.md for the crossover
/// methodology).
pub const DEFAULT_BITSET_CUTOFF: usize = 128;

/// Per-worker arena: every buffer the kernel needs, reused across
/// invocations so steady-state enumeration performs no allocation.
#[derive(Default)]
struct BitScratch {
    /// sorted window = cand ∪ fini; doubles as the local→global map.
    window: Vec<Vertex>,
    /// induced adjacency rows over the window.
    adj: BitMatrix,
    /// excluded in-window pairs (exclusion runs only).
    excl_adj: BitMatrix,
    /// local vertices excluded against the outer K (exclusion runs only).
    excl_outer: Vec<u64>,
    /// local members of K pushed inside the kernel (exclusion runs only).
    kbits: Vec<u64>,
    cand_row: Vec<u64>,
    fini_row: Vec<u64>,
    /// recursion frames: 3 rows (ext, cand_q, fini_q) per level.
    arena: Vec<u64>,
}

thread_local! {
    static SCRATCH: RefCell<BitScratch> = RefCell::new(BitScratch::default());
}

/// Enumerate all maximal cliques containing `k`, extendable by `cand`,
/// excluding any vertex of `fini` — [`crate::mce::ttt::ttt_from`]
/// semantics, run entirely in bitset space.  `cand`/`fini` must be
/// sorted and disjoint, all members adjacent to every vertex of `k`.
pub fn enumerate_subproblem<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: &[Vertex],
    fini: &[Vertex],
    sink: &dyn CliqueSink,
) {
    SCRATCH.with(|s| run(g, k, cand, fini, None, sink, &mut s.borrow_mut()));
}

/// As [`enumerate_subproblem`] but pruning any branch whose clique would
/// contain an edge of `excl` — [`crate::dynamic::ttt_exclude`] semantics
/// for the IMCE/ParIMCE recompute calls.
pub fn enumerate_subproblem_excl<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: &[Vertex],
    fini: &[Vertex],
    excl: &EdgeSet,
    sink: &dyn CliqueSink,
) {
    let excl = (!excl.is_empty()).then_some(excl);
    SCRATCH.with(|s| run(g, k, cand, fini, excl, sink, &mut s.borrow_mut()));
}

/// Read-only kernel state shared by every recursion level.
struct Kernel<'a> {
    window: &'a [Vertex],
    adj: &'a BitMatrix,
    excl: Option<ExclRows<'a>>,
}

struct ExclRows<'a> {
    pairs: &'a BitMatrix,
    outer: &'a [u64],
}

fn run<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: &[Vertex],
    fini: &[Vertex],
    excl: Option<&EdgeSet>,
    sink: &dyn CliqueSink,
    s: &mut BitScratch,
) {
    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(k);
        }
        return;
    }
    let BitScratch {
        window,
        adj,
        excl_adj,
        excl_outer,
        kbits,
        cand_row,
        fini_row,
        arena,
    } = s;

    // Relabel: the sorted union is the local→global map; a vertex's
    // local id is its position in `window`.
    vset::union_into(cand, fini, window);
    let w = window.len();
    let stride = w.div_ceil(64);

    // Induced adjacency rows (row i = in-window neighbours of window[i]).
    adj.reset(w);
    for (i, &v) in window.iter().enumerate() {
        mark_common(window, g.neighbors(v), adj.row_mut(i));
    }

    cand_row.clear();
    cand_row.resize(stride, 0);
    fini_row.clear();
    fini_row.resize(stride, 0);
    mark_common(window, cand, cand_row);
    mark_common(window, fini, fini_row);

    // Exclusion structure: iterate the (normalized) excluded edges once.
    // In-window pairs land in `excl_adj`; an edge between the window and
    // a member of the *outer* K permanently blocks its window endpoint
    // (K is fixed above this subtree), folded into one `excl_outer` row.
    let has_excl = excl.is_some();
    if let Some(e) = excl {
        excl_adj.reset(w);
        excl_outer.clear();
        excl_outer.resize(stride, 0);
        for (u, v) in e.iter() {
            match (window.binary_search(&u), window.binary_search(&v)) {
                (Ok(a), Ok(b)) => {
                    excl_adj.set(a, b);
                    excl_adj.set(b, a);
                }
                (Ok(a), Err(_)) if k.contains(&v) => row::set(excl_outer, a as u32),
                (Err(_), Ok(b)) if k.contains(&u) => row::set(excl_outer, b as u32),
                _ => {}
            }
        }
    }
    kbits.clear();
    kbits.resize(stride, 0);

    // Frame arena: depth is bounded by w + 1 (cand strictly shrinks per
    // level), each level consumes 3 rows (ext, cand_q, fini_q).  Grown
    // but never zeroed — every frame row is fully written (AND / AND-NOT
    // over all `stride` words) before it is read, so stale words from
    // earlier invocations are unobservable.
    let need = (w + 2) * 3 * stride;
    if arena.len() < need {
        arena.resize(need, 0);
    }

    let kernel = Kernel {
        window,
        adj,
        excl: has_excl.then(|| ExclRows {
            pairs: excl_adj,
            outer: excl_outer,
        }),
    };
    rec(&kernel, k, kbits, cand_row, fini_row, arena, sink);
}

fn rec(
    kn: &Kernel<'_>,
    k: &mut Vec<Vertex>,
    kbits: &mut [u64],
    cand: &mut [u64],
    fini: &mut [u64],
    arena: &mut [u64],
    sink: &dyn CliqueSink,
) {
    if row::is_empty(cand) {
        if row::is_empty(fini) {
            sink.emit(k);
        }
        return;
    }
    let stride = kn.adj.stride();

    // Pivot: maximize |cand ∩ Γ(u)| over u ∈ cand ∪ fini — a popcount
    // of row ANDs per candidate, no slice walks.
    let mut best = (usize::MAX, 0usize);
    for u in row::iter(cand).chain(row::iter(fini)) {
        let score = row::and_count(cand, kn.adj.row(u as usize));
        if best.0 == usize::MAX || score > best.1 {
            best = (u as usize, score);
        }
    }
    let pivot = best.0;

    // ext = cand \ Γ(pivot); children get cand_q/fini_q from the arena.
    let (ext, rest) = arena.split_at_mut(stride);
    row::and_not_into(cand, kn.adj.row(pivot), ext);
    let (cand_q, rest) = rest.split_at_mut(stride);
    let (fini_q, rest) = rest.split_at_mut(stride);

    for q in row::iter(ext) {
        // Exclusion pruning (Alg. 8 lines 7–10): the branch is skipped,
        // but q still migrates cand → fini so sibling branches treat it
        // as explored.
        if let Some(e) = &kn.excl {
            if row::test(e.outer, q) || row::intersects(kbits, e.pairs.row(q as usize)) {
                row::clear(cand, q);
                row::set(fini, q);
                continue;
            }
        }
        row::and_into(cand, kn.adj.row(q as usize), cand_q);
        row::and_into(fini, kn.adj.row(q as usize), fini_q);
        k.push(kn.window[q as usize]);
        if kn.excl.is_some() {
            row::set(kbits, q);
        }
        rec(kn, k, kbits, cand_q, fini_q, rest, sink);
        if kn.excl.is_some() {
            row::clear(kbits, q);
        }
        k.pop();
        row::clear(cand, q);
        row::set(fini, q);
    }
}

/// Set bit `i` for every `i` with `window[i] ∈ other` (both sorted
/// ascending; `out` pre-zeroed).  Gallops over `other` when it is much
/// larger than the window (a high-degree vertex's neighbour list).
fn mark_common(window: &[Vertex], other: &[Vertex], out: &mut [u64]) {
    if window.is_empty() || other.is_empty() {
        return;
    }
    if other.len() / window.len() >= 8 {
        let mut j = 0;
        for (i, &v) in window.iter().enumerate() {
            j = vset::gallop_lower_bound(other, j, v);
            if j >= other.len() {
                return;
            }
            if other[j] == v {
                row::set(out, i as u32);
                j += 1;
            }
        }
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < window.len() && j < other.len() {
        match window[i].cmp(&other[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                row::set(out, i as u32);
                i += 1;
                j += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::mce::sink::CollectSink;
    use crate::mce::ttt;

    fn kernel_cliques(
        g: &CsrGraph,
        k0: Vec<Vertex>,
        cand: Vec<Vertex>,
        fini: Vec<Vertex>,
    ) -> Vec<Vec<Vertex>> {
        let sink = CollectSink::new();
        let mut k = k0;
        enumerate_subproblem(g, &mut k, &cand, &fini, &sink);
        sink.into_canonical()
    }

    fn slice_cliques(
        g: &CsrGraph,
        k0: Vec<Vertex>,
        cand: Vec<Vertex>,
        fini: Vec<Vertex>,
    ) -> Vec<Vec<Vertex>> {
        let sink = CollectSink::new();
        let mut k = k0;
        ttt::ttt_from_with_cutoff(g, &mut k, cand, fini, &sink, 0);
        sink.into_canonical()
    }

    #[test]
    fn whole_graph_matches_slice_path() {
        for seed in 0..6 {
            let g = generators::gnp(20, 0.45, seed);
            let all: Vec<Vertex> = (0..20).collect();
            assert_eq!(
                kernel_cliques(&g, vec![], all.clone(), vec![]),
                slice_cliques(&g, vec![], all, vec![]),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn relabeling_round_trips_non_contiguous_ids() {
        // The window {3, 17, 29, 41, 57} is sparse in a 64-vertex id
        // space: a triangle 3-17-29 plus edges 29-41, 41-57.  Local ids
        // 0..5 must translate back to these exact globals.
        let g = CsrGraph::from_edges(
            64,
            &[(3, 17), (3, 29), (17, 29), (29, 41), (41, 57)],
        );
        let window: Vec<Vertex> = vec![3, 17, 29, 41, 57];
        let got = kernel_cliques(&g, vec![], window.clone(), vec![]);
        assert_eq!(got, vec![vec![3, 17, 29], vec![29, 41], vec![41, 57]]);
        // every emitted vertex is a window member (global ids, not local)
        for c in &got {
            for v in c {
                assert!(window.contains(v), "non-window vertex {v} leaked");
            }
        }
        assert_eq!(got, slice_cliques(&g, vec![], window, vec![]));
    }

    #[test]
    fn bitmatrix_rows_mirror_induced_adjacency() {
        // Direct check of the relabel map: row bits of the window-induced
        // matrix must match the graph restricted to the window.
        let g = generators::gnp(40, 0.4, 9);
        let window: Vec<Vertex> = (0..40).filter(|v| v % 3 != 1).collect();
        let w = window.len();
        let mut adj = BitMatrix::new(w);
        for (i, &v) in window.iter().enumerate() {
            mark_common(&window, g.neighbors(v), adj.row_mut(i));
        }
        for i in 0..w {
            for j in 0..w {
                let connected = crate::util::vset::contains(
                    g.neighbors(window[i]),
                    window[j],
                );
                assert_eq!(adj.test(i, j), connected, "({i},{j})");
            }
        }
    }

    #[test]
    fn subproblem_with_fini_and_outer_k_matches_slice() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let sink = CollectSink::new();
        let mut k = vec![2];
        enumerate_subproblem(&g, &mut k, &[3], &[0, 1], &sink);
        assert_eq!(sink.into_canonical(), vec![vec![2, 3]]);
        assert_eq!(k, vec![2], "K restored after enumeration");
    }

    #[test]
    fn exclusion_matches_slice_path_randomized() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 91, iters: 30 },
            |rng, level| {
                let n = 6 + rng.gen_usize(14 >> level.min(2));
                let g = generators::gnp(n, 0.5, rng.next_u64());
                let mut edges = g.edges();
                rng.shuffle(&mut edges);
                let cut = edges.len().min(1 + rng.gen_usize(4));
                (g, cut)
            },
            |(g, cut)| {
                let edges = g.edges();
                let excl = EdgeSet::from_edges(&edges[..*cut]);
                let all: Vec<Vertex> = (0..g.n() as Vertex).collect();

                let bit = CollectSink::new();
                let mut k = Vec::new();
                enumerate_subproblem_excl(g, &mut k, &all, &[], &excl, &bit);

                let slice = CollectSink::new();
                let mut k2 = Vec::new();
                crate::dynamic::ttt_exclude::ttt_exclude_edges_with_cutoff(
                    g,
                    &mut k2,
                    all.clone(),
                    Vec::new(),
                    &excl,
                    &slice,
                    0,
                );
                let got = bit.into_canonical();
                let want = slice.into_canonical();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("bit {} cliques, slice {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn outer_k_exclusion_blocks_window_vertices() {
        // K4 on {0,1,2,3}; outer K = {0}, window = {1,2,3}, excluded edge
        // (0,2): any clique through 2 would close it, so only branches
        // avoiding 2 survive — but 2 ∈ fini then kills maximality of
        // {0,1,3} ∪ … subsets that 2 extends.
        let g = generators::complete(4);
        let excl = EdgeSet::from_edges(&[(0, 2)]);

        let bit = CollectSink::new();
        let mut k = vec![0];
        enumerate_subproblem_excl(&g, &mut k, &[1, 2, 3], &[], &excl, &bit);

        let slice = CollectSink::new();
        let mut k2 = vec![0];
        crate::dynamic::ttt_exclude::ttt_exclude_edges_with_cutoff(
            &g,
            &mut k2,
            vec![1, 2, 3],
            Vec::new(),
            &excl,
            &slice,
            0,
        );
        assert_eq!(bit.into_canonical(), slice.into_canonical());
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let g = CsrGraph::from_edges(3, &[]);
        // empty cand + empty fini ⇒ K itself is maximal
        let got = kernel_cliques(&g, vec![1], vec![], vec![]);
        assert_eq!(got, vec![vec![1]]);
        // empty cand + non-empty fini ⇒ nothing
        let got = kernel_cliques(&g, vec![1], vec![], vec![0]);
        assert!(got.is_empty());
        // singleton windows
        let got = kernel_cliques(&g, vec![], vec![2], vec![]);
        assert_eq!(got, vec![vec![2]]);
    }
}
