//! Vertex rankings for ParMCE's per-vertex subproblem decomposition (§4.2).
//!
//! rank(v) = (metric(v), id(v)) lexicographically; vertex v's subproblem
//! enumerates exactly the maximal cliques in which v is the *lowest-ranked*
//! member, so a higher rank means a smaller share — the PECO-style load
//! balancing idea.  Metrics: degree (free), triangle count (CPU forward
//! algorithm or the AOT Pallas kernel via [`TriangleBackend`]), degeneracy
//! (O(n+m) peeling).

use anyhow::Result;

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::{degeneracy, triangles, Vertex};
use crate::telemetry;

/// Which vertex-ordering metric ParMCE uses (ParMCEDegree / Tri / Degen).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RankStrategy {
    /// identifier only (ablation baseline; not in the paper's tables)
    Id,
    /// degree-based — "available for free when the input graph is read"
    Degree,
    /// triangle-count-based
    Triangle,
    /// degeneracy-number-based (Eppstein et al. ordering)
    Degeneracy,
}

impl RankStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            RankStrategy::Id => "Id",
            RankStrategy::Degree => "Degree",
            RankStrategy::Triangle => "Tri",
            RankStrategy::Degeneracy => "Degen",
        }
    }
}

/// Pluggable triangle-count provider: CPU forward algorithm, or the
/// PJRT-executed Pallas kernel (`runtime::tri_rank::PjrtTriangleBackend`).
/// Backend-based ranking runs as a single-threaded pre-pass on the
/// session thread (the paper computes rankings sequentially, §6.2), so
/// implementations need not be Sync — which lets the Rc-based PJRT
/// client implement it directly.  The ingest pipeline's
/// [`Ranking::compute_parallel`] bypasses the backend seam and fans the
/// same exact-equal CPU computation out over the ingest pool instead.
pub trait TriangleBackend {
    fn per_vertex(&self, g: &CsrGraph) -> Result<Vec<u64>>;
    fn name(&self) -> &'static str;
}

/// The paper's sequential CPU routine (§6.2).
pub struct CpuTriangleBackend;

impl TriangleBackend for CpuTriangleBackend {
    fn per_vertex(&self, g: &CsrGraph) -> Result<Vec<u64>> {
        Ok(triangles::per_vertex(g))
    }

    fn name(&self) -> &'static str {
        "cpu-forward"
    }
}

/// A computed total order on vertices.
#[derive(Clone, Debug)]
pub struct Ranking {
    /// metric value per vertex; ties broken by id
    metric: Vec<u64>,
    strategy: RankStrategy,
}

impl Ranking {
    /// Compute with the default (CPU) backends.
    pub fn compute(g: &CsrGraph, strategy: RankStrategy) -> Ranking {
        Self::compute_with(g, strategy, &CpuTriangleBackend).expect("CPU backends are infallible")
    }

    /// Compute with an explicit triangle backend (PJRT offload path).
    pub fn compute_with(
        g: &CsrGraph,
        strategy: RankStrategy,
        tri: &dyn TriangleBackend,
    ) -> Result<Ranking> {
        let span = telemetry::SpanTimer::start();
        let metric = match strategy {
            RankStrategy::Id => vec![0; g.n()],
            RankStrategy::Degree => (0..g.n()).map(|v| g.degree(v as Vertex) as u64).collect(),
            RankStrategy::Triangle => tri.per_vertex(g)?,
            RankStrategy::Degeneracy => degeneracy::core_decomposition(g)
                .core
                .iter()
                .map(|&c| c as u64)
                .collect(),
        };
        telemetry::global().ingest_rank_ns.record(span.elapsed_ns());
        Ok(Ranking { metric, strategy })
    }

    /// [`compute`](Self::compute) with the metric pre-pass fanned out
    /// across `pool`: triangle counts via
    /// [`triangles::per_vertex_parallel`] and degeneracy cores via
    /// [`degeneracy::core_decomposition_parallel`], both of which equal
    /// their sequential oracles exactly — so the resulting ranking (and
    /// therefore every enumeration order built on it) is bit-identical
    /// to the sequential path for any thread count.
    pub fn compute_parallel(g: &CsrGraph, strategy: RankStrategy, pool: &ThreadPool) -> Ranking {
        let span = telemetry::SpanTimer::start();
        let metric = match strategy {
            RankStrategy::Id => vec![0; g.n()],
            RankStrategy::Degree => (0..g.n()).map(|v| g.degree(v as Vertex) as u64).collect(),
            RankStrategy::Triangle => triangles::per_vertex_parallel(g, pool),
            RankStrategy::Degeneracy => degeneracy::core_decomposition_parallel(g, pool)
                .core
                .iter()
                .map(|&c| c as u64)
                .collect(),
        };
        telemetry::global().ingest_rank_ns.record(span.elapsed_ns());
        Ranking { metric, strategy }
    }

    /// Construct from an explicit metric vector (ablation studies that
    /// test non-paper orderings, e.g. inverted degree).
    pub fn from_metric(metric: Vec<u64>) -> Ranking {
        Ranking {
            metric,
            strategy: RankStrategy::Id,
        }
    }

    pub fn strategy(&self) -> RankStrategy {
        self.strategy
    }

    /// rank(v) > rank(w)?
    #[inline]
    pub fn higher(&self, v: Vertex, w: Vertex) -> bool {
        (self.metric[v as usize], v) > (self.metric[w as usize], w)
    }

    /// Split Γ(v) into (cand, fini) for v's subproblem (Alg. 4 lines 4–6):
    /// higher-ranked neighbours go to cand, lower-ranked to fini.
    pub fn split_neighbors(&self, g: &CsrGraph, v: Vertex) -> (Vec<Vertex>, Vec<Vertex>) {
        let mut cand = Vec::new();
        let mut fini = Vec::new();
        for &w in g.neighbors(v) {
            if self.higher(w, v) {
                cand.push(w);
            } else {
                fini.push(w);
            }
        }
        (cand, fini)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn total_order_antisymmetric() {
        let g = generators::gnp(50, 0.2, 1);
        for s in [
            RankStrategy::Id,
            RankStrategy::Degree,
            RankStrategy::Triangle,
            RankStrategy::Degeneracy,
        ] {
            let r = Ranking::compute(&g, s);
            for v in 0..50u32 {
                for w in 0..50u32 {
                    if v != w {
                        assert!(
                            r.higher(v, w) ^ r.higher(w, v),
                            "{s:?}: exactly one of rank(v)>rank(w), rank(w)>rank(v)"
                        );
                    } else {
                        assert!(!r.higher(v, w));
                    }
                }
            }
        }
    }

    #[test]
    fn degree_ranking_orders_by_degree() {
        // star: center has max degree
        let g = CsrGraph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let r = Ranking::compute(&g, RankStrategy::Degree);
        for leaf in 1..5u32 {
            assert!(r.higher(0, leaf));
        }
    }

    #[test]
    fn split_neighbors_partitions() {
        let g = generators::gnp(40, 0.3, 7);
        let r = Ranking::compute(&g, RankStrategy::Degree);
        for v in 0..40u32 {
            let (cand, fini) = r.split_neighbors(&g, v);
            assert_eq!(cand.len() + fini.len(), g.degree(v));
            for &w in &cand {
                assert!(r.higher(w, v));
            }
            for &w in &fini {
                assert!(r.higher(v, w));
            }
            // sorted outputs (neighbor order is preserved)
            assert!(cand.windows(2).all(|w| w[0] < w[1]));
            assert!(fini.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn parallel_ranking_equals_sequential() {
        let g = generators::gnp(120, 0.1, 42);
        for s in [
            RankStrategy::Id,
            RankStrategy::Degree,
            RankStrategy::Triangle,
            RankStrategy::Degeneracy,
        ] {
            let seq = Ranking::compute(&g, s);
            for threads in [1, 2, 4] {
                let pool = ThreadPool::new(threads);
                let par = Ranking::compute_parallel(&g, s, &pool);
                assert_eq!(par.metric, seq.metric, "{s:?} threads={threads}");
                assert_eq!(par.strategy(), seq.strategy());
            }
        }
    }

    #[test]
    fn triangle_backend_names() {
        assert_eq!(CpuTriangleBackend.name(), "cpu-forward");
        let g = generators::complete(5);
        let counts = CpuTriangleBackend.per_vertex(&g).unwrap();
        assert_eq!(counts, vec![6; 5]);
    }
}
