//! ParTTT (paper Algorithm 3): work-efficient parallelization of TTT.
//!
//! The loop-carried dependency of Algorithm 1 (cand/fini evolve across
//! iterations) is removed by *unrolling*: with ext = ⟨v₁…v_κ⟩ in a fixed
//! order, iteration i explicitly computes
//!
//! ```text
//! cand_i = (cand \ ext[..i]) ∩ Γ(vᵢ)
//! fini_i = (fini ∪ ext[..i]) ∩ Γ(vᵢ)
//! ```
//!
//! so every recursive call is independent and forked onto the
//! work-stealing pool.  Below `seq_cutoff` the task falls back to
//! sequential TTT — the granularity control that keeps the O(n) unrolling
//! overhead (Lemma 2) from dominating at the bottom of the recursion.

use crate::util::sync::Arc;

use crate::coordinator::pool::{ScopeHandle, ThreadPool};
use crate::graph::{AdjacencyGraph, Vertex};
use crate::mce::bitkernel;
use crate::mce::pivot::{choose_pivot, par_pivot};
use crate::mce::sink::CliqueSink;
use crate::mce::ttt;
use crate::telemetry;
use crate::telemetry::SubCell;
use crate::util::vset;

#[derive(Clone, Copy, Debug)]
pub struct ParTttConfig {
    /// |cand| + |fini| at or below which the task runs sequential TTT.
    pub seq_cutoff: usize,
    /// |cand ∪ fini| above which the pivot itself is computed in parallel
    /// (ParPivot, Algorithm 2); below, sequential pivoting is cheaper.
    pub par_pivot_min: usize,
    /// |cand| + |fini| at or below which the subproblem finishes in the
    /// dense bit-parallel kernel ([`crate::mce::bitkernel`]); 0 disables
    /// the kernel.  Composes with `seq_cutoff`: tasks above both spawn,
    /// tasks between them run sequential slice TTT (which itself hands
    /// off once the working set shrinks under this threshold).
    pub bitset_cutoff: usize,
}

impl Default for ParTttConfig {
    fn default() -> Self {
        ParTttConfig {
            seq_cutoff: 32,
            par_pivot_min: 4096,
            bitset_cutoff: bitkernel::DEFAULT_BITSET_CUTOFF,
        }
    }
}

/// Enumerate all maximal cliques of `g` into `sink` using the pool.
/// Generic over the adjacency source: runs identically on a static
/// [`crate::graph::csr::CsrGraph`] and on a published
/// [`crate::graph::snapshot::GraphSnapshot`].
pub fn parttt<G: AdjacencyGraph + Send + Sync + 'static>(
    pool: &ThreadPool,
    g: &Arc<G>,
    sink: &Arc<dyn CliqueSink>,
    cfg: ParTttConfig,
) {
    if g.n() == 0 {
        return;
    }
    let cand: Vec<Vertex> = (0..g.n() as Vertex).collect();
    pool.scope(|s| {
        spawn_subtree(
            s,
            Arc::clone(g),
            Vec::new(),
            cand,
            Vec::new(),
            Arc::clone(sink),
            cfg,
            None,
        );
    });
}

/// Fork the enumeration of the (k, cand, fini) subtree into `scope`.
/// Shared by ParTTT (root = whole graph) and ParMCE (root = one vertex's
/// subproblem) — the "additional recursive level of parallelism" of §4.2.
///
/// `cell`, when present, accumulates per-root skew data
/// ([`crate::telemetry::SubCell`]): each task adds its own exclusive
/// execution time (children time themselves), so the cell's total is the
/// CPU work of the whole subtree regardless of which workers ran it.
/// Clique attribution rides the sink (see
/// [`crate::telemetry::SubCellSink`]), not this parameter.
pub(crate) fn spawn_subtree<G: AdjacencyGraph + Send + Sync + 'static>(
    scope: &ScopeHandle,
    g: Arc<G>,
    k: Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: Arc<dyn CliqueSink>,
    cfg: ParTttConfig,
    cell: Option<Arc<SubCell>>,
) {
    telemetry::global().parttt_tasks_spawned.inc();
    scope.spawn(move |s| run_task(s, g, k, cand, fini, sink, cfg, cell));
}

#[allow(clippy::too_many_arguments)]
fn run_task<G: AdjacencyGraph + Send + Sync + 'static>(
    scope: &ScopeHandle,
    g: Arc<G>,
    k: Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: Arc<dyn CliqueSink>,
    cfg: ParTttConfig,
    cell: Option<Arc<SubCell>>,
) {
    // Subproblem timing is explicit opt-in (independent of the
    // `telemetry-off` feature), so read the clock directly rather than
    // through the feature-gated SpanTimer; `cell` is None on every
    // untimed run and this costs nothing.
    let t0 = cell.as_ref().map(|_| std::time::Instant::now());
    run_task_inner(scope, g, k, cand, fini, sink, cfg, &cell);
    if let (Some(cell), Some(t0)) = (&cell, t0) {
        cell.add_ns(t0.elapsed().as_nanos() as u64);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_task_inner<G: AdjacencyGraph + Send + Sync + 'static>(
    scope: &ScopeHandle,
    g: Arc<G>,
    mut k: Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    sink: Arc<dyn CliqueSink>,
    cfg: ParTttConfig,
    cell: &Option<Arc<SubCell>>,
) {
    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(&k);
        }
        return;
    }
    // dense hand-off: working sets under the bitset threshold finish
    // entirely in the bit-parallel kernel (sequentially, in-task —
    // parallel spawning still happens above this point)
    if cfg.bitset_cutoff > 0 && cand.len() + fini.len() <= cfg.bitset_cutoff {
        telemetry::global().bitkernel_handoffs.inc();
        bitkernel::enumerate_subproblem(g.as_ref(), &mut k, &cand, &fini, sink.as_ref());
        return;
    }
    // granularity control: small subproblems run sequentially in-task
    if cand.len() + fini.len() <= cfg.seq_cutoff {
        telemetry::global().parttt_seq_cutovers.inc();
        ttt::ttt_from_with_cutoff(
            g.as_ref(),
            &mut k,
            cand,
            fini,
            sink.as_ref(),
            cfg.bitset_cutoff,
        );
        return;
    }

    // Line 3: pivot — parallel above the threshold (Algorithm 2).
    // par_pivot borrows cand/fini directly; no per-call Arc clones on
    // the recursion hot path.
    let pivot = if cand.len() + fini.len() >= cfg.par_pivot_min {
        telemetry::global().parttt_par_pivots.inc();
        par_pivot(scope.pool(), g.as_ref(), &cand, &fini)
    } else {
        choose_pivot(g.as_ref(), &cand, &fini)
    };

    // Line 4: ext = cand − Γ(pivot), in cand's (sorted) order.
    let ext = vset::difference(&cand, g.neighbors(pivot));

    // Lines 5–10, unrolled: iteration i sees cand \ ext[..i], fini ∪ ext[..i].
    let mut buf = Vec::new();
    for (i, &q) in ext.iter().enumerate() {
        let nbrs = g.neighbors(q);
        // cand_q = (cand ∩ Γ(q)) \ ext[..i]   (ext[..i] is sorted)
        vset::intersect_into(&cand, nbrs, &mut buf);
        let cand_q = vset::difference(&buf, &ext[..i]);
        // fini_q = (fini ∩ Γ(q)) ∪ (ext[..i] ∩ Γ(q))
        vset::intersect_into(&fini, nbrs, &mut buf);
        let fini_q = vset::union(&buf, &vset::intersect(&ext[..i], nbrs));

        let mut k_q = Vec::with_capacity(k.len() + 1);
        k_q.extend_from_slice(&k);
        k_q.push(q);

        spawn_subtree(
            scope,
            Arc::clone(&g),
            k_q,
            cand_q,
            fini_q,
            Arc::clone(&sink),
            cfg,
            cell.clone(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::{CollectSink, CountSink};

    fn run_parttt(g: CsrGraph, threads: usize, cfg: ParTttConfig) -> Vec<Vec<Vertex>> {
        let pool = ThreadPool::new(threads);
        let g = Arc::new(g);
        let sink = Arc::new(CollectSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        parttt(&pool, &g, &dyn_sink, cfg);
        drop(dyn_sink);
        Arc::try_unwrap(sink).ok().unwrap().into_canonical()
    }

    #[test]
    fn matches_ttt_on_small_graphs() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(
            run_parttt(g, 4, ParTttConfig::default()),
            vec![vec![0, 1, 2], vec![2, 3]]
        );
    }

    #[test]
    fn zero_cutoff_forces_full_parallel_recursion() {
        // cutoff 0: every recursive call is its own task — stresses the
        // unrolled cand/fini computation itself.
        let cfg = ParTttConfig {
            seq_cutoff: 0,
            par_pivot_min: 8, // force the ParPivot path too
            bitset_cutoff: 0, // slice path all the way down
        };
        let g = generators::moon_moser(3);
        let cliques = run_parttt(g, 4, cfg);
        assert_eq!(cliques.len(), 27);
    }

    #[test]
    fn matches_oracle_randomized() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 41, iters: 15 },
            |rng, level| {
                let n = 6 + rng.gen_usize(16 >> level.min(2));
                generators::gnp(n, 0.4 + 0.3 * rng.gen_f64(), rng.next_u64())
            },
            |g| {
                let got = run_parttt(
                    g.clone(),
                    3,
                    ParTttConfig {
                        seq_cutoff: 2,
                        par_pivot_min: 4096,
                        bitset_cutoff: 3,
                    },
                );
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {}, want {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn bitset_cutoff_values_agree_under_parallel_spawning() {
        let g = generators::planted_cliques(120, 0.04, 5, 5, 9, 7);
        let want = run_parttt(
            g.clone(),
            4,
            ParTttConfig {
                bitset_cutoff: 0,
                ..ParTttConfig::default()
            },
        );
        for cutoff in [4, 64, usize::MAX] {
            let got = run_parttt(
                g.clone(),
                4,
                ParTttConfig {
                    bitset_cutoff: cutoff,
                    ..ParTttConfig::default()
                },
            );
            assert_eq!(got, want, "cutoff {cutoff}");
        }
    }

    #[test]
    fn larger_graph_count_matches_sequential() {
        let g = generators::planted_cliques(300, 0.02, 8, 6, 10, 13);
        let seq = CountSink::new();
        crate::mce::ttt::ttt(&g, &seq);

        let pool = ThreadPool::new(4);
        let g = Arc::new(g);
        let sink = Arc::new(CountSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        parttt(&pool, &g, &dyn_sink, ParTttConfig::default());
        assert_eq!(sink.count(), seq.count());
        assert!(sink.count() > 0);
    }

    #[test]
    fn single_thread_correct() {
        let g = generators::moon_moser(4);
        let cliques = run_parttt(g, 1, ParTttConfig::default());
        assert_eq!(cliques.len(), 81);
    }
}
