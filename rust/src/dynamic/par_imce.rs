//! ParIMCE (paper §5): ParIMCENew (Algorithm 5) + ParIMCESub (Algorithm 7).
//!
//! ParIMCENew processes the batch's edges as parallel tasks on the
//! work-stealing pool; each task enumerates the new maximal cliques
//! containing its edge (and no earlier edge) with ParTTTExcludeEdges
//! semantics.  ParIMCESub then processes each new maximal clique as a
//! parallel task: candidate generation (endpoint removals) plus the
//! concurrent-registry candidacy check, whose atomic remove guarantees a
//! subsumed clique is reported exactly once even when reachable from
//! several new cliques.

use std::time::Instant;

use crate::util::sync::{plock, Arc, Mutex, ScopeShare, ScopedPtr};

use crate::coordinator::pool::ThreadPool;
use crate::dynamic::imce::{subsumption_candidates, BatchTimings};
use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::ttt_exclude::{ttt_exclude_edges_with_cutoff, EdgeSet};
use crate::dynamic::BatchResult;
use crate::graph::snapshot::{GraphSnapshot, SnapshotGraph};
use crate::graph::{Edge, Vertex};
use crate::mce::bitkernel::DEFAULT_BITSET_CUTOFF;
use crate::mce::sink::CollectSink;

/// Apply one batch in parallel; the registry is updated to C(G + H).
/// Semantically identical to [`crate::dynamic::imce_batch`] (tests assert
/// equality); only the schedule differs.
pub fn par_imce_batch(
    pool: &ThreadPool,
    graph: &mut SnapshotGraph,
    registry: &CliqueRegistry,
    batch: &[Edge],
) -> (BatchResult, BatchTimings) {
    par_imce_batch_with_cutoff(pool, graph, registry, batch, DEFAULT_BITSET_CUTOFF)
}

/// As [`par_imce_batch`] with an explicit bitset hand-off threshold for
/// the per-edge TTT-exclude recompute tasks (0 = slice-only recursion).
pub fn par_imce_batch_with_cutoff(
    pool: &ThreadPool,
    graph: &mut SnapshotGraph,
    registry: &CliqueRegistry,
    batch: &[Edge],
    bitset_cutoff: usize,
) -> (BatchResult, BatchTimings) {
    // graph mutation is the single-threaded step between batches (Fig. 4);
    // publishing then hands every enumeration task the same immutable
    // epoch snapshot — a plain `Arc`, no lifetime-erased graph borrow.
    let added = Arc::new(graph.insert_batch(batch));
    let snap = graph.publish();
    let timings = Mutex::new(BatchTimings::default());

    // --- ParIMCENew (Algorithm 5): one task per new edge ------------------
    let new_cliques: Mutex<Vec<Vec<Vertex>>> = Mutex::new(Vec::new());
    {
        // Tasks borrow `new_cliques` and `timings` — both outlive the
        // scope because `pool.scope` blocks.  The pool API requires
        // 'static, so the borrows are lifetime-erased through the audited
        // ScopeShare/ScopedPtr surface in `util::sync` (the graph itself
        // travels as an owned `Arc<GraphSnapshot>`, no erasure needed).
        //
        // SAFETY: every shared referent lives until after `pool.scope`
        // returns, and the scope joins all tasks holding the pointers.
        #[allow(unsafe_code)]
        let share = unsafe { ScopeShare::new() };
        let shared = SharedBatchCtx {
            graph: Arc::clone(&snap),
            added: Arc::clone(&added),
            new_cliques: share.share(&new_cliques),
            timings: share.share(&timings),
            bitset_cutoff,
        };
        pool.scope(|s| {
            for i in 0..added.len() {
                let ctx = shared.clone();
                s.spawn(move |_| {
                    let graph = ctx.graph.as_ref();
                    let new_cliques = ctx.new_cliques.get();
                    let timings = ctx.timings.get();
                    let (u, v) = ctx.added[i];
                    let t0 = Instant::now();
                    // exclusion set: edges earlier in the batch order
                    let excl = EdgeSet::from_edges(&ctx.added[..i]);
                    let sink = CollectSink::new();
                    let cand = graph.common_neighbors(u, v);
                    let mut k = vec![u.min(v), u.max(v)];
                    ttt_exclude_edges_with_cutoff(
                        graph,
                        &mut k,
                        cand,
                        Vec::new(),
                        &excl,
                        &sink,
                        ctx.bitset_cutoff,
                    );
                    // per-clique sort only; the batch-level set is
                    // canonicalized once after both phases join
                    let found = sink.into_sorted_cliques();
                    let ns = t0.elapsed().as_nanos() as u64;
                    if !found.is_empty() {
                        plock(new_cliques).extend(found);
                    }
                    plock(timings).new_task_ns.push(ns);
                });
            }
        });
    }
    let new_cliques = new_cliques.into_inner().unwrap();

    // --- ParIMCESub (Algorithm 7): one task per new maximal clique --------
    let subsumed: Mutex<Vec<Vec<Vertex>>> = Mutex::new(Vec::new());
    {
        // SAFETY: as above — the referents outlive the joining scope.
        #[allow(unsafe_code)]
        let share = unsafe { ScopeShare::new() };
        let shared = SharedSubCtx {
            registry: share.share(registry),
            added: Arc::clone(&added),
            new_cliques: share.share(new_cliques.as_slice()),
            subsumed: share.share(&subsumed),
            timings: share.share(&timings),
        };
        pool.scope(|s| {
            for ci in 0..new_cliques.len() {
                let ctx = shared.clone();
                s.spawn(move |_| {
                    let registry = ctx.registry.get();
                    let cliques = ctx.new_cliques.get();
                    let subsumed = ctx.subsumed.get();
                    let timings = ctx.timings.get();
                    let t0 = Instant::now();
                    let mut local: Vec<Vec<Vertex>> = Vec::new();
                    for cand in subsumption_candidates(&cliques[ci], &ctx.added) {
                        // concurrent atomic remove: exactly-once reporting;
                        // candidates are canonical, so no re-sort/re-box
                        if registry.remove_canonical(&cand) {
                            local.push(cand.into_vec());
                        }
                    }
                    let ns = t0.elapsed().as_nanos() as u64;
                    if !local.is_empty() {
                        plock(subsumed).extend(local);
                    }
                    plock(timings).sub_task_ns.push(ns);
                });
            }
        });
    }

    for c in &new_cliques {
        registry.insert_canonical(c);
    }

    let mut result = BatchResult {
        new_cliques,
        subsumed: subsumed.into_inner().unwrap(),
    };
    result.canonicalize();
    (result, timings.into_inner().unwrap())
}

/// Scope-shared borrows handed to 'static pool tasks.  `Send` is derived
/// from [`ScopedPtr`]'s audited impls (all pointees are `Sync`); the
/// liveness argument lives at the single `ScopeShare::new` site per scope
/// in [`par_imce_batch_with_cutoff`].
#[derive(Clone)]
struct SharedBatchCtx {
    /// the published epoch snapshot — owned, so no liveness argument needed
    graph: Arc<GraphSnapshot>,
    added: Arc<Vec<Edge>>,
    new_cliques: ScopedPtr<Mutex<Vec<Vec<Vertex>>>>,
    timings: ScopedPtr<Mutex<BatchTimings>>,
    bitset_cutoff: usize,
}

#[derive(Clone)]
struct SharedSubCtx {
    registry: ScopedPtr<CliqueRegistry>,
    added: Arc<Vec<Edge>>,
    new_cliques: ScopedPtr<[Vec<Vertex>]>,
    subsumed: ScopedPtr<Mutex<Vec<Vec<Vertex>>>>,
    timings: ScopedPtr<Mutex<BatchTimings>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::imce_batch;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::mce::oracle;

    /// Parallel and sequential batches must produce identical change sets
    /// and registry states.
    fn check_equivalence(n: usize, initial: &[Edge], batch: &[Edge]) {
        let pool = ThreadPool::new(4);
        let g0 = CsrGraph::from_edges(n, initial);

        let mut g_seq = SnapshotGraph::from_csr(&g0);
        let reg_seq = CliqueRegistry::from_graph(&g0);
        let (r_seq, _) = imce_batch(&mut g_seq, &reg_seq, batch);

        let mut g_par = SnapshotGraph::from_csr(&g0);
        let reg_par = CliqueRegistry::from_graph(&g0);
        let (r_par, _) = par_imce_batch(&pool, &mut g_par, &reg_par, batch);

        assert_eq!(r_seq, r_par, "sequential vs parallel change set");
        assert_eq!(reg_seq.len(), reg_par.len());
        assert_eq!(reg_seq.drain_canonical(), reg_par.drain_canonical());
    }

    #[test]
    fn equivalent_on_figure3() {
        let initial = [(0, 1), (0, 4), (1, 4), (1, 2), (1, 3), (2, 3)];
        check_equivalence(5, &initial, &[(4, 3)]);
    }

    #[test]
    fn equivalent_on_dense_completion() {
        let g = generators::complete_minus_edge(10);
        check_equivalence(10, &g.edges(), &[(0, 1)]);
    }

    #[test]
    fn equivalent_randomized() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 71, iters: 12 },
            |rng, level| {
                let n = 6 + rng.gen_usize(12 >> level.min(2));
                let g = generators::gnp(n, 0.5, rng.next_u64());
                let mut edges = g.edges();
                rng.shuffle(&mut edges);
                let cut = edges.len() * 2 / 3;
                (n, edges, cut)
            },
            |(n, edges, cut)| {
                check_equivalence(*n, &edges[..*cut], &edges[*cut..]);
                Ok(())
            },
        );
    }

    #[test]
    fn parallel_batch_matches_from_scratch() {
        let pool = ThreadPool::new(3);
        let target = generators::planted_cliques(60, 0.05, 4, 5, 8, 21);
        let edges = target.edges();
        let cut = edges.len() / 2;
        let g0 = CsrGraph::from_edges(60, &edges[..cut]);
        let mut graph = SnapshotGraph::from_csr(&g0);
        let registry = CliqueRegistry::from_graph(&g0);
        par_imce_batch(&pool, &mut graph, &registry, &edges[cut..]);
        let after = oracle::maximal_cliques(&graph.to_csr());
        assert_eq!(registry.len(), after.len());
        for c in &after {
            assert!(registry.contains(c));
        }
    }

    #[test]
    fn moon_moser_edge_addition_explodes_change() {
        // §5: adding one edge inside a Moon–Moser part multiplies cliques.
        let pool = ThreadPool::new(2);
        let g0 = generators::moon_moser(3); // 27 maximal cliques
        let mut graph = SnapshotGraph::from_csr(&g0);
        let registry = CliqueRegistry::from_graph(&g0);
        let (r, _) = par_imce_batch(&pool, &mut graph, &registry, &[(0, 1)]);
        // edge inside part {0,1,2}: 9 new cliques {0,1,x,y}; every old
        // clique containing 0 or 1 (9 + 9) is now extendable by the other
        // endpoint, hence subsumed.
        assert_eq!(r.new_cliques.len(), 9);
        assert_eq!(r.subsumed.len(), 18);
        assert_eq!(registry.len(), 27 - 18 + 9);
    }
}
