//! Sequential IMCE (Das–Svendsen–Tirthapura, VLDB 2019) — the baseline the
//! paper's ParIMCE is measured against (Table 6, Figures 8/9).
//!
//! `FastIMCENewClq`: for each new edge eᵢ = (u,v) (in batch order), the new
//! maximal cliques containing eᵢ — and no earlier new edge — are the
//! maximal cliques of the common-neighbourhood subproblem
//! (K = {u,v}, cand = Γ(u) ∩ Γ(v)) enumerated by TTTExcludeEdges with
//! exclusion set {e₁…eᵢ₋₁}.
//!
//! `IMCESubClq`: every subsumed clique is a subset of some new maximal
//! clique c, reachable by removing one endpoint of each new edge of c in
//! all combinations; a candidate that is a *current* maximal clique (i.e.
//! in the registry) is subsumed.

use std::collections::HashSet;
use std::time::Instant;

use crate::dynamic::registry::{canonical, CliqueKey, CliqueRegistry};
use crate::dynamic::ttt_exclude::{ttt_exclude_edges_with_cutoff, EdgeSet};
use crate::dynamic::BatchResult;
use crate::graph::snapshot::SnapshotGraph;
use crate::graph::{Edge, Vertex};
use crate::mce::bitkernel::DEFAULT_BITSET_CUTOFF;
use crate::mce::sink::CollectSink;

/// Phase timings, for the Table 6 / Fig. 8 accounting and the per-phase
/// scheduler simulation (Fig. 9).
#[derive(Clone, Debug, Default)]
pub struct BatchTimings {
    /// per-edge enumeration task durations (FastIMCENewClq inner loop)
    pub new_task_ns: Vec<u64>,
    /// per-new-clique subsumption task durations (IMCESubClq outer loop)
    pub sub_task_ns: Vec<u64>,
}

impl BatchTimings {
    pub fn new_ns(&self) -> u64 {
        self.new_task_ns.iter().sum()
    }

    pub fn sub_ns(&self) -> u64 {
        self.sub_task_ns.iter().sum()
    }

    pub fn total_ns(&self) -> u64 {
        self.new_ns() + self.sub_ns()
    }
}

/// Apply one batch of edge insertions; returns the change set (canonical)
/// and per-task timings. The registry is updated to C(G + H).
pub fn imce_batch(
    graph: &mut SnapshotGraph,
    registry: &CliqueRegistry,
    batch: &[Edge],
) -> (BatchResult, BatchTimings) {
    imce_batch_with_cutoff(graph, registry, batch, DEFAULT_BITSET_CUTOFF)
}

/// As [`imce_batch`] with an explicit bitset hand-off threshold for the
/// TTT-exclude recompute calls (0 = slice-only recursion).
pub fn imce_batch_with_cutoff(
    graph: &mut SnapshotGraph,
    registry: &CliqueRegistry,
    batch: &[Edge],
    bitset_cutoff: usize,
) -> (BatchResult, BatchTimings) {
    // Figure 4 step 1: apply the batch to the shared graph (dedup), then
    // publish the post-batch epoch; enumeration reads the immutable
    // snapshot, never the writer.
    let added = graph.insert_batch(batch);
    let snap = graph.publish();
    let g = snap.as_ref();
    let mut timings = BatchTimings::default();

    // --- FastIMCENewClq ---------------------------------------------------
    let mut new_cliques: Vec<Vec<Vertex>> = Vec::new();
    let mut excl = EdgeSet::new();
    for &(u, v) in &added {
        let t0 = Instant::now();
        let sink = CollectSink::new();
        let cand = g.common_neighbors(u, v);
        let mut k = vec![u.min(v), u.max(v)];
        k.sort_unstable();
        ttt_exclude_edges_with_cutoff(
            g,
            &mut k,
            cand,
            Vec::new(),
            &excl,
            &sink,
            bitset_cutoff,
        );
        // per-clique sort only (subsumption_candidates binary-searches
        // members); the set-level sort happens once in canonicalize()
        new_cliques.extend(sink.into_sorted_cliques());
        excl.insert(u, v);
        timings.new_task_ns.push(t0.elapsed().as_nanos() as u64);
    }

    // --- IMCESubClq --------------------------------------------------------
    let mut subsumed: Vec<Vec<Vertex>> = Vec::new();
    for c in &new_cliques {
        let t0 = Instant::now();
        for cand in subsumption_candidates(c, &added) {
            // candidates are already canonical — skip the sort-and-box
            if registry.remove_canonical(&cand) {
                subsumed.push(cand.into_vec());
            }
        }
        timings.sub_task_ns.push(t0.elapsed().as_nanos() as u64);
    }

    // update C(G): subsumed already removed; add the new cliques
    // (per-clique sorted above, so the canonical fast path applies)
    for c in &new_cliques {
        registry.insert_canonical(c);
    }

    let mut result = BatchResult {
        new_cliques,
        subsumed,
    };
    result.canonicalize();
    (result, timings)
}

/// Candidate subsumed cliques derivable from new maximal clique `c`
/// (Alg. 7 lines 3–12): for each new edge inside c, split every current
/// candidate containing both endpoints into the two endpoint-removals.
/// Candidates are deduplicated; none contains a complete new edge.
pub fn subsumption_candidates(c: &[Vertex], new_edges: &[Edge]) -> Vec<CliqueKey> {
    let members: HashSet<Vertex> = c.iter().copied().collect();
    // E(c) ∩ H — new edges with both endpoints in c (O(ρ) per clique,
    // the min{M², ρ} bound of Lemma 4)
    let inner: Vec<Edge> = new_edges
        .iter()
        .copied()
        .filter(|&(u, v)| members.contains(&u) && members.contains(&v))
        .collect();
    if inner.is_empty() {
        return Vec::new();
    }
    let mut s: HashSet<CliqueKey> = HashSet::new();
    s.insert(canonical(c));
    for &(u, v) in &inner {
        let mut next: HashSet<CliqueKey> = HashSet::with_capacity(s.len() * 2);
        for c_prime in s {
            let has_u = c_prime.binary_search(&u).is_ok();
            let has_v = c_prime.binary_search(&v).is_ok();
            if has_u && has_v {
                let c1: CliqueKey = c_prime
                    .iter()
                    .copied()
                    .filter(|&x| x != u)
                    .collect::<Vec<_>>()
                    .into_boxed_slice();
                let c2: CliqueKey = c_prime
                    .iter()
                    .copied()
                    .filter(|&x| x != v)
                    .collect::<Vec<_>>()
                    .into_boxed_slice();
                next.insert(c1);
                next.insert(c2);
            } else {
                next.insert(c_prime);
            }
        }
        s = next;
    }
    // the original clique c contains its own new edges, so it never
    // survives; all survivors are G-cliques (no complete new edge).
    let mut out: Vec<CliqueKey> = s.into_iter().filter(|k| !k.is_empty()).collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::mce::oracle;

    /// Cross-check: registry after the batch must equal C(G+H) from scratch.
    fn check_batch(n: usize, initial: &[Edge], batch: &[Edge]) -> BatchResult {
        let g0 = CsrGraph::from_edges(n, initial);
        let mut graph = SnapshotGraph::from_csr(&g0);
        let registry = CliqueRegistry::from_graph(&g0);
        let before = oracle::maximal_cliques(&g0);

        let (result, _t) = imce_batch(&mut graph, &registry, batch);

        let after = oracle::maximal_cliques(&graph.to_csr());
        // 1. registry state matches from-scratch enumeration
        assert_eq!(registry.len(), after.len());
        for c in &after {
            assert!(registry.contains(c), "missing {c:?}");
        }
        // 2. new = after \ before, subsumed = before \ after
        let before_set: std::collections::BTreeSet<_> = before.iter().cloned().collect();
        let after_set: std::collections::BTreeSet<_> = after.iter().cloned().collect();
        let want_new: Vec<Vec<Vertex>> =
            after_set.difference(&before_set).cloned().collect();
        let want_sub: Vec<Vec<Vertex>> =
            before_set.difference(&after_set).cloned().collect();
        assert_eq!(result.new_cliques, want_new, "Λnew mismatch");
        assert_eq!(result.subsumed, want_sub, "Λdel mismatch");
        result
    }

    #[test]
    fn paper_figure3_example() {
        // Fig. 3: G has maximal cliques {a,b,e}, {b,c,d}; adding (e,d)
        // creates {b,d,e}. (a=0 b=1 c=2 d=3 e=4)
        let initial = [(0, 1), (0, 4), (1, 4), (1, 2), (1, 3), (2, 3)];
        let r = check_batch(5, &initial, &[(4, 3)]);
        assert_eq!(r.new_cliques, vec![vec![1, 3, 4]]);
        assert!(r.subsumed.is_empty());
    }

    #[test]
    fn paper_figure3_completion() {
        // Fig. 3(c): adding (a,c),(a,d),(c,e) too turns the whole graph
        // into one maximal clique subsuming everything.
        let initial = [(0, 1), (0, 4), (1, 4), (1, 2), (1, 3), (2, 3), (3, 4)];
        let r = check_batch(5, &initial, &[(0, 2), (0, 3), (2, 4)]);
        assert_eq!(r.new_cliques, vec![vec![0, 1, 2, 3, 4]]);
        assert!(!r.subsumed.is_empty());
    }

    #[test]
    fn missing_edge_completion_is_small_change() {
        // §5: K_n minus one edge + that edge = 1 new clique, 2 subsumed.
        let g = generators::complete_minus_edge(8);
        let r = check_batch(8, &g.edges(), &[(0, 1)]);
        assert_eq!(r.new_cliques.len(), 1);
        assert_eq!(r.subsumed.len(), 2);
        assert_eq!(r.change_size(), 3);
    }

    #[test]
    fn duplicate_and_existing_edges_are_noops() {
        let initial = [(0, 1), (1, 2)];
        let g0 = CsrGraph::from_edges(4, &initial);
        let mut graph = SnapshotGraph::from_csr(&g0);
        let registry = CliqueRegistry::from_graph(&g0);
        let (r, _) = imce_batch(&mut graph, &registry, &[(0, 1), (1, 0)]);
        assert_eq!(r.change_size(), 0);
    }

    #[test]
    fn batch_from_empty_graph() {
        // the §6 methodology: start from an edgeless graph, add everything
        let target = generators::gnp(12, 0.5, 3);
        let mut graph = SnapshotGraph::empty(12);
        let registry = CliqueRegistry::new();
        for v in 0..12u32 {
            registry.insert(&[v]); // C(empty graph) = singletons
        }
        let (_, _) = imce_batch(&mut graph, &registry, &target.edges());
        let after = oracle::maximal_cliques(&target);
        assert_eq!(registry.len(), after.len());
    }

    #[test]
    fn randomized_incremental_equals_from_scratch() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 61, iters: 20 },
            |rng, level| {
                let n = 6 + rng.gen_usize(12 >> level.min(2));
                let g = generators::gnp(n, 0.5, rng.next_u64());
                let mut edges = g.edges();
                rng.shuffle(&mut edges);
                let cut = edges.len() / 2;
                (n, edges.clone(), cut)
            },
            |(n, edges, cut)| {
                let initial = &edges[..*cut];
                let batch = &edges[*cut..];
                let g0 = CsrGraph::from_edges(*n, initial);
                let mut graph = SnapshotGraph::from_csr(&g0);
                let registry = CliqueRegistry::from_graph(&g0);
                imce_batch(&mut graph, &registry, batch);
                let after = oracle::maximal_cliques(&graph.to_csr());
                if registry.len() != after.len() {
                    return Err(format!(
                        "registry {} vs from-scratch {}",
                        registry.len(),
                        after.len()
                    ));
                }
                for c in &after {
                    if !registry.contains(c) {
                        return Err(format!("missing {c:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn candidates_never_contain_new_edges() {
        let c: Vec<Vertex> = (0..6).collect();
        let new_edges = [(0, 1), (2, 3)];
        for cand in subsumption_candidates(&c, &new_edges) {
            for &(u, v) in &new_edges {
                assert!(
                    !(cand.binary_search(&u).is_ok() && cand.binary_search(&v).is_ok()),
                    "candidate {cand:?} contains new edge ({u},{v})"
                );
            }
        }
    }

    #[test]
    fn candidate_count_bounded() {
        // k new edges → ≤ 2^k candidates
        let c: Vec<Vertex> = (0..8).collect();
        let new_edges = [(0, 1), (2, 3), (4, 5)];
        let cands = subsumption_candidates(&c, &new_edges);
        assert!(cands.len() <= 8);
        assert!(!cands.is_empty());
    }
}
