//! Batched edge-stream replay — the §6 dynamic-graph methodology:
//! "start with an empty graph that contains all vertices but no edges and
//! add a set of edges in increasing order of timestamps" (batch size 1000,
//! or 10 for the dense Ca-Cit-HepTh), measuring per-batch change sizes and
//! cumulative runtimes (Table 6, Figures 8/9).  Static graphs are converted
//! by randomly permuting their edges (LiveJournal).
//!
//! Also implements the decremental case (§5.3) by reduction: deleted
//! cliques are those containing a removed edge; replacement maximal cliques
//! are recovered from endpoint-removal candidates plus an explicit
//! maximality check.

use crate::coordinator::pool::ThreadPool;
use crate::dynamic::imce::subsumption_candidates;
use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::BatchResult;
use crate::graph::csr::CsrGraph;
use crate::graph::edgelist::TimedEdge;
use crate::graph::snapshot::SnapshotGraph;
use crate::graph::{AdjacencyGraph, Edge, Vertex};
use crate::session::dynamic::{DynAlgo, DynamicSession};
use crate::util::rng::Rng;
use crate::util::vset;

/// An ordered edge stream over a fixed vertex set.
#[derive(Clone, Debug)]
pub struct EdgeStream {
    pub n: usize,
    pub edges: Vec<Edge>,
}

impl EdgeStream {
    /// From a static graph by random edge permutation (the paper's
    /// LiveJournal treatment).
    pub fn permuted(g: &CsrGraph, seed: u64) -> Self {
        let mut edges = g.edges();
        Rng::new(seed).shuffle(&mut edges);
        EdgeStream { n: g.n(), edges }
    }

    /// From timestamped edges (sorted by timestamp, stable).
    pub fn from_timed(mut timed: Vec<TimedEdge>, n: usize) -> Self {
        timed.sort_by_key(|e| e.t);
        EdgeStream {
            n,
            edges: timed.iter().map(|e| (e.u, e.v)).collect(),
        }
    }

    pub fn batches(&self, batch_size: usize) -> impl Iterator<Item = &[Edge]> {
        self.edges.chunks(batch_size.max(1))
    }
}

/// Per-batch record of a replay run.
#[derive(Clone, Debug)]
pub struct BatchRecord {
    pub batch_index: usize,
    pub new_cliques: usize,
    pub subsumed: usize,
    pub ns: u64,
    /// per-task durations for the scheduler simulation (Fig. 9)
    pub new_task_ns: Vec<u64>,
    pub sub_task_ns: Vec<u64>,
}

impl BatchRecord {
    pub fn change_size(&self) -> usize {
        self.new_cliques + self.subsumed
    }
}

/// Which incremental engine a replay uses.
#[derive(Clone, Copy)]
pub enum Engine<'p> {
    Sequential,
    Parallel(&'p ThreadPool),
}

/// Replay `stream` in batches from the empty graph, maintaining C(G).
/// Returns per-batch records; `max_batches` truncates long streams.
/// (Thin compatibility shim over [`DynamicSession::replay`].)
pub fn replay(
    stream: &EdgeStream,
    batch_size: usize,
    engine: Engine<'_>,
    max_batches: Option<usize>,
) -> (Vec<BatchRecord>, SnapshotGraph, CliqueRegistry) {
    let mut session = match engine {
        Engine::Sequential => DynamicSession::from_empty(stream.n, DynAlgo::Imce),
        Engine::Parallel(pool) => {
            DynamicSession::from_empty(stream.n, DynAlgo::ParImce).with_pool(pool.clone())
        }
    };
    let records = session.replay(stream, batch_size, max_batches);
    let (graph, registry) = session.into_parts();
    (records, graph, registry)
}

/// Decremental case (§5.3): remove a batch of edges, maintaining C(G).
pub fn imce_remove_batch(
    graph: &mut SnapshotGraph,
    registry: &CliqueRegistry,
    batch: &[Edge],
) -> BatchResult {
    // apply removals (dedup), then publish the post-batch epoch; the
    // maximality checks below read the immutable snapshot
    let removed = graph.remove_batch(batch);
    let snap = graph.publish();

    // Λdel = old maximal cliques containing ≥1 removed edge: collect by
    // scanning the registry once per removed edge's endpoints' cliques —
    // registry has no per-vertex index, so generate candidates from the
    // graph side instead: a clique is affected iff it contains some (u,v).
    // We drain-and-filter: cheaper structures are possible, but removals
    // are the paper's secondary path (§5.3 defers to [13]).
    let all = registry.drain_canonical();
    let mut deleted: Vec<Vec<Vertex>> = Vec::new();
    for c in all {
        let contains_removed = removed.iter().any(|&(u, v)| {
            c.binary_search(&u).is_ok() && c.binary_search(&v).is_ok()
        });
        if contains_removed {
            deleted.push(c);
        } else {
            // survivors came out of drain_canonical() already sorted
            registry.insert_canonical(&c);
        }
    }

    // Λnew: endpoint-removal candidates of each deleted clique that are
    // (a) cliques of G−H [by construction], (b) maximal in G−H, and
    // (c) not already registered.
    let mut new_cliques: Vec<Vec<Vertex>> = Vec::new();
    for c in &deleted {
        for cand in subsumption_candidates(c, &removed) {
            if cand.is_empty() {
                continue;
            }
            if is_maximal(snap.as_ref(), &cand) && registry.insert_canonical(&cand) {
                new_cliques.push(cand.into_vec());
            }
        }
    }

    let mut result = BatchResult {
        new_cliques,
        subsumed: deleted,
    };
    result.canonicalize();
    result
}

/// Explicit maximality check of a clique in the dynamic graph.
fn is_maximal<G: AdjacencyGraph + ?Sized>(g: &G, clique: &[Vertex]) -> bool {
    let seed = clique
        .iter()
        .copied()
        .min_by_key(|&v| g.degree(v))
        .expect("non-empty clique");
    let mut common: Vec<Vertex> = g.neighbors(seed).to_vec();
    for &u in clique {
        if u != seed {
            common = vset::intersect(&common, g.neighbors(u));
        }
    }
    common.iter().all(|w| clique.binary_search(w).is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::imce_batch;
    use crate::graph::generators;
    use crate::mce::oracle;

    #[test]
    fn replay_reaches_from_scratch_state() {
        let g = generators::gnp(30, 0.25, 7);
        let stream = EdgeStream::permuted(&g, 42);
        let (records, graph, registry) = replay(&stream, 10, Engine::Sequential, None);
        assert!(!records.is_empty());
        let want = oracle::maximal_cliques(&graph.to_csr());
        assert_eq!(registry.len(), want.len());
        // final graph is the original graph
        assert_eq!(graph.m(), g.m());
    }

    #[test]
    fn parallel_replay_equals_sequential() {
        let g = generators::planted_cliques(40, 0.05, 3, 5, 7, 5);
        let stream = EdgeStream::permuted(&g, 9);
        let (seq, _, reg_s) = replay(&stream, 25, Engine::Sequential, None);
        let pool = ThreadPool::new(3);
        let (par, _, reg_p) = replay(&stream, 25, Engine::Parallel(&pool), None);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.new_cliques, b.new_cliques, "batch {}", a.batch_index);
            assert_eq!(a.subsumed, b.subsumed, "batch {}", a.batch_index);
        }
        assert_eq!(reg_s.drain_canonical(), reg_p.drain_canonical());
    }

    #[test]
    fn final_partial_batch_is_yielded() {
        // 23 edges in batches of 5 → 4 full batches + one of 3; the
        // iterator must not drop the remainder
        let edges: Vec<Edge> = (0..23).map(|i| (i, i + 1)).collect();
        let s = EdgeStream { n: 24, edges };
        let sizes: Vec<usize> = s.batches(5).map(<[Edge]>::len).collect();
        assert_eq!(sizes, vec![5, 5, 5, 5, 3]);
        assert_eq!(sizes.iter().sum::<usize>(), 23);
    }

    #[test]
    fn replay_with_non_dividing_batch_size_preserves_clique_counts() {
        // regression: if the final partial batch were dropped, the replayed
        // registry would diverge from the from-scratch enumeration
        let g = generators::gnp(22, 0.3, 13);
        let mut stream = EdgeStream::permuted(&g, 5);
        let batch = 7;
        if stream.edges.len() % batch == 0 {
            stream.edges.pop(); // force a trailing partial batch
        }
        let (records, graph, registry) = replay(&stream, batch, Engine::Sequential, None);
        assert_eq!(records.len(), stream.edges.len().div_ceil(batch));
        assert_eq!(
            graph.m(),
            stream.edges.len(),
            "every streamed edge must have been applied"
        );
        let want = oracle::maximal_cliques(&graph.to_csr());
        assert_eq!(registry.len(), want.len());
        assert_eq!(registry.drain_canonical(), want);
    }

    #[test]
    fn max_batches_truncates() {
        let g = generators::gnp(20, 0.3, 1);
        let stream = EdgeStream::permuted(&g, 2);
        let (records, _, _) = replay(&stream, 5, Engine::Sequential, Some(3));
        assert_eq!(records.len(), 3);
    }

    #[test]
    fn timed_stream_ordering() {
        let timed = vec![
            TimedEdge { u: 0, v: 1, t: 30 },
            TimedEdge { u: 1, v: 2, t: 10 },
            TimedEdge { u: 2, v: 3, t: 20 },
        ];
        let s = EdgeStream::from_timed(timed, 4);
        assert_eq!(s.edges, vec![(1, 2), (2, 3), (0, 1)]);
    }

    #[test]
    fn removal_restores_from_scratch_state() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 81, iters: 15 },
            |rng, level| {
                let n = 6 + rng.gen_usize(12 >> level.min(2));
                let g = generators::gnp(n, 0.5, rng.next_u64());
                let mut edges = g.edges();
                rng.shuffle(&mut edges);
                let k = 1 + rng.gen_usize(edges.len().max(2) - 1);
                (n, edges, k)
            },
            |(n, edges, k)| {
                let g = CsrGraph::from_edges(*n, edges);
                let mut graph = SnapshotGraph::from_csr(&g);
                let registry = CliqueRegistry::from_graph(&g);
                imce_remove_batch(&mut graph, &registry, &edges[..*k]);
                let want = oracle::maximal_cliques(&graph.to_csr());
                if registry.len() != want.len() {
                    return Err(format!(
                        "registry {} vs scratch {} after removing {k} edges",
                        registry.len(),
                        want.len()
                    ));
                }
                for c in &want {
                    if !registry.contains(c) {
                        return Err(format!("missing {c:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn remove_then_add_roundtrip() {
        let g = generators::complete(6);
        let mut graph = SnapshotGraph::from_csr(&g);
        let registry = CliqueRegistry::from_graph(&g);
        assert_eq!(registry.len(), 1);
        let r = imce_remove_batch(&mut graph, &registry, &[(0, 1)]);
        assert_eq!(r.subsumed.len(), 1);
        assert_eq!(r.new_cliques.len(), 2); // K6\{0}, K6\{1}
        // add it back
        let (r2, _) = imce_batch(&mut graph, &registry, &[(0, 1)]);
        assert_eq!(r2.new_cliques, vec![(0..6).collect::<Vec<_>>()]);
        assert_eq!(registry.len(), 1);
    }
}
