//! Concurrent registry of the current maximal clique set C(G).
//!
//! Cliques are stored in canonical form (sorted vertex list) inside the
//! sharded concurrent set (`util::chashmap`), standing in for the TBB
//! `concurrent_hash_map` the paper uses.  ParIMCESub's candidacy check
//! (Alg. 7 line 14) and removal (line 16) are single concurrent calls, so
//! a clique subsumed via several new cliques is reported exactly once.

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::sink::{CallbackSink, CliqueSink};
use crate::mce::ttt;
use crate::util::chashmap::ConcurrentSet;
use std::sync::Mutex;

/// Canonical clique key: sorted, boxed.
pub type CliqueKey = Box<[Vertex]>;

pub fn canonical(clique: &[Vertex]) -> CliqueKey {
    let mut v = clique.to_vec();
    v.sort_unstable();
    v.into_boxed_slice()
}

#[derive(Default)]
pub struct CliqueRegistry {
    set: ConcurrentSet<CliqueKey>,
}

impl CliqueRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bootstrap from a static graph: C(G) via sequential TTT.
    pub fn from_graph(g: &CsrGraph) -> Self {
        let reg = CliqueRegistry::new();
        let sink = CallbackSink::new(|c: &[Vertex]| {
            reg.insert(c);
        });
        ttt::ttt(g, &sink);
        drop(sink);
        reg
    }

    /// Insert (canonicalized); true if newly added.
    pub fn insert(&self, clique: &[Vertex]) -> bool {
        self.set.insert(canonical(clique))
    }

    /// Remove; true if it was present (at most one caller wins).
    pub fn remove(&self, clique: &[Vertex]) -> bool {
        self.set.remove(&canonical(clique))
    }

    pub fn contains(&self, clique: &[Vertex]) -> bool {
        self.set.contains(&canonical(clique))
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Snapshot as canonical sorted list (drains the registry).
    pub fn drain_canonical(&self) -> Vec<Vec<Vertex>> {
        let mut all: Vec<Vec<Vertex>> = self
            .set
            .drain_all()
            .into_iter()
            .map(|k| k.into_vec())
            .collect();
        all.sort();
        all
    }
}

/// A sink that records cliques into a mutex'd vector AND the registry —
/// used when bootstrapping while also wanting the list.
pub struct RegistryCollectSink<'a> {
    pub registry: &'a CliqueRegistry,
    pub collected: Mutex<Vec<Vec<Vertex>>>,
}

impl CliqueSink for RegistryCollectSink<'_> {
    fn emit(&self, clique: &[Vertex]) {
        self.registry.insert(clique);
        self.collected.lock().unwrap().push(clique.to_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn canonicalization_is_order_insensitive() {
        let r = CliqueRegistry::new();
        assert!(r.insert(&[3, 1, 2]));
        assert!(!r.insert(&[1, 2, 3]), "same clique, different order");
        assert!(r.contains(&[2, 3, 1]));
        assert!(r.remove(&[1, 3, 2]));
        assert!(!r.remove(&[1, 2, 3]), "second remove loses");
    }

    #[test]
    fn from_graph_matches_oracle() {
        let g = generators::gnp(20, 0.4, 3);
        let reg = CliqueRegistry::from_graph(&g);
        let want = crate::mce::oracle::maximal_cliques(&g);
        assert_eq!(reg.len(), want.len());
        for c in &want {
            assert!(reg.contains(c));
        }
        assert_eq!(reg.drain_canonical(), want);
        assert!(reg.is_empty());
    }

    #[test]
    fn concurrent_removal_single_winner() {
        let reg = std::sync::Arc::new(CliqueRegistry::new());
        reg.insert(&[1, 2, 3]);
        let wins = std::sync::Arc::new(std::sync::atomic::AtomicU32::new(0));
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let reg = reg.clone();
                let wins = wins.clone();
                std::thread::spawn(move || {
                    if reg.remove(&[1, 2, 3]) {
                        wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(wins.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
