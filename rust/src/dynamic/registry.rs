//! Concurrent registry of the current maximal clique set C(G).
//!
//! Cliques are stored in canonical form (sorted vertex list) inside the
//! sharded concurrent set (`util::chashmap`), standing in for the TBB
//! `concurrent_hash_map` the paper uses.  ParIMCESub's candidacy check
//! (Alg. 7 line 14) and removal (line 16) are single concurrent calls, so
//! a clique subsumed via several new cliques is reported exactly once.
//!
//! The `*_canonical` variants skip the per-call sort-and-box when the
//! caller already holds a canonical (sorted) clique — the IMCE/ParIMCE
//! hot paths only ever touch canonical data, so they never pay
//! [`canonical`] twice.

use crate::coordinator::pool::ThreadPool;
use crate::util::sync::{plock, Arc, Mutex};

use crate::graph::{AdjacencyGraph, Vertex};
use crate::mce::sink::{CallbackSink, CliqueSink};
use crate::mce::{parttt, ttt, ParTttConfig};
use crate::util::chashmap::ConcurrentSet;

/// Canonical clique key: sorted, boxed.
pub type CliqueKey = Box<[Vertex]>;

pub fn canonical(clique: &[Vertex]) -> CliqueKey {
    let mut v = clique.to_vec();
    v.sort_unstable();
    v.into_boxed_slice()
}

#[inline]
fn debug_assert_canonical(clique: &[Vertex]) {
    debug_assert!(
        clique.windows(2).all(|w| w[0] < w[1]),
        "clique {clique:?} is not canonical (sorted, deduped)"
    );
}

#[derive(Default)]
pub struct CliqueRegistry {
    set: ConcurrentSet<CliqueKey>,
}

impl CliqueRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bootstrap from a static graph: C(G) via sequential TTT.  Generic
    /// over the adjacency source, so it runs on a `CsrGraph` or directly
    /// on a published [`crate::graph::snapshot::GraphSnapshot`].
    pub fn from_graph<G: AdjacencyGraph + ?Sized>(g: &G) -> Self {
        let reg = CliqueRegistry::new();
        if g.n() == 0 {
            return reg;
        }
        let sink = CallbackSink::new(|c: &[Vertex]| {
            reg.insert(c);
        });
        let cand: Vec<Vertex> = (0..g.n() as Vertex).collect();
        let mut k = Vec::new();
        ttt::ttt_from(g, &mut k, cand, Vec::new(), &sink);
        drop(sink);
        reg
    }

    /// Bootstrap from a static graph in parallel: C(G) via ParTTT on
    /// `pool`, every worker inserting straight into the sharded set —
    /// the concurrent registry *is* the sharded sink, so no merge step.
    /// Takes the graph by `Arc` (ParTTT's 'static task bound) so callers
    /// that already hold one — e.g. a published snapshot — share it with
    /// zero adjacency copies.
    pub fn from_graph_parallel<G: AdjacencyGraph + Send + Sync + 'static>(
        g: &Arc<G>,
        pool: &ThreadPool,
    ) -> Self {
        let reg = Arc::new(CliqueRegistry::new());
        let sink: Arc<dyn CliqueSink> = Arc::new(RegistrySink(Arc::clone(&reg)));
        parttt::parttt(pool, g, &sink, ParTttConfig::default());
        drop(sink);
        Arc::try_unwrap(reg).ok().expect("bootstrap tasks joined; sink dropped")
    }

    /// Insert (canonicalized); true if newly added.
    pub fn insert(&self, clique: &[Vertex]) -> bool {
        self.set.insert(canonical(clique))
    }

    /// Remove; true if it was present (at most one caller wins).
    pub fn remove(&self, clique: &[Vertex]) -> bool {
        self.set.remove(&canonical(clique))
    }

    pub fn contains(&self, clique: &[Vertex]) -> bool {
        self.set.contains(&canonical(clique))
    }

    /// [`insert`](Self::insert) for a clique the caller guarantees is
    /// already canonical — one boxed copy, no sort.
    pub fn insert_canonical(&self, clique: &[Vertex]) -> bool {
        debug_assert_canonical(clique);
        self.set.insert(clique.to_vec().into_boxed_slice())
    }

    /// [`insert_canonical`](Self::insert_canonical) taking ownership of a
    /// prebuilt key — no copy at all.
    pub fn insert_canonical_key(&self, key: CliqueKey) -> bool {
        debug_assert_canonical(&key);
        self.set.insert(key)
    }

    /// [`remove`](Self::remove) for a canonical clique — no sort, no
    /// allocation (borrowed-slice lookup into the sharded set).
    pub fn remove_canonical(&self, clique: &[Vertex]) -> bool {
        debug_assert_canonical(clique);
        self.set.remove_borrowed::<[Vertex]>(clique)
    }

    /// [`contains`](Self::contains) for a canonical clique — no sort, no
    /// allocation.
    pub fn contains_canonical(&self, clique: &[Vertex]) -> bool {
        debug_assert_canonical(clique);
        self.set.contains_borrowed::<[Vertex]>(clique)
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Apply `f` to every registered clique under shard locks, without
    /// draining — the bootstrap path for snapshot/index rebuilds
    /// ([`crate::service`]).
    pub fn for_each(&self, mut f: impl FnMut(&[Vertex])) {
        self.set.for_each(|k| f(k));
    }

    /// Snapshot as canonical sorted list (drains the registry).
    pub fn drain_canonical(&self) -> Vec<Vec<Vertex>> {
        let mut all: Vec<Vec<Vertex>> = self
            .set
            .drain_all()
            .into_iter()
            .map(|k| k.into_vec())
            .collect();
        all.sort();
        all
    }
}

/// Owning sink adapter: every emitted clique lands in the registry.
/// Used by the parallel bootstrap, whose pool tasks need `'static`.
struct RegistrySink(Arc<CliqueRegistry>);

impl CliqueSink for RegistrySink {
    fn emit(&self, clique: &[Vertex]) {
        self.0.insert(clique);
    }
}

/// A sink that records cliques into a mutex'd vector AND the registry —
/// used when bootstrapping while also wanting the list.
pub struct RegistryCollectSink<'a> {
    pub registry: &'a CliqueRegistry,
    pub collected: Mutex<Vec<Vec<Vertex>>>,
}

impl CliqueSink for RegistryCollectSink<'_> {
    fn emit(&self, clique: &[Vertex]) {
        self.registry.insert(clique);
        plock(&self.collected).push(clique.to_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn canonicalization_is_order_insensitive() {
        let r = CliqueRegistry::new();
        assert!(r.insert(&[3, 1, 2]));
        assert!(!r.insert(&[1, 2, 3]), "same clique, different order");
        assert!(r.contains(&[2, 3, 1]));
        assert!(r.remove(&[1, 3, 2]));
        assert!(!r.remove(&[1, 2, 3]), "second remove loses");
    }

    #[test]
    fn canonical_variants_agree_with_sorting_ones() {
        let r = CliqueRegistry::new();
        assert!(r.insert_canonical(&[1, 2, 3]));
        assert!(!r.insert(&[3, 2, 1]), "same clique through the sort path");
        assert!(r.contains_canonical(&[1, 2, 3]));
        assert!(!r.contains_canonical(&[1, 2]));
        assert!(r.remove_canonical(&[1, 2, 3]));
        assert!(!r.remove_canonical(&[1, 2, 3]));
        assert!(r.insert_canonical_key(canonical(&[5, 4])));
        assert!(r.contains(&[4, 5]));
    }

    #[test]
    fn from_graph_matches_oracle() {
        let g = generators::gnp(20, 0.4, 3);
        let reg = CliqueRegistry::from_graph(&g);
        let want = crate::mce::oracle::maximal_cliques(&g);
        assert_eq!(reg.len(), want.len());
        for c in &want {
            assert!(reg.contains(c));
        }
        assert_eq!(reg.drain_canonical(), want);
        assert!(reg.is_empty());
    }

    #[test]
    fn parallel_bootstrap_matches_sequential() {
        let g = Arc::new(generators::planted_cliques(40, 0.08, 3, 4, 6, 11));
        let pool = ThreadPool::new(3);
        let par = CliqueRegistry::from_graph_parallel(&g, &pool);
        let seq = CliqueRegistry::from_graph(g.as_ref());
        assert_eq!(par.len(), seq.len());
        assert_eq!(par.drain_canonical(), seq.drain_canonical());
    }

    #[test]
    fn for_each_is_non_draining() {
        let g = generators::gnp(12, 0.5, 9);
        let reg = CliqueRegistry::from_graph(&g);
        let mut seen = Vec::new();
        reg.for_each(|c| seen.push(c.to_vec()));
        seen.sort();
        assert_eq!(seen.len(), reg.len(), "registry must survive iteration");
        assert_eq!(seen, reg.drain_canonical());
    }

    #[test]
    fn concurrent_removal_single_winner() {
        use crate::util::sync::atomic::{AtomicU32, Ordering};
        let reg = Arc::new(CliqueRegistry::new());
        reg.insert(&[1, 2, 3]);
        let wins = Arc::new(AtomicU32::new(0));
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let reg = reg.clone();
                let wins = wins.clone();
                std::thread::spawn(move || {
                    if reg.remove(&[1, 2, 3]) {
                        wins.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(wins.load(Ordering::SeqCst), 1);
    }
}
