//! Dynamic (incremental) maximal clique maintenance — paper §5.
//!
//! When a batch H of edges is added to G, the set of maximal cliques
//! changes by Λⁿᵉʷ = C(G+H) \ C(G) (new cliques) and Λᵈᵉˡ = C(G) \ C(G+H)
//! (subsumed cliques).  [`imce`] is the sequential baseline (Das–Svendsen–
//! Tirthapura, VLDB 2019: FastIMCENewClq + IMCESubClq); [`par_imce`] is the
//! paper's parallel version (Algorithms 5–7).  [`registry`] maintains C(G)
//! in a concurrent canonical-form set; [`stream`] replays timestamped or
//! permuted edge streams in batches (the §6 methodology).

pub mod imce;
pub mod par_imce;
pub mod registry;
pub mod stream;
pub mod ttt_exclude;

pub use imce::{imce_batch, imce_batch_with_cutoff};
pub use par_imce::{par_imce_batch, par_imce_batch_with_cutoff};
pub use registry::CliqueRegistry;

/// The change set produced by one batch, canonical form
/// (each clique sorted; lists sorted) so algorithm variants compare equal.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BatchResult {
    pub new_cliques: Vec<Vec<crate::graph::Vertex>>,
    pub subsumed: Vec<Vec<crate::graph::Vertex>>,
}

impl BatchResult {
    /// |Λⁿᵉʷ| + |Λᵈᵉˡ| — the paper's "size of change" (Fig. 8 x-axis).
    pub fn change_size(&self) -> usize {
        self.new_cliques.len() + self.subsumed.len()
    }

    pub fn canonicalize(&mut self) {
        for c in self.new_cliques.iter_mut() {
            c.sort_unstable();
        }
        for c in self.subsumed.iter_mut() {
            c.sort_unstable();
        }
        self.new_cliques.sort();
        self.subsumed.sort();
    }
}
