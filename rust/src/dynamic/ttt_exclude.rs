//! TTTExcludeEdges (paper Algorithm 8) and its unrolled parallel-ready
//! sibling ParTTTExcludeEdges (Algorithm 6).
//!
//! Identical to TTT except that any branch whose clique K∪{q} would
//! contain an edge from the exclusion set E is pruned.  ParIMCENew gives
//! edge eᵢ the exclusion set {e₁…eᵢ₋₁}, so every new maximal clique is
//! enumerated exactly once — at the *first* new edge (in the batch order)
//! it contains.

use std::collections::HashSet;

use crate::graph::{norm_edge, AdjacencyGraph, Edge, Vertex};
use crate::mce::bitkernel::{self, DEFAULT_BITSET_CUTOFF};
use crate::mce::pivot::choose_pivot;
use crate::mce::sink::CliqueSink;
use crate::util::vset;

/// Exclusion set with O(1) membership; the "two global hashtables" of the
/// paper's Appendix A are folded into one normalized-edge hash set.
#[derive(Clone, Debug, Default)]
pub struct EdgeSet {
    set: HashSet<Edge>,
}

impl EdgeSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_edges(edges: &[Edge]) -> Self {
        let mut s = Self::new();
        for &(u, v) in edges {
            s.insert(u, v);
        }
        s
    }

    pub fn insert(&mut self, u: Vertex, v: Vertex) -> bool {
        match norm_edge(u, v) {
            Some(e) => self.set.insert(e),
            None => false,
        }
    }

    #[inline]
    pub fn contains(&self, u: Vertex, v: Vertex) -> bool {
        match norm_edge(u, v) {
            Some(e) => self.set.contains(&e),
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Iterate the normalized excluded edges (arbitrary order) — the
    /// bit kernel walks these once per hand-off to build its local
    /// exclusion rows.
    pub fn iter(&self) -> impl Iterator<Item = Edge> + '_ {
        self.set.iter().copied()
    }

    /// Does clique `k` plus vertex `q` close an excluded edge?
    /// (K itself is invariantly exclusion-free, so only q×K pairs matter —
    /// the O(n)-work check of Appendix A.)
    #[inline]
    pub fn closes_excluded(&self, k: &[Vertex], q: Vertex) -> bool {
        if self.set.is_empty() {
            return false;
        }
        k.iter().any(|&w| self.contains(w, q))
    }
}

/// Enumerate all maximal cliques of `g` containing `k`, extendable by
/// `cand`, excluding vertices of `fini`, and *pruning* any branch whose
/// clique would contain an edge of `excl` (Algorithm 8 semantics).
pub fn ttt_exclude_edges<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    excl: &EdgeSet,
    sink: &dyn CliqueSink,
) {
    ttt_exclude_edges_with_cutoff(g, k, cand, fini, excl, sink, DEFAULT_BITSET_CUTOFF)
}

/// As [`ttt_exclude_edges`] with an explicit bitset hand-off threshold
/// (0 = slice-only): working sets at or below it finish in the dense
/// kernel's exclusion-aware recursion.
pub fn ttt_exclude_edges_with_cutoff<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    fini: Vec<Vertex>,
    excl: &EdgeSet,
    sink: &dyn CliqueSink,
    bitset_cutoff: usize,
) {
    rec(g, k, cand, fini, excl, sink, bitset_cutoff);
}

fn rec<G: AdjacencyGraph + ?Sized>(
    g: &G,
    k: &mut Vec<Vertex>,
    mut cand: Vec<Vertex>,
    mut fini: Vec<Vertex>,
    excl: &EdgeSet,
    sink: &dyn CliqueSink,
    bitset_cutoff: usize,
) {
    if bitset_cutoff > 0 && cand.len() + fini.len() <= bitset_cutoff {
        bitkernel::enumerate_subproblem_excl(g, k, &cand, &fini, excl, sink);
        return;
    }
    if cand.is_empty() {
        if fini.is_empty() {
            sink.emit(k);
        }
        return;
    }
    let pivot = choose_pivot(g, &cand, &fini);
    let ext = vset::difference(&cand, g.neighbors(pivot));
    let mut cand_q = Vec::new();
    let mut fini_q = Vec::new();
    for q in ext {
        // Alg. 8 lines 7–10: skip the branch, but q still migrates
        // cand → fini so sibling branches treat it as explored.
        if excl.closes_excluded(k, q) {
            vset::remove_sorted(&mut cand, q);
            vset::insert_sorted(&mut fini, q);
            continue;
        }
        let nbrs = g.neighbors(q);
        vset::intersect_into(&cand, nbrs, &mut cand_q);
        vset::intersect_into(&fini, nbrs, &mut fini_q);
        k.push(q);
        rec(
            g,
            k,
            std::mem::take(&mut cand_q),
            std::mem::take(&mut fini_q),
            excl,
            sink,
            bitset_cutoff,
        );
        k.pop();
        vset::remove_sorted(&mut cand, q);
        vset::insert_sorted(&mut fini, q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::mce::sink::CollectSink;

    fn run(
        g: &CsrGraph,
        k0: Vec<Vertex>,
        cand: Vec<Vertex>,
        fini: Vec<Vertex>,
        excl: &EdgeSet,
    ) -> Vec<Vec<Vertex>> {
        let sink = CollectSink::new();
        let mut k = k0;
        ttt_exclude_edges(g, &mut k, cand, fini, excl, &sink);
        sink.into_canonical()
    }

    #[test]
    fn empty_exclusion_equals_ttt() {
        let g = generators::gnp(18, 0.45, 5);
        let all: Vec<Vertex> = (0..18).collect();
        let got = run(&g, vec![], all, vec![], &EdgeSet::new());
        assert_eq!(got, crate::mce::oracle::maximal_cliques(&g));
    }

    #[test]
    fn excluded_edge_prunes_cliques_containing_it() {
        // K4 on {0,1,2,3}; excluding edge (0,1) leaves no maximal clique
        // containing both 0 and 1.
        let g = generators::complete(4);
        let excl = EdgeSet::from_edges(&[(0, 1)]);
        let got = run(&g, vec![], (0..4).collect(), vec![], &excl);
        for c in &got {
            assert!(
                !(c.contains(&0) && c.contains(&1)),
                "clique {c:?} contains the excluded edge"
            );
        }
    }

    #[test]
    fn bitset_cutoff_values_agree_under_exclusion() {
        let g = generators::gnp(16, 0.5, 9);
        let edges = g.edges();
        let excl = EdgeSet::from_edges(&edges[..4.min(edges.len())]);
        let all: Vec<Vertex> = (0..16).collect();
        let run_at = |cutoff: usize| {
            let sink = CollectSink::new();
            let mut k = Vec::new();
            ttt_exclude_edges_with_cutoff(
                &g,
                &mut k,
                all.clone(),
                Vec::new(),
                &excl,
                &sink,
                cutoff,
            );
            sink.into_canonical()
        };
        let want = run_at(0);
        for cutoff in [4, 64, usize::MAX] {
            assert_eq!(run_at(cutoff), want, "cutoff {cutoff}");
        }
    }

    #[test]
    fn edge_set_membership() {
        let mut s = EdgeSet::new();
        assert!(s.insert(5, 2));
        assert!(!s.insert(2, 5), "normalized duplicate");
        assert!(!s.insert(3, 3), "self-loop rejected");
        assert!(s.contains(2, 5) && s.contains(5, 2));
        assert!(!s.contains(2, 4));
        assert!(s.closes_excluded(&[7, 2], 5));
        assert!(!s.closes_excluded(&[7, 3], 5));
    }

    #[test]
    fn exclusion_partition_covers_all_cliques_once() {
        // Enumerating "cliques containing e_i but none of e_1..e_{i-1}"
        // over ALL edges partitions the set of maximal cliques (with ≥1
        // edge). This is the heart of ParIMCENew's no-duplication claim.
        let g = generators::gnp(14, 0.5, 8);
        let edges = g.edges();
        let mut seen = std::collections::BTreeSet::new();
        for (i, &(u, v)) in edges.iter().enumerate() {
            let excl = EdgeSet::from_edges(&edges[..i]);
            let cand = crate::util::vset::intersect(g.neighbors(u), g.neighbors(v));
            let got = run(&g, vec![u, v], cand, vec![], &excl);
            for mut c in got {
                c.sort_unstable();
                assert!(seen.insert(c.clone()), "clique {c:?} enumerated twice");
            }
        }
        let oracle: Vec<Vec<Vertex>> = crate::mce::oracle::maximal_cliques(&g)
            .into_iter()
            .filter(|c| c.len() >= 2)
            .collect();
        assert_eq!(seen.len(), oracle.len());
    }
}
