//! GP (Wang et al., JPDC 2017) — distributed MPI vertex-partitioned MCE,
//! reproduced as a deterministic simulation (Table 9).
//!
//! GP assigns each vertex's subproblem to an MPI worker; overloaded
//! workers ship subproblems to *randomly chosen* receivers, paying a
//! serialization cost proportional to the subproblem's subgraph size.
//! §6.4 observes the exchange overhead is "huge and skewed towards a few
//! MPI nodes".  We simulate exactly that cost model on measured
//! subproblem durations: round-robin initial placement, random
//! rebalancing of a worker's excess, a per-byte transfer charge, and
//! per-worker memory ceilings (GP's Table 9 "ran out of memory" cells).

use crate::coordinator::stats::Subproblem;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct GpConfig {
    /// simulated ns to ship one byte of subproblem payload between nodes
    pub ns_per_byte: f64,
    /// a worker ships subproblems while its queue exceeds this multiple of
    /// the mean load
    pub imbalance_threshold: f64,
    /// per-node memory (bytes) for buffered incoming subproblems;
    /// exceeded ⇒ the run "runs out of memory" (× cells of Table 9)
    pub node_mem_bytes: usize,
    pub seed: u64,
}

impl Default for GpConfig {
    fn default() -> Self {
        GpConfig {
            // MPI eager-message path on a cluster NIC, ~1 GB/s effective
            ns_per_byte: 1.0,
            imbalance_threshold: 1.5,
            node_mem_bytes: 64 << 20,
            seed: 0x6997,
        }
    }
}

#[derive(Clone, Debug)]
pub enum GpOutcome {
    /// simulated makespan in ns (max node busy time incl. transfer costs)
    Finished { makespan_ns: u64, bytes_shipped: u64 },
    /// a node's receive buffer exceeded its memory ceiling
    OutOfMemory { node: usize },
}

/// Simulate GP on `workers` MPI nodes given measured per-vertex
/// subproblems (from `mce::parmce::subproblems_timed`).
pub fn simulate_gp(
    g: &CsrGraph,
    subs: &[Subproblem],
    workers: usize,
    cfg: GpConfig,
) -> GpOutcome {
    assert!(workers >= 1);
    let mut rng = Rng::new(cfg.seed);

    // payload size of shipping v's subproblem: its induced subgraph edges
    let payload = |v: Vertex| -> u64 {
        let d = g.degree(v) as u64;
        8 * d * d.min(64) + 64 // adjacency lists + message header
    };

    // initial placement: round-robin over vertex ids (GP's static hash)
    let mut queues: Vec<Vec<usize>> = vec![Vec::new(); workers];
    for (i, s) in subs.iter().enumerate() {
        queues[s.vertex as usize % workers].push(i);
    }

    let total_ns: u64 = subs.iter().map(|s| s.ns).sum();
    let mean_load = total_ns as f64 / workers as f64;

    // rebalancing pass: overloaded nodes ship their *smallest* subproblems
    // to random receivers (the random choice is GP's; the skew this causes
    // is what §6.4 measured)
    let mut busy: Vec<f64> = queues
        .iter()
        .map(|q| q.iter().map(|&i| subs[i].ns as f64).sum())
        .collect();
    let mut recv_bytes: Vec<u64> = vec![0; workers];
    let mut bytes_shipped = 0u64;
    for w in 0..workers {
        while busy[w] > cfg.imbalance_threshold * mean_load {
            // ship the smallest task (GP ships work units, not the hog —
            // it cannot split a subproblem, which is its core limitation)
            let Some(pos) = queues[w]
                .iter()
                .enumerate()
                .min_by_key(|(_, &i)| subs[i].ns)
                .map(|(p, _)| p)
            else {
                break;
            };
            let task = queues[w].remove(pos);
            let dst = rng.gen_usize(workers);
            let bytes = payload(subs[task].vertex);
            recv_bytes[dst] += bytes;
            bytes_shipped += bytes;
            if recv_bytes[dst] as usize > cfg.node_mem_bytes {
                return GpOutcome::OutOfMemory { node: dst };
            }
            let cost = bytes as f64 * cfg.ns_per_byte;
            busy[w] -= subs[task].ns as f64;
            busy[w] += cost; // sender pays serialization
            busy[dst] += subs[task].ns as f64 + cost; // receiver pays too
            queues[dst].push(task);
            if busy[w] <= 0.0 {
                break;
            }
        }
    }

    let makespan = busy.iter().cloned().fold(0.0f64, f64::max);
    GpOutcome::Finished {
        makespan_ns: makespan as u64,
        bytes_shipped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::parmce::subproblems_timed;
    use crate::mce::ranking::{RankStrategy, Ranking};

    fn measured(g: &CsrGraph) -> Vec<Subproblem> {
        let ranking = Ranking::compute(g, RankStrategy::Id);
        subproblems_timed(g, &ranking)
    }

    #[test]
    fn single_worker_makespan_is_total_work() {
        let g = generators::gnp(60, 0.2, 5);
        let subs = measured(&g);
        let total: u64 = subs.iter().map(|s| s.ns).sum();
        match simulate_gp(&g, &subs, 1, GpConfig::default()) {
            GpOutcome::Finished { makespan_ns, .. } => {
                assert_eq!(makespan_ns, total);
            }
            _ => panic!("should finish"),
        }
    }

    #[test]
    fn more_workers_not_slower_without_transfer_cost() {
        let g = generators::planted_cliques(120, 0.03, 4, 6, 9, 8);
        let subs = measured(&g);
        let cfg = GpConfig {
            ns_per_byte: 0.0,
            ..Default::default()
        };
        let at = |w: usize| match simulate_gp(&g, &subs, w, cfg) {
            GpOutcome::Finished { makespan_ns, .. } => makespan_ns,
            _ => panic!(),
        };
        assert!(at(8) <= at(1));
    }

    #[test]
    fn tiny_memory_ceiling_ooms() {
        let g = generators::planted_cliques(150, 0.05, 6, 8, 12, 4);
        let subs = measured(&g);
        let cfg = GpConfig {
            node_mem_bytes: 16, // absurd ceiling: first shipped task trips
            imbalance_threshold: 0.0001,
            ..Default::default()
        };
        match simulate_gp(&g, &subs, 8, cfg) {
            GpOutcome::OutOfMemory { .. } => {}
            GpOutcome::Finished { bytes_shipped, .. } => {
                assert_eq!(bytes_shipped, 0, "no shipping happened — imbalance never triggered?");
            }
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let g = generators::gnp(80, 0.15, 2);
        let subs = measured(&g);
        let a = format!("{:?}", simulate_gp(&g, &subs, 4, GpConfig::default()));
        let b = format!("{:?}", simulate_gp(&g, &subs, 4, GpConfig::default()));
        assert_eq!(a, b);
    }
}
