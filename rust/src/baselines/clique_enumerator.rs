//! CliqueEnumerator (Zhang et al., SC 2005; Kose et al. 2001 style) —
//! iterative clique-metabolite expansion with per-clique bit vectors.
//!
//! Each round-k clique carries an n-bit vector of vertices that can extend
//! it; round k+1 intersects bit vectors.  §6.4: "a memory issue is
//! inevitable for a graph with millions of vertices" — every intermediate
//! non-maximal clique holds Θ(n) bits.  All bit-vector allocations are
//! charged to a [`MemBudget`]; exceeding it returns the paper's
//! "Out of memory" row.

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::bitset::BitSet;
use crate::util::membudget::{BudgetError, MemBudget};

/// Run to completion or OOM. On success every maximal clique is emitted.
pub fn clique_enumerator(
    g: &CsrGraph,
    sink: &dyn CliqueSink,
    budget: &MemBudget,
) -> Result<(), BudgetError> {
    let n = g.n();
    if n == 0 {
        return Ok(());
    }
    // neighbour bit vectors (also charged — the "bit vector for each
    // vertex as large as the input graph" of §6.4)
    let mut nbr_bits: Vec<BitSet> = Vec::with_capacity(n);
    for v in 0..n as Vertex {
        let bs = BitSet::from_iter_cap(n, g.neighbors(v).iter().copied());
        budget.charge(bs.heap_bytes())?;
        nbr_bits.push(bs);
    }

    // frontier of (clique, extension-bits); extension = vertices > max(c)
    // adjacent to all of c — dedup-free by construction
    struct Item {
        clique: Vec<Vertex>,
        ext: BitSet,
    }
    let mut frontier: Vec<Item> = Vec::new();
    for v in 0..n as Vertex {
        let mut ext = nbr_bits[v as usize].clone();
        // only higher ids to avoid duplicates
        for u in 0..=v {
            ext.remove(u);
        }
        budget.charge(ext.heap_bytes())?;
        frontier.push(Item {
            clique: vec![v],
            ext,
        });
    }

    while !frontier.is_empty() {
        let mut next: Vec<Item> = Vec::new();
        for item in &frontier {
            let mut extended = false;
            for q in item.ext.iter() {
                let mut ext2 = item.ext.clone();
                ext2.intersect_with(&nbr_bits[q as usize]);
                // keep only ids > q (canonical growth order)
                for u in item.ext.iter() {
                    if u <= q {
                        ext2.remove(u);
                    }
                }
                budget.charge(ext2.heap_bytes())?;
                let mut clique = item.clique.clone();
                clique.push(q);
                extended = true;
                next.push(Item { clique, ext: ext2 });
            }
            if !extended {
                // no higher extension: maximal iff nothing at all extends it
                if is_maximal(g, &item.clique) {
                    sink.emit(&item.clique);
                }
            }
        }
        // previous frontier's bit vectors are released
        for item in &frontier {
            budget.release(item.ext.heap_bytes());
        }
        frontier = next;
    }
    Ok(())
}

fn is_maximal(g: &CsrGraph, clique: &[Vertex]) -> bool {
    let seed = clique
        .iter()
        .copied()
        .min_by_key(|&v| g.degree(v))
        .unwrap();
    'outer: for &w in g.neighbors(seed) {
        if clique.contains(&w) {
            continue;
        }
        for &u in clique {
            if !g.has_edge(u, w) {
                continue 'outer;
            }
        }
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::CollectSink;

    #[test]
    fn correct_with_unlimited_budget() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 101, iters: 10 },
            |rng, level| {
                let n = 5 + rng.gen_usize(12 >> level.min(2));
                generators::gnp(n, 0.5, rng.next_u64())
            },
            |g| {
                let sink = CollectSink::new();
                clique_enumerator(g, &sink, &MemBudget::unlimited()).unwrap();
                let got = sink.into_canonical();
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{} vs {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn ooms_on_clique_rich_graph_with_small_budget() {
        let g = generators::moon_moser(5); // 243 maximal cliques, n=15
        let sink = CollectSink::new();
        let budget = MemBudget::new(4 * 1024); // 4 KiB: far too small
        let err = clique_enumerator(&g, &sink, &budget);
        assert!(matches!(err, Err(BudgetError::OutOfBudget { .. })));
        assert!(budget.peak() > 0);
    }
}
