//! Prior-work comparison algorithms (paper §6.4, Tables 7–10).
//!
//! Reimplemented from their papers' core ideas at the fidelity the
//! comparison's *shape* needs (DESIGN.md "Substitutions" item 4): the
//! pruning-free searchers are slow, the iterative k→k+1 expanders are
//! memory-bound (charged against `util::membudget` instead of actually
//! exhausting RAM), PECO is the rank-partitioned ancestor of ParMCE
//! without nested parallelism, and GP is a deterministic simulation of the
//! MPI vertex-partitioned enumerator.

pub mod bk;
pub mod clique_enumerator;
pub mod gp;
pub mod greedybb;
pub mod hashing;
pub mod peamc;
pub mod peco;
