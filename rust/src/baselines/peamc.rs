//! Peamc (Du et al. 2009) — shared-memory parallel MCE *without* pivoting
//! and with an explicit per-clique maximality test.
//!
//! Table 8 shows it "did not complete in 5 hours" on every input; §6.4
//! attributes that to (1) no pivot pruning and (2) an inefficient
//! maximality check.  This reimplementation keeps both misfeatures
//! faithfully: per-vertex parallel tasks run unpivoted backtracking and
//! re-verify maximality of each emitted clique by scanning the
//! neighbourhood of every member.  A [`Deadline`] reproduces the paper's
//! timeout rows without burning five real hours.

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;
use std::time::Duration;

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::membudget::{BudgetError, Deadline};
use crate::util::vset;

/// Run Peamc with a wall-clock cap. Err(TimedOut) reproduces Table 8.
pub fn peamc(
    pool: &ThreadPool,
    g: &Arc<CsrGraph>,
    sink: &Arc<dyn CliqueSink>,
    cap: Duration,
) -> Result<(), BudgetError> {
    let deadline = Arc::new(Deadline::new(cap));
    let timed_out = Arc::new(AtomicBool::new(false));
    pool.scope(|s| {
        for v in 0..g.n() as Vertex {
            let g = Arc::clone(g);
            let sink = Arc::clone(sink);
            let deadline = Arc::clone(&deadline);
            let timed_out = Arc::clone(&timed_out);
            s.spawn(move |_| {
                if timed_out.load(Ordering::Relaxed) {
                    return;
                }
                // subproblem: cliques where v is the smallest id (id
                // ordering only — no cost-aware ranking, unlike ParMCE)
                let cand: Vec<Vertex> = g
                    .neighbors(v)
                    .iter()
                    .copied()
                    .filter(|&u| u > v)
                    .collect();
                let mut k = vec![v];
                if rec(&g, &mut k, cand, sink.as_ref(), &deadline).is_err() {
                    timed_out.store(true, Ordering::Relaxed);
                }
            });
        }
    });
    if timed_out.load(Ordering::Relaxed) {
        Err(deadline.check().unwrap_err())
    } else {
        Ok(())
    }
}

fn rec(
    g: &CsrGraph,
    k: &mut Vec<Vertex>,
    cand: Vec<Vertex>,
    sink: &dyn CliqueSink,
    deadline: &Deadline,
) -> Result<(), BudgetError> {
    deadline.check()?;
    if cand.is_empty() {
        // inefficient explicit maximality test (misfeature #2): check
        // every neighbour of every member for full adjacency
        if is_maximal_slow(g, k) {
            sink.emit(k);
        }
        return Ok(());
    }
    // no pivot (misfeature #1): branch on every candidate
    for (i, &q) in cand.iter().enumerate() {
        let nbrs = g.neighbors(q);
        let next: Vec<Vertex> = cand[i + 1..]
            .iter()
            .copied()
            .filter(|u| nbrs.binary_search(u).is_ok())
            .collect();
        k.push(q);
        rec(g, k, next, sink, deadline)?;
        k.pop();
    }
    Ok(())
}

fn is_maximal_slow(g: &CsrGraph, k: &[Vertex]) -> bool {
    // the subproblem only explores ids > v, so extendability must be
    // checked against the *whole* neighbourhood (this is what makes the
    // emitted set correct — and slow)
    let mut sorted = k.to_vec();
    sorted.sort_unstable();
    for &m in k {
        'cand: for &w in g.neighbors(m) {
            if vset::contains(&sorted, w) {
                continue;
            }
            for &u in k {
                if !g.has_edge(u, w) {
                    continue 'cand;
                }
            }
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::{CliqueSink, CollectSink};

    #[test]
    fn correct_when_given_time() {
        let g = Arc::new(generators::gnp(16, 0.5, 3));
        let pool = ThreadPool::new(3);
        let sink = Arc::new(CollectSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        peamc(&pool, &g, &dyn_sink, Duration::from_secs(60)).unwrap();
        drop(dyn_sink);
        let got = Arc::try_unwrap(sink).ok().unwrap().into_canonical();
        assert_eq!(got, oracle::maximal_cliques(&g));
    }

    #[test]
    fn times_out_on_hard_input() {
        // Moon–Moser k=7: 3^7 = 2187 maximal cliques but unpivoted search
        // explores vastly more subsets — a microsecond budget must trip.
        let g = Arc::new(generators::moon_moser(7));
        let pool = ThreadPool::new(2);
        let sink = Arc::new(crate::mce::sink::CountSink::new());
        let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
        let err = peamc(&pool, &g, &dyn_sink, Duration::from_micros(50));
        assert!(matches!(err, Err(BudgetError::TimedOut { .. })));
    }
}
