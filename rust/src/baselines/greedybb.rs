//! GreedyBB (San Segundo et al. 2018-style) — bit-parallel
//! branch-and-bound enumeration.
//!
//! Enumerates with dense bitset P/X sets (word-parallel intersections) but
//! no TTT pivot; every recursion level materializes full n-bit sets, so
//! memory grows with depth × branching, and without pivoting the search
//! tree explodes on clique-rich graphs.  Table 10: "worse than TTT",
//! OOM/timeout on the large inputs — reproduced via the charged budget and
//! deadline.

use std::time::Duration;

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::bitset::BitSet;
use crate::util::membudget::{BudgetError, Deadline, MemBudget};

pub fn greedybb(
    g: &CsrGraph,
    sink: &dyn CliqueSink,
    budget: &MemBudget,
    cap: Duration,
) -> Result<(), BudgetError> {
    let n = g.n();
    if n == 0 {
        return Ok(());
    }
    let deadline = Deadline::new(cap);
    // dense adjacency bitsets (bit-parallel core of the algorithm)
    let mut adj: Vec<BitSet> = Vec::with_capacity(n);
    for v in 0..n as Vertex {
        let bs = BitSet::from_iter_cap(n, g.neighbors(v).iter().copied());
        budget.charge(bs.heap_bytes())?;
        adj.push(bs);
    }
    let mut p = BitSet::from_iter_cap(n, 0..n as Vertex);
    let x = BitSet::new(n);
    budget.charge(p.heap_bytes() + x.heap_bytes())?;
    let mut r = Vec::new();
    rec(&adj, &mut r, &mut p, x, n, sink, budget, &deadline)
}

#[allow(clippy::too_many_arguments)]
fn rec(
    adj: &[BitSet],
    r: &mut Vec<Vertex>,
    p: &mut BitSet,
    mut x: BitSet,
    n: usize,
    sink: &dyn CliqueSink,
    budget: &MemBudget,
    deadline: &Deadline,
) -> Result<(), BudgetError> {
    deadline.check()?;
    if p.is_empty() {
        if x.is_empty() && !r.is_empty() {
            sink.emit(r);
        }
        return Ok(());
    }
    // greedy branching order: highest-degree-in-P first (the "greedy"
    // bound of the B&B — but no pivot-based subtree elimination)
    let mut order: Vec<Vertex> = p.iter().collect();
    order.sort_by_key(|&v| std::cmp::Reverse(adj[v as usize].intersection_count(p)));
    for v in order {
        if !p.contains(v) {
            continue;
        }
        // two fresh n-bit sets per branch — the memory profile of Table 10
        let mut p2 = BitSet::new(n);
        let mut x2 = BitSet::new(n);
        budget.charge(p2.heap_bytes() + x2.heap_bytes())?;
        p.intersection_into(&adj[v as usize], &mut p2);
        x.intersection_into(&adj[v as usize], &mut x2);
        r.push(v);
        let res = rec(adj, r, &mut p2, x2, n, sink, budget, deadline);
        r.pop();
        budget.release(p2.heap_bytes() * 2);
        res?;
        p.remove(v);
        x.insert(v);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::CollectSink;

    #[test]
    fn correct_with_unlimited_resources() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 121, iters: 10 },
            |rng, level| {
                let n = 5 + rng.gen_usize(12 >> level.min(2));
                generators::gnp(n, 0.5, rng.next_u64())
            },
            |g| {
                let sink = CollectSink::new();
                greedybb(g, &sink, &MemBudget::unlimited(), Duration::from_secs(60)).unwrap();
                let got = sink.into_canonical();
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{} vs {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn budget_trips_on_large_dense_graph() {
        let g = generators::moon_moser(6);
        let sink = CollectSink::new();
        // adjacency bitsets alone are 18 × 8 = 144 bytes; the recursion
        // path adds 16 bytes per level — 200 bytes must trip mid-search.
        let err = greedybb(&g, &sink, &MemBudget::new(200), Duration::from_secs(60));
        assert!(matches!(err, Err(BudgetError::OutOfBudget { .. })));
    }
}
