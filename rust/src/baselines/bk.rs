//! Bron–Kerbosch family: the sequential comparators of Table 10.
//!
//! * [`bk_basic`] — Algorithm 457 (1973), no pivoting: the exponential
//!   blow-up Peamc inherits.
//! * [`bk_pivot`] — BK with max-degree-in-P pivoting (an independent
//!   implementation, *not* the TTT module, so the two cross-validate).
//! * [`bk_degeneracy`] — Eppstein–Löffler–Strash: outer level in
//!   degeneracy order, inner levels pivoted; O(d·n·3^{d/3}).

use crate::graph::csr::CsrGraph;
use crate::graph::degeneracy::core_decomposition;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::vset;

/// Plain Bron–Kerbosch, no pivot.
pub fn bk_basic(g: &CsrGraph, sink: &dyn CliqueSink) {
    let p: Vec<Vertex> = (0..g.n() as Vertex).collect();
    let mut r = Vec::new();
    rec_basic(g, &mut r, p, Vec::new(), sink);
}

fn rec_basic(
    g: &CsrGraph,
    r: &mut Vec<Vertex>,
    p: Vec<Vertex>,
    x: Vec<Vertex>,
    sink: &dyn CliqueSink,
) {
    if p.is_empty() && x.is_empty() {
        if !r.is_empty() {
            sink.emit(r);
        }
        return;
    }
    let mut p_rest = p.clone();
    let mut x_rest = x;
    for v in p {
        let nbrs = g.neighbors(v);
        r.push(v);
        rec_basic(
            g,
            r,
            vset::intersect(&p_rest, nbrs),
            vset::intersect(&x_rest, nbrs),
            sink,
        );
        r.pop();
        vset::remove_sorted(&mut p_rest, v);
        vset::insert_sorted(&mut x_rest, v);
    }
}

/// BK with pivoting (pivot = max |P ∩ Γ(u)| over u ∈ P ∪ X).
pub fn bk_pivot(g: &CsrGraph, sink: &dyn CliqueSink) {
    let p: Vec<Vertex> = (0..g.n() as Vertex).collect();
    let mut r = Vec::new();
    rec_pivot(g, &mut r, p, Vec::new(), sink);
}

fn rec_pivot(
    g: &CsrGraph,
    r: &mut Vec<Vertex>,
    mut p: Vec<Vertex>,
    mut x: Vec<Vertex>,
    sink: &dyn CliqueSink,
) {
    if p.is_empty() {
        if x.is_empty() && !r.is_empty() {
            sink.emit(r);
        }
        return;
    }
    // independent pivot selection (no early-exit bound — deliberately a
    // *different* implementation than mce::pivot, for cross-validation)
    let pivot = p
        .iter()
        .chain(x.iter())
        .copied()
        .max_by_key(|&u| vset::intersection_count(&p, g.neighbors(u)))
        .unwrap();
    let ext = vset::difference(&p, g.neighbors(pivot));
    for v in ext {
        let nbrs = g.neighbors(v);
        r.push(v);
        rec_pivot(
            g,
            r,
            vset::intersect(&p, nbrs),
            vset::intersect(&x, nbrs),
            sink,
        );
        r.pop();
        vset::remove_sorted(&mut p, v);
        vset::insert_sorted(&mut x, v);
    }
}

/// Eppstein–Löffler–Strash degeneracy-ordered BK (Table 10's
/// BKDegeneracy).
pub fn bk_degeneracy(g: &CsrGraph, sink: &dyn CliqueSink) {
    let decomp = core_decomposition(g);
    let pos = &decomp.pos;
    for &v in &decomp.order {
        // P = later neighbours in degeneracy order, X = earlier ones
        let mut p = Vec::new();
        let mut x = Vec::new();
        for &u in g.neighbors(v) {
            if pos[u as usize] > pos[v as usize] {
                p.push(u);
            } else {
                x.push(u);
            }
        }
        let mut r = vec![v];
        rec_pivot(g, &mut r, p, x, sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::CollectSink;

    fn canon(f: impl Fn(&CsrGraph, &dyn CliqueSink), g: &CsrGraph) -> Vec<Vec<Vertex>> {
        let sink = CollectSink::new();
        f(g, &sink);
        sink.into_canonical()
    }

    #[test]
    fn all_variants_match_oracle() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 91, iters: 15 },
            |rng, level| {
                let n = 5 + rng.gen_usize(14 >> level.min(2));
                generators::gnp(n, 0.3 + 0.5 * rng.gen_f64(), rng.next_u64())
            },
            |g| {
                let want = oracle::maximal_cliques(g);
                for (name, f) in [
                    ("basic", bk_basic as fn(&CsrGraph, &dyn CliqueSink)),
                    ("pivot", bk_pivot),
                    ("degeneracy", bk_degeneracy),
                ] {
                    let got = canon(f, g);
                    if got != want {
                        return Err(format!("{name}: {} vs oracle {}", got.len(), want.len()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn degeneracy_handles_isolated_vertices() {
        let g = CsrGraph::from_edges(4, &[(0, 1)]);
        assert_eq!(
            canon(bk_degeneracy, &g),
            vec![vec![0, 1], vec![2], vec![3]]
        );
    }

    #[test]
    fn moon_moser_counts() {
        let g = generators::moon_moser(3);
        assert_eq!(canon(bk_pivot, &g).len(), 27);
        assert_eq!(canon(bk_degeneracy, &g).len(), 27);
    }
}
