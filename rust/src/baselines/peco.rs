//! PECO (Svendsen–Mukherjee–Tirthapura, JPDC 2015) adapted to
//! shared-memory — the paper's own Table 7 comparator.
//!
//! PECO introduced the rank-ordered per-vertex subproblem construction
//! that ParMCE inherits; the two differences (§4.2) are exactly what this
//! module preserves: (1) PECO was distributed — here the subgraph copies
//! are gone because the graph sits in shared memory (the paper's own
//! modification for Table 7), and (2) each per-vertex subproblem runs a
//! *sequential* TTT — no nested parallelism, so one monster subproblem
//! pins a core while the rest idle.

use crate::util::sync::Arc;

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::ranking::Ranking;
use crate::mce::sink::CliqueSink;
use crate::mce::ttt;

/// Shared-memory PECO with the given vertex ranking
/// (PECODegree / PECODegen / PECOTri = Table 7 columns).
/// `bitset_cutoff` is the dense-kernel hand-off threshold of the inner
/// sequential TTT (0 = slice-only).
pub fn peco(
    pool: &ThreadPool,
    g: &Arc<CsrGraph>,
    ranking: &Arc<Ranking>,
    sink: &Arc<dyn CliqueSink>,
    bitset_cutoff: usize,
) {
    pool.scope(|s| {
        for v in 0..g.n() as Vertex {
            let g = Arc::clone(g);
            let ranking = Arc::clone(ranking);
            let sink = Arc::clone(sink);
            s.spawn(move |_| {
                let (cand, fini) = ranking.split_neighbors(&g, v);
                let mut k = vec![v];
                // sequential inner enumeration — the PECO limitation
                ttt::ttt_from_with_cutoff(
                    g.as_ref(),
                    &mut k,
                    cand,
                    fini,
                    sink.as_ref(),
                    bitset_cutoff,
                );
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::ranking::RankStrategy;
    use crate::mce::sink::CollectSink;

    #[test]
    fn matches_oracle_all_rankings() {
        for strat in [
            RankStrategy::Degree,
            RankStrategy::Triangle,
            RankStrategy::Degeneracy,
        ] {
            let g = generators::planted_cliques(80, 0.05, 4, 5, 8, 31);
            let want = oracle::maximal_cliques(&g);
            let pool = ThreadPool::new(3);
            let ranking = Arc::new(Ranking::compute(&g, strat));
            let g = Arc::new(g);
            let sink = Arc::new(CollectSink::new());
            let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
            peco(&pool, &g, &ranking, &dyn_sink, 64);
            drop(dyn_sink);
            let got = Arc::try_unwrap(sink).ok().unwrap().into_canonical();
            assert_eq!(got, want, "{strat:?}");
        }
    }
}
