//! Hashing (Lessley et al., LDAV 2017) — data-parallel iterative MCE.
//!
//! Rounds of k-clique → (k+1)-clique expansion over a *global* table of
//! intermediate cliques, deduplicated by hashing.  §6.4: "the number of
//! intermediate non-maximal cliques may be very large, even for graphs
//! with few maximal cliques" (a maximal clique of size c spawns ~2^c
//! subsets on the way up) — the paper's Table 8 shows OOM on every input.
//! The intermediate table is charged to a [`MemBudget`].

use std::collections::HashSet;

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::sink::CliqueSink;
use crate::util::membudget::{BudgetError, MemBudget};
use crate::util::vset;

/// Run to completion or OOM.
pub fn hashing(
    g: &CsrGraph,
    sink: &dyn CliqueSink,
    budget: &MemBudget,
) -> Result<(), BudgetError> {
    // round 1: all vertices as 1-cliques
    let mut frontier: Vec<Vec<Vertex>> = (0..g.n() as Vertex).map(|v| vec![v]).collect();
    let bytes_of = |c: &Vec<Vertex>| c.len() * 4 + 24;
    for c in &frontier {
        budget.charge(bytes_of(c))?; // initial table is charged too
    }

    while !frontier.is_empty() {
        // the data-parallel expand + hash-dedup step
        let mut table: HashSet<Vec<Vertex>> = HashSet::new();
        let mut next_bytes = 0usize;
        let mut next: Vec<Vec<Vertex>> = Vec::new();
        for c in &frontier {
            // common neighbourhood of the clique
            let mut common: Vec<Vertex> = g.neighbors(c[0]).to_vec();
            for &u in &c[1..] {
                common = vset::intersect(&common, g.neighbors(u));
            }
            if common.is_empty() {
                sink.emit(c); // no extension at all → maximal
                continue;
            }
            for &q in &common {
                let mut bigger = c.clone();
                vset::insert_sorted(&mut bigger, q);
                if table.insert(bigger.clone()) {
                    next_bytes += bytes_of(&bigger);
                    budget.charge(bytes_of(&bigger))?;
                    next.push(bigger);
                }
            }
        }
        // previous frontier released, table kept only as next frontier
        for c in &frontier {
            budget.release(bytes_of(c));
        }
        let _ = next_bytes;
        frontier = next;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::mce::sink::CollectSink;

    #[test]
    fn correct_with_unlimited_budget() {
        crate::util::prop::forall(
            crate::util::prop::Config { seed: 111, iters: 10 },
            |rng, level| {
                let n = 5 + rng.gen_usize(10 >> level.min(2));
                generators::gnp(n, 0.5, rng.next_u64())
            },
            |g| {
                let sink = CollectSink::new();
                hashing(g, &sink, &MemBudget::unlimited()).unwrap();
                let got = sink.into_canonical();
                let want = oracle::maximal_cliques(g);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{} vs {}", got.len(), want.len()))
                }
            },
        );
    }

    #[test]
    fn intermediate_explosion_ooms() {
        // one 18-clique → ~2^18 intermediate subsets on the way up
        let g = generators::complete(18);
        let sink = CollectSink::new();
        let budget = MemBudget::new(64 * 1024);
        let err = hashing(&g, &sink, &budget);
        assert!(matches!(err, Err(BudgetError::OutOfBudget { .. })));
    }
}
