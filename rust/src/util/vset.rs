//! Sorted-vector vertex-set operations.
//!
//! `cand` / `fini` and CSR neighbour lists are sorted `&[u32]` slices; all
//! TTT-family set algebra reduces to merge/gallop intersections here.  These
//! functions are the L3 hot path (see EXPERIMENTS.md §Perf for the
//! merge-vs-gallop crossover measurement).

/// Binary-search membership on a sorted slice.
#[inline]
pub fn contains(sorted: &[u32], x: u32) -> bool {
    sorted.binary_search(&x).is_ok()
}

/// Index of the first element of `s[from..]` that is `>= x`, found by
/// true exponential search: doubling probes from the cursor bracket the
/// answer in O(log gap), then a binary search finishes inside the
/// bracket.  The gallop loops below carry the cursor across the small
/// side's elements, so a lopsided intersection costs
/// O(small · log(gap)) instead of O(small · log(big)).
#[inline]
pub(crate) fn gallop_lower_bound(s: &[u32], from: usize, x: u32) -> usize {
    if from >= s.len() || s[from] >= x {
        return from;
    }
    // s[from] < x: probe from+1, from+2, from+4, … until we overshoot.
    let mut ofs = 1usize;
    while from + ofs < s.len() && s[from + ofs] < x {
        ofs <<= 1;
    }
    // answer ∈ (from + ofs/2, from + ofs]
    let lo = from + ofs / 2 + 1;
    let hi = (from + ofs).min(s.len());
    lo + s[lo..hi].partition_point(|&y| y < x)
}

/// |a ∩ b| for sorted slices, galloping when sizes are lopsided.
pub fn intersection_count(a: &[u32], b: &[u32]) -> usize {
    if a.len() > b.len() {
        return intersection_count(b, a);
    }
    // `a` is the smaller side.
    if a.is_empty() {
        return 0;
    }
    if b.len() / a.len() >= 8 {
        // gallop: exponential search from a moving cursor on the big side
        let mut j = 0;
        let mut n = 0;
        for &x in a {
            j = gallop_lower_bound(b, j, x);
            if j >= b.len() {
                break;
            }
            if b[j] == x {
                n += 1;
                j += 1;
            }
        }
        return n;
    }
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// a ∩ b into `out` (cleared first). Sorted in, sorted out.
pub fn intersect_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    if a.len() > b.len() {
        return intersect_into_inner(b, a, out);
    }
    intersect_into_inner(a, b, out)
}

fn intersect_into_inner(small: &[u32], big: &[u32], out: &mut Vec<u32>) {
    if small.is_empty() || big.is_empty() {
        return;
    }
    if big.len() / small.len() >= 8 {
        let mut j = 0;
        for &x in small {
            j = gallop_lower_bound(big, j, x);
            if j >= big.len() {
                return;
            }
            if big[j] == x {
                out.push(x);
                j += 1;
            }
        }
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < small.len() && j < big.len() {
        match small[i].cmp(&big[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(small[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// a ∩ b as a fresh Vec.
pub fn intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len().min(b.len()));
    intersect_into(a, b, &mut out);
    out
}

/// a \ b into `out` (cleared first).
pub fn difference_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            out.push(a[i]);
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
}

/// a \ b as a fresh Vec.
pub fn difference(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len());
    difference_into(a, b, &mut out);
    out
}

/// a ∪ b as a fresh sorted Vec (inputs sorted, deduped).
pub fn union(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    union_into(a, b, &mut out);
    out
}

/// a ∪ b into `out` (cleared first). Inputs sorted+deduped; so is `out`.
pub fn union_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        if j >= b.len() || (i < a.len() && a[i] < b[j]) {
            out.push(a[i]);
            i += 1;
        } else if i >= a.len() || a[i] > b[j] {
            out.push(b[j]);
            j += 1;
        } else {
            out.push(a[i]);
            i += 1;
            j += 1;
        }
    }
}

/// Is `a` ⊆ `b`? Both sorted.
pub fn is_subset(a: &[u32], b: &[u32]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    if !a.is_empty() && b.len() / a.len() >= 16 {
        let mut j = 0;
        for &x in a {
            j = gallop_lower_bound(b, j, x);
            if j >= b.len() || b[j] != x {
                return false;
            }
            j += 1;
        }
        return true;
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => return false,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    i == a.len()
}

/// Insert into a sorted Vec, keeping it sorted; false if already present.
pub fn insert_sorted(v: &mut Vec<u32>, x: u32) -> bool {
    match v.binary_search(&x) {
        Ok(_) => false,
        Err(pos) => {
            v.insert(pos, x);
            true
        }
    }
}

/// Remove from a sorted Vec; false if absent.
pub fn remove_sorted(v: &mut Vec<u32>, x: u32) -> bool {
    match v.binary_search(&x) {
        Ok(pos) => {
            v.remove(pos);
            true
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_sorted(rng: &mut Rng, max: u32, p: f64) -> Vec<u32> {
        (0..max).filter(|_| rng.gen_bool(p)).collect()
    }

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| b.contains(x)).copied().collect()
    }

    #[test]
    fn ops_match_naive_randomized() {
        let mut rng = Rng::new(1234);
        for round in 0..200 {
            let p1 = 0.05 + 0.9 * rng.gen_f64();
            let p2 = 0.05 + 0.9 * rng.gen_f64();
            let a = rand_sorted(&mut rng, 150, p1);
            let b = rand_sorted(&mut rng, 150, p2);
            let ni = naive_intersect(&a, &b);
            assert_eq!(intersect(&a, &b), ni, "round {round}");
            assert_eq!(intersection_count(&a, &b), ni.len());
            let nd: Vec<u32> = a.iter().filter(|x| !b.contains(x)).copied().collect();
            assert_eq!(difference(&a, &b), nd);
            let mut nu: Vec<u32> = a.iter().chain(&b).copied().collect();
            nu.sort_unstable();
            nu.dedup();
            assert_eq!(union(&a, &b), nu);
            assert!(is_subset(&ni, &a));
            assert!(is_subset(&ni, &b));
        }
    }

    #[test]
    fn gallop_path_exercised() {
        // small side ≤ big/16 → gallop branch
        let small = vec![5u32, 500, 5000];
        let big: Vec<u32> = (0..6000).collect();
        assert_eq!(intersect(&small, &big), small);
        assert_eq!(intersection_count(&small, &big), 3);
        assert!(is_subset(&small, &big));
    }

    #[test]
    fn empty_edges() {
        let e: Vec<u32> = vec![];
        let a = vec![1u32, 2, 3];
        assert_eq!(intersect(&e, &a), e);
        assert_eq!(difference(&a, &e), a);
        assert_eq!(difference(&e, &a), e);
        assert_eq!(union(&e, &e), e);
        assert!(is_subset(&e, &a));
        assert!(!is_subset(&a, &e));
    }

    #[test]
    fn sorted_mutation() {
        let mut v = vec![2u32, 5, 9];
        assert!(insert_sorted(&mut v, 7));
        assert!(!insert_sorted(&mut v, 7));
        assert_eq!(v, vec![2, 5, 7, 9]);
        assert!(remove_sorted(&mut v, 5));
        assert!(!remove_sorted(&mut v, 5));
        assert_eq!(v, vec![2, 7, 9]);
    }

    #[test]
    fn intersect_into_reuses_buffer() {
        let mut buf = vec![99u32; 8];
        intersect_into(&[1, 3, 5], &[3, 5, 7], &mut buf);
        assert_eq!(buf, vec![3, 5]);
    }

    #[test]
    fn gallop_lower_bound_matches_partition_point() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let s = rand_sorted(&mut rng, 400, 0.3);
            let from = rng.gen_usize(s.len() + 1);
            let x = rng.gen_usize(420) as u32;
            let got = gallop_lower_bound(&s, from, x);
            let want = from + s[from..].partition_point(|&y| y < x);
            assert_eq!(got, want, "s.len()={}, from={from}, x={x}", s.len());
        }
        // cursor past the end and empty slices are fine
        assert_eq!(gallop_lower_bound(&[], 0, 5), 0);
        assert_eq!(gallop_lower_bound(&[1, 2], 2, 0), 2);
    }

    #[test]
    fn union_into_reuses_buffer() {
        let mut buf = vec![42u32; 4];
        union_into(&[1, 4], &[2, 4, 9], &mut buf);
        assert_eq!(buf, vec![1, 2, 4, 9]);
    }
}
