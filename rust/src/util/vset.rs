//! Sorted-vector vertex-set operations.
//!
//! `cand` / `fini` and CSR neighbour lists are sorted `&[u32]` slices; all
//! TTT-family set algebra reduces to merge/gallop intersections here.  These
//! functions are the L3 hot path (see EXPERIMENTS.md §Perf for the
//! merge-vs-gallop crossover measurement).

/// Binary-search membership on a sorted slice.
#[inline]
pub fn contains(sorted: &[u32], x: u32) -> bool {
    sorted.binary_search(&x).is_ok()
}

/// |a ∩ b| for sorted slices, galloping when sizes are lopsided.
pub fn intersection_count(a: &[u32], b: &[u32]) -> usize {
    if a.len() > b.len() {
        return intersection_count(b, a);
    }
    // `a` is the smaller side.
    if a.is_empty() {
        return 0;
    }
    if b.len() / a.len() >= 8 {
        // gallop: binary-search each element of the small side
        return a.iter().filter(|&&x| contains(b, x)).count();
    }
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// a ∩ b into `out` (cleared first). Sorted in, sorted out.
pub fn intersect_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    if a.len() > b.len() {
        return intersect_into_inner(b, a, out);
    }
    intersect_into_inner(a, b, out)
}

fn intersect_into_inner(small: &[u32], big: &[u32], out: &mut Vec<u32>) {
    if small.is_empty() || big.is_empty() {
        return;
    }
    if big.len() / small.len() >= 8 {
        out.extend(small.iter().filter(|&&x| contains(big, x)));
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < small.len() && j < big.len() {
        match small[i].cmp(&big[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(small[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// a ∩ b as a fresh Vec.
pub fn intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len().min(b.len()));
    intersect_into(a, b, &mut out);
    out
}

/// a \ b into `out` (cleared first).
pub fn difference_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            out.push(a[i]);
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
}

/// a \ b as a fresh Vec.
pub fn difference(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len());
    difference_into(a, b, &mut out);
    out
}

/// a ∪ b as a fresh sorted Vec (inputs sorted, deduped).
pub fn union(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        if j >= b.len() || (i < a.len() && a[i] < b[j]) {
            out.push(a[i]);
            i += 1;
        } else if i >= a.len() || a[i] > b[j] {
            out.push(b[j]);
            j += 1;
        } else {
            out.push(a[i]);
            i += 1;
            j += 1;
        }
    }
    out
}

/// Is `a` ⊆ `b`? Both sorted.
pub fn is_subset(a: &[u32], b: &[u32]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    if !a.is_empty() && b.len() / a.len() >= 16 {
        return a.iter().all(|&x| contains(b, x));
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => return false,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    i == a.len()
}

/// Insert into a sorted Vec, keeping it sorted; false if already present.
pub fn insert_sorted(v: &mut Vec<u32>, x: u32) -> bool {
    match v.binary_search(&x) {
        Ok(_) => false,
        Err(pos) => {
            v.insert(pos, x);
            true
        }
    }
}

/// Remove from a sorted Vec; false if absent.
pub fn remove_sorted(v: &mut Vec<u32>, x: u32) -> bool {
    match v.binary_search(&x) {
        Ok(pos) => {
            v.remove(pos);
            true
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_sorted(rng: &mut Rng, max: u32, p: f64) -> Vec<u32> {
        (0..max).filter(|_| rng.gen_bool(p)).collect()
    }

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| b.contains(x)).copied().collect()
    }

    #[test]
    fn ops_match_naive_randomized() {
        let mut rng = Rng::new(1234);
        for round in 0..200 {
            let p1 = 0.05 + 0.9 * rng.gen_f64();
            let p2 = 0.05 + 0.9 * rng.gen_f64();
            let a = rand_sorted(&mut rng, 150, p1);
            let b = rand_sorted(&mut rng, 150, p2);
            let ni = naive_intersect(&a, &b);
            assert_eq!(intersect(&a, &b), ni, "round {round}");
            assert_eq!(intersection_count(&a, &b), ni.len());
            let nd: Vec<u32> = a.iter().filter(|x| !b.contains(x)).copied().collect();
            assert_eq!(difference(&a, &b), nd);
            let mut nu: Vec<u32> = a.iter().chain(&b).copied().collect();
            nu.sort_unstable();
            nu.dedup();
            assert_eq!(union(&a, &b), nu);
            assert_eq!(is_subset(&ni, &a), true);
            assert_eq!(is_subset(&ni, &b), true);
        }
    }

    #[test]
    fn gallop_path_exercised() {
        // small side ≤ big/16 → gallop branch
        let small = vec![5u32, 500, 5000];
        let big: Vec<u32> = (0..6000).collect();
        assert_eq!(intersect(&small, &big), small);
        assert_eq!(intersection_count(&small, &big), 3);
        assert!(is_subset(&small, &big));
    }

    #[test]
    fn empty_edges() {
        let e: Vec<u32> = vec![];
        let a = vec![1u32, 2, 3];
        assert_eq!(intersect(&e, &a), e);
        assert_eq!(difference(&a, &e), a);
        assert_eq!(difference(&e, &a), e);
        assert_eq!(union(&e, &e), e);
        assert!(is_subset(&e, &a));
        assert!(!is_subset(&a, &e));
    }

    #[test]
    fn sorted_mutation() {
        let mut v = vec![2u32, 5, 9];
        assert!(insert_sorted(&mut v, 7));
        assert!(!insert_sorted(&mut v, 7));
        assert_eq!(v, vec![2, 5, 7, 9]);
        assert!(remove_sorted(&mut v, 5));
        assert!(!remove_sorted(&mut v, 5));
        assert_eq!(v, vec![2, 7, 9]);
    }

    #[test]
    fn intersect_into_reuses_buffer() {
        let mut buf = vec![99u32; 8];
        intersect_into(&[1, 3, 5], &[3, 5, 7], &mut buf);
        assert_eq!(buf, vec![3, 5]);
    }
}
