//! Substrate utilities built from scratch (the offline environment provides
//! no rayon/serde/rand/criterion — see DESIGN.md "Substitutions").

pub mod bench;
pub mod bitset;
pub mod chashmap;
pub mod failpoints;
pub mod json;
#[cfg(loom)]
pub mod loom_shim;
pub mod membudget;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod table;
pub mod vset;

/// Format a nanosecond duration as a human-readable string.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else {
        format!("{}ns", ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5), "5ns");
        assert_eq!(fmt_ns(1_500), "1.50µs");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_250_000_000), "3.25s");
    }
}
