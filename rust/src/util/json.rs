//! Minimal JSON: a value type, a writer, and a recursive-descent parser.
//!
//! serde is not available offline; this covers the two uses we have:
//! writing machine-readable experiment results (`results/*.json`) and
//! reading `artifacts/manifest.json` (shape constants shared with L2).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(entries: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing input at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other.map(|b| b as char))),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!("expected , or ] found {:?}", other.map(|b| b as char)))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!("expected , or }} found {:?}", other.map(|b| b as char)))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj([
            ("name", Json::str("par\"mce")),
            ("n", Json::num(42)),
            ("ratio", Json::num(1.5)),
            ("tags", Json::arr([Json::str("a"), Json::Null, Json::Bool(true)])),
        ]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "constants": {"FULL_N": 512, "TILE_B": 256},
          "rank_tri_tile": {"file": "rank_tri_tile.hlo.txt", "chars": 2985}
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(
            v.get("constants").unwrap().get("TILE_B").unwrap().as_f64(),
            Some(256.0)
        );
        assert_eq!(
            v.get("rank_tri_tile").unwrap().get("file").unwrap().as_str(),
            Some("rank_tri_tile.hlo.txt")
        );
    }

    #[test]
    fn parse_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("line\nquote\" back\\ tab\t unicode\u{1}end");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("17").unwrap().as_f64(), Some(17.0));
        // integer formatting avoids trailing .0
        assert_eq!(Json::num(17).to_string(), "17");
    }
}
