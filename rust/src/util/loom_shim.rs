//! Instrumented sync primitives compiled only under `--cfg loom`.
//!
//! Offline stand-in for the `loom` model checker (unavailable in this
//! vendored build — see DESIGN.md "Substitutions"): the wrappers delegate
//! to `std` but call [`step`] at every synchronization edge (lock, notify,
//! atomic load/store/RMW).  [`step`] consults a per-thread PRNG seeded from
//! the current exploration iteration and randomly yields or spins, so one
//! [`model`] call exercises many distinct interleavings instead of loom's
//! exhaustive state-space walk.  Weaker than loom — it cannot *prove*
//! absence of races — but it reliably reproduces lost-wakeup and
//! ordering-dependent bugs that a plain test almost never hits, and the
//! test code is written against the real loom API shape so a vendored loom
//! can slot in behind `util::sync` without touching any model.
//!
//! Never compiled in normal builds: `cfg(loom)` is set only by
//! `RUSTFLAGS="--cfg loom"` (CI's loom job).

#![allow(dead_code)]

use std::cell::Cell;
use std::sync::atomic as std_atomic;
use std::sync::atomic::Ordering;
use std::time::Duration;

pub use std::sync::{LockResult, MutexGuard, WaitTimeoutResult};

/// Global exploration state: nonzero while a `model` run is active; the
/// value seeds each thread's local scheduler PRNG.
static EXPLORE_SEED: std_atomic::AtomicU64 = std_atomic::AtomicU64::new(0);
/// Monotone thread counter used to decorrelate per-thread PRNG streams.
static THREAD_IDS: std_atomic::AtomicU64 = std_atomic::AtomicU64::new(1);

thread_local! {
    /// Per-thread scheduler PRNG state (lazily mixed from the global seed).
    static SCHED_RNG: Cell<u64> = const { Cell::new(0) };
}

/// Scheduling perturbation point: called by every wrapper on every
/// synchronization edge.  No-op outside a `model` run.
pub(crate) fn step() {
    let seed = EXPLORE_SEED.load(Ordering::Relaxed);
    if seed == 0 {
        return;
    }
    SCHED_RNG.with(|s| {
        let mut x = s.get();
        if x == 0 {
            let tid = THREAD_IDS.fetch_add(1, Ordering::Relaxed);
            // splitmix-style init so (seed, tid) pairs give distinct streams
            x = (seed ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15)) | 1;
        }
        // xorshift64
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        s.set(x);
        match x % 8 {
            0 | 1 => std::thread::yield_now(),
            2 => {
                for _ in 0..(x >> 32) % 64 {
                    std::hint::spin_loop();
                }
            }
            _ => {}
        }
    });
}

/// Explore `f` under scheduling perturbation.
///
/// Mirrors `loom::model`'s signature.  Runs the body `LOOM_MAX_ITERS`
/// times (default 64) with a fresh scheduler seed each iteration; any
/// panic inside the body propagates with the iteration's seed printed so
/// the failing schedule class is identifiable.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let iters: u64 = std::env::var("LOOM_MAX_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    for i in 0..iters {
        let seed = 0x5DEE_CE66u64.wrapping_mul(i + 1) | 1;
        EXPLORE_SEED.store(seed, Ordering::SeqCst);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(&f));
        EXPLORE_SEED.store(0, Ordering::SeqCst);
        if let Err(payload) = result {
            eprintln!("loom_shim: model failed at iteration {i} (seed {seed:#x})");
            std::panic::resume_unwind(payload);
        }
    }
}

/// `std::sync::Mutex` with perturbation on lock acquisition.
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized>(std::sync::Mutex<T>);

impl<T> Mutex<T> {
    pub fn new(t: T) -> Self {
        Mutex(std::sync::Mutex::new(t))
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.0.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        step();
        let g = self.0.lock();
        step();
        g
    }

    pub fn try_lock(&self) -> std::sync::TryLockResult<MutexGuard<'_, T>> {
        step();
        self.0.try_lock()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.0.get_mut()
    }
}

/// `std::sync::Condvar` with perturbation around notify/wait.
#[derive(Debug, Default)]
pub struct Condvar(std::sync::Condvar);

impl Condvar {
    pub fn new() -> Self {
        Condvar(std::sync::Condvar::new())
    }

    pub fn notify_one(&self) {
        step();
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        step();
        self.0.notify_all();
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        step();
        self.0.wait(guard)
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        step();
        self.0.wait_timeout(guard, dur)
    }
}

macro_rules! shim_atomic {
    ($name:ident, $std:ty, $prim:ty) => {
        /// Std atomic with perturbation on every access.
        #[derive(Debug, Default)]
        pub struct $name($std);

        impl $name {
            pub fn new(v: $prim) -> Self {
                $name(<$std>::new(v))
            }

            pub fn load(&self, o: Ordering) -> $prim {
                step();
                self.0.load(o)
            }

            pub fn store(&self, v: $prim, o: Ordering) {
                step();
                self.0.store(v, o);
                step();
            }

            pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                step();
                self.0.swap(v, o)
            }

            pub fn compare_exchange(
                &self,
                cur: $prim,
                new: $prim,
                ok: Ordering,
                err: Ordering,
            ) -> Result<$prim, $prim> {
                step();
                self.0.compare_exchange(cur, new, ok, err)
            }

            pub fn into_inner(self) -> $prim {
                self.0.into_inner()
            }
        }
    };
}

macro_rules! shim_atomic_int {
    ($name:ident, $std:ty, $prim:ty) => {
        shim_atomic!($name, $std, $prim);

        impl $name {
            pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                step();
                self.0.fetch_add(v, o)
            }

            pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                step();
                self.0.fetch_sub(v, o)
            }

            pub fn fetch_max(&self, v: $prim, o: Ordering) -> $prim {
                step();
                self.0.fetch_max(v, o)
            }

            pub fn fetch_min(&self, v: $prim, o: Ordering) -> $prim {
                step();
                self.0.fetch_min(v, o)
            }
        }
    };
}

shim_atomic!(AtomicBool, std_atomic::AtomicBool, bool);
shim_atomic_int!(AtomicU32, std_atomic::AtomicU32, u32);
shim_atomic_int!(AtomicU64, std_atomic::AtomicU64, u64);
shim_atomic_int!(AtomicUsize, std_atomic::AtomicUsize, usize);
