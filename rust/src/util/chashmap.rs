//! Sharded concurrent hash map / set.
//!
//! Stand-in for TBB `concurrent_hash_map` (paper §6.2) and for the
//! Shalev–Shavit lock-free table the analysis cites (Theorem 3.1): N mutex
//! shards give O(1)-expected concurrent insert/find/remove with contention
//! spread across shards.  Used for the dynamic-graph clique registry C(G)
//! and for cross-thread dedup in the Hashing baseline.

use std::borrow::Borrow;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::hash::{BuildHasher, BuildHasherDefault, Hash, Hasher};

use crate::util::sync::{plock, Mutex};

/// FxHash-style multiply hasher — fast for the small keys we use.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u64(b as u64);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.hash = (self.hash.rotate_left(5) ^ x).wrapping_mul(0x51_7c_c1_b7_27_22_0a_95);
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.write_u64(x as u64);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SHARD_BITS: usize = 6;
const NUM_SHARDS: usize = 1 << SHARD_BITS;

pub struct ConcurrentMap<K, V> {
    shards: Vec<Mutex<HashMap<K, V, FxBuildHasher>>>,
    hasher: FxBuildHasher,
}

impl<K: Hash + Eq, V> Default for ConcurrentMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Hash + Eq, V> ConcurrentMap<K, V> {
    pub fn new() -> Self {
        ConcurrentMap {
            shards: (0..NUM_SHARDS)
                .map(|_| Mutex::new(HashMap::default()))
                .collect(),
            hasher: FxBuildHasher::default(),
        }
    }

    #[inline]
    fn shard(&self, key: &K) -> usize {
        self.shard_of(key)
    }

    #[inline]
    fn shard_of<Q: Hash + ?Sized>(&self, key: &Q) -> usize {
        let h = self.hasher.hash_one(key);
        // use high bits: the multiply hasher's low bits are weaker
        (h >> (64 - SHARD_BITS)) as usize
    }

    /// Insert; returns the previous value if any.
    pub fn insert(&self, key: K, value: V) -> Option<V> {
        let s = self.shard(&key);
        plock(&self.shards[s]).insert(key, value)
    }

    /// Insert only if vacant; returns true if inserted.
    pub fn insert_if_absent(&self, key: K, value: V) -> bool {
        let s = self.shard(&key);
        match plock(&self.shards[s]).entry(key) {
            Entry::Occupied(_) => false,
            Entry::Vacant(e) => {
                e.insert(value);
                true
            }
        }
    }

    pub fn remove(&self, key: &K) -> Option<V> {
        self.remove_borrowed(key)
    }

    pub fn contains(&self, key: &K) -> bool {
        self.contains_borrowed(key)
    }

    /// [`remove`](Self::remove) through a borrowed form of the key (e.g.
    /// `&[u32]` for `Box<[u32]>` keys) — no owned-key allocation needed.
    /// The `Borrow` contract guarantees the borrowed form hashes like `K`,
    /// so shard routing agrees with the owned-key path.
    pub fn remove_borrowed<Q>(&self, key: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        let s = self.shard_of(key);
        plock(&self.shards[s]).remove(key)
    }

    /// [`contains`](Self::contains) through a borrowed form of the key.
    pub fn contains_borrowed<Q>(&self, key: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        let s = self.shard_of(key);
        plock(&self.shards[s]).contains_key(key)
    }

    pub fn get_cloned(&self, key: &K) -> Option<V>
    where
        V: Clone,
    {
        let s = self.shard(key);
        plock(&self.shards[s]).get(key).cloned()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| plock(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        for s in &self.shards {
            plock(s).clear();
        }
    }

    /// Drain all entries into a Vec (single-threaded epilogue).
    pub fn drain_all(&self) -> Vec<(K, V)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(plock(s).drain());
        }
        out
    }

    /// Apply `f` to every entry under shard locks.
    pub fn for_each(&self, mut f: impl FnMut(&K, &V)) {
        for s in &self.shards {
            for (k, v) in plock(s).iter() {
                f(k, v);
            }
        }
    }
}

/// Concurrent set, as a map with unit values.
pub struct ConcurrentSet<K> {
    map: ConcurrentMap<K, ()>,
}

impl<K: Hash + Eq> Default for ConcurrentSet<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Hash + Eq> ConcurrentSet<K> {
    pub fn new() -> Self {
        ConcurrentSet {
            map: ConcurrentMap::new(),
        }
    }

    /// True if newly inserted.
    pub fn insert(&self, key: K) -> bool {
        self.map.insert_if_absent(key, ())
    }

    pub fn remove(&self, key: &K) -> bool {
        self.map.remove(key).is_some()
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains(key)
    }

    /// Remove through a borrowed form of the key (no owned-key build).
    pub fn remove_borrowed<Q>(&self, key: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.map.remove_borrowed(key).is_some()
    }

    /// Membership through a borrowed form of the key.
    pub fn contains_borrowed<Q>(&self, key: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.map.contains_borrowed(key)
    }

    /// Apply `f` to every element under shard locks (non-draining).
    pub fn for_each(&self, mut f: impl FnMut(&K)) {
        self.map.for_each(|k, _| f(k));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn drain_all(&self) -> Vec<K> {
        self.map.drain_all().into_iter().map(|(k, _)| k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicU64, Ordering};
    use crate::util::sync::Arc;

    #[test]
    fn basic_map_ops() {
        let m: ConcurrentMap<u64, u64> = ConcurrentMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(1, 10), None);
        assert_eq!(m.insert(1, 20), Some(10));
        assert!(m.contains(&1));
        assert_eq!(m.get_cloned(&1), Some(20));
        assert_eq!(m.remove(&1), Some(20));
        assert!(!m.contains(&1));
    }

    #[test]
    fn insert_if_absent_semantics() {
        let m: ConcurrentMap<String, u32> = ConcurrentMap::new();
        assert!(m.insert_if_absent("a".into(), 1));
        assert!(!m.insert_if_absent("a".into(), 2));
        assert_eq!(m.get_cloned(&"a".to_string()), Some(1));
    }

    #[test]
    fn concurrent_inserts_all_land() {
        let s: Arc<ConcurrentSet<u64>> = Arc::new(ConcurrentSet::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        s.insert(t * 10_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(s.len(), 8000);
    }

    #[test]
    fn concurrent_dedup_exactly_once() {
        // All threads insert the same keys; exactly one insert per key wins.
        let s: Arc<ConcurrentSet<u64>> = Arc::new(ConcurrentSet::new());
        let wins: Arc<AtomicU64> = Arc::new(AtomicU64::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let s = Arc::clone(&s);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        if s.insert(i) {
                            wins.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(wins.load(Ordering::SeqCst), 500);
        assert_eq!(s.len(), 500);
    }

    #[test]
    fn concurrent_upsert_stress_seeded() {
        // Seeded interleaving loop over a mixed insert/remove workload on a
        // deliberately tiny key range (high per-stripe contention).  The
        // per-key win/loss ledger must balance exactly in every round:
        //   wins(k) - evictions(k) == 1 if k survived else 0
        // where a "win" is a successful insert and an "eviction" a
        // successful remove.  Any lost update, double report, or torn
        // insert/remove pair breaks the ledger.
        for seed in 0..8u64 {
            let s: Arc<ConcurrentSet<u64>> = Arc::new(ConcurrentSet::new());
            const KEYS: usize = 16;
            let wins: Arc<Vec<AtomicU64>> =
                Arc::new((0..KEYS).map(|_| AtomicU64::new(0)).collect());
            let evictions: Arc<Vec<AtomicU64>> =
                Arc::new((0..KEYS).map(|_| AtomicU64::new(0)).collect());
            let threads: Vec<_> = (0..8u64)
                .map(|t| {
                    let s = Arc::clone(&s);
                    let wins = Arc::clone(&wins);
                    let evictions = Arc::clone(&evictions);
                    std::thread::spawn(move || {
                        // per-(seed, thread) xorshift stream: reruns of one
                        // seed replay the same per-thread op sequence, and
                        // the loop varies the cross-thread interleaving
                        let mut x = (seed << 8 | t).wrapping_mul(0x9E37_79B9) | 1;
                        for _ in 0..2000 {
                            x ^= x << 13;
                            x ^= x >> 7;
                            x ^= x << 17;
                            let k = x % KEYS as u64;
                            if x & 0x100 == 0 {
                                if s.insert(k) {
                                    wins[k as usize].fetch_add(1, Ordering::SeqCst);
                                }
                            } else if s.remove(&k) {
                                evictions[k as usize].fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    })
                })
                .collect();
            for t in threads {
                t.join().unwrap();
            }
            for k in 0..KEYS {
                let w = wins[k].load(Ordering::SeqCst);
                let e = evictions[k].load(Ordering::SeqCst);
                let live = u64::from(s.contains(&(k as u64)));
                assert_eq!(
                    w - e,
                    live,
                    "seed {seed} key {k}: {w} wins, {e} evictions, live={live}"
                );
            }
        }
    }

    #[test]
    fn borrowed_key_ops_agree_with_owned() {
        let s: ConcurrentSet<Box<[u32]>> = ConcurrentSet::new();
        let key: Box<[u32]> = vec![1, 2, 3].into_boxed_slice();
        assert!(s.insert(key));
        // the borrowed form must route to the same shard as the owned key
        assert!(s.contains_borrowed::<[u32]>(&[1, 2, 3]));
        assert!(!s.contains_borrowed::<[u32]>(&[1, 2]));
        assert!(s.remove_borrowed::<[u32]>(&[1, 2, 3]));
        assert!(!s.remove_borrowed::<[u32]>(&[1, 2, 3]));
        assert!(s.is_empty());
    }

    #[test]
    fn set_for_each_visits_all() {
        let s: ConcurrentSet<u64> = ConcurrentSet::new();
        for i in 0..50 {
            s.insert(i);
        }
        let mut seen = Vec::new();
        s.for_each(|&k| seen.push(k));
        seen.sort_unstable();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
        assert_eq!(s.len(), 50, "for_each must not drain");
    }

    #[test]
    fn drain_returns_everything() {
        let m: ConcurrentMap<u32, u32> = ConcurrentMap::new();
        for i in 0..100 {
            m.insert(i, i * 2);
        }
        let mut all = m.drain_all();
        all.sort_unstable();
        assert_eq!(all.len(), 100);
        assert_eq!(all[10], (10, 20));
        assert!(m.is_empty());
    }
}
