//! Swappable synchronization layer (ISSUE 6 tentpole).
//!
//! Every concurrent module in the crate imports its primitives from here
//! instead of `std::sync`.  Under a normal build the re-exports below are
//! zero-cost aliases for the `std` types.  Under `RUSTFLAGS="--cfg loom"`
//! the lock/condvar/atomic types are swapped for the instrumented wrappers
//! in [`crate::util::loom_shim`], which inject scheduling perturbation at
//! every synchronization edge so the models in `rust/tests/loom_models.rs`
//! explore many interleavings per run.
//!
//! The offline build environment cannot vendor the real `loom` crate (no
//! network, no `cargo add` — see DESIGN.md "Substitutions"), so the shim is
//! a bundled, loom-shaped stress explorer: same import surface
//! (`util::sync::{Mutex, Condvar, atomic::*}`, `util::sync::model`), same
//! test layout, delegating to `std` with seeded yield points instead of
//! exhaustive interleaving search.  If a vendored loom ever lands, only the
//! `cfg(loom)` arm of this file changes; no call site moves.
//!
//! `cargo xtask lint-invariants` enforces that `std::sync::` / `core::sync::`
//! imports appear nowhere else in `rust/src` (this file and the shim are the
//! two allowlisted exceptions).
//!
//! This module also hosts the crate's audited unsafe surface:
//! [`ScopeShare`] / [`ScopedPtr`], the single lifetime-erasure mechanism
//! used to hand short-lived borrows to `'static` pool tasks.  All other
//! modules are `unsafe`-free (`#![warn(unsafe_code)]` in `lib.rs`).

// --- std arm -------------------------------------------------------------

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard, OnceLock, WaitTimeoutResult};

/// Atomic types and [`Ordering`](std::sync::atomic::Ordering).
#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
}

/// Run a concurrency model once (std arm: plain execution, no exploration).
#[cfg(not(loom))]
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    f();
}

// --- loom arm ------------------------------------------------------------

#[cfg(loom)]
pub use crate::util::loom_shim::{model, Condvar, Mutex};
#[cfg(loom)]
pub use std::sync::{Arc, LockResult, MutexGuard, OnceLock, WaitTimeoutResult};

#[cfg(loom)]
pub mod atomic {
    pub use crate::util::loom_shim::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
    pub use std::sync::atomic::Ordering;
}

// --- poison-immune locking -----------------------------------------------

/// Lock `m`, recovering the guard even if another thread panicked while
/// holding it (ISSUE 9: panic-safe pool).
///
/// `std`'s lock poisoning turns one panic into a cascade: every later
/// `lock().unwrap()` on the same mutex re-panics, so a single failed
/// subproblem can take down sibling workers, the scope join, and the whole
/// session.  Our panic-safety contract is enforced structurally instead —
/// the pool catches unwinds at the job boundary and re-surfaces the first
/// payload at scope join ([`RunOutcome::Panicked`]
/// (crate::session::RunOutcome)) — so poison adds no protection here, only
/// the cascade.  Every crate-internal lock therefore goes through `plock`;
/// `cargo xtask lint-invariants` (rule `no-lock-unwrap`) forbids
/// `lock().unwrap()` / `lock().expect(` outside this seam.
///
/// The data-consistency caveat is real but bounded: a guard recovered from
/// a poisoned mutex may see state mid-update.  Crate locks guard
/// append/swap-shaped state (queues, buffers, snapshot cells) whose
/// invariants hold between statements, and results from a panicked scope
/// are only ever reported as partial.
#[inline]
pub fn plock<'a, T: ?Sized>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`Condvar::wait_timeout`] with the same poison-immune discipline as
/// [`plock`]: a panic elsewhere must never cascade into a waiting thread.
#[inline]
pub fn pwait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    match cv.wait_timeout(guard, dur) {
        Ok(r) => r,
        Err(poisoned) => poisoned.into_inner(),
    }
}

// --- audited lifetime-erasure surface ------------------------------------

/// Witness that a pool scope pins the lifetime of shared borrows.
///
/// The pool's `'static` task bound forces parallel kernels that borrow
/// caller data (`par_pivot`, `par_imce_batch`) to erase lifetimes.  Instead
/// of per-call-site raw-pointer structs with hand-rolled `unsafe impl Send`
/// (the pre-ISSUE-6 pattern), each kernel creates **one** `ScopeShare`
/// witness — the only `unsafe` act — and derives every shared pointer from
/// it via the safe [`share`](Self::share).
///
/// # Safety contract (checked at construction)
///
/// `ScopeShare::new` is `unsafe`; the caller promises that every reference
/// later passed to [`share`](Self::share) **outlives every task that can
/// observe the resulting [`ScopedPtr`]**.  In this codebase that holds
/// because the pointers are only moved into tasks spawned inside a
/// [`ThreadPool::scope`](crate::coordinator::pool::ThreadPool::scope) call,
/// which blocks until all (transitively) spawned tasks complete — the
/// borrows live across the whole scope.
pub struct ScopeShare {
    _priv: (),
}

impl ScopeShare {
    /// Create the witness for one pool scope.
    ///
    /// # Safety
    ///
    /// Every reference subsequently passed to [`share`](Self::share) must
    /// remain valid until every task holding a derived [`ScopedPtr`] has
    /// finished.  The canonical pattern is: create the witness, share the
    /// borrows, spawn tasks **only** inside a `pool.scope(..)` whose join
    /// precedes the end of every shared borrow.
    #[allow(unsafe_code)]
    pub unsafe fn new() -> Self {
        ScopeShare { _priv: () }
    }

    /// Erase the lifetime of `r` under this witness's contract.
    ///
    /// Safe because the validity obligation was assumed when the witness
    /// was created with [`ScopeShare::new`].
    #[inline]
    pub fn share<T: ?Sized>(&self, r: &T) -> ScopedPtr<T> {
        ScopedPtr { ptr: r as *const T }
    }
}

/// A lifetime-erased shared reference produced by [`ScopeShare::share`].
///
/// `Copy`, `Send`/`Sync` when `T: Sync` (it only ever hands out `&T`), and
/// dereferenced through the safe [`get`](Self::get) — the pointee is alive
/// for as long as any task can hold the pointer, per the [`ScopeShare`]
/// contract.
pub struct ScopedPtr<T: ?Sized> {
    ptr: *const T,
}

impl<T: ?Sized> Clone for ScopedPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T: ?Sized> Copy for ScopedPtr<T> {}

// SAFETY: a ScopedPtr only ever yields `&T` (never `&mut T`), so moving or
// sharing it across threads is exactly as safe as sharing `&T`, i.e. sound
// when `T: Sync`.  Pointee liveness across threads is the ScopeShare
// contract: tasks holding the pointer are joined before the borrow ends.
#[allow(unsafe_code)]
unsafe impl<T: ?Sized + Sync> Send for ScopedPtr<T> {}
// SAFETY: as above — `&ScopedPtr<T>` exposes nothing beyond `&T`.
#[allow(unsafe_code)]
unsafe impl<T: ?Sized + Sync> Sync for ScopedPtr<T> {}

impl<T: ?Sized> ScopedPtr<T> {
    /// Borrow the pointee.
    #[inline]
    pub fn get(&self) -> &T {
        // SAFETY: this pointer was created by ScopeShare::share; the
        // (unsafe) ScopeShare::new contract guarantees the referent is
        // alive until every task that can observe the pointer has
        // completed, which bounds the lifetime of this borrow.
        #[allow(unsafe_code)]
        unsafe {
            &*self.ptr
        }
    }
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::*;

    #[test]
    fn model_runs_body() {
        // std arm: `model` must execute the closure (exactly once per call).
        static HITS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        model(|| {
            HITS.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert!(HITS.load(std::sync::atomic::Ordering::SeqCst) >= 1);
    }

    #[test]
    fn scoped_ptr_round_trips() {
        let data = vec![1u32, 2, 3];
        let total = AtomicUsize::new(0);
        // SAFETY: the shared borrows (`data`, `total`) outlive every thread
        // below — all threads are joined before this frame returns.
        #[allow(unsafe_code)]
        let share = unsafe { ScopeShare::new() };
        let d = share.share(data.as_slice());
        let t = share.share(&total);
        let threads: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let sum: u32 = d.get().iter().sum();
                    t.get().fetch_add(sum as usize, Ordering::SeqCst);
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 6);
    }

    #[test]
    fn scoped_ptr_is_copy() {
        let x = 42u64;
        // SAFETY: `x` outlives both copies; no threads involved.
        #[allow(unsafe_code)]
        let share = unsafe { ScopeShare::new() };
        let p = share.share(&x);
        let q = p; // Copy
        assert_eq!(*p.get(), 42);
        assert_eq!(*q.get(), 42);
    }
}
