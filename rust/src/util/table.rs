//! Markdown table rendering for the experiment harness — each `parmce exp`
//! subcommand prints the same rows as the corresponding paper table/figure.

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

/// Format seconds with sensible precision for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.1}")
    } else if s >= 0.001 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

/// Format a speedup factor.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format large counts with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Dataset", "TTT", "ParMCE"]);
        t.row(vec!["dblp-like".into(), "42".into(), "2".into()]);
        t.row(vec!["x".into(), "28923".into(), "1676".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| Dataset   | TTT   | ParMCE |"));
        assert!(r.contains("| x         | 28923 | 1676   |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(12.34), "12.3");
        assert_eq!(fmt_secs(0.01234), "12.3ms");
        assert_eq!(fmt_secs(0.0000123), "12µs");
        assert_eq!(fmt_speedup(21.456), "21.46x");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(42), "42");
    }
}
