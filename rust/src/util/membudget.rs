//! Cooperative memory budget.
//!
//! Table 8 of the paper reports that CliqueEnumerator and Hashing run *out
//! of memory* on every input while ParMCE completes.  Actually exhausting
//! RAM in CI is antisocial, so the reimplemented baselines charge their
//! dominant allocations (bit vectors, intermediate non-maximal clique sets)
//! against a `MemBudget`; exceeding it aborts the run with `OutOfBudget`,
//! which the experiment harness prints as the paper's "Out of memory" cell.

use crate::util::sync::atomic::{AtomicUsize, Ordering};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BudgetError {
    /// The run exceeded its byte budget (reported bytes = attempted total).
    OutOfBudget { attempted: usize, cap: usize },
    /// The run exceeded its wall-clock deadline.
    TimedOut { elapsed_ms: u64, cap_ms: u64 },
}

impl std::fmt::Display for BudgetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetError::OutOfBudget { attempted, cap } => write!(
                f,
                "out of memory budget: attempted {attempted} bytes > cap {cap} bytes"
            ),
            BudgetError::TimedOut { elapsed_ms, cap_ms } => {
                write!(f, "timed out: {elapsed_ms}ms > cap {cap_ms}ms")
            }
        }
    }
}

impl std::error::Error for BudgetError {}

pub struct MemBudget {
    used: AtomicUsize,
    peak: AtomicUsize,
    cap: usize,
}

impl MemBudget {
    pub fn new(cap_bytes: usize) -> Self {
        MemBudget {
            used: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            cap: cap_bytes,
        }
    }

    /// Effectively unlimited (for running a baseline to completion).
    pub fn unlimited() -> Self {
        Self::new(usize::MAX)
    }

    /// Charge `bytes`; error if the running total would exceed the cap.
    pub fn charge(&self, bytes: usize) -> Result<(), BudgetError> {
        // `membudget-charge` failpoint: `error` makes this reservation
        // the one that trips the budget (an injected alloc denial).
        if crate::util::failpoints::hit(crate::util::failpoints::Site::MembudgetCharge) {
            return Err(BudgetError::OutOfBudget {
                attempted: usize::MAX,
                cap: self.cap,
            });
        }
        let prev = self.used.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
        if now > self.cap {
            Err(BudgetError::OutOfBudget {
                attempted: now,
                cap: self.cap,
            })
        } else {
            Ok(())
        }
    }

    /// Return `bytes` to the budget (freed allocation).
    pub fn release(&self, bytes: usize) {
        self.used.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn used(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }
}

/// Wall-clock deadline guard (Table 8's "did not complete in 5 hours" rows).
pub struct Deadline {
    start: std::time::Instant,
    cap: std::time::Duration,
}

impl Deadline {
    pub fn new(cap: std::time::Duration) -> Self {
        Deadline {
            start: std::time::Instant::now(),
            cap,
        }
    }

    pub fn check(&self) -> Result<(), BudgetError> {
        let elapsed = self.start.elapsed();
        if elapsed > self.cap {
            Err(BudgetError::TimedOut {
                elapsed_ms: elapsed.as_millis() as u64,
                cap_ms: self.cap.as_millis() as u64,
            })
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_under_cap_ok() {
        let b = MemBudget::new(1000);
        assert!(b.charge(400).is_ok());
        assert!(b.charge(400).is_ok());
        assert_eq!(b.used(), 800);
        assert_eq!(b.peak(), 800);
    }

    #[test]
    fn charge_over_cap_errors() {
        let b = MemBudget::new(1000);
        b.charge(900).unwrap();
        let err = b.charge(200).unwrap_err();
        match err {
            BudgetError::OutOfBudget { attempted, cap } => {
                assert_eq!(attempted, 1100);
                assert_eq!(cap, 1000);
            }
            _ => panic!("wrong error kind"),
        }
    }

    #[test]
    fn release_frees_headroom() {
        let b = MemBudget::new(1000);
        b.charge(900).unwrap();
        b.release(800);
        assert!(b.charge(500).is_ok());
        assert_eq!(b.peak(), 900.max(b.used()));
    }

    #[test]
    fn deadline_trips() {
        let d = Deadline::new(std::time::Duration::from_millis(1));
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(d.check().is_err());
        let ok = Deadline::new(std::time::Duration::from_secs(3600));
        assert!(ok.check().is_ok());
    }
}
