//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::bench`]: warmup, then timed iterations, reporting min /
//! median / mean / MAD.  Results can be dumped as JSON for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u32,
    pub min_ns: u64,
    pub median_ns: u64,
    pub mean_ns: u64,
    pub mad_ns: u64,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median_ns as f64 / 1e9
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters)),
            ("min_ns", Json::num(self.min_ns as f64)),
            ("median_ns", Json::num(self.median_ns as f64)),
            ("mean_ns", Json::num(self.mean_ns as f64)),
            ("mad_ns", Json::num(self.mad_ns as f64)),
        ])
    }
}

pub struct Bencher {
    pub warmup: u32,
    pub iters: u32,
    pub results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 1,
            iters: 5,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup: u32, iters: u32) -> Self {
        Bencher {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Quick-mode bencher honoring PARMCE_BENCH_FAST=1 (CI-friendly).
    pub fn from_env() -> Self {
        if std::env::var("PARMCE_BENCH_FAST").as_deref() == Ok("1") {
            Bencher::new(0, 2)
        } else {
            Bencher::default()
        }
    }

    /// Time `f` and record stats under `name`. Returns the median in ns.
    pub fn bench<R>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> R) -> u64 {
        let name = name.into();
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        samples.sort_unstable();
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<u64>() / samples.len() as u64;
        let mad = {
            let mut dev: Vec<u64> = samples.iter().map(|&s| s.abs_diff(median)).collect();
            dev.sort_unstable();
            dev[dev.len() / 2]
        };
        let stats = Stats {
            name: name.clone(),
            iters: self.iters,
            min_ns: min,
            median_ns: median,
            mean_ns: mean,
            mad_ns: mad,
        };
        println!(
            "bench {:<48} median {:>12}  min {:>12}  mean {:>12}  ±{}",
            stats.name,
            crate::util::fmt_ns(stats.median_ns),
            crate::util::fmt_ns(stats.min_ns),
            crate::util::fmt_ns(stats.mean_ns),
            crate::util::fmt_ns(stats.mad_ns),
        );
        self.results.push(stats);
        median
    }

    /// Write accumulated results as JSON to `path` (best-effort).
    pub fn dump_json(&self, path: &str) {
        let arr = Json::arr(self.results.iter().map(|s| s.to_json()));
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Err(e) = std::fs::write(path, arr.to_string_pretty()) {
            eprintln!("warn: could not write {path}: {e}");
        } else {
            println!("wrote {path}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_stats() {
        let mut b = Bencher::new(0, 3);
        let med = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(med > 0);
        assert_eq!(b.results.len(), 1);
        let s = &b.results[0];
        assert!(s.min_ns <= s.median_ns);
        assert_eq!(s.name, "spin");
    }

    #[test]
    fn dump_json_writes_file() {
        let mut b = Bencher::new(0, 1);
        b.bench("x", || 1 + 1);
        let dir = std::env::temp_dir().join("parmce_bench_test");
        let path = dir.join("out.json");
        b.dump_json(path.to_str().unwrap());
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
