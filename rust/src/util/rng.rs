//! Deterministic pseudo-random numbers: SplitMix64 seeding + xoshiro256**.
//!
//! Every stochastic component of the library (graph generators, property
//! tests, the GP placement simulator) takes an explicit seed so that every
//! experiment in EXPERIMENTS.md is exactly reproducible.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step: used to expand a single u64 seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-batch seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn gen_usize(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Geometric-ish heavy-tail sample in [lo, hi]: P(x) ∝ alpha^-x.
    pub fn gen_powerlaw(&mut self, lo: u64, hi: u64, alpha: f64) -> u64 {
        debug_assert!(lo <= hi && alpha > 1.0);
        // inverse-CDF of a truncated Pareto on [lo, hi+1)
        let (l, h) = (lo as f64, (hi + 1) as f64);
        let a = 1.0 - alpha;
        let u = self.gen_f64();
        let x = ((h.powf(a) - l.powf(a)) * u + l.powf(a)).powf(1.0 / a);
        (x as u64).clamp(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle left identity (astronomically unlikely)");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        for _ in 0..50 {
            let s = r.sample_indices(20, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn powerlaw_in_bounds_and_skewed() {
        let mut r = Rng::new(13);
        let mut lows = 0;
        for _ in 0..5000 {
            let x = r.gen_powerlaw(1, 100, 2.5);
            assert!((1..=100).contains(&x));
            if x <= 3 {
                lows += 1;
            }
        }
        assert!(lows > 2500, "power law should concentrate near the low end, got {lows}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
