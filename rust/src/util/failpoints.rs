//! Deterministic fault injection at the crate's hot seams (ISSUE 9).
//!
//! A *failpoint* is a named site threaded through a seam where real
//! deployments fail — pool job dequeue/spawn, sink emit/merge/flush,
//! memory-budget reservation, snapshot publish, service freeze, dynamic
//! batch apply.  Tests and the CLI (`--fail-spec`, `PARMCE_FAIL_SPEC`)
//! arm a site with an [`Action`]:
//!
//! * `panic` — unwind with the message `failpoint <site>: injected panic`
//!   (the site name is recoverable from the payload, see
//!   [`crate::session::RunOutcome::Panicked`]);
//! * `error` — [`hit`] returns `true` and the call site maps that to its
//!   local error type (an `io::Error`, a `BudgetError`, a batch-apply
//!   rejection, …);
//! * `delay(ms)` — sleep at the site, for deadline/backoff paths.
//!
//! Firing is **deterministic**: a site fires always, with probability `p`
//! (seeded splitmix64 over the per-site hit counter — same seed, same
//! schedule), or exactly on its `K`-th hit (`@K`, for reproducible
//! mid-run faults).  Spec grammar, comma-separated:
//!
//! ```text
//! site=action[:prob][:@K][:seed]
//! sink-emit=panic:@100            # panic on the 100th emit
//! pool-spawn=error                # every worker spawn fails
//! service-freeze=error:0.5:42     # half of freezes fail, seed 42
//! dynamic-apply=delay(20)         # 20ms stall per batch apply
//! ```
//!
//! Without the `failpoints` cargo feature the whole registry compiles to
//! an `#[inline(always)] false`, so the default build carries zero
//! failpoint branches (acceptance-checked by `benches/`); mirroring the
//! `telemetry-off` pattern, call sites are identical in both builds.

use std::fmt;

/// Every registered fail-point site.  Adding a site means adding a
/// variant here, threading a [`hit`] call through the seam, and listing
/// it in DESIGN.md's failpoint site table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Worker-thread creation in `ThreadPool::new` (`error` simulates OS
    /// spawn failure; the pool degrades to fewer workers).
    PoolSpawn,
    /// Start of every dequeued pool job, inside the unwind-catch
    /// boundary (`panic`/`delay`; `error` is a no-op — a job cannot be
    /// dropped without hanging its scope).
    PoolDequeue,
    /// `CountedSink::emit` — the per-clique hot path (`error` drops the
    /// emit).
    SinkEmit,
    /// Sharded-sink merge after scope join (`panic`/`delay`).
    SinkMerge,
    /// `StreamWriterSink` buffer flush to the underlying writer (`error`
    /// injects a sticky `io::Error`).
    SinkFlush,
    /// `MemBudget::charge` (`error` synthesizes an out-of-budget
    /// rejection).
    MembudgetCharge,
    /// `GraphCell::publish` — the epoch-snapshot publish seam
    /// (`panic`/`delay`; `error` is a no-op, a skipped graph publish
    /// would break epoch monotonicity).
    GraphPublish,
    /// `ServiceShared::on_batch` freeze/publish (`error` is retried with
    /// backoff, then degrades to skip-publish).
    ServiceFreeze,
    /// `DynamicSession` batch apply/remove entry, before any mutation
    /// (`error` rejects the batch at an exact boundary).
    DynamicApply,
}

impl Site {
    pub const ALL: [Site; 9] = [
        Site::PoolSpawn,
        Site::PoolDequeue,
        Site::SinkEmit,
        Site::SinkMerge,
        Site::SinkFlush,
        Site::MembudgetCharge,
        Site::GraphPublish,
        Site::ServiceFreeze,
        Site::DynamicApply,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Site::PoolSpawn => "pool-spawn",
            Site::PoolDequeue => "pool-dequeue",
            Site::SinkEmit => "sink-emit",
            Site::SinkMerge => "sink-merge",
            Site::SinkFlush => "sink-flush",
            Site::MembudgetCharge => "membudget-charge",
            Site::GraphPublish => "graph-publish",
            Site::ServiceFreeze => "service-freeze",
            Site::DynamicApply => "dynamic-apply",
        }
    }

    pub fn parse(s: &str) -> Option<Site> {
        Site::ALL.iter().copied().find(|site| site.name() == s)
    }

    fn index(self) -> usize {
        Site::ALL
            .iter()
            .position(|&s| s == self)
            .expect("Site::ALL lists every variant")
    }
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What an armed site does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Unwind with `failpoint <site>: injected panic`.
    Panic,
    /// [`hit`] returns `true`; the call site maps it to its local error.
    ReturnError,
    /// Sleep this many milliseconds, then behave as a non-fire.
    Delay(u64),
}

/// When an armed site fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Every hit.
    Always,
    /// Each hit independently with this probability, from the seeded
    /// per-site counter stream (deterministic across runs).
    Prob(f64),
    /// Exactly the `K`-th hit (1-based), once.
    OnHit(u64),
}

/// One armed site: the action, its trigger, and the RNG seed for
/// [`Trigger::Prob`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteConfig {
    pub action: Action,
    pub trigger: Trigger,
    pub seed: u64,
}

/// Parse a full `--fail-spec` string into `(site, config)` pairs.
///
/// Compiled in every build so the CLI can *validate* a spec (and report
/// that the binary lacks the feature) even when injection is compiled
/// out.
pub fn parse_spec(spec: &str) -> Result<Vec<(Site, SiteConfig)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site_s, rest) = part
            .split_once('=')
            .ok_or_else(|| format!("fail-spec `{part}`: expected site=action"))?;
        let site = Site::parse(site_s.trim()).ok_or_else(|| {
            let known: Vec<&str> = Site::ALL.iter().map(|s| s.name()).collect();
            format!(
                "fail-spec `{part}`: unknown site `{}` (known: {})",
                site_s.trim(),
                known.join(", ")
            )
        })?;
        let mut tokens = rest.split(':');
        let action_s = tokens.next().unwrap_or("").trim();
        let action = parse_action(action_s)
            .ok_or_else(|| format!("fail-spec `{part}`: unknown action `{action_s}` (panic, error, delay(ms))"))?;
        let mut cfg = SiteConfig {
            action,
            trigger: Trigger::Always,
            seed: 0x9e37_79b9_7f4a_7c15,
        };
        for tok in tokens {
            let tok = tok.trim();
            if let Some(k) = tok.strip_prefix('@') {
                let k: u64 = k
                    .parse()
                    .map_err(|_| format!("fail-spec `{part}`: bad hit index `{tok}`"))?;
                if k == 0 {
                    return Err(format!("fail-spec `{part}`: hit index is 1-based"));
                }
                cfg.trigger = Trigger::OnHit(k);
            } else if tok.contains('.') {
                let p: f64 = tok
                    .parse()
                    .map_err(|_| format!("fail-spec `{part}`: bad probability `{tok}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fail-spec `{part}`: probability `{tok}` not in [0,1]"));
                }
                cfg.trigger = Trigger::Prob(p);
            } else {
                cfg.seed = tok
                    .parse()
                    .map_err(|_| format!("fail-spec `{part}`: bad seed `{tok}`"))?;
            }
        }
        out.push((site, cfg));
    }
    Ok(out)
}

fn parse_action(s: &str) -> Option<Action> {
    match s {
        "panic" => Some(Action::Panic),
        "error" | "return-error" => Some(Action::ReturnError),
        _ => {
            let ms = s.strip_prefix("delay(")?.strip_suffix(')')?;
            ms.trim().parse().ok().map(Action::Delay)
        }
    }
}

// --- enabled arm ----------------------------------------------------------

#[cfg(feature = "failpoints")]
mod enabled {
    use super::*;
    use crate::util::sync::atomic::{AtomicU32, AtomicU64, Ordering};
    use crate::util::sync::{plock, Mutex, OnceLock};

    struct State {
        /// Bitmask of armed sites — the only load on a hit for unarmed
        /// sites, so an idle registry stays cheap even with the feature
        /// compiled in.
        armed: AtomicU32,
        sites: [Mutex<Option<SiteConfig>>; Site::ALL.len()],
        counters: [AtomicU64; Site::ALL.len()],
    }

    fn state() -> &'static State {
        static STATE: OnceLock<State> = OnceLock::new();
        STATE.get_or_init(|| State {
            armed: AtomicU32::new(0),
            sites: std::array::from_fn(|_| Mutex::new(None)),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    fn splitmix64(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^ (x >> 31)
    }

    /// Evaluate the site: sleeps for `delay`, unwinds for `panic`,
    /// returns `true` for `error`.
    pub fn hit(site: Site) -> bool {
        let st = state();
        let bit = 1u32 << site.index();
        if st.armed.load(Ordering::Acquire) & bit == 0 {
            return false;
        }
        let cfg = match *plock(&st.sites[site.index()]) {
            Some(cfg) => cfg,
            None => return false,
        };
        // 1-based hit number; SeqCst so `@K` fires exactly once even when
        // several workers hit the site concurrently.
        let n = st.counters[site.index()].fetch_add(1, Ordering::SeqCst) + 1;
        let fire = match cfg.trigger {
            Trigger::Always => true,
            Trigger::OnHit(k) => n == k,
            Trigger::Prob(p) => {
                let draw = splitmix64(cfg.seed ^ n) as f64 / u64::MAX as f64;
                draw < p
            }
        };
        if !fire {
            return false;
        }
        match cfg.action {
            Action::Delay(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                false
            }
            Action::ReturnError => true,
            Action::Panic => panic!("failpoint {site}: injected panic"),
        }
    }

    /// Arm one site (replacing any previous config for it).
    pub fn configure(site: Site, cfg: SiteConfig) {
        let st = state();
        *plock(&st.sites[site.index()]) = Some(cfg);
        st.counters[site.index()].store(0, Ordering::SeqCst);
        st.armed
            .fetch_or(1u32 << site.index(), Ordering::Release);
    }

    /// Disarm everything and zero the hit counters.
    pub fn clear() {
        let st = state();
        st.armed.store(0, Ordering::Release);
        for (slot, ctr) in st.sites.iter().zip(st.counters.iter()) {
            *plock(slot) = None;
            ctr.store(0, Ordering::SeqCst);
        }
    }

    /// Hits recorded at `site` since the last [`configure`]/[`clear`].
    pub fn hits(site: Site) -> u64 {
        state().counters[site.index()].load(Ordering::SeqCst)
    }

    pub fn configure_from_spec(spec: &str) -> Result<(), String> {
        for (site, cfg) in parse_spec(spec)? {
            configure(site, cfg);
        }
        Ok(())
    }

    /// The registry is process-global; tests that arm it hold this guard
    /// so concurrent `#[test]`s cannot cross-arm or clear each other.
    pub fn exclusive() -> crate::util::sync::MutexGuard<'static, ()> {
        static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
        plock(GUARD.get_or_init(|| Mutex::new(())))
    }
}

#[cfg(feature = "failpoints")]
pub use enabled::{clear, configure, configure_from_spec, exclusive, hit, hits};

// --- disabled arm ---------------------------------------------------------

#[cfg(not(feature = "failpoints"))]
mod disabled {
    use super::*;

    /// Constant `false`: the compiler folds the branch away, so the
    /// default build contains zero failpoint branches.
    #[inline(always)]
    pub fn hit(_site: Site) -> bool {
        false
    }

    /// Validates the spec, then reports that injection is compiled out —
    /// a silently ignored `--fail-spec` would be worse than an error.
    pub fn configure_from_spec(spec: &str) -> Result<(), String> {
        parse_spec(spec)?;
        Err("this build has no fault injection (rebuild with `--features failpoints`)".into())
    }

    pub fn configure(_site: Site, _cfg: SiteConfig) {}
    pub fn clear() {}
    pub fn hits(_site: Site) -> u64 {
        0
    }
}

#[cfg(not(feature = "failpoints"))]
pub use disabled::{clear, configure, configure_from_spec, hit, hits};

/// Read `PARMCE_FAIL_SPEC` if set; `Ok(false)` when absent.
pub fn init_from_env() -> Result<bool, String> {
    match std::env::var("PARMCE_FAIL_SPEC") {
        Ok(spec) if !spec.trim().is_empty() => {
            configure_from_spec(&spec)?;
            Ok(true)
        }
        _ => Ok(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_every_form() {
        let parsed = parse_spec(
            "sink-emit=panic:@100, pool-spawn=error, service-freeze=error:0.5:42, dynamic-apply=delay(20)",
        )
        .unwrap();
        assert_eq!(parsed.len(), 4);
        assert_eq!(parsed[0].0, Site::SinkEmit);
        assert_eq!(parsed[0].1.action, Action::Panic);
        assert_eq!(parsed[0].1.trigger, Trigger::OnHit(100));
        assert_eq!(parsed[1].1.action, Action::ReturnError);
        assert_eq!(parsed[1].1.trigger, Trigger::Always);
        assert_eq!(parsed[2].1.trigger, Trigger::Prob(0.5));
        assert_eq!(parsed[2].1.seed, 42);
        assert_eq!(parsed[3].1.action, Action::Delay(20));
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(parse_spec("nope=panic").is_err());
        assert!(parse_spec("sink-emit").is_err());
        assert!(parse_spec("sink-emit=explode").is_err());
        assert!(parse_spec("sink-emit=panic:@0").is_err());
        assert!(parse_spec("sink-emit=panic:1.5").is_err());
        assert!(parse_spec("sink-emit=delay(x)").is_err());
    }

    #[test]
    fn site_names_round_trip() {
        for site in Site::ALL {
            assert_eq!(Site::parse(site.name()), Some(site));
        }
        assert_eq!(Site::parse("bogus"), None);
    }

    #[cfg(feature = "failpoints")]
    mod armed {
        use super::*;

        #[test]
        fn error_action_fires_on_exact_hit() {
            let _g = exclusive();
            clear();
            configure(
                Site::MembudgetCharge,
                SiteConfig {
                    action: Action::ReturnError,
                    trigger: Trigger::OnHit(3),
                    seed: 0,
                },
            );
            let fired: Vec<bool> = (0..5).map(|_| hit(Site::MembudgetCharge)).collect();
            assert_eq!(fired, vec![false, false, true, false, false]);
            assert_eq!(hits(Site::MembudgetCharge), 5);
            clear();
        }

        #[test]
        fn prob_schedule_is_deterministic_and_roughly_calibrated() {
            let _g = exclusive();
            let run = || {
                clear();
                configure(
                    Site::MembudgetCharge,
                    SiteConfig {
                        action: Action::ReturnError,
                        trigger: Trigger::Prob(0.3),
                        seed: 7,
                    },
                );
                let v: Vec<bool> = (0..1000).map(|_| hit(Site::MembudgetCharge)).collect();
                clear();
                v
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "same seed must give the same schedule");
            let fires = a.iter().filter(|&&f| f).count();
            assert!((150..450).contains(&fires), "p=0.3 fired {fires}/1000");
        }

        #[test]
        fn panic_action_unwinds_with_site_in_message() {
            let _g = exclusive();
            clear();
            configure(
                Site::SinkMerge,
                SiteConfig {
                    action: Action::Panic,
                    trigger: Trigger::Always,
                    seed: 0,
                },
            );
            let err = std::panic::catch_unwind(|| hit(Site::SinkMerge)).unwrap_err();
            clear();
            let msg = err
                .downcast_ref::<String>()
                .expect("panic payload is a String");
            assert_eq!(msg, "failpoint sink-merge: injected panic");
        }

        #[test]
        fn unarmed_sites_never_fire() {
            let _g = exclusive();
            clear();
            for site in Site::ALL {
                assert!(!hit(site));
            }
        }
    }
}
