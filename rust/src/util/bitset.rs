//! Fixed-capacity bitset over u64 words.
//!
//! The dense representation for `cand` / `fini` inside small subproblems
//! (the perf-pass hot path, see DESIGN.md §Perf): intersection with a
//! neighbourhood becomes word-wise AND, and pivot scoring becomes popcount.

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    pub fn new(capacity: usize) -> Self {
        BitSet {
            words: vec![0; capacity.div_ceil(64)],
            capacity,
        }
    }

    pub fn from_iter_cap(capacity: usize, it: impl IntoIterator<Item = u32>) -> Self {
        let mut s = BitSet::new(capacity);
        for v in it {
            s.insert(v);
        }
        s
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, i: u32) {
        debug_assert!((i as usize) < self.capacity);
        self.words[i as usize >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn remove(&mut self, i: u32) {
        debug_assert!((i as usize) < self.capacity);
        self.words[i as usize >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn contains(&self, i: u32) -> bool {
        ((self.words[i as usize >> 6] >> (i & 63)) & 1) != 0
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// self ∩= other
    pub fn intersect_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// self ∪= other
    pub fn union_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// self \= other
    pub fn subtract(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// |self ∩ other| without allocating.
    pub fn intersection_count(&self, other: &BitSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// out = self ∩ other (out is cleared first; capacities must match).
    pub fn intersection_into(&self, other: &BitSet, out: &mut BitSet) {
        for ((o, a), b) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            *o = a & b;
        }
    }

    pub fn iter(&self) -> BitIter<'_> {
        BitIter::over(&self.words)
    }

    /// First set bit, if any.
    pub fn first(&self) -> Option<u32> {
        self.iter().next()
    }

    pub fn to_vec(&self) -> Vec<u32> {
        self.iter().collect()
    }

    /// Approximate heap footprint in bytes (for the memory-budget guard).
    pub fn heap_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// A bit row is a plain word slice: the unit [`BitMatrix`] hands out and
/// the [`row`] helpers below operate on.  All rows in one kernel share a
/// stride, so word-wise zips never run ragged.
pub type BitRow = [u64];

/// Word-slice primitives for fixed-stride rows (the bit-parallel kernel
/// hot path — see `mce::bitkernel`).  Callers guarantee equal lengths;
/// the zips silently truncate otherwise, so debug asserts guard it.
pub mod row {
    use super::{BitIter, BitRow};

    /// out = a ∩ b.
    #[inline]
    pub fn and_into(a: &BitRow, b: &BitRow, out: &mut BitRow) {
        debug_assert!(a.len() == b.len() && a.len() == out.len());
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x & y;
        }
    }

    /// out = a \ b.
    #[inline]
    pub fn and_not_into(a: &BitRow, b: &BitRow, out: &mut BitRow) {
        debug_assert!(a.len() == b.len() && a.len() == out.len());
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x & !y;
        }
    }

    /// |a ∩ b| by popcount, no allocation.
    #[inline]
    pub fn and_count(a: &BitRow, b: &BitRow) -> usize {
        debug_assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as usize).sum()
    }

    /// Does a ∩ b have any member?
    #[inline]
    pub fn intersects(a: &BitRow, b: &BitRow) -> bool {
        a.iter().zip(b).any(|(&x, &y)| x & y != 0)
    }

    #[inline]
    pub fn count(a: &BitRow) -> usize {
        a.iter().map(|w| w.count_ones() as usize).sum()
    }

    #[inline]
    pub fn is_empty(a: &BitRow) -> bool {
        a.iter().all(|&w| w == 0)
    }

    #[inline]
    pub fn set(a: &mut BitRow, i: u32) {
        a[i as usize >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(a: &mut BitRow, i: u32) {
        a[i as usize >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn test(a: &BitRow, i: u32) -> bool {
        (a[i as usize >> 6] >> (i & 63)) & 1 != 0
    }

    /// Iterate set bits in ascending order.
    #[inline]
    pub fn iter(a: &BitRow) -> BitIter<'_> {
        BitIter::over(a)
    }
}

/// Fixed-stride dense adjacency over a relabeled `0..w` vertex window:
/// row `i` holds the in-window neighbours of local vertex `i` as bits.
/// One flat allocation, reusable across kernel invocations via
/// [`BitMatrix::reset`] (the per-worker arena keeps one around).
#[derive(Clone, Debug, Default)]
pub struct BitMatrix {
    words: Vec<u64>,
    stride: usize,
    rows: usize,
}

impl BitMatrix {
    pub fn new(rows: usize) -> Self {
        let mut m = BitMatrix::default();
        m.reset(rows);
        m
    }

    /// Re-shape to a square `rows × rows` matrix, zeroing every bit.
    /// Keeps the existing allocation when it is large enough.
    pub fn reset(&mut self, rows: usize) {
        self.rows = rows;
        self.stride = rows.div_ceil(64);
        self.words.clear();
        self.words.resize(rows * self.stride, 0);
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Words per row — the shared stride of every [`BitRow`] here.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn row(&self, r: usize) -> &BitRow {
        &self.words[r * self.stride..(r + 1) * self.stride]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut BitRow {
        &mut self.words[r * self.stride..(r + 1) * self.stride]
    }

    /// Set the (r, c) bit — `c` is a local column id `< rows`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize) {
        debug_assert!(r < self.rows && c < self.rows);
        self.words[r * self.stride + (c >> 6)] |= 1u64 << (c & 63);
    }

    #[inline]
    pub fn test(&self, r: usize, c: usize) -> bool {
        (self.words[r * self.stride + (c >> 6)] >> (c & 63)) & 1 != 0
    }
}

pub struct BitIter<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl<'a> BitIter<'a> {
    /// Iterate the set bits of a raw word slice.
    #[inline]
    pub fn over(words: &'a [u64]) -> Self {
        BitIter {
            words,
            word_idx: 0,
            current: words.first().copied().unwrap_or(0),
        }
    }
}

impl Iterator for BitIter<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros();
                self.current &= self.current - 1;
                return Some((self.word_idx as u32) * 64 + bit);
            }
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(200);
        assert!(!s.contains(5));
        s.insert(5);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(5) && s.contains(64) && s.contains(199));
        assert_eq!(s.len(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_in_order() {
        let s = BitSet::from_iter_cap(300, [7u32, 0, 255, 64, 63]);
        assert_eq!(s.to_vec(), vec![0, 7, 63, 64, 255]);
    }

    #[test]
    fn set_ops_match_naive() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let cap = 130;
            let a_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.3)).collect();
            let b_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.3)).collect();
            let a = BitSet::from_iter_cap(cap, a_v.iter().copied());
            let b = BitSet::from_iter_cap(cap, b_v.iter().copied());

            let inter_naive: Vec<u32> =
                a_v.iter().filter(|v| b_v.contains(v)).copied().collect();
            let mut i = a.clone();
            i.intersect_with(&b);
            assert_eq!(i.to_vec(), inter_naive);
            assert_eq!(a.intersection_count(&b), inter_naive.len());

            let mut u = a.clone();
            u.union_with(&b);
            let mut union_naive = a_v.clone();
            union_naive.extend(b_v.iter().filter(|v| !a_v.contains(v)));
            union_naive.sort_unstable();
            assert_eq!(u.to_vec(), union_naive);

            let mut d = a.clone();
            d.subtract(&b);
            let diff_naive: Vec<u32> =
                a_v.iter().filter(|v| !b_v.contains(v)).copied().collect();
            assert_eq!(d.to_vec(), diff_naive);
        }
    }

    #[test]
    fn intersection_into_reuses_buffer() {
        let a = BitSet::from_iter_cap(128, [1u32, 2, 3, 100]);
        let b = BitSet::from_iter_cap(128, [2u32, 100, 127]);
        let mut out = BitSet::from_iter_cap(128, [9u32, 10]);
        a.intersection_into(&b, &mut out);
        assert_eq!(out.to_vec(), vec![2, 100]);
    }

    #[test]
    fn empty_and_clear() {
        let mut s = BitSet::from_iter_cap(64, [0u32, 63]);
        assert!(!s.is_empty());
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.first(), None);
    }

    #[test]
    fn matrix_rows_round_trip() {
        let mut m = BitMatrix::new(70);
        assert_eq!(m.stride(), 2);
        m.set(0, 69);
        m.set(69, 0);
        m.set(3, 3);
        assert!(m.test(0, 69) && m.test(69, 0) && m.test(3, 3));
        assert!(!m.test(0, 68));
        assert_eq!(row::iter(m.row(0)).collect::<Vec<_>>(), vec![69]);
        // reset reshapes and zeroes
        m.reset(10);
        assert_eq!(m.rows(), 10);
        assert_eq!(m.stride(), 1);
        assert!(row::is_empty(m.row(3)));
    }

    #[test]
    fn row_ops_match_bitset_ops() {
        let mut rng = Rng::new(123);
        for _ in 0..40 {
            let cap = 190;
            let a_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.4)).collect();
            let b_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.4)).collect();
            let stride = cap.div_ceil(64);
            let mut a = vec![0u64; stride];
            let mut b = vec![0u64; stride];
            for &x in &a_v {
                row::set(&mut a, x);
            }
            for &x in &b_v {
                row::set(&mut b, x);
            }
            let inter: Vec<u32> = a_v.iter().filter(|x| b_v.contains(x)).copied().collect();
            let mut out = vec![u64::MAX; stride];
            row::and_into(&a, &b, &mut out);
            assert_eq!(row::iter(&out).collect::<Vec<_>>(), inter);
            assert_eq!(row::and_count(&a, &b), inter.len());
            assert_eq!(row::intersects(&a, &b), !inter.is_empty());
            row::and_not_into(&a, &b, &mut out);
            let diff: Vec<u32> = a_v.iter().filter(|x| !b_v.contains(x)).copied().collect();
            assert_eq!(row::iter(&out).collect::<Vec<_>>(), diff);
            assert_eq!(row::count(&a), a_v.len());
            for &x in &a_v {
                assert!(row::test(&a, x));
            }
            if let Some(&x) = a_v.first() {
                row::clear(&mut a, x);
                assert!(!row::test(&a, x));
            }
        }
    }
}
