//! Fixed-capacity bitset over u64 words.
//!
//! The dense representation for `cand` / `fini` inside small subproblems
//! (the perf-pass hot path, see DESIGN.md §Perf): intersection with a
//! neighbourhood becomes word-wise AND, and pivot scoring becomes popcount.

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    pub fn new(capacity: usize) -> Self {
        BitSet {
            words: vec![0; capacity.div_ceil(64)],
            capacity,
        }
    }

    pub fn from_iter_cap(capacity: usize, it: impl IntoIterator<Item = u32>) -> Self {
        let mut s = BitSet::new(capacity);
        for v in it {
            s.insert(v);
        }
        s
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, i: u32) {
        debug_assert!((i as usize) < self.capacity);
        self.words[i as usize >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn remove(&mut self, i: u32) {
        debug_assert!((i as usize) < self.capacity);
        self.words[i as usize >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn contains(&self, i: u32) -> bool {
        ((self.words[i as usize >> 6] >> (i & 63)) & 1) != 0
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// self ∩= other
    pub fn intersect_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// self ∪= other
    pub fn union_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// self \= other
    pub fn subtract(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// |self ∩ other| without allocating.
    pub fn intersection_count(&self, other: &BitSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// out = self ∩ other (out is cleared first; capacities must match).
    pub fn intersection_into(&self, other: &BitSet, out: &mut BitSet) {
        for ((o, a), b) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            *o = a & b;
        }
    }

    pub fn iter(&self) -> BitIter<'_> {
        BitIter {
            words: &self.words,
            word_idx: 0,
            current: self.words.first().copied().unwrap_or(0),
        }
    }

    /// First set bit, if any.
    pub fn first(&self) -> Option<u32> {
        self.iter().next()
    }

    pub fn to_vec(&self) -> Vec<u32> {
        self.iter().collect()
    }

    /// Approximate heap footprint in bytes (for the memory-budget guard).
    pub fn heap_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

pub struct BitIter<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for BitIter<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros();
                self.current &= self.current - 1;
                return Some((self.word_idx as u32) * 64 + bit);
            }
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(200);
        assert!(!s.contains(5));
        s.insert(5);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(5) && s.contains(64) && s.contains(199));
        assert_eq!(s.len(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_in_order() {
        let s = BitSet::from_iter_cap(300, [7u32, 0, 255, 64, 63]);
        assert_eq!(s.to_vec(), vec![0, 7, 63, 64, 255]);
    }

    #[test]
    fn set_ops_match_naive() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let cap = 130;
            let a_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.3)).collect();
            let b_v: Vec<u32> = (0..cap as u32).filter(|_| rng.gen_bool(0.3)).collect();
            let a = BitSet::from_iter_cap(cap, a_v.iter().copied());
            let b = BitSet::from_iter_cap(cap, b_v.iter().copied());

            let inter_naive: Vec<u32> =
                a_v.iter().filter(|v| b_v.contains(v)).copied().collect();
            let mut i = a.clone();
            i.intersect_with(&b);
            assert_eq!(i.to_vec(), inter_naive);
            assert_eq!(a.intersection_count(&b), inter_naive.len());

            let mut u = a.clone();
            u.union_with(&b);
            let mut union_naive = a_v.clone();
            union_naive.extend(b_v.iter().filter(|v| !a_v.contains(v)));
            union_naive.sort_unstable();
            assert_eq!(u.to_vec(), union_naive);

            let mut d = a.clone();
            d.subtract(&b);
            let diff_naive: Vec<u32> =
                a_v.iter().filter(|v| !b_v.contains(v)).copied().collect();
            assert_eq!(d.to_vec(), diff_naive);
        }
    }

    #[test]
    fn intersection_into_reuses_buffer() {
        let a = BitSet::from_iter_cap(128, [1u32, 2, 3, 100]);
        let b = BitSet::from_iter_cap(128, [2u32, 100, 127]);
        let mut out = BitSet::from_iter_cap(128, [9u32, 10]);
        a.intersection_into(&b, &mut out);
        assert_eq!(out.to_vec(), vec![2, 100]);
    }

    #[test]
    fn empty_and_clear() {
        let mut s = BitSet::from_iter_cap(64, [0u32, 63]);
        assert!(!s.is_empty());
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.first(), None);
    }
}
