//! Seeded randomized property-testing harness (proptest is unavailable
//! offline — DESIGN.md "Substitutions").
//!
//! `forall` runs `iters` random cases; on the first failure it retries with
//! progressively "smaller" cases drawn from the same generator (shrink-lite:
//! the generator receives a shrink level it can use to reduce sizes) and
//! panics with the reproducing seed.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub seed: u64,
    pub iters: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 0xC0FFEE,
            iters: 64,
        }
    }
}

/// Run `prop` on `iters` cases drawn by `gen`. Panics with the failing seed.
///
/// `gen` receives (rng, shrink_level); level 0 = full-size cases. On failure
/// the harness retries the same seed at levels 1..=3, reporting the smallest
/// level that still fails so the panic message points at a small repro.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng, u32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.iters {
        let case_seed = meta.next_u64();
        let input = gen(&mut Rng::new(case_seed), 0);
        if let Err(msg) = prop(&input) {
            // shrink-lite: retry same seed with smaller generator levels
            let mut best: (u32, T, String) = (0, input, msg);
            for level in (1..=3).rev() {
                let small = gen(&mut Rng::new(case_seed), level);
                if let Err(m) = prop(&small) {
                    best = (level, small, m);
                    break; // highest level (smallest case) that fails
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, shrink level {}):\n  {}\n  input: {:?}",
                best.0, best.2, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config { seed: 1, iters: 50 },
            |rng, _| rng.gen_usize(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config { seed: 2, iters: 50 },
            |rng, level| rng.gen_usize(100 >> level),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 5"))
                }
            },
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen = Vec::new();
        forall(
            Config { seed: 3, iters: 10 },
            |rng, _| rng.next_u64(),
            |&x| {
                seen.push(x);
                Ok(())
            },
        );
        let mut second = Vec::new();
        forall(
            Config { seed: 3, iters: 10 },
            |rng, _| rng.next_u64(),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(seen, second);
    }
}
