//! # parmce — shared-memory parallel maximal clique enumeration
//!
//! Reproduction of Das, Sanei-Mehri & Tirthapura, *"Shared-Memory Parallel
//! Maximal Clique Enumeration from Static and Dynamic Graphs"* (ACM TOPC
//! 2020), built as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Entry point: the session API
//!
//! Everything routes through [`session`]: one builder, one [`session::Algo`]
//! enum covering the paper's algorithms and every comparison baseline, one
//! [`session::DynamicSession`] for incremental maintenance.
//!
//! ```
//! use parmce::graph::generators;
//! use parmce::session::{Algo, MceSession, RunOutcome};
//!
//! let g = generators::gnp(80, 0.15, 42);
//! let session = MceSession::builder()
//!     .graph(g)
//!     .algo(Algo::ParMce)   // rank-decomposed, load-balanced (Alg. 4)
//!     .threads(4)
//!     .build()
//!     .unwrap();
//! let run = session.run();
//! assert_eq!(run.report.outcome, RunOutcome::Completed);
//! println!("{} maximal cliques in {:?}", run.report.cliques, run.report.wall);
//! ```
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the paper's contribution: the sequential
//!   [`mce::ttt`] baseline, the work-efficient parallel [`mce::parttt`],
//!   the load-balanced [`mce::parmce`] with degree/triangle/degeneracy
//!   rankings, and the incremental [`dynamic`] algorithms (IMCE /
//!   ParIMCE), all running on the in-crate work-stealing pool
//!   ([`coordinator::pool`]) behind the [`session`] facade.  The
//!   [`service`] layer serves queries over the maintained clique set
//!   through epoch-versioned immutable snapshots, concurrently with
//!   batch updates (`parmce serve-replay`).
//! * **L2/L1 (python/compile, build-time only)** — the triangle-count
//!   vertex ranking as a blocked Pallas kernel, AOT-lowered to HLO text
//!   and executed from Rust via PJRT ([`runtime`]; requires the `pjrt`
//!   cargo feature and `make artifacts`).
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! reproduced tables/figures.
//!
//! ## Unsafe policy
//!
//! `unsafe` is denied-by-default: the only sanctioned sites are the audited
//! lifetime-erasure surface in [`util::sync`] ([`util::sync::ScopeShare`] /
//! [`util::sync::ScopedPtr`]) and its per-scope `ScopeShare::new` calls in
//! the parallel kernels.  Every site carries a `// SAFETY:` comment and a
//! local `#[allow(unsafe_code)]`; `cargo xtask lint-invariants` enforces
//! both, plus the `util::sync`-only rule for `std::sync` imports.

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(unsafe_code)]

pub mod baselines;
pub mod coordinator;
pub mod dynamic;
pub mod experiments;
pub mod graph;
pub mod mce;
pub mod runtime;
pub mod service;
pub mod session;
pub mod telemetry;
pub mod util;
