//! # parmce — shared-memory parallel maximal clique enumeration
//!
//! Reproduction of Das, Sanei-Mehri & Tirthapura, *"Shared-Memory Parallel
//! Maximal Clique Enumeration from Static and Dynamic Graphs"* (ACM TOPC
//! 2020), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the sequential [`mce::ttt`]
//!   baseline, the work-efficient parallel [`mce::parttt`], the load-balanced
//!   [`mce::parmce`] with degree/triangle/degeneracy rankings, and the
//!   incremental [`dynamic`] algorithms (IMCE / ParIMCE), all running on the
//!   in-crate work-stealing pool ([`coordinator::pool`]).
//! * **L2/L1 (python/compile, build-time only)** — the triangle-count vertex
//!   ranking as a blocked Pallas kernel, AOT-lowered to HLO text and executed
//!   from Rust via PJRT ([`runtime`]).
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! reproduced tables/figures.

pub mod baselines;
pub mod coordinator;
pub mod dynamic;
pub mod experiments;
pub mod mce;
pub mod graph;
pub mod runtime;
pub mod util;
