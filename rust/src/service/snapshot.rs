//! Immutable epoch snapshots of C(G) and the publish/subscribe point.
//!
//! A [`CliqueSnapshot`] is a frozen view of the maximal clique set at one
//! batch boundary: interned clique storage (one `Arc<[Vertex]>` per
//! clique, shared across epochs), the vertex → clique-id inverted index,
//! a size-ordered id list and size histogram bins — all chunked into
//! `Arc`'d copy-on-write blocks (`service::store`), so freezing one is
//! pointer clones only.  Each snapshot also pins the
//! [`GraphSnapshot`] its clique set was enumerated against, so a query
//! answered at epoch *e* is consistent with *exactly* the graph after
//! batch *e* — adjacency checks included — never a partially-applied
//! batch and never a later graph.
//!
//! [`SnapshotCell`] is the single writer → many readers handoff:
//! `publish` swaps the current `Arc` under a mutex and bumps an atomic
//! version; [`SnapshotReader`] caches the last `Arc` it fetched and
//! revalidates with one atomic load, so the steady-state read hot path
//! (queries between publishes) takes no lock at all.

use crate::graph::snapshot::GraphSnapshot;
use crate::graph::Vertex;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{plock, Arc, Mutex};
use crate::mce::sink::SizeHistogram;
use crate::util::vset;

use super::store::{PostingIndex, SlotMap};

/// Stable identifier of an interned clique. Ids are assigned once, never
/// reused; a subsumed clique's id is retired with it.
pub type CliqueId = u32;

/// Frozen view of C(G) at one epoch (batch boundary). Cheap to clone at
/// the `Arc` level; all queries are lock-free and allocation-light.
pub struct CliqueSnapshot {
    pub(crate) epoch: u64,
    /// the graph epoch this clique set is exact for (pinned `Arc` — the
    /// delta-CSR payload is immutable and shared with the graph writer)
    pub(crate) graph: Arc<GraphSnapshot>,
    /// id-indexed interned cliques (canonical member order); retired
    /// slots read as `None`.
    pub(crate) cliques: SlotMap,
    /// vertex-indexed posting lists of live clique ids, sorted ascending.
    pub(crate) index: PostingIndex,
    /// `size_buckets[s]` = live ids of size-`s` cliques, ascending —
    /// size-ordered walks go bucket-by-bucket from the largest down, and
    /// the bucket lengths are the size histogram.
    pub(crate) size_buckets: Arc<Vec<Arc<Vec<CliqueId>>>>,
    pub(crate) live: usize,
}

impl CliqueSnapshot {
    /// The batch boundary this snapshot reflects (0 = bootstrap state),
    /// counting batches since the service wrapped the session.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The graph epoch snapshot this clique set was enumerated against —
    /// adjacency queries about *this* epoch go here, no matter how far
    /// the writer has advanced since.
    pub fn graph(&self) -> &Arc<GraphSnapshot> {
        &self.graph
    }

    /// Epoch of the pinned graph (batches since the *session* was
    /// created — distinct from [`epoch`](Self::epoch) when the service
    /// wrapped an already-running session).
    pub fn graph_epoch(&self) -> u64 {
        self.graph.epoch()
    }

    /// |C(G)| at this epoch.
    pub fn count(&self) -> usize {
        self.live
    }

    /// Number of vertices the index covers.
    pub fn n(&self) -> usize {
        self.index.n()
    }

    /// Members of clique `id`, if it is live at this epoch.
    pub fn clique(&self, id: CliqueId) -> Option<&[Vertex]> {
        self.cliques.get(id as usize).map(|c| &**c)
    }

    /// Ids of the live maximal cliques containing `v` (sorted ascending);
    /// empty for out-of-range vertices.
    pub fn ids_containing(&self, v: Vertex) -> &[CliqueId] {
        self.index.posting(v)
    }

    /// The maximal cliques containing `v`.
    pub fn cliques_containing(&self, v: Vertex) -> Vec<Arc<[Vertex]>> {
        self.ids_containing(v).iter().map(|&id| self.intern(id)).collect()
    }

    /// Ids of the live maximal cliques containing *all* of `verts`
    /// (posting-list intersection, smallest list first). Empty input or
    /// any out-of-range vertex yields the empty answer.
    pub fn ids_containing_all(&self, verts: &[Vertex]) -> Vec<CliqueId> {
        let Some((&first, rest)) = verts.split_first() else {
            return Vec::new();
        };
        // start from the shortest posting list
        let mut seed = first;
        for &v in rest {
            if self.ids_containing(v).len() < self.ids_containing(seed).len() {
                seed = v;
            }
        }
        let mut acc = self.ids_containing(seed).to_vec();
        for &v in verts {
            if v == seed {
                continue;
            }
            if acc.is_empty() {
                break;
            }
            acc = vset::intersect(&acc, self.ids_containing(v));
        }
        acc
    }

    /// The maximal cliques containing all of `verts`.
    pub fn cliques_containing_all(&self, verts: &[Vertex]) -> Vec<Arc<[Vertex]>> {
        self.ids_containing_all(verts).iter().map(|&id| self.intern(id)).collect()
    }

    /// The `k` largest maximal cliques (size descending, id ascending
    /// among ties); fewer if |C(G)| < k.  Walks the per-size buckets
    /// from the largest size down, so the cost is O(k) plus the empty
    /// buckets skipped — independent of |C(G)|.
    pub fn top_k_largest(&self, k: usize) -> Vec<Arc<[Vertex]>> {
        let mut out = Vec::with_capacity(k.min(self.live));
        for bucket in self.size_buckets.iter().rev() {
            for &id in bucket.iter() {
                if out.len() == k {
                    return out;
                }
                out.push(self.intern(id));
            }
        }
        out
    }

    /// Largest clique size at this epoch (0 when C(G) is empty).
    pub fn max_size(&self) -> usize {
        self.size_buckets
            .iter()
            .rposition(|b| !b.is_empty())
            .unwrap_or(0)
    }

    /// Clique-size histogram at this epoch (the Figure 5 shape, served
    /// from the maintained bucket lengths — no enumeration).
    pub fn size_histogram(&self) -> SizeHistogram {
        let hist = SizeHistogram::new(self.size_buckets.len().saturating_sub(1).max(1));
        for (size, bucket) in self.size_buckets.iter().enumerate() {
            hist.record_many(size, bucket.len() as u64);
        }
        hist
    }

    /// True iff the vertex set `verts` (any order; duplicates make it a
    /// non-set, hence `false`) is exactly a maximal clique of the
    /// current graph.
    pub fn is_maximal_clique(&self, verts: &[Vertex]) -> bool {
        if verts.is_empty() {
            return false;
        }
        let mut sorted = verts.to_vec();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            return false;
        }
        // a live clique containing every member and of equal size IS the set
        self.ids_containing_all(&sorted)
            .iter()
            .any(|&id| self.clique(id).is_some_and(|c| c.len() == sorted.len()))
    }

    /// All live cliques in canonical order (each sorted; list sorted) —
    /// the comparison form for tests and rebuild verification.
    pub fn canonical_cliques(&self) -> Vec<Vec<Vertex>> {
        let mut out: Vec<Vec<Vertex>> = self
            .cliques
            .iter_live()
            .map(|(_, c)| c.to_vec())
            .collect();
        out.sort();
        out
    }

    /// Full structural self-check (tests / debugging): index ↔ storage
    /// agreement, posting-list order, by-size order, bin totals, and
    /// every live clique maximal in the *pinned* graph epoch.
    pub fn validate(&self) -> Result<(), String> {
        let mut live = 0usize;
        let mut bins: Vec<u64> = Vec::new();
        for (id, c) in self.cliques.iter_live() {
            live += 1;
            if bins.len() <= c.len() {
                bins.resize(c.len() + 1, 0);
            }
            bins[c.len()] += 1;
            for &v in c.iter() {
                let posting = self.ids_containing(v);
                if posting.binary_search(&(id as CliqueId)).is_err() {
                    return Err(format!("clique {id} missing from index[{v}]"));
                }
            }
            if !self.graph.is_maximal_clique(c) {
                return Err(format!(
                    "clique {id} {:?} is not maximal in pinned graph epoch {}",
                    c.as_ref(),
                    self.graph.epoch()
                ));
            }
        }
        if live != self.live {
            return Err(format!("live count {} != stored {}", live, self.live));
        }
        for v in 0..self.index.n() as Vertex {
            let posting = self.index.posting(v);
            if !posting.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("index[{v}] not sorted"));
            }
            for &id in posting.iter() {
                match self.clique(id) {
                    None => return Err(format!("index[{v}] holds retired id {id}")),
                    Some(c) if c.binary_search(&v).is_err() => {
                        return Err(format!("index[{v}] holds non-member clique {id}"))
                    }
                    _ => {}
                }
            }
        }
        let bucketed: usize = self.size_buckets.iter().map(|b| b.len()).sum();
        if bucketed != live {
            return Err(format!("size buckets hold {bucketed} ids != live {live}"));
        }
        for (size, bucket) in self.size_buckets.iter().enumerate() {
            if !bucket.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("size bucket {size} not ascending"));
            }
            for &id in bucket.iter() {
                match self.clique(id) {
                    None => return Err(format!("size bucket {size} holds retired id {id}")),
                    Some(c) if c.len() != size => {
                        return Err(format!(
                            "size bucket {size} holds id {id} of size {}",
                            c.len()
                        ))
                    }
                    _ => {}
                }
            }
        }
        let mut stored: Vec<u64> = self.size_buckets.iter().map(|b| b.len() as u64).collect();
        while stored.last() == Some(&0) {
            stored.pop();
        }
        while bins.last() == Some(&0) {
            bins.pop();
        }
        if stored != bins {
            return Err(format!("size buckets {stored:?} != recomputed {bins:?}"));
        }
        Ok(())
    }

    #[inline]
    fn intern(&self, id: CliqueId) -> Arc<[Vertex]> {
        Arc::clone(self.cliques.get(id as usize).expect("posting id must be live"))
    }

    /// Minimal synthetic snapshot: `n` single-vertex cliques at `epoch`,
    /// pinned to the matching edgeless [`GraphSnapshot::synthetic`]
    /// (singletons are exactly its maximal cliques, so `validate`
    /// passes).
    ///
    /// Concurrency-harness hook (`rust/tests/loom_models.rs` builds
    /// distinguishable snapshots per epoch without a session); hidden
    /// from docs because the fields stay `pub(crate)` and real snapshots
    /// come from [`crate::service::CliqueService`].
    #[doc(hidden)]
    pub fn synthetic(epoch: u64, n: usize) -> CliqueSnapshot {
        let mut cliques = SlotMap::new();
        let mut index = PostingIndex::new(n);
        for v in 0..n {
            cliques.push(vec![v as Vertex].into());
            index.push_id(v as Vertex, v as CliqueId);
        }
        let buckets = vec![
            Arc::new(Vec::new()),
            Arc::new((0..n as CliqueId).collect::<Vec<_>>()),
        ];
        CliqueSnapshot {
            epoch,
            graph: Arc::new(GraphSnapshot::synthetic(epoch, n)),
            cliques,
            index,
            size_buckets: Arc::new(buckets),
            live: n,
        }
    }
}

/// Single-writer, many-reader snapshot handoff (copy-on-publish RCU).
pub struct SnapshotCell {
    /// epoch of `current`, published with Release so a reader that sees
    /// the new version also sees the new snapshot through `load`.
    version: AtomicU64,
    current: Mutex<Arc<CliqueSnapshot>>,
}

impl SnapshotCell {
    pub fn new(initial: Arc<CliqueSnapshot>) -> Self {
        SnapshotCell {
            version: AtomicU64::new(initial.epoch()),
            current: Mutex::new(initial),
        }
    }

    /// Make `snap` the current snapshot. Writer-only; epochs must be
    /// monotone.
    pub fn publish(&self, snap: Arc<CliqueSnapshot>) {
        let mut cur = plock(&self.current);
        debug_assert!(snap.epoch() >= cur.epoch(), "epochs must not go back");
        self.version.store(snap.epoch(), Ordering::Release);
        *cur = snap;
    }

    /// Epoch of the currently published snapshot (one atomic load).
    pub fn published_epoch(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Fetch the current snapshot (brief mutex hold: one `Arc` clone).
    pub fn load(&self) -> Arc<CliqueSnapshot> {
        Arc::clone(&plock(&self.current))
    }
}

/// Per-reader cached snapshot handle: [`current`](Self::current) costs
/// one atomic load while no new epoch has been published, and one brief
/// `Arc` clone under the cell mutex when one has — the query hot path
/// never holds a lock while it reads the index.
pub struct SnapshotReader {
    cell: Arc<SnapshotCell>,
    cached: Arc<CliqueSnapshot>,
}

impl SnapshotReader {
    /// A caching reader handle bound to `cell`.
    pub fn new(cell: &Arc<SnapshotCell>) -> SnapshotReader {
        SnapshotReader {
            cached: cell.load(),
            cell: Arc::clone(cell),
        }
    }

    /// The freshest published snapshot (revalidates the cache).
    pub fn current(&mut self) -> &Arc<CliqueSnapshot> {
        if self.cell.published_epoch() != self.cached.epoch() {
            self.cached = self.cell.load();
        }
        &self.cached
    }

    /// The cached snapshot without revalidation (possibly stale).
    pub fn cached(&self) -> &Arc<CliqueSnapshot> {
        &self.cached
    }

    /// How many epochs the cache currently lags the published snapshot.
    pub fn staleness(&self) -> u64 {
        self.cell
            .published_epoch()
            .saturating_sub(self.cached.epoch())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;
    use crate::graph::snapshot::SnapshotGraph;
    use crate::graph::Edge;

    fn graph(n: usize, edges: &[Edge]) -> Arc<GraphSnapshot> {
        SnapshotGraph::from_csr(&CsrGraph::from_edges(n, edges)).current()
    }

    fn tiny_snapshot() -> CliqueSnapshot {
        // graph: triangle {0,1,2} + edge (1,3); its maximal cliques are
        // exactly the live entries: 0 = {0,1,2}, 1 = {1,3}; id 2 was
        // interned and later retired
        let mut cliques = SlotMap::new();
        cliques.push(vec![0, 1, 2].into());
        cliques.push(vec![1, 3].into());
        cliques.push(vec![0, 1].into()); // subsumed, retired below
        cliques.clear(2);
        let mut index = PostingIndex::new(4);
        index.push_id(0, 0);
        index.push_id(1, 0);
        index.push_id(1, 1);
        index.push_id(2, 0);
        index.push_id(3, 1);
        CliqueSnapshot {
            epoch: 7,
            graph: graph(4, &[(0, 1), (0, 2), (1, 2), (1, 3)]),
            cliques,
            index,
            size_buckets: Arc::new(vec![
                Arc::new(vec![]),
                Arc::new(vec![]),
                Arc::new(vec![1]),
                Arc::new(vec![0]),
            ]),
            live: 2,
        }
    }

    #[test]
    fn snapshot_queries_answer_from_frozen_state() {
        let s = tiny_snapshot();
        assert!(s.validate().is_ok(), "{:?}", s.validate());
        assert_eq!(s.epoch(), 7);
        assert_eq!(s.count(), 2);
        assert_eq!(s.n(), 4);
        assert_eq!(s.ids_containing(1), &[0, 1]);
        assert_eq!(s.ids_containing(9), &[] as &[CliqueId]);
        assert_eq!(s.ids_containing_all(&[1, 3]), vec![1]);
        assert_eq!(s.ids_containing_all(&[0, 3]), Vec::<CliqueId>::new());
        assert_eq!(s.ids_containing_all(&[]), Vec::<CliqueId>::new());
        assert_eq!(s.top_k_largest(1)[0].as_ref(), &[0, 1, 2]);
        assert_eq!(s.top_k_largest(10).len(), 2);
        assert_eq!(s.max_size(), 3);
        assert!(s.is_maximal_clique(&[2, 0, 1]));
        assert!(!s.is_maximal_clique(&[0, 1]), "strict subset is not maximal");
        assert!(!s.is_maximal_clique(&[0, 3]));
        assert!(!s.is_maximal_clique(&[]));
        assert!(!s.is_maximal_clique(&[1, 1]), "duplicates are not a set");
        let h = s.size_histogram();
        assert_eq!(h.count(), 2);
        assert_eq!(h.nonzero_bins(), vec![(2, 1), (3, 1)]);
        assert_eq!(
            s.canonical_cliques(),
            vec![vec![0, 1, 2], vec![1, 3]]
        );
        // the pinned graph answers adjacency for this exact epoch
        assert_eq!(s.graph_epoch(), 0);
        assert!(s.graph().has_edge(1, 3));
        assert!(!s.graph().has_edge(0, 3));
    }

    #[test]
    fn validate_catches_inconsistencies() {
        let mut s = tiny_snapshot();
        s.live = 3;
        assert!(s.validate().is_err());
        let mut s = tiny_snapshot();
        s.index.push_id(0, 2); // retired id in posting
        assert!(s.validate().is_err());
        let mut s = tiny_snapshot();
        // id 0 (size 3) filed under bucket 2, id 1 (size 2) under 3
        s.size_buckets = Arc::new(vec![
            Arc::new(vec![]),
            Arc::new(vec![]),
            Arc::new(vec![0]),
            Arc::new(vec![1]),
        ]);
        assert!(s.validate().is_err());
        let mut s = tiny_snapshot();
        // wrong pinned graph: {0,1,2} is no clique of the edgeless graph
        s.graph = Arc::new(GraphSnapshot::synthetic(0, 4));
        assert!(s.validate().is_err());
    }

    #[test]
    fn reader_cache_revalidates_on_publish() {
        let cell = Arc::new(SnapshotCell::new(Arc::new(tiny_snapshot())));
        let mut reader = SnapshotReader::new(&cell);
        assert_eq!(reader.current().epoch(), 7);
        assert_eq!(reader.staleness(), 0);

        let mut next = tiny_snapshot();
        next.epoch = 8;
        cell.publish(Arc::new(next));
        assert_eq!(reader.cached().epoch(), 7, "cache is stale until touched");
        assert_eq!(reader.staleness(), 1);
        assert_eq!(reader.current().epoch(), 8);
        assert_eq!(reader.staleness(), 0);
        assert_eq!(cell.published_epoch(), 8);
    }
}
