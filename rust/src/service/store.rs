//! The writer-side clique store: interned storage + incrementally
//! maintained inverted index, frozen into [`CliqueSnapshot`]s.
//!
//! Every clique is interned once (`Arc<[Vertex]>`, canonical member
//! order) and addressed by a stable [`CliqueId`]; a batch's change set
//! (Λⁿᵉʷ, Λᵈᵉˡ) updates only the touched posting lists and per-size
//! buckets — never a rebuild.  Both the id-slot table ([`SlotMap`]) and
//! the per-vertex inverted index ([`PostingIndex`]) are chunked into
//! `Arc`'d blocks, so `freeze` publishes by pointer clones alone: a
//! batch deep-copies only the blocks it touched (`Arc::make_mut`
//! copy-on-write), and every untouched block is shared with all prior
//! snapshots.  Ids are never reused, so the slot table grows with
//! *total interned* cliques over the service's lifetime (retired slots
//! stay `None`) — the price of id stability under remove/re-insert
//! churn; live-set queries are unaffected.
//!
//! The store also pins the [`GraphSnapshot`] each clique set is exact
//! for: `apply` swaps in the batch's post-mutation graph epoch, and
//! `freeze` carries it into the snapshot, so a reader holding an old
//! snapshot can answer maximality queries against the *exact* graph its
//! clique set was enumerated on, regardless of later batches.

use std::collections::HashMap;
use crate::util::sync::Arc;

use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::BatchResult;
use crate::graph::snapshot::GraphSnapshot;
use crate::graph::Vertex;
use crate::util::chashmap::FxBuildHasher;

use super::snapshot::{CliqueId, CliqueSnapshot};

/// Slots per [`SlotMap`] block.
pub(crate) const SLOT_BLOCK: usize = 512;
/// Vertices per [`PostingIndex`] block.
pub(crate) const POSTING_BLOCK: usize = 256;

/// Chunked id → interned-clique slot table.  Append-only ids; retired
/// slots are cleared to `None` but never reused.  Blocks are `Arc`'d so
/// a clone (the `freeze` path) copies `len / SLOT_BLOCK` pointers, and a
/// mutation deep-copies exactly the one block holding the touched slot.
#[derive(Clone)]
pub(crate) struct SlotMap {
    blocks: Arc<Vec<Arc<Vec<Option<Arc<[Vertex]>>>>>>,
    len: usize,
}

impl SlotMap {
    pub fn new() -> Self {
        SlotMap {
            blocks: Arc::new(Vec::new()),
            len: 0,
        }
    }

    /// Total slots ever assigned (retired slots included) — the next
    /// fresh id.
    pub fn len(&self) -> usize {
        self.len
    }

    /// The clique in slot `i`, if live.
    pub fn get(&self, i: usize) -> Option<&Arc<[Vertex]>> {
        if i >= self.len {
            return None;
        }
        self.blocks[i / SLOT_BLOCK][i % SLOT_BLOCK].as_ref()
    }

    /// Fill the next slot (id = previous [`len`](Self::len)) with `c`.
    pub fn push(&mut self, c: Arc<[Vertex]>) {
        let blocks = Arc::make_mut(&mut self.blocks);
        if self.len % SLOT_BLOCK == 0 {
            blocks.push(Arc::new(Vec::with_capacity(SLOT_BLOCK)));
        }
        let last = blocks.last_mut().expect("block just ensured");
        Arc::make_mut(last).push(Some(c));
        self.len += 1;
    }

    /// Retire slot `i`; its id stays burned.
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len, "clearing unassigned slot {i}");
        let blocks = Arc::make_mut(&mut self.blocks);
        Arc::make_mut(&mut blocks[i / SLOT_BLOCK])[i % SLOT_BLOCK] = None;
    }

    /// `(id, clique)` over live slots, ascending id.
    pub fn iter_live(&self) -> impl Iterator<Item = (usize, &Arc<[Vertex]>)> + '_ {
        self.blocks.iter().enumerate().flat_map(|(bi, block)| {
            block
                .iter()
                .enumerate()
                .filter_map(move |(i, slot)| slot.as_ref().map(|c| (bi * SLOT_BLOCK + i, c)))
        })
    }
}

/// Chunked vertex → clique-ids inverted index.  Three `Arc` layers
/// (spine → block → posting list) all copy-on-write, so one posting
/// update deep-copies a single list plus its 256-entry block of
/// pointers; everything else stays shared with published snapshots.
#[derive(Clone)]
pub(crate) struct PostingIndex {
    blocks: Arc<Vec<Arc<Vec<Arc<Vec<CliqueId>>>>>>,
    n: usize,
}

impl PostingIndex {
    pub fn new(n: usize) -> Self {
        let mut idx = PostingIndex {
            blocks: Arc::new(Vec::new()),
            n: 0,
        };
        if n > 0 {
            idx.ensure((n - 1) as Vertex);
        }
        idx
    }

    /// Number of vertices the index covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Live clique ids containing `v`, ascending; empty for
    /// out-of-range vertices.
    pub fn posting(&self, v: Vertex) -> &[CliqueId] {
        let vi = v as usize;
        if vi >= self.n {
            return &[];
        }
        self.blocks[vi / POSTING_BLOCK][vi % POSTING_BLOCK].as_slice()
    }

    /// Grow coverage to include `v`.
    fn ensure(&mut self, v: Vertex) {
        let vi = v as usize;
        if vi < self.n {
            return;
        }
        let blocks = Arc::make_mut(&mut self.blocks);
        while blocks.len() * POSTING_BLOCK <= vi {
            // every fresh slot shares one empty posting until its first
            // write — a new block is POSTING_BLOCK pointer copies
            blocks.push(Arc::new(vec![Arc::new(Vec::new()); POSTING_BLOCK]));
        }
        self.n = vi + 1;
    }

    /// Append `id` to `v`'s posting (ids arrive ascending, so push
    /// keeps the list sorted).
    pub fn push_id(&mut self, v: Vertex, id: CliqueId) {
        self.ensure(v);
        let vi = v as usize;
        let blocks = Arc::make_mut(&mut self.blocks);
        let block = Arc::make_mut(&mut blocks[vi / POSTING_BLOCK]);
        Arc::make_mut(&mut block[vi % POSTING_BLOCK]).push(id);
    }

    /// Remove `id` from `v`'s posting; false if absent.
    pub fn remove_id(&mut self, v: Vertex, id: CliqueId) -> bool {
        let vi = v as usize;
        if vi >= self.n {
            return false;
        }
        let blocks = Arc::make_mut(&mut self.blocks);
        let block = Arc::make_mut(&mut blocks[vi / POSTING_BLOCK]);
        let list = Arc::make_mut(&mut block[vi % POSTING_BLOCK]);
        match list.binary_search(&id) {
            Ok(p) => {
                list.remove(p);
                true
            }
            Err(_) => false,
        }
    }
}

pub(crate) struct CliqueStore {
    /// Batches applied since this store was created (counts from the
    /// wrap point — distinct from the pinned graph's own epoch, which
    /// counts batches since the *session* was created).
    epoch: u64,
    /// The graph epoch snapshot the live clique set is exact for.
    graph: Arc<GraphSnapshot>,
    cliques: SlotMap,
    /// canonical members → id, for Λᵈᵉˡ retirement (writer-private).
    by_key: HashMap<Arc<[Vertex]>, CliqueId, FxBuildHasher>,
    index: PostingIndex,
    /// `size_buckets[s]` = live ids of size-`s` cliques, ascending.
    /// Fresh ids are maximal, so `add` is an O(1) push; `retire` is a
    /// binary-search remove within one bucket; `top_k_largest` walks
    /// buckets from the largest size down.  Per-bucket `Arc`s give the
    /// same pointer-level COW publish as the posting lists: a batch
    /// deep-copies only the buckets it touches.
    size_buckets: Arc<Vec<Arc<Vec<CliqueId>>>>,
    live: usize,
}

impl CliqueStore {
    pub fn new(graph: Arc<GraphSnapshot>, epoch: u64) -> Self {
        let index = PostingIndex::new(graph.n());
        CliqueStore {
            epoch,
            graph,
            cliques: SlotMap::new(),
            by_key: HashMap::default(),
            index,
            size_buckets: Arc::new(Vec::new()),
            live: 0,
        }
    }

    /// Build from the live registry contents (bootstrap or from-scratch
    /// rebuild verification); `graph` is the epoch snapshot the
    /// registry's C(G) was enumerated on.
    pub fn from_registry(graph: Arc<GraphSnapshot>, registry: &CliqueRegistry, epoch: u64) -> Self {
        let mut store = CliqueStore::new(graph, epoch);
        // deterministic id assignment in (size desc, canonical) order —
        // stable across engine variants, and every bucket fills in
        // ascending-id order as a side effect
        let mut all: Vec<Vec<Vertex>> = Vec::with_capacity(registry.len());
        registry.for_each(|c| all.push(c.to_vec()));
        all.sort_unstable_by(|a, b| b.len().cmp(&a.len()).then_with(|| a.cmp(b)));
        for c in &all {
            store.add(c);
        }
        store
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Apply one batch's change set and advance the epoch: retire Λᵈᵉˡ,
    /// intern Λⁿᵉʷ, pin `graph` (the post-batch graph epoch the change
    /// set was computed against).  Both lists are canonical and disjoint
    /// (the IMCE invariants), so order within the batch does not matter.
    pub fn apply(&mut self, result: &BatchResult, graph: &Arc<GraphSnapshot>) {
        for c in &result.subsumed {
            self.retire(c);
        }
        for c in &result.new_cliques {
            self.add(c);
        }
        self.graph = Arc::clone(graph);
        self.epoch += 1;
    }

    /// Freeze the current state into an immutable snapshot: pointer
    /// clones of the chunked spines — no clique bytes, no posting lists.
    pub fn freeze(&self) -> CliqueSnapshot {
        CliqueSnapshot {
            epoch: self.epoch,
            graph: Arc::clone(&self.graph),
            cliques: self.cliques.clone(),
            index: self.index.clone(),
            size_buckets: Arc::clone(&self.size_buckets),
            live: self.live,
        }
    }

    /// Intern a new clique (canonical members) under a fresh stable id.
    fn add(&mut self, c: &[Vertex]) {
        debug_assert!(c.windows(2).all(|w| w[0] < w[1]), "clique not canonical");
        // ids are never reused, so the space is total-interned — fail
        // loudly rather than wrap and corrupt the index
        let id = CliqueId::try_from(self.cliques.len()).expect("CliqueId space exhausted");
        let interned: Arc<[Vertex]> = c.into();
        let prev = self.by_key.insert(Arc::clone(&interned), id);
        debug_assert!(prev.is_none(), "clique {c:?} interned twice");
        self.cliques.push(interned);
        for &v in c {
            // fresh ids are maximal, so push preserves the sort
            self.index.push_id(v, id);
        }
        let buckets = Arc::make_mut(&mut self.size_buckets);
        if buckets.len() <= c.len() {
            buckets.resize_with(c.len() + 1, || Arc::new(Vec::new()));
        }
        // fresh ids are maximal, so push keeps the bucket ascending: O(1)
        Arc::make_mut(&mut buckets[c.len()]).push(id);
        self.live += 1;
    }

    /// Retire a subsumed clique; its id is never reused.
    fn retire(&mut self, c: &[Vertex]) {
        let Some(id) = self.by_key.remove(c) else {
            debug_assert!(false, "retiring unknown clique {c:?}");
            return;
        };
        let buckets = Arc::make_mut(&mut self.size_buckets);
        let bucket = Arc::make_mut(&mut buckets[c.len()]);
        match bucket.binary_search(&id) {
            Ok(p) => {
                bucket.remove(p);
            }
            Err(_) => debug_assert!(false, "size bucket {} missing id {id}", c.len()),
        }
        for &v in c {
            let removed = self.index.remove_id(v, id);
            debug_assert!(removed, "index[{v}] missing id {id}");
        }
        self.cliques.clear(id as usize);
        self.live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::registry::CliqueRegistry;
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators;
    use crate::graph::snapshot::SnapshotGraph;
    use crate::graph::Edge;

    fn batch(new: &[&[Vertex]], gone: &[&[Vertex]]) -> BatchResult {
        BatchResult {
            new_cliques: new.iter().map(|c| c.to_vec()).collect(),
            subsumed: gone.iter().map(|c| c.to_vec()).collect(),
        }
    }

    fn graph(n: usize, edges: &[Edge]) -> Arc<GraphSnapshot> {
        SnapshotGraph::from_csr(&CsrGraph::from_edges(n, edges)).current()
    }

    #[test]
    fn incremental_deltas_keep_the_index_exact() {
        // graph 1: triangle {0,1,2} plus the pendant edge (2,3)
        let g1 = graph(5, &[(0, 1), (0, 2), (1, 2), (2, 3)]);
        // graph 2: + (2,4),(3,4) — {2,3} grows into {2,3,4}
        let g2 = graph(5, &[(0, 1), (0, 2), (1, 2), (2, 3), (2, 4), (3, 4)]);

        let mut s = CliqueStore::new(Arc::clone(&g1), 0);
        s.apply(&batch(&[&[0, 1, 2], &[2, 3], &[4]], &[]), &g1);
        assert_eq!(s.epoch(), 1);
        let snap = s.freeze();
        snap.validate().unwrap();
        assert_eq!(snap.count(), 3);
        assert_eq!(snap.ids_containing(2).len(), 2);
        assert_eq!(snap.graph_epoch(), g1.epoch());

        // {2,3} absorbed into {2,3,4}; singleton {4} too; {0,1,2} stays
        s.apply(&batch(&[&[2, 3, 4]], &[&[2, 3], &[4]]), &g2);
        let snap = s.freeze();
        snap.validate().unwrap();
        assert_eq!(snap.epoch(), 2);
        assert_eq!(
            snap.canonical_cliques(),
            vec![vec![0, 1, 2], vec![2, 3, 4]]
        );
        assert!(snap.is_maximal_clique(&[4, 2, 3]));
        assert!(!snap.is_maximal_clique(&[2, 3]));
        assert!(snap.graph().has_edge(3, 4), "snapshot pins the new graph");
    }

    #[test]
    fn frozen_snapshots_are_isolated_from_later_writes() {
        // graph 1: path edge (0,1) + triangle {1,2,3}
        let g1 = graph(4, &[(0, 1), (1, 2), (1, 3), (2, 3)]);
        // graph 2: + (0,2) — {0,1} grows into {0,1,2}
        let g2 = graph(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]);

        let mut s = CliqueStore::new(Arc::clone(&g1), 0);
        s.apply(&batch(&[&[0, 1], &[1, 2, 3]], &[]), &g1);
        let before = s.freeze();
        s.apply(&batch(&[&[0, 1, 2]], &[&[0, 1]]), &g2);
        let after = s.freeze();
        // the old snapshot still answers from its own epoch — clique set
        // AND pinned graph
        assert_eq!(before.epoch(), 1);
        assert_eq!(before.count(), 2);
        assert!(before.is_maximal_clique(&[0, 1]));
        assert!(!before.graph().has_edge(0, 2));
        assert_eq!(after.epoch(), 2);
        assert!(!after.is_maximal_clique(&[0, 1]));
        assert!(after.is_maximal_clique(&[0, 1, 2]));
        assert!(after.graph().has_edge(0, 2));
        before.validate().unwrap();
        after.validate().unwrap();
    }

    #[test]
    fn from_registry_matches_registry_contents() {
        let g = generators::gnp(18, 0.4, 2);
        let reg = CliqueRegistry::from_graph(&g);
        let want = crate::mce::oracle::maximal_cliques(&g);
        let gs = SnapshotGraph::from_csr(&g).current();
        let snap = CliqueStore::from_registry(gs, &reg, 5).freeze();
        snap.validate().unwrap();
        assert_eq!(snap.epoch(), 5);
        assert_eq!(snap.canonical_cliques(), want);
        assert_eq!(reg.len(), want.len(), "from_registry must not drain");
    }

    #[test]
    fn chunked_structures_span_block_boundaries() {
        // enough cliques to cross SLOT_BLOCK and enough vertices to
        // cross POSTING_BLOCK, exercising block allocation + COW edges
        let n = POSTING_BLOCK + 40;
        let total = SLOT_BLOCK + 30;
        let g = graph(n, &[]); // edgeless; singletons are maximal
        let mut s = CliqueStore::new(Arc::clone(&g), 0);
        // intern `total` singletons (recycling vertices past n-1 is not
        // needed: keep ids and vertices distinct where possible)
        for i in 0..total {
            let v = (i % n) as Vertex;
            if s.by_key.contains_key(&[v][..]) {
                // duplicate singleton: retire it first so interning stays
                // unique (the store invariant)
                s.retire(&[v]);
            }
            s.add(&[v]);
        }
        assert_eq!(s.cliques.len(), total);
        let snap = s.freeze();
        // every live posting resolves to a live slot in the right block
        for v in 0..n as Vertex {
            for &id in snap.ids_containing(v) {
                assert_eq!(snap.clique(id), Some(&[v][..]));
            }
        }
        // retired slots (the re-interned duplicates) read as None
        let retired = total - n.min(total);
        let live_ids: usize = (0..s.cliques.len())
            .filter(|&i| s.cliques.get(i).is_some())
            .count();
        assert_eq!(live_ids, total - retired);
    }
}
