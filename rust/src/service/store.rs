//! The writer-side clique store: interned storage + incrementally
//! maintained inverted index, frozen into [`CliqueSnapshot`]s.
//!
//! Every clique is interned once (`Arc<[Vertex]>`, canonical member
//! order) and addressed by a stable [`CliqueId`]; a batch's change set
//! (Λⁿᵉʷ, Λᵈᵉˡ) updates only the touched posting lists and per-size
//! buckets — never a rebuild.  `freeze` then publishes by
//! copying at the pointer level: untouched posting lists, clique data
//! and size buckets are all shared with previous snapshots
//! (`Arc` copy-on-write via `make_mut`), so publish cost is pointer
//! clones, not clique bytes.  Ids are never reused, so the id-indexed
//! slot table grows with *total interned* cliques over the service's
//! lifetime (retired slots stay `None`) — the price of id stability
//! under remove/re-insert churn; live-set queries are unaffected.

use std::collections::HashMap;
use crate::util::sync::Arc;

use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::BatchResult;
use crate::graph::Vertex;
use crate::util::chashmap::FxBuildHasher;

use super::snapshot::{CliqueId, CliqueSnapshot};

pub(crate) struct CliqueStore {
    epoch: u64,
    cliques: Vec<Option<Arc<[Vertex]>>>,
    /// canonical members → id, for Λᵈᵉˡ retirement (writer-private).
    by_key: HashMap<Arc<[Vertex]>, CliqueId, FxBuildHasher>,
    index: Vec<Arc<Vec<CliqueId>>>,
    /// `size_buckets[s]` = live ids of size-`s` cliques, ascending.
    /// Fresh ids are maximal, so `add` is an O(1) push; `retire` is a
    /// binary-search remove within one bucket; `top_k_largest` walks
    /// buckets from the largest size down.  Per-bucket `Arc`s give the
    /// same pointer-level COW publish as the posting lists: a batch
    /// deep-copies only the buckets it touches.
    size_buckets: Arc<Vec<Arc<Vec<CliqueId>>>>,
    live: usize,
}

impl CliqueStore {
    pub fn new(n: usize, epoch: u64) -> Self {
        CliqueStore {
            epoch,
            cliques: Vec::new(),
            by_key: HashMap::default(),
            index: (0..n).map(|_| Arc::new(Vec::new())).collect(),
            size_buckets: Arc::new(Vec::new()),
            live: 0,
        }
    }

    /// Build from the live registry contents (bootstrap or from-scratch
    /// rebuild verification).
    pub fn from_registry(n: usize, registry: &CliqueRegistry, epoch: u64) -> Self {
        let mut store = CliqueStore::new(n, epoch);
        // deterministic id assignment in (size desc, canonical) order —
        // stable across engine variants, and every bucket fills in
        // ascending-id order as a side effect
        let mut all: Vec<Vec<Vertex>> = Vec::with_capacity(registry.len());
        registry.for_each(|c| all.push(c.to_vec()));
        all.sort_unstable_by(|a, b| b.len().cmp(&a.len()).then_with(|| a.cmp(b)));
        for c in &all {
            store.add(c);
        }
        store
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Apply one batch's change set and advance the epoch: retire Λᵈᵉˡ,
    /// intern Λⁿᵉʷ. Both lists are canonical and disjoint (the IMCE
    /// invariants), so order within the batch does not matter.
    pub fn apply(&mut self, result: &BatchResult) {
        for c in &result.subsumed {
            self.retire(c);
        }
        for c in &result.new_cliques {
            self.add(c);
        }
        self.epoch += 1;
    }

    /// Freeze the current state into an immutable snapshot.
    pub fn freeze(&self) -> CliqueSnapshot {
        CliqueSnapshot {
            epoch: self.epoch,
            cliques: self.cliques.clone(),
            index: self.index.clone(),
            size_buckets: Arc::clone(&self.size_buckets),
            live: self.live,
        }
    }

    /// Intern a new clique (canonical members) under a fresh stable id.
    fn add(&mut self, c: &[Vertex]) {
        debug_assert!(c.windows(2).all(|w| w[0] < w[1]), "clique not canonical");
        // ids are never reused, so the space is total-interned — fail
        // loudly rather than wrap and corrupt the index
        let id = CliqueId::try_from(self.cliques.len()).expect("CliqueId space exhausted");
        let interned: Arc<[Vertex]> = c.into();
        let prev = self.by_key.insert(Arc::clone(&interned), id);
        debug_assert!(prev.is_none(), "clique {c:?} interned twice");
        self.cliques.push(Some(interned));
        for &v in c {
            if self.index.len() <= v as usize {
                self.index.resize_with(v as usize + 1, || Arc::new(Vec::new()));
            }
            // fresh ids are maximal, so push preserves the sort
            Arc::make_mut(&mut self.index[v as usize]).push(id);
        }
        let buckets = Arc::make_mut(&mut self.size_buckets);
        if buckets.len() <= c.len() {
            buckets.resize_with(c.len() + 1, || Arc::new(Vec::new()));
        }
        // fresh ids are maximal, so push keeps the bucket ascending: O(1)
        Arc::make_mut(&mut buckets[c.len()]).push(id);
        self.live += 1;
    }

    /// Retire a subsumed clique; its id is never reused.
    fn retire(&mut self, c: &[Vertex]) {
        let Some(id) = self.by_key.remove(c) else {
            debug_assert!(false, "retiring unknown clique {c:?}");
            return;
        };
        let buckets = Arc::make_mut(&mut self.size_buckets);
        let bucket = Arc::make_mut(&mut buckets[c.len()]);
        match bucket.binary_search(&id) {
            Ok(p) => {
                bucket.remove(p);
            }
            Err(_) => debug_assert!(false, "size bucket {} missing id {id}", c.len()),
        }
        for &v in c {
            let list = Arc::make_mut(&mut self.index[v as usize]);
            match list.binary_search(&id) {
                Ok(p) => {
                    list.remove(p);
                }
                Err(_) => debug_assert!(false, "index[{v}] missing id {id}"),
            }
        }
        self.cliques[id as usize] = None;
        self.live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::registry::CliqueRegistry;
    use crate::graph::generators;

    fn batch(new: &[&[Vertex]], gone: &[&[Vertex]]) -> BatchResult {
        BatchResult {
            new_cliques: new.iter().map(|c| c.to_vec()).collect(),
            subsumed: gone.iter().map(|c| c.to_vec()).collect(),
        }
    }

    #[test]
    fn incremental_deltas_keep_the_index_exact() {
        let mut s = CliqueStore::new(5, 0);
        s.apply(&batch(&[&[0, 1, 2], &[2, 3]], &[]));
        assert_eq!(s.epoch(), 1);
        let snap = s.freeze();
        snap.validate().unwrap();
        assert_eq!(snap.count(), 2);
        assert_eq!(snap.ids_containing(2).len(), 2);

        // {2,3} absorbed into {2,3,4}; {0,1,2} stays
        s.apply(&batch(&[&[2, 3, 4]], &[&[2, 3]]));
        let snap = s.freeze();
        snap.validate().unwrap();
        assert_eq!(snap.epoch(), 2);
        assert_eq!(
            snap.canonical_cliques(),
            vec![vec![0, 1, 2], vec![2, 3, 4]]
        );
        assert!(snap.is_maximal_clique(&[4, 2, 3]));
        assert!(!snap.is_maximal_clique(&[2, 3]));
    }

    #[test]
    fn frozen_snapshots_are_isolated_from_later_writes() {
        let mut s = CliqueStore::new(4, 0);
        s.apply(&batch(&[&[0, 1], &[1, 2, 3]], &[]));
        let before = s.freeze();
        s.apply(&batch(&[&[0, 1, 2]], &[&[0, 1]]));
        let after = s.freeze();
        // the old snapshot still answers from its own epoch
        assert_eq!(before.epoch(), 1);
        assert_eq!(before.count(), 2);
        assert!(before.is_maximal_clique(&[0, 1]));
        assert_eq!(after.epoch(), 2);
        assert!(!after.is_maximal_clique(&[0, 1]));
        assert!(after.is_maximal_clique(&[0, 1, 2]));
        before.validate().unwrap();
        after.validate().unwrap();
    }

    #[test]
    fn from_registry_matches_registry_contents() {
        let g = generators::gnp(18, 0.4, 2);
        let reg = CliqueRegistry::from_graph(&g);
        let want = crate::mce::oracle::maximal_cliques(&g);
        let snap = CliqueStore::from_registry(g.n(), &reg, 5).freeze();
        snap.validate().unwrap();
        assert_eq!(snap.epoch(), 5);
        assert_eq!(snap.canonical_cliques(), want);
        assert_eq!(reg.len(), want.len(), "from_registry must not drain");
    }
}
