//! CliqueService: the epoch-snapshotted query/serving layer over the
//! dynamic clique set.
//!
//! The dynamic algorithms (§5) keep C(G) live under edge batches; this
//! module makes that maintained set *servable*: a writer applies batches
//! through the wrapped [`DynamicSession`] while any number of readers
//! answer queries against immutable epoch snapshots — snapshot isolation
//! by construction, no reader ever observes a partially-applied batch.
//!
//! * [`store`] — interned clique storage (stable ids) + the vertex →
//!   clique-ids inverted index, both chunked into `Arc`'d COW blocks,
//!   maintained incrementally from each batch's (Λⁿᵉʷ, Λᵈᵉˡ) change set.
//! * [`snapshot`] — the immutable [`CliqueSnapshot`] query surface,
//!   published through [`SnapshotCell`] / cached [`SnapshotReader`]s
//!   (one atomic load on the steady-state read path).  Every snapshot
//!   pins the exact [`GraphSnapshot`](crate::graph::snapshot::GraphSnapshot)
//!   epoch its clique set was enumerated on, so adjacency and
//!   maximality queries answer against *that* graph even after the
//!   writer moves on.
//! * [`driver`] — replays a mixed update/query workload on the
//!   coordinator pool and reports query throughput, update latency and
//!   epoch lag (`parmce serve-replay`).
//!
//! ```
//! use parmce::service::CliqueService;
//! use parmce::session::DynAlgo;
//!
//! let mut svc = CliqueService::from_empty(5, DynAlgo::Imce);
//! svc.apply_batch(&[(0, 1), (1, 2), (0, 2), (2, 3)]);
//! let count = svc.handle().count();
//! assert_eq!(count.epoch, 1);
//! assert_eq!(count.value, 3); // {0,1,2}, {2,3}, {4}
//! assert!(svc.handle().is_maximal_clique(&[0, 1, 2]).value);
//! ```

pub mod driver;
pub mod snapshot;
mod store;

use crate::util::failpoints;
use crate::util::sync::{plock, Arc, Mutex};

use crate::dynamic::stream::{BatchRecord, EdgeStream};
use crate::dynamic::BatchResult;
use crate::graph::csr::CsrGraph;
use crate::graph::snapshot::GraphSnapshot;
use crate::graph::{Edge, Vertex};
use crate::mce::sink::SizeHistogram;
use crate::session::dynamic::{
    BatchApplyError, BatchEvent, BatchObserver, DynAlgo, DynamicSession,
};

pub use driver::{serve_replay, DriverConfig, DriverReport};
pub use snapshot::{CliqueId, CliqueSnapshot, SnapshotCell, SnapshotReader};

use store::CliqueStore;

/// A query answer stamped with the epoch it was computed at.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tagged<T> {
    /// Batch boundary the answer is consistent with.
    pub epoch: u64,
    pub value: T,
}

/// Shared between the service (writer) and every [`ServiceHandle`].
struct ServiceShared {
    store: Mutex<CliqueStore>,
    cell: Arc<SnapshotCell>,
}

impl ServiceShared {
    /// The publish-on-batch observer body: fold the change set into the
    /// index, pin the post-batch graph epoch, freeze, publish. Runs on
    /// the writer thread inside `apply_batch`/`remove_batch`, so "batch
    /// applied" and "epoch visible" are one step.
    fn on_batch(&self, result: &BatchResult, graph: &Arc<GraphSnapshot>) {
        let mut store = plock(&self.store);
        store.apply(result, graph);
        // `service-freeze` failpoint: the `error` action models a
        // transient freeze/publish failure — retried with doubling
        // backoff, then the publish is *skipped*: readers stay on the
        // previous epoch, which is still internally consistent (the
        // next successful publish carries the accumulated state, since
        // the store itself already applied the batch).  `panic`
        // propagates to the writer thread.
        let mut published = false;
        for attempt in 0u32..3 {
            if failpoints::hit(failpoints::Site::ServiceFreeze) {
                std::thread::sleep(std::time::Duration::from_millis(1 << attempt));
                continue;
            }
            self.cell.publish(Arc::new(store.freeze()));
            published = true;
            break;
        }
        let t = crate::telemetry::global();
        if published {
            t.service_publishes.inc();
            t.service_published_epoch.set(self.cell.published_epoch());
        } else {
            t.service_publish_failures.inc();
        }
    }
}

/// The serving layer: one writer ([`apply_batch`](Self::apply_batch) /
/// [`remove_batch`](Self::remove_batch) / [`replay`](Self::replay)),
/// any number of concurrent readers through [`handle`](Self::handle).
pub struct CliqueService {
    session: DynamicSession,
    shared: Arc<ServiceShared>,
}

impl CliqueService {
    /// Wrap an existing session. The current registry contents become
    /// the epoch-0 snapshot; every subsequent batch publishes the next
    /// epoch (epochs count batches *since wrapping*).
    pub fn wrap(mut session: DynamicSession) -> CliqueService {
        let store = CliqueStore::from_registry(session.current_graph(), session.registry(), 0);
        let cell = Arc::new(SnapshotCell::new(Arc::new(store.freeze())));
        let shared = Arc::new(ServiceShared {
            store: Mutex::new(store),
            cell,
        });
        let hook = Arc::clone(&shared);
        let observer: BatchObserver =
            Arc::new(move |ev: &BatchEvent<'_>| hook.on_batch(ev.result, ev.graph));
        session.set_batch_observer(observer);
        CliqueService { session, shared }
    }

    /// Serve the edgeless graph on `n` vertices (epoch 0 = singletons).
    pub fn from_empty(n: usize, algo: DynAlgo) -> CliqueService {
        Self::wrap(DynamicSession::from_empty(n, algo))
    }

    /// Serve an existing static graph (C(G) bootstrapped by the session,
    /// in parallel when its thread count exceeds 1).
    pub fn from_graph(g: &CsrGraph, algo: DynAlgo) -> CliqueService {
        Self::wrap(DynamicSession::from_graph(g, algo))
    }

    pub fn session(&self) -> &DynamicSession {
        &self.session
    }

    /// Unwrap, detaching the publish hook.
    pub fn into_session(mut self) -> DynamicSession {
        self.session.clear_batch_observer();
        self.session
    }

    /// Apply one insertion batch; the new epoch is published before this
    /// returns.
    pub fn apply_batch(&mut self, edges: &[Edge]) -> BatchResult {
        self.session.apply_batch(edges)
    }

    /// Apply one removal batch (§5.3); publishes likewise.
    pub fn remove_batch(&mut self, edges: &[Edge]) -> BatchResult {
        self.session.remove_batch(edges)
    }

    /// Fallible [`apply_batch`](Self::apply_batch): a rejected batch
    /// mutates nothing and publishes nothing — the serve-replay driver
    /// retries these with backoff (ISSUE 9).
    pub fn try_apply_batch(&mut self, edges: &[Edge]) -> Result<BatchResult, BatchApplyError> {
        self.session.try_apply_batch(edges)
    }

    /// Fallible [`remove_batch`](Self::remove_batch); see
    /// [`try_apply_batch`](Self::try_apply_batch).
    pub fn try_remove_batch(&mut self, edges: &[Edge]) -> Result<BatchResult, BatchApplyError> {
        self.session.try_remove_batch(edges)
    }

    /// Replay a stream batch-by-batch, publishing one epoch per batch.
    pub fn replay(
        &mut self,
        stream: &EdgeStream,
        batch_size: usize,
        max_batches: Option<usize>,
    ) -> Vec<BatchRecord> {
        self.session.replay(stream, batch_size, max_batches)
    }

    /// A cloneable, `Send + Sync` read handle for query threads.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// The currently published snapshot.
    pub fn snapshot(&self) -> Arc<CliqueSnapshot> {
        self.shared.cell.load()
    }

    pub fn published_epoch(&self) -> u64 {
        self.shared.cell.published_epoch()
    }

    /// From-scratch rebuild of the snapshot at the current epoch — the
    /// verification twin of the incrementally maintained index (tests,
    /// `validate`-style audits). Ids are freshly assigned, so compare
    /// *contents* ([`CliqueSnapshot::canonical_cliques`], postings per
    /// vertex), not ids.
    pub fn rebuilt_snapshot(&self) -> CliqueSnapshot {
        CliqueStore::from_registry(
            self.session.current_graph(),
            self.session.registry(),
            self.published_epoch(),
        )
        .freeze()
    }
}

/// Cheap cloneable read-side handle (no access to the writer).
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<ServiceShared>,
}

impl ServiceHandle {
    /// A caching [`SnapshotReader`] — the hot-path access for query
    /// loops (one atomic load per revalidation).
    pub fn reader(&self) -> SnapshotReader {
        SnapshotReader::new(&self.shared.cell)
    }

    /// The currently published snapshot (for one-shot queries).
    pub fn snapshot(&self) -> Arc<CliqueSnapshot> {
        self.shared.cell.load()
    }

    pub fn published_epoch(&self) -> u64 {
        self.shared.cell.published_epoch()
    }

    /// |C(G)| now.
    pub fn count(&self) -> Tagged<usize> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.count(),
        }
    }

    /// The maximal cliques containing `v`.
    pub fn cliques_containing(&self, v: Vertex) -> Tagged<Vec<Arc<[Vertex]>>> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.cliques_containing(v),
        }
    }

    /// The maximal cliques containing every vertex in `verts`.
    pub fn cliques_containing_all(&self, verts: &[Vertex]) -> Tagged<Vec<Arc<[Vertex]>>> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.cliques_containing_all(verts),
        }
    }

    /// The `k` largest maximal cliques.
    pub fn top_k_largest(&self, k: usize) -> Tagged<Vec<Arc<[Vertex]>>> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.top_k_largest(k),
        }
    }

    /// Clique-size histogram of the current C(G).
    pub fn size_histogram(&self) -> Tagged<SizeHistogram> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.size_histogram(),
        }
    }

    /// Is `verts` exactly a maximal clique right now?
    pub fn is_maximal_clique(&self, verts: &[Vertex]) -> Tagged<bool> {
        let s = self.snapshot();
        Tagged {
            epoch: s.epoch(),
            value: s.is_maximal_clique(verts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;

    #[test]
    fn epochs_advance_per_batch_and_tag_answers() {
        let mut svc = CliqueService::from_empty(6, DynAlgo::Imce);
        assert_eq!(svc.published_epoch(), 0);
        assert_eq!(svc.handle().count().value, 6, "singletons at epoch 0");

        svc.apply_batch(&[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(svc.published_epoch(), 1);
        assert_eq!(svc.snapshot().graph_epoch(), 1, "graph epoch rides along");
        let t = svc.handle().cliques_containing(1);
        assert_eq!(t.epoch, 1);
        assert_eq!(t.value.len(), 1);
        assert_eq!(t.value[0].as_ref(), &[0, 1, 2]);

        svc.remove_batch(&[(0, 1)]);
        assert_eq!(svc.published_epoch(), 2);
        assert!(!svc.handle().is_maximal_clique(&[0, 1, 2]).value);
    }

    #[test]
    fn replay_publishes_every_batch_and_matches_oracle() {
        let g = generators::gnp(16, 0.4, 21);
        let stream = EdgeStream::permuted(&g, 4);
        let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
        let records = svc.replay(&stream, 9, None);
        assert_eq!(svc.published_epoch(), records.len() as u64);

        let snap = svc.snapshot();
        snap.validate().unwrap();
        let want = oracle::maximal_cliques(&g);
        assert_eq!(snap.canonical_cliques(), want);

        // the incrementally maintained index equals a from-scratch rebuild
        let rebuilt = svc.rebuilt_snapshot();
        rebuilt.validate().unwrap();
        assert_eq!(snap.canonical_cliques(), rebuilt.canonical_cliques());
        for v in 0..g.n() as Vertex {
            let mut a: Vec<Vec<Vertex>> = snap
                .cliques_containing(v)
                .iter()
                .map(|c| c.to_vec())
                .collect();
            let mut b: Vec<Vec<Vertex>> = rebuilt
                .cliques_containing(v)
                .iter()
                .map(|c| c.to_vec())
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b, "postings diverge at vertex {v}");
        }
    }

    #[test]
    fn wrap_serves_a_bootstrapped_graph_and_parallel_session() {
        let g = generators::planted_cliques(30, 0.1, 3, 4, 5, 9);
        let svc = CliqueService::wrap(DynamicSession::from_graph_threads(&g, DynAlgo::ParImce, 3));
        let snap = svc.snapshot();
        snap.validate().unwrap();
        assert_eq!(snap.epoch(), 0);
        assert_eq!(snap.canonical_cliques(), oracle::maximal_cliques(&g));
        let top = svc.handle().top_k_largest(1).value;
        assert!(!top.is_empty());
        assert!(svc.handle().is_maximal_clique(&top[0]).value);
    }

    #[test]
    fn old_snapshots_survive_later_epochs() {
        let mut svc = CliqueService::from_empty(5, DynAlgo::Imce);
        svc.apply_batch(&[(0, 1), (1, 2)]);
        let old = svc.snapshot();
        svc.apply_batch(&[(0, 2), (3, 4)]);
        // the old Arc still answers at its own epoch
        assert_eq!(old.epoch(), 1);
        assert!(old.is_maximal_clique(&[0, 1]));
        assert!(!svc.snapshot().is_maximal_clique(&[0, 1]));
        assert_eq!(svc.snapshot().epoch(), 2);
        // ... and pins the exact graph its answers were computed on,
        // even across a later removal
        svc.remove_batch(&[(0, 1)]);
        assert_eq!(old.graph_epoch(), 1);
        assert!(old.graph().has_edge(0, 1), "pinned graph keeps the edge");
        assert!(!svc.snapshot().graph().has_edge(0, 1));
        assert_eq!(svc.snapshot().graph_epoch(), 3);
        old.validate().unwrap();
        svc.snapshot().validate().unwrap();
    }
}
