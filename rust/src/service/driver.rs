//! Workload driver: replay a mixed update/query trace against a
//! [`CliqueService`] and measure the serving-path numbers that matter —
//! query throughput, per-batch update latency, and epoch lag (how far
//! reader caches trail the published epoch, and how long a published
//! epoch takes to be observed by a reader).
//!
//! The writer applies stream batches on the calling thread (the single-
//! writer discipline of Figure 4); `readers` long-lived query tasks run
//! on the coordinator pool, each with its own cached [`SnapshotReader`]
//! so the steady-state query path costs one atomic load, no lock.
//! Optional churn re-removes and re-inserts every k-th batch, driving
//! the §5.3 decremental path through the same serving pipeline.

use std::time::{Duration, Instant};

use crate::coordinator::pool::ThreadPool;
use crate::session::report::{PartialProgress, RunOutcome};
use crate::telemetry::{self, TelemetrySnapshot};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{plock, Arc, Mutex};
use crate::dynamic::stream::EdgeStream;
use crate::graph::{Edge, Vertex};
use crate::util::rng::Rng;

use super::CliqueService;

/// Knobs for one [`serve_replay`] run.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Edges per insertion batch (one epoch per batch).
    pub batch_size: usize,
    /// Truncate the stream after this many insertion batches.
    pub max_batches: Option<usize>,
    /// Long-lived query tasks on the pool (≤ pool threads to run all
    /// concurrently; excess tasks only start once earlier ones stop).
    pub readers: usize,
    /// Queries each reader issues per snapshot revalidation.
    pub queries_per_round: usize,
    /// Every k-th batch is removed and re-applied after insertion —
    /// exercises `remove_batch` under concurrent reads (net no-op).
    pub churn_every: Option<usize>,
    pub seed: u64,
    /// Per-query latency deadline: queries that take longer are counted
    /// in [`DriverReport::query_timeouts`] (the query still completes —
    /// readers are synchronous — but the SLO breach is recorded).
    pub query_deadline: Option<Duration>,
    /// Retry attempts for an update rejected at admission (transient
    /// publish/IO failures, e.g. the `dynamic-apply` failpoint) before
    /// the update is dropped and counted in
    /// [`DriverReport::failed_updates`].
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub retry_backoff: Duration,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            batch_size: 100,
            max_batches: None,
            readers: 2,
            queries_per_round: 8,
            churn_every: None,
            seed: 0x5eed,
            query_deadline: None,
            max_retries: 3,
            retry_backoff: Duration::from_millis(1),
        }
    }
}

/// What one [`serve_replay`] run measured.
#[derive(Clone, Debug, Default)]
pub struct DriverReport {
    /// Update events applied (insert batches + churn removes/re-inserts).
    pub updates: usize,
    pub edges_streamed: usize,
    pub final_epoch: u64,
    pub final_cliques: usize,
    pub total_update_ns: u64,
    pub max_update_ns: u64,
    /// Queries answered across all readers.
    pub queries: u64,
    pub wall_ns: u64,
    /// Epoch-lag samples: how many epochs reader caches trailed the
    /// published snapshot, sampled once per reader round *before*
    /// revalidation.
    pub lag_samples: u64,
    pub lag_sum: u64,
    pub max_epoch_lag: u64,
    /// Reader-side self-checks that failed (a clique read from a
    /// snapshot must be maximal in that same snapshot) — always 0
    /// unless a published snapshot's index is internally inconsistent.
    /// (Cross-epoch isolation is proved by tests/service_consistency.rs,
    /// which validates answers against per-epoch oracle state.)
    pub consistency_violations: u64,
    /// Published epochs some reader actually observed.
    pub epochs_observed: usize,
    /// Mean publish → first-observation delay over observed epochs.
    pub mean_visibility_ns: u64,
    /// Telemetry delta over the replay window (global-registry sweep at
    /// run end minus the sweep at run start); `None` only on a
    /// default-constructed report.
    pub telemetry: Option<Arc<TelemetrySnapshot>>,
    /// How the replay ended: `Completed`, or `Panicked` when the writer
    /// or a reader task died mid-run (the scope drained, readers were
    /// stopped, and the report still carries everything measured up to
    /// the fault — ISSUE 9).
    pub outcome: RunOutcome,
    /// Progress at the fault; populated (possibly with zeros) whenever
    /// [`outcome`](Self::outcome) is not `Completed`, `None` on success.
    pub partial: Option<PartialProgress>,
    /// Update retry attempts performed (admission failures retried with
    /// backoff).
    pub retries: u64,
    /// Updates dropped after exhausting [`DriverConfig::max_retries`].
    pub failed_updates: usize,
    /// Queries that exceeded [`DriverConfig::query_deadline`].
    pub query_timeouts: u64,
}

impl DriverReport {
    pub fn mean_update_ns(&self) -> u64 {
        if self.updates == 0 {
            0
        } else {
            self.total_update_ns / self.updates as u64
        }
    }

    pub fn mean_epoch_lag(&self) -> f64 {
        if self.lag_samples == 0 {
            0.0
        } else {
            self.lag_sum as f64 / self.lag_samples as f64
        }
    }

    /// Queries per second over the whole replay wall time.
    pub fn qps(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.queries as f64 * 1e9 / self.wall_ns as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "updates {} ({} edges) in {:.3}s | update mean {:.3}ms max {:.3}ms | \
             queries {} ({:.0}/s) | epoch lag mean {:.2} max {} | \
             visibility mean {:.3}ms over {} epochs | final epoch {} ({} cliques) | violations {}",
            self.updates,
            self.edges_streamed,
            self.wall_ns as f64 / 1e9,
            self.mean_update_ns() as f64 / 1e6,
            self.max_update_ns as f64 / 1e6,
            self.queries,
            self.qps(),
            self.mean_epoch_lag(),
            self.max_epoch_lag,
            self.mean_visibility_ns as f64 / 1e6,
            self.epochs_observed,
            self.final_epoch,
            self.final_cliques,
            self.consistency_violations,
        )
    }
}

/// Publish/first-seen timeline per epoch (offsets from the run start),
/// for the update-to-visibility accounting.
struct VisBoard {
    base_epoch: u64,
    publish_ns: Vec<AtomicU64>,
    seen_ns: Vec<AtomicU64>,
}

impl VisBoard {
    fn new(base_epoch: u64, events: usize) -> Self {
        VisBoard {
            base_epoch,
            publish_ns: (0..events).map(|_| AtomicU64::new(u64::MAX)).collect(),
            seen_ns: (0..events).map(|_| AtomicU64::new(u64::MAX)).collect(),
        }
    }

    fn slot(&self, epoch: u64) -> Option<usize> {
        if epoch <= self.base_epoch {
            return None; // the pre-existing snapshot is not an event
        }
        let idx = (epoch - self.base_epoch - 1) as usize;
        (idx < self.publish_ns.len()).then_some(idx)
    }

    fn mark_published(&self, epoch: u64, ns: u64) {
        if let Some(i) = self.slot(epoch) {
            self.publish_ns[i].store(ns, Ordering::Relaxed);
        }
    }

    fn mark_seen(&self, epoch: u64, ns: u64) {
        if let Some(i) = self.slot(epoch) {
            self.seen_ns[i].fetch_min(ns, Ordering::Relaxed);
        }
    }

    /// (epochs observed, mean publish→seen ns).
    fn visibility(&self) -> (usize, u64) {
        let mut observed = 0usize;
        let mut total = 0u64;
        for (p, s) in self.publish_ns.iter().zip(&self.seen_ns) {
            let (p, s) = (p.load(Ordering::Relaxed), s.load(Ordering::Relaxed));
            if p != u64::MAX && s != u64::MAX {
                observed += 1;
                total += s.saturating_sub(p);
            }
        }
        let mean = if observed == 0 { 0 } else { total / observed as u64 };
        (observed, mean)
    }
}

#[derive(Default)]
struct ReaderTotals {
    queries: u64,
    lag_samples: u64,
    lag_sum: u64,
    max_lag: u64,
    violations: u64,
    query_timeouts: u64,
}

/// Sets the readers' stop flag when dropped — *including* on unwind, so
/// a writer panic inside the replay scope can never leave reader loops
/// spinning forever waiting for a stop that would not come (ISSUE 9).
struct StopGuard(Arc<AtomicBool>);

impl Drop for StopGuard {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Release);
    }
}

/// Replay `stream` through `service` while `cfg.readers` query tasks on
/// `pool` hammer the published snapshots. Returns the measured report.
///
/// Use a pool distinct from the session's ParIMCE pool — reader loops
/// occupy workers for the whole run.
pub fn serve_replay(
    service: &mut CliqueService,
    stream: &EdgeStream,
    pool: &ThreadPool,
    cfg: &DriverConfig,
) -> DriverReport {
    let batch_size = cfg.batch_size.max(1);
    let n_batches = stream
        .edges
        .len()
        .div_ceil(batch_size)
        .min(cfg.max_batches.unwrap_or(usize::MAX));
    let churned = cfg.churn_every.map(|k| n_batches / k.max(1)).unwrap_or(0);
    let events = n_batches + 2 * churned;

    let tel_before = telemetry::snapshot();
    let base_epoch = service.published_epoch();
    let board = Arc::new(VisBoard::new(base_epoch, events));
    let stop = Arc::new(AtomicBool::new(false));
    let totals = Arc::new(Mutex::new(ReaderTotals::default()));
    let handle = service.handle();
    let t0 = Instant::now();

    let mut report = DriverReport::default();

    // `scope_catch` instead of `scope`: a panic in the writer closure or
    // in any reader task is caught at the scope join instead of
    // propagating, so the replay always returns a report (ISSUE 9).
    let joined = pool.scope_catch(|s| {
        // dropped on every exit from this closure — normal return *or*
        // unwind — so reader loops always see the stop flag
        let _stop_on_exit = StopGuard(Arc::clone(&stop));
        for r in 0..cfg.readers {
            let reader = handle.reader();
            let board = Arc::clone(&board);
            let stop = Arc::clone(&stop);
            let totals = Arc::clone(&totals);
            let seed = cfg.seed ^ (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let queries_per_round = cfg.queries_per_round.max(1);
            let deadline = cfg.query_deadline;
            s.spawn(move |_| {
                let local =
                    run_reader(reader, board, stop, seed, queries_per_round, deadline, t0);
                let mut t = plock(&totals);
                t.queries += local.queries;
                t.lag_samples += local.lag_samples;
                t.lag_sum += local.lag_sum;
                t.max_lag = t.max_lag.max(local.max_lag);
                t.violations += local.violations;
                t.query_timeouts += local.query_timeouts;
            });
        }

        // --- the writer: one batch per epoch on this thread ---------------
        let mut epoch = base_epoch;
        for (i, batch) in stream.batches(batch_size).take(n_batches).enumerate() {
            apply_update(service, batch, false, &mut report, &mut epoch, &board, t0, cfg);
            report.edges_streamed += batch.len();
            if let Some(k) = cfg.churn_every {
                if (i + 1) % k.max(1) == 0 {
                    // tear the batch back out, then re-serve it (net no-op)
                    apply_update(service, batch, true, &mut report, &mut epoch, &board, t0, cfg);
                    apply_update(service, batch, false, &mut report, &mut epoch, &board, t0, cfg);
                }
            }
        }
    });

    report.wall_ns = t0.elapsed().as_nanos() as u64;
    let final_snap = service.snapshot();
    report.final_epoch = final_snap.epoch();
    report.final_cliques = final_snap.count();
    let t = plock(&totals);
    report.queries = t.queries;
    report.lag_samples = t.lag_samples;
    report.lag_sum = t.lag_sum;
    report.max_epoch_lag = t.max_lag;
    report.consistency_violations = t.violations;
    report.query_timeouts = t.query_timeouts;
    drop(t);
    let (observed, mean_vis) = board.visibility();
    report.epochs_observed = observed;
    report.mean_visibility_ns = mean_vis;
    report.telemetry = Some(Arc::new(telemetry::snapshot().delta(&tel_before)));
    if let Err(payload) = joined {
        report.outcome = RunOutcome::from_panic(payload.as_ref());
    }
    if report.outcome != RunOutcome::Completed {
        report.partial = Some(PartialProgress {
            cliques_emitted: report.final_cliques as u64,
            batches_applied: report.updates as u64,
            bytes_flushed: 0,
        });
    }
    report
}

/// One timed update event: apply (or remove) a batch, account for it,
/// and stamp the publish time of the epoch it produced.  An update
/// rejected at admission (transient failure) is retried with doubling
/// backoff up to [`DriverConfig::max_retries`] times, then dropped and
/// counted — the epoch sequence simply skips it.
#[allow(clippy::too_many_arguments)]
fn apply_update(
    svc: &mut CliqueService,
    edges: &[Edge],
    remove: bool,
    report: &mut DriverReport,
    epoch: &mut u64,
    board: &VisBoard,
    t0: Instant,
    cfg: &DriverConfig,
) {
    let tb = Instant::now();
    let mut backoff = cfg.retry_backoff;
    let mut attempt = 0u32;
    loop {
        let result = if remove {
            svc.try_remove_batch(edges)
        } else {
            svc.try_apply_batch(edges)
        };
        match result {
            Ok(_) => break,
            Err(_) if attempt < cfg.max_retries => {
                attempt += 1;
                report.retries += 1;
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
            }
            Err(_) => {
                // dropped: nothing was mutated or published, the session
                // still sits at the previous batch boundary
                report.failed_updates += 1;
                return;
            }
        }
    }
    let ns = tb.elapsed().as_nanos() as u64;
    // the observer publishes at the tail of apply/remove, so stamping
    // right after return is within counter-update nanoseconds of the
    // true publish instant; a reader beating the stamp reads as 0 delay
    *epoch += 1;
    board.mark_published(*epoch, t0.elapsed().as_nanos() as u64);
    report.updates += 1;
    report.total_update_ns += ns;
    report.max_update_ns = report.max_update_ns.max(ns);
}

fn run_reader(
    mut reader: super::SnapshotReader,
    board: Arc<VisBoard>,
    stop: Arc<AtomicBool>,
    seed: u64,
    queries_per_round: usize,
    query_deadline: Option<Duration>,
    t0: Instant,
) -> ReaderTotals {
    let mut rng = Rng::new(seed);
    let mut local = ReaderTotals::default();
    let tel = telemetry::global();
    // do-while: every reader task completes at least one query round
    // even if it is first scheduled after the writer finished
    loop {
        // staleness sampled *before* revalidating: how far did this
        // reader's cache trail the writer since the last round?
        let lag = reader.staleness();
        local.lag_samples += 1;
        local.lag_sum += lag;
        local.max_lag = local.max_lag.max(lag);
        tel.service_epoch_lag_sum.add(lag);
        tel.service_epoch_lag_samples.inc();
        tel.service_epoch_lag_max.set_max(lag);

        let snap = Arc::clone(reader.current());
        board.mark_seen(snap.epoch(), t0.elapsed().as_nanos() as u64);
        let n = snap.n().max(1) as u64;
        for _ in 0..queries_per_round {
            let tq = query_deadline.map(|_| Instant::now());
            match rng.gen_range(6) {
                0 => {
                    let v = rng.gen_range(n) as Vertex;
                    std::hint::black_box(snap.cliques_containing(v).len());
                }
                1 => {
                    let u = rng.gen_range(n) as Vertex;
                    let v = rng.gen_range(n) as Vertex;
                    std::hint::black_box(snap.cliques_containing_all(&[u, v]).len());
                }
                2 => {
                    std::hint::black_box(snap.top_k_largest(4).len());
                }
                3 => {
                    std::hint::black_box(snap.count());
                }
                4 => {
                    // self-check: a clique served by this snapshot must be
                    // maximal in this same snapshot (intra-snapshot index
                    // integrity; the cross-epoch isolation proof lives in
                    // tests/service_consistency.rs)
                    let v = rng.gen_range(n) as Vertex;
                    if let Some(&id) = snap.ids_containing(v).first() {
                        let c = snap.clique(id).expect("live posting id");
                        if !snap.is_maximal_clique(c) {
                            local.violations += 1;
                        }
                    }
                }
                _ => {
                    std::hint::black_box(snap.size_histogram().count());
                }
            }
            local.queries += 1;
            tel.service_queries.inc();
            // per-query deadline: readers are synchronous, so a breach
            // is recorded (SLO accounting) rather than aborted mid-query
            if let (Some(deadline), Some(tq)) = (query_deadline, tq) {
                if tq.elapsed() > deadline {
                    local.query_timeouts += 1;
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;
    use crate::session::dynamic::DynAlgo;

    #[test]
    fn driver_replays_and_serves_consistently() {
        let g = generators::gnp(14, 0.4, 33);
        let stream = EdgeStream::permuted(&g, 8);
        let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
        let pool = ThreadPool::new(2);
        let cfg = DriverConfig {
            batch_size: 5,
            readers: 2,
            queries_per_round: 4,
            churn_every: Some(3),
            seed: 7,
            max_batches: None,
            ..DriverConfig::default()
        };
        let report = serve_replay(&mut svc, &stream, &pool, &cfg);

        let n_batches = stream.edges.len().div_ceil(5);
        assert_eq!(report.updates, n_batches + 2 * (n_batches / 3));
        assert_eq!(report.final_epoch, report.updates as u64);
        assert_eq!(report.edges_streamed, stream.edges.len());
        assert_eq!(report.consistency_violations, 0);
        assert!(report.queries > 0, "readers must have run");
        assert!(report.lag_samples > 0);
        assert_eq!(report.outcome, RunOutcome::Completed);
        assert!(report.partial.is_none(), "no fault, no partial report");
        assert_eq!(report.retries, 0);
        assert_eq!(report.failed_updates, 0);

        // churn is a net no-op: final state equals the full graph's C(G)
        let want = oracle::maximal_cliques(&g);
        let snap = svc.snapshot();
        snap.validate().unwrap();
        assert_eq!(report.final_cliques, want.len());
        assert_eq!(snap.canonical_cliques(), want);
        let line = report.summary();
        assert!(line.contains("violations 0"), "{line}");

        // the embedded telemetry delta reconciles with the report totals
        // (≥: the registry is process-global, parallel tests can add)
        let d = report.telemetry.as_ref().expect("driver attaches telemetry");
        if !cfg!(feature = "telemetry-off") {
            use crate::telemetry::names;
            assert!(d.counter(names::SERVICE_QUERIES).unwrap() >= report.queries);
            assert!(d.counter(names::SERVICE_PUBLISHES).unwrap() >= report.updates as u64);
            assert!(d.counter(names::SERVICE_EPOCH_LAG_SAMPLES).unwrap() >= report.lag_samples);
            assert!(d.gauge(names::SERVICE_EPOCH_LAG_MAX).unwrap() >= report.max_epoch_lag);
        }
    }

    #[test]
    fn max_batches_caps_the_replay() {
        let g = generators::gnp(12, 0.4, 1);
        let stream = EdgeStream::permuted(&g, 2);
        let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
        let pool = ThreadPool::new(1);
        let cfg = DriverConfig {
            batch_size: 4,
            max_batches: Some(3),
            readers: 1,
            queries_per_round: 2,
            churn_every: None,
            seed: 1,
            // an unmeetable deadline: every measured query breaches it,
            // which pins the SLO accounting without slowing the run
            query_deadline: Some(Duration::ZERO),
            ..DriverConfig::default()
        };
        let report = serve_replay(&mut svc, &stream, &pool, &cfg);
        assert_eq!(report.updates, 3);
        assert_eq!(report.final_epoch, 3);
        assert_eq!(report.edges_streamed, 12.min(stream.edges.len()));
        assert!(
            report.query_timeouts > 0,
            "a zero deadline must record breaches ({} queries)",
            report.queries
        );
    }
}
