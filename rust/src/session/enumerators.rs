//! One object-safe [`Enumerator`] per algorithm, and the [`Algo`] enum
//! that names them all.
//!
//! Each adapter translates an algorithm's bespoke signature — pool or no
//! pool, ranking or none, `Result<(), BudgetError>` or `()` — into the
//! uniform `enumerate(ctx, graph, sink) -> RunReport` contract.  A
//! counting shim wraps the caller's sink so every report carries the
//! emitted-clique count regardless of what the sink does with them.

use crate::util::sync::Arc;
use std::time::Instant;

use crate::baselines::gp::{simulate_gp, GpConfig, GpOutcome};
use crate::baselines::{bk, clique_enumerator, greedybb, hashing, peamc, peco};
use crate::coordinator::stats::Subproblem;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::parmce::parmce;
use crate::mce::parttt::parttt;
use crate::mce::sink::{CliqueSink, CountSink, ShardedCountSink, TeeSink};
use crate::mce::{ttt, ParMceConfig};
use crate::telemetry;
use crate::util::membudget::BudgetError;

use super::context::ExecContext;
use super::report::{PartialProgress, RunOutcome, RunReport};

/// Every enumeration algorithm the engine can run behind one name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Sequential TTT (paper Algorithm 1) — the work-efficiency baseline.
    Ttt,
    /// Work-efficient parallel TTT (Algorithm 3).
    ParTtt,
    /// Rank-decomposed ParTTT with nested parallelism (Algorithm 4); uses
    /// the session's [`crate::mce::ranking::RankStrategy`].
    ParMce,
    /// Bron–Kerbosch with pivoting (independent implementation).
    Bk,
    /// Bron–Kerbosch without pivoting (Algorithm 457, 1973).
    BkBasic,
    /// Eppstein–Löffler–Strash degeneracy-ordered BK.
    BkDegeneracy,
    /// Shared-memory PECO: rank-partitioned, no nested parallelism.
    Peco,
    /// Peamc: unpivoted parallel search + slow maximality test; honors
    /// the session deadline (Table 8's timeout rows).
    Peamc,
    /// GP: enumerates the rank decomposition, then prices the MPI
    /// exchange cost model at the session's thread count (Table 9).
    Gp,
    /// GreedyBB: bit-parallel branch-and-bound; honors the session
    /// memory budget and deadline (Table 10).
    GreedyBb,
    /// CliqueEnumerator: iterative expansion with per-clique bit vectors;
    /// honors the session memory budget (Table 8's OOM rows).
    CliqueEnumerator,
    /// Hashing: global-table k→k+1 expansion; honors the memory budget.
    Hashing,
}

impl Algo {
    /// Every algorithm, in table order.
    pub const ALL: [Algo; 12] = [
        Algo::Ttt,
        Algo::ParTtt,
        Algo::ParMce,
        Algo::Bk,
        Algo::BkBasic,
        Algo::BkDegeneracy,
        Algo::Peco,
        Algo::Peamc,
        Algo::Gp,
        Algo::GreedyBb,
        Algo::CliqueEnumerator,
        Algo::Hashing,
    ];

    /// [`ALL`](Self::ALL) as a slice (iteration convenience).
    pub fn all() -> &'static [Algo] {
        &Self::ALL
    }

    /// Display name used in reports and experiment tables.
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Ttt => "TTT",
            Algo::ParTtt => "ParTTT",
            Algo::ParMce => "ParMCE",
            Algo::Bk => "BKPivot",
            Algo::BkBasic => "BKBasic",
            Algo::BkDegeneracy => "BKDegeneracy",
            Algo::Peco => "PECO",
            Algo::Peamc => "Peamc",
            Algo::Gp => "GP",
            Algo::GreedyBb => "GreedyBB",
            Algo::CliqueEnumerator => "CliqueEnumerator",
            Algo::Hashing => "Hashing",
        }
    }

    /// CLI spelling → algorithm (see `parmce help`).
    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "ttt" => Algo::Ttt,
            "parttt" => Algo::ParTtt,
            "parmce" => Algo::ParMce,
            "bk" => Algo::Bk,
            "bk-basic" => Algo::BkBasic,
            "bk-degeneracy" => Algo::BkDegeneracy,
            "peco" => Algo::Peco,
            "peamc" => Algo::Peamc,
            "gp" => Algo::Gp,
            "greedybb" => Algo::GreedyBb,
            "clique-enumerator" => Algo::CliqueEnumerator,
            "hashing" => Algo::Hashing,
            _ => return None,
        })
    }

    /// The [`Enumerator`] adapter that runs this algorithm.
    pub fn enumerator(self) -> Box<dyn Enumerator> {
        match self {
            Algo::Ttt => Box::new(TttEnumerator),
            Algo::ParTtt => Box::new(ParTttEnumerator),
            Algo::ParMce => Box::new(ParMceEnumerator),
            Algo::Bk => Box::new(BkEnumerator),
            Algo::BkBasic => Box::new(BkBasicEnumerator),
            Algo::BkDegeneracy => Box::new(BkDegeneracyEnumerator),
            Algo::Peco => Box::new(PecoEnumerator),
            Algo::Peamc => Box::new(PeamcEnumerator),
            Algo::Gp => Box::new(GpEnumerator),
            Algo::GreedyBb => Box::new(GreedyBbEnumerator),
            Algo::CliqueEnumerator => Box::new(CliqueEnumeratorEnumerator),
            Algo::Hashing => Box::new(HashingEnumerator),
        }
    }
}

/// Object-safe enumeration contract: run the algorithm on `g`, emit
/// every maximal clique into `sink`, report what happened.  All state an
/// algorithm needs beyond the graph (pool, ranking, budget, deadline)
/// comes from the [`ExecContext`].
pub trait Enumerator: Send + Sync {
    /// Display name (matches [`Algo::name`] for the built-in adapters).
    fn name(&self) -> &'static str;

    /// Run the algorithm on `g`, emitting into `sink`.
    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport;
}

/// Pass-through sink that counts emissions for the [`RunReport`].
///
/// Every run of every algorithm goes through this shim, which makes it
/// the one emit that can never be opted out of — so it counts through a
/// worker-sharded counter rather than a shared atomic, keeping the
/// mandatory part of the emit hot path off shared cache lines.  The
/// telemetry `cliques_emitted` counter is bumped here too (same sharded
/// discipline; the registry reference is cached at construction so the
/// emit path never touches the `OnceLock`).
struct CountedSink {
    inner: Arc<dyn CliqueSink>,
    emitted: ShardedCountSink,
    cliques_metric: &'static telemetry::Counter,
}

impl CliqueSink for CountedSink {
    #[inline]
    fn emit(&self, clique: &[Vertex]) {
        // `sink-emit` failpoint: the one emit every run goes through.
        // `panic` unwinds into the enumerator (contained at the pool job
        // boundary, or by `run_counted` on the caller thread); `error`
        // drops this clique on the floor.
        if crate::util::failpoints::hit(crate::util::failpoints::Site::SinkEmit) {
            return;
        }
        self.emitted.emit(clique);
        self.cliques_metric.inc();
        self.inner.emit(clique);
    }
}

/// Shared run harness: wrap the sink in a sharded counter, honor the
/// cancellation flag, time the run, assemble the report — including the
/// telemetry delta over the run's window (the global registry swept
/// before and after; subtraction isolates this run from everything the
/// process did earlier).
fn run_counted(
    algo: Algo,
    ctx: &ExecContext,
    sink: &Arc<dyn CliqueSink>,
    f: impl FnOnce(&Arc<dyn CliqueSink>) -> RunOutcome,
) -> RunReport {
    let counted = Arc::new(CountedSink {
        inner: Arc::clone(sink),
        emitted: ShardedCountSink::new(ctx.threads()),
        cliques_metric: &telemetry::global().cliques_emitted,
    });
    let as_dyn: Arc<dyn CliqueSink> = Arc::clone(&counted);
    let before = telemetry::snapshot();
    let t0 = Instant::now();
    let outcome = if ctx.is_cancelled() {
        RunOutcome::Cancelled
    } else {
        // Unwind boundary for the whole run: a panic on the caller thread
        // (sequential algorithms) or one re-raised by a scope join
        // (parallel algorithms drain their siblings first) becomes a
        // structured outcome instead of killing the session (ISSUE 9).
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&as_dyn))) {
            Ok(outcome) => outcome,
            Err(payload) => RunOutcome::from_panic(payload.as_ref()),
        }
    };
    let wall = t0.elapsed();
    let delta = telemetry::snapshot().delta(&before);
    let cliques = counted.emitted.count();
    // every non-Completed outcome reports what was already safe: the
    // cliques that reached the sink before the fault
    let partial = (outcome != RunOutcome::Completed).then(|| PartialProgress {
        cliques_emitted: cliques,
        ..PartialProgress::default()
    });
    RunReport {
        algo,
        cliques,
        wall,
        outcome,
        telemetry: Some(Arc::new(delta)),
        partial,
    }
}

fn budget_outcome(err: BudgetError) -> RunOutcome {
    match err {
        BudgetError::OutOfBudget { .. } => RunOutcome::OutOfMemory,
        BudgetError::TimedOut { .. } => RunOutcome::TimedOut,
    }
}

/// Adapter for sequential [`Algo::Ttt`].
pub struct TttEnumerator;

impl Enumerator for TttEnumerator {
    fn name(&self) -> &'static str {
        Algo::Ttt.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::Ttt, ctx, sink, |s| {
            ttt::ttt_with_cutoff(g, s.as_ref(), ctx.parttt_config().bitset_cutoff);
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::ParTtt`] on the session pool.
pub struct ParTttEnumerator;

impl Enumerator for ParTttEnumerator {
    fn name(&self) -> &'static str {
        Algo::ParTtt.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::ParTtt, ctx, sink, |s| {
            parttt(ctx.pool(), g, s, ctx.parttt_config());
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::ParMce`] (rank-decomposed, session ranking).
pub struct ParMceEnumerator;

impl Enumerator for ParMceEnumerator {
    fn name(&self) -> &'static str {
        Algo::ParMce.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        // rankings are expensive: don't compute one for a cancelled run
        let ranking = (!ctx.is_cancelled()).then(|| ctx.ranking(g, ctx.rank_strategy()));
        run_counted(Algo::ParMce, ctx, sink, |s| {
            let ranking = ranking.unwrap_or_else(|| ctx.ranking(g, ctx.rank_strategy()));
            let cfg = ParMceConfig {
                parttt: ctx.parttt_config(),
            };
            parmce(ctx.pool(), g, &ranking, s, cfg);
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::Bk`] (Bron–Kerbosch with pivoting).
pub struct BkEnumerator;

impl Enumerator for BkEnumerator {
    fn name(&self) -> &'static str {
        Algo::Bk.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::Bk, ctx, sink, |s| {
            bk::bk_pivot(g, s.as_ref());
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::BkBasic`] (unpivoted Bron–Kerbosch).
pub struct BkBasicEnumerator;

impl Enumerator for BkBasicEnumerator {
    fn name(&self) -> &'static str {
        Algo::BkBasic.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::BkBasic, ctx, sink, |s| {
            bk::bk_basic(g, s.as_ref());
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::BkDegeneracy`].
pub struct BkDegeneracyEnumerator;

impl Enumerator for BkDegeneracyEnumerator {
    fn name(&self) -> &'static str {
        Algo::BkDegeneracy.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::BkDegeneracy, ctx, sink, |s| {
            bk::bk_degeneracy(g, s.as_ref());
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::Peco`] (rank-partitioned, flat tasks).
pub struct PecoEnumerator;

impl Enumerator for PecoEnumerator {
    fn name(&self) -> &'static str {
        Algo::Peco.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        let ranking = (!ctx.is_cancelled()).then(|| ctx.ranking(g, ctx.rank_strategy()));
        run_counted(Algo::Peco, ctx, sink, |s| {
            let ranking = ranking.unwrap_or_else(|| ctx.ranking(g, ctx.rank_strategy()));
            peco::peco(ctx.pool(), g, &ranking, s, ctx.parttt_config().bitset_cutoff);
            RunOutcome::Completed
        })
    }
}

/// Adapter for [`Algo::Peamc`] (deadline-aware).
pub struct PeamcEnumerator;

impl Enumerator for PeamcEnumerator {
    fn name(&self) -> &'static str {
        Algo::Peamc.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        run_counted(Algo::Peamc, ctx, sink, |s| {
            match peamc::peamc(ctx.pool(), g, s, ctx.deadline()) {
                Ok(()) => RunOutcome::Completed,
                Err(e) => budget_outcome(e),
            }
        })
    }
}

/// Adapter for [`Algo::Gp`] (measures, then prices the MPI model).
pub struct GpEnumerator;

impl Enumerator for GpEnumerator {
    fn name(&self) -> &'static str {
        Algo::Gp.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        let strategy = ctx.rank_strategy();
        let ranking = (!ctx.is_cancelled()).then(|| ctx.ranking(g, strategy));
        run_counted(Algo::Gp, ctx, sink, |s| {
            // enumerate the rank decomposition, timing each subproblem —
            // the measured input the GP exchange cost model reprices.
            // (Same decomposition as `subproblems_timed`, but tee'd into
            // the caller's sink and cancellable between vertices.)
            let ranking = ranking.unwrap_or_else(|| ctx.ranking(g, strategy));
            let mut subs: Vec<Subproblem> = Vec::with_capacity(g.n());
            for v in 0..g.n() as Vertex {
                if ctx.is_cancelled() {
                    return RunOutcome::Cancelled;
                }
                let (cand, fini) = ranking.split_neighbors(g, v);
                let local = CountSink::new();
                let tee = TeeSink {
                    a: &local,
                    b: s.as_ref(),
                };
                let mut k = vec![v];
                let t0 = Instant::now();
                ttt::ttt_from_with_cutoff(
                    g.as_ref(),
                    &mut k,
                    cand,
                    fini,
                    &tee,
                    ctx.parttt_config().bitset_cutoff,
                );
                subs.push(Subproblem {
                    vertex: v,
                    cliques: local.count(),
                    ns: t0.elapsed().as_nanos() as u64,
                });
            }
            let outcome = match simulate_gp(g, &subs, ctx.threads(), GpConfig::default()) {
                GpOutcome::Finished { .. } => RunOutcome::Completed,
                GpOutcome::OutOfMemory { .. } => RunOutcome::OutOfMemory,
            };
            // the full decomposition was just measured — share it with
            // later subproblems()/simulate_gp() calls on this context
            ctx.seed_subproblems(g, strategy, Arc::new(subs));
            outcome
        })
    }
}

/// Adapter for [`Algo::GreedyBb`] (budget- and deadline-aware).
pub struct GreedyBbEnumerator;

impl Enumerator for GreedyBbEnumerator {
    fn name(&self) -> &'static str {
        Algo::GreedyBb.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        let budget = ctx.mem_budget();
        run_counted(Algo::GreedyBb, ctx, sink, |s| {
            match greedybb::greedybb(g, s.as_ref(), &budget, ctx.deadline()) {
                Ok(()) => RunOutcome::Completed,
                Err(e) => budget_outcome(e),
            }
        })
    }
}

/// Adapter for [`Algo::CliqueEnumerator`] (budget-aware).
pub struct CliqueEnumeratorEnumerator;

impl Enumerator for CliqueEnumeratorEnumerator {
    fn name(&self) -> &'static str {
        Algo::CliqueEnumerator.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        let budget = ctx.mem_budget();
        run_counted(Algo::CliqueEnumerator, ctx, sink, |s| {
            match clique_enumerator::clique_enumerator(g, s.as_ref(), &budget) {
                Ok(()) => RunOutcome::Completed,
                Err(e) => budget_outcome(e),
            }
        })
    }
}

/// Adapter for [`Algo::Hashing`] (budget-aware).
pub struct HashingEnumerator;

impl Enumerator for HashingEnumerator {
    fn name(&self) -> &'static str {
        Algo::Hashing.name()
    }

    fn enumerate(
        &self,
        ctx: &ExecContext,
        g: &Arc<CsrGraph>,
        sink: &Arc<dyn CliqueSink>,
    ) -> RunReport {
        let budget = ctx.mem_budget();
        run_counted(Algo::Hashing, ctx, sink, |s| {
            match hashing::hashing(g, s.as_ref(), &budget) {
                Ok(()) => RunOutcome::Completed,
                Err(e) => budget_outcome(e),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_and_parse_round_trip() {
        for &a in Algo::all() {
            assert!(!a.name().is_empty());
        }
        assert_eq!(Algo::parse("ttt"), Some(Algo::Ttt));
        assert_eq!(Algo::parse("clique-enumerator"), Some(Algo::CliqueEnumerator));
        assert_eq!(Algo::parse("nope"), None);
        assert_eq!(Algo::all().len(), 12);
    }

    #[test]
    fn enumerator_factory_covers_every_variant() {
        for &a in Algo::all() {
            assert_eq!(a.enumerator().name(), a.name());
        }
    }
}
