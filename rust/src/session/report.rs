//! Uniform run reporting for every enumeration algorithm.
//!
//! The pre-session API leaked each algorithm's failure mode through its
//! signature: the TTT family returned `()`, the memory-bound baselines
//! returned `Result<(), BudgetError>`, GP returned its own outcome enum.
//! A [`RunReport`] normalizes all of them so callers compare algorithms
//! without per-algorithm plumbing — the paper's Table 8/10 "Out of
//! memory" and "did not complete" cells become [`RunOutcome`] variants.

use std::time::Duration;

use super::enumerators::Algo;

/// How an enumeration run ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunOutcome {
    /// Every maximal clique was emitted into the sink.
    Completed,
    /// The run exceeded its [`crate::util::membudget::MemBudget`]
    /// (the paper's "Out of memory" cells).
    OutOfMemory,
    /// The run exceeded its wall-clock deadline (the paper's "did not
    /// complete in 5 hours" cells).
    TimedOut,
    /// The session's cancellation flag was set before the run started.
    Cancelled,
}

/// What one enumeration run did: which algorithm, how many cliques
/// reached the sink, how long it took, and how it ended.
#[derive(Clone, Copy, Debug)]
pub struct RunReport {
    pub algo: Algo,
    /// Cliques that reached the sink. On a non-`Completed` outcome this
    /// is the count emitted before the run aborted.
    pub cliques: u64,
    pub wall: Duration,
    pub outcome: RunOutcome,
}

impl RunReport {
    pub fn completed(&self) -> bool {
        self.outcome == RunOutcome::Completed
    }

    pub fn secs(&self) -> f64 {
        self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_helpers() {
        let r = RunReport {
            algo: Algo::Ttt,
            cliques: 3,
            wall: Duration::from_millis(1500),
            outcome: RunOutcome::Completed,
        };
        assert!(r.completed());
        assert!((r.secs() - 1.5).abs() < 1e-9);
        let oom = RunReport {
            outcome: RunOutcome::OutOfMemory,
            ..r
        };
        assert!(!oom.completed());
    }
}
