//! Uniform run reporting for every enumeration algorithm.
//!
//! The pre-session API leaked each algorithm's failure mode through its
//! signature: the TTT family returned `()`, the memory-bound baselines
//! returned `Result<(), BudgetError>`, GP returned its own outcome enum.
//! A [`RunReport`] normalizes all of them so callers compare algorithms
//! without per-algorithm plumbing — the paper's Table 8/10 "Out of
//! memory" and "did not complete" cells become [`RunOutcome`] variants.

use std::time::Duration;

use crate::telemetry::TelemetrySnapshot;
use crate::util::sync::Arc;

use super::enumerators::Algo;

/// How an enumeration run ended.
///
/// Not `Copy` since ISSUE 9: [`Panicked`](RunOutcome::Panicked) and
/// [`SinkFailed`](RunOutcome::SinkFailed) carry the fault description.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RunOutcome {
    /// Every maximal clique was emitted into the sink.
    Completed,
    /// The run exceeded its [`crate::util::membudget::MemBudget`]
    /// (the paper's "Out of memory" cells).
    OutOfMemory,
    /// The run exceeded its wall-clock deadline (the paper's "did not
    /// complete in 5 hours" cells).
    TimedOut,
    /// The session's cancellation flag was set before the run started.
    Cancelled,
    /// A worker (or the run itself) panicked; the pool drained the
    /// sibling tasks, the first payload was captured at scope join, and
    /// the run returned instead of hanging or aborting (ISSUE 9).
    Panicked {
        /// Failpoint site name when the panic came from an injected
        /// fault (parsed from the payload's `failpoint <site>:` prefix),
        /// `"unknown"` for organic panics.
        site: String,
        /// The panic payload, when it was a string (the common case).
        message: String,
    },
    /// The output sink reported an I/O failure; everything written
    /// before the fault is accounted in [`RunReport::partial`] and the
    /// run's [`OutputStats`].
    SinkFailed {
        /// Failure description from the writer.
        message: String,
    },
}

impl Default for RunOutcome {
    /// `Completed` — so `Default`-constructed reports (e.g. the driver's
    /// `DriverReport::default()`) start from success and only a caught
    /// fault overwrites the outcome.
    fn default() -> Self {
        RunOutcome::Completed
    }
}

impl RunOutcome {
    /// Build a [`RunOutcome::Panicked`] from a caught unwind payload
    /// (e.g. [`crate::coordinator::pool::ThreadPool::scope_catch`]).
    pub fn from_panic(payload: &(dyn std::any::Any + Send)) -> RunOutcome {
        let message = if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = payload.downcast_ref::<&'static str>() {
            (*s).to_string()
        } else {
            "non-string panic payload".to_string()
        };
        let site = message
            .strip_prefix("failpoint ")
            .and_then(|rest| rest.split(':').next())
            .unwrap_or("unknown")
            .to_string();
        RunOutcome::Panicked { site, message }
    }
}

/// What had already safely happened when a run ended early — attached to
/// every non-[`Completed`](RunOutcome::Completed) [`RunReport`] /
/// `DriverReport` so a fault still yields the partial results that were
/// produced before it (ISSUE 9 graceful degradation).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PartialProgress {
    /// Cliques that reached the sink before the fault.
    pub cliques_emitted: u64,
    /// Dynamic batches fully applied before the fault (0 for static runs).
    pub batches_applied: u64,
    /// Bytes flushed to the output writer before the fault (0 for
    /// non-streaming sinks).
    pub bytes_flushed: u64,
}

impl PartialProgress {
    /// True when the fault struck before anything at all was produced.
    pub fn is_empty(&self) -> bool {
        self.cliques_emitted == 0 && self.batches_applied == 0 && self.bytes_flushed == 0
    }
}

/// What one enumeration run did: which algorithm, how many cliques
/// reached the sink, how long it took, and how it ended.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Which algorithm ran.
    pub algo: Algo,
    /// Cliques that reached the sink. On a non-`Completed` outcome this
    /// is the count emitted before the run aborted.
    pub cliques: u64,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// How the run ended.
    pub outcome: RunOutcome,
    /// Telemetry delta over this run's window (global-registry sweep at
    /// run end minus the sweep at run start): pool scheduling, ParTTT
    /// cutover/hand-off counts, per-worker busy time.  `None` only when
    /// a report is synthesized outside the run harness.  Shared via
    /// `Arc` so reports stay cheap to clone.
    pub telemetry: Option<Arc<TelemetrySnapshot>>,
    /// Progress made before a fault: populated (possibly with zeros) on
    /// every non-`Completed` outcome, `None` on success.
    pub partial: Option<PartialProgress>,
}

impl RunReport {
    /// Did the run emit every clique ([`RunOutcome::Completed`])?
    pub fn completed(&self) -> bool {
        self.outcome == RunOutcome::Completed
    }

    /// Wall time in seconds.
    pub fn secs(&self) -> f64 {
        self.wall.as_secs_f64()
    }

    /// Emitted cliques per second of wall time (0 for a zero-length run)
    /// — the output-dominated-workload headline number.
    pub fn cliques_per_sec(&self) -> f64 {
        let s = self.secs();
        if s > 0.0 {
            self.cliques as f64 / s
        } else {
            0.0
        }
    }
}

/// Materialized-output statistics for runs whose sink writes somewhere
/// (the streaming writer): what reached the output and what the byte /
/// clique budget rejected.  Carried by
/// [`crate::session::SessionRun::output`] next to the [`RunReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutputStats {
    /// Bytes accepted by the writer (equals bytes on disk after flush).
    pub bytes_written: u64,
    /// Cliques the writer accepted.
    pub cliques_written: u64,
    /// Buffer flushes to the shared output.
    pub flushes: u64,
    /// Cliques rejected by the output budget (0 = complete output).
    pub dropped: u64,
}

impl OutputStats {
    /// True when every emitted clique reached the output.
    pub fn complete(&self) -> bool {
        self.dropped == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_helpers() {
        let r = RunReport {
            algo: Algo::Ttt,
            cliques: 3,
            wall: Duration::from_millis(1500),
            outcome: RunOutcome::Completed,
            telemetry: None,
            partial: None,
        };
        assert!(r.completed());
        assert!((r.secs() - 1.5).abs() < 1e-9);
        let oom = RunReport {
            outcome: RunOutcome::OutOfMemory,
            ..r.clone()
        };
        assert!(!oom.completed());
        assert!((r.cliques_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn panic_payload_maps_to_outcome() {
        let injected: Box<dyn std::any::Any + Send> =
            Box::new("failpoint sink-emit: injected panic".to_string());
        match RunOutcome::from_panic(injected.as_ref()) {
            RunOutcome::Panicked { site, message } => {
                assert_eq!(site, "sink-emit");
                assert_eq!(message, "failpoint sink-emit: injected panic");
            }
            other => panic!("wrong outcome {other:?}"),
        }
        let organic: Box<dyn std::any::Any + Send> = Box::new("index out of bounds");
        match RunOutcome::from_panic(organic.as_ref()) {
            RunOutcome::Panicked { site, message } => {
                assert_eq!(site, "unknown");
                assert_eq!(message, "index out of bounds");
            }
            other => panic!("wrong outcome {other:?}"),
        }
        let opaque: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert!(matches!(
            RunOutcome::from_panic(opaque.as_ref()),
            RunOutcome::Panicked { ref site, .. } if site == "unknown"
        ));
    }

    #[test]
    fn partial_progress_emptiness() {
        assert!(PartialProgress::default().is_empty());
        assert!(!PartialProgress {
            cliques_emitted: 1,
            ..Default::default()
        }
        .is_empty());
    }

    #[test]
    fn output_stats_completeness() {
        let full = OutputStats {
            bytes_written: 10,
            cliques_written: 2,
            flushes: 1,
            dropped: 0,
        };
        assert!(full.complete());
        assert!(!OutputStats { dropped: 1, ..full }.complete());
        assert!(OutputStats::default().complete());
    }
}
