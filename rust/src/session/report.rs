//! Uniform run reporting for every enumeration algorithm.
//!
//! The pre-session API leaked each algorithm's failure mode through its
//! signature: the TTT family returned `()`, the memory-bound baselines
//! returned `Result<(), BudgetError>`, GP returned its own outcome enum.
//! A [`RunReport`] normalizes all of them so callers compare algorithms
//! without per-algorithm plumbing — the paper's Table 8/10 "Out of
//! memory" and "did not complete" cells become [`RunOutcome`] variants.

use std::time::Duration;

use crate::telemetry::TelemetrySnapshot;
use crate::util::sync::Arc;

use super::enumerators::Algo;

/// How an enumeration run ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunOutcome {
    /// Every maximal clique was emitted into the sink.
    Completed,
    /// The run exceeded its [`crate::util::membudget::MemBudget`]
    /// (the paper's "Out of memory" cells).
    OutOfMemory,
    /// The run exceeded its wall-clock deadline (the paper's "did not
    /// complete in 5 hours" cells).
    TimedOut,
    /// The session's cancellation flag was set before the run started.
    Cancelled,
}

/// What one enumeration run did: which algorithm, how many cliques
/// reached the sink, how long it took, and how it ended.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algo: Algo,
    /// Cliques that reached the sink. On a non-`Completed` outcome this
    /// is the count emitted before the run aborted.
    pub cliques: u64,
    pub wall: Duration,
    pub outcome: RunOutcome,
    /// Telemetry delta over this run's window (global-registry sweep at
    /// run end minus the sweep at run start): pool scheduling, ParTTT
    /// cutover/hand-off counts, per-worker busy time.  `None` only when
    /// a report is synthesized outside the run harness.  Shared via
    /// `Arc` so reports stay cheap to clone.
    pub telemetry: Option<Arc<TelemetrySnapshot>>,
}

impl RunReport {
    pub fn completed(&self) -> bool {
        self.outcome == RunOutcome::Completed
    }

    pub fn secs(&self) -> f64 {
        self.wall.as_secs_f64()
    }

    /// Emitted cliques per second of wall time (0 for a zero-length run)
    /// — the output-dominated-workload headline number.
    pub fn cliques_per_sec(&self) -> f64 {
        let s = self.secs();
        if s > 0.0 {
            self.cliques as f64 / s
        } else {
            0.0
        }
    }
}

/// Materialized-output statistics for runs whose sink writes somewhere
/// (the streaming writer): what reached the output and what the byte /
/// clique budget rejected.  Carried by
/// [`crate::session::SessionRun::output`] next to the [`RunReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutputStats {
    /// Bytes accepted by the writer (equals bytes on disk after flush).
    pub bytes_written: u64,
    /// Cliques the writer accepted.
    pub cliques_written: u64,
    /// Buffer flushes to the shared output.
    pub flushes: u64,
    /// Cliques rejected by the output budget (0 = complete output).
    pub dropped: u64,
}

impl OutputStats {
    /// True when every emitted clique reached the output.
    pub fn complete(&self) -> bool {
        self.dropped == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_helpers() {
        let r = RunReport {
            algo: Algo::Ttt,
            cliques: 3,
            wall: Duration::from_millis(1500),
            outcome: RunOutcome::Completed,
            telemetry: None,
        };
        assert!(r.completed());
        assert!((r.secs() - 1.5).abs() < 1e-9);
        let oom = RunReport {
            outcome: RunOutcome::OutOfMemory,
            ..r.clone()
        };
        assert!(!oom.completed());
        assert!((r.cliques_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn output_stats_completeness() {
        let full = OutputStats {
            bytes_written: 10,
            cliques_written: 2,
            flushes: 1,
            dropped: 0,
        };
        assert!(full.complete());
        assert!(!OutputStats { dropped: 1, ..full }.complete());
        assert!(OutputStats::default().complete());
    }
}
