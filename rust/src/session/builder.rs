//! [`SessionBuilder`] → [`MceSession`]: the crate's front door for
//! static maximal clique enumeration.
//!
//! One builder replaces the manual pool/ranking/sink dance: pick a graph
//! source, an [`Algo`], a [`RankStrategy`], resource limits and a sink
//! shape, and get a session whose [`ExecContext`] owns the pool and the
//! cached rankings.  Every algorithm then runs through the same
//! `count` / `collect` / `run` verbs and reports a uniform [`RunReport`].

use std::path::{Path, PathBuf};
use crate::util::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::baselines::gp::{simulate_gp, GpConfig, GpOutcome};
use crate::coordinator::pool::ThreadPool;
use crate::coordinator::sim::Trace;
use crate::coordinator::stats::Subproblem;
use crate::graph::csr::CsrGraph;
use crate::graph::datasets::{Dataset, Scale};
use crate::graph::{Edge, Vertex};
use crate::mce::parmce::{parmce_with_subproblems, subproblems_timed, trace, trace_parttt};
use crate::mce::ParMceConfig;
use crate::mce::ranking::{RankStrategy, Ranking};
use crate::mce::sink::{
    CliqueSink, CountSink, NullSink, ShardedCollectSink, ShardedHistogramSink, SizeHistogram,
    StreamWriterSink, WriterConfig, WriterFormat, WriterStats,
};
use crate::mce::ParTttConfig;

use super::context::ExecContext;
use super::enumerators::Algo;
use super::report::{OutputStats, PartialProgress, RunOutcome, RunReport};

/// What the session's default [`MceSession::run`] does with emitted
/// cliques.  Custom sinks go through [`MceSession::run_with_sink`].
///
/// All shapes are served by the sharded sink layer (one lock-free shard
/// per pool worker, merged after the scope joins) — emits on the
/// parallel hot path touch no shared cache line.
#[derive(Clone, Debug)]
pub enum SinkSpec {
    /// O(1)-memory counting (the default; Orkut has 2.27B cliques).
    Count,
    /// Materialize every clique in canonical order (tests/small graphs).
    Collect,
    /// Clique-size histogram (Figure 5).
    Histogram { max_size: usize },
    /// Stream every clique to `path` in `format`, with the byte budget
    /// tied to the session memory limit (see [`MceSession::stream_to`]).
    Stream { path: PathBuf, format: WriterFormat },
}

/// Builder for [`MceSession`]. All knobs have sensible defaults; only a
/// graph source is required.
///
/// ```
/// use parmce::session::{Algo, MceSession};
///
/// let session = MceSession::builder()
///     .dataset(parmce::graph::datasets::Dataset::DblpLike,
///              parmce::graph::datasets::Scale::Tiny)
///     .threads(2)
///     .ingest_threads(2) // parallel ranking pre-pass, identical results
///     .build()
///     .unwrap();
/// let report = session.count(Algo::ParMce);
/// assert!(report.cliques > 0);
/// ```
pub struct SessionBuilder {
    graph: Option<Arc<CsrGraph>>,
    algo: Algo,
    rank: RankStrategy,
    threads: usize,
    ingest_threads: Option<usize>,
    mem_budget: Option<usize>,
    deadline: Duration,
    parttt: ParTttConfig,
    sink: SinkSpec,
    seeded_rankings: Vec<Arc<Ranking>>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            graph: None,
            algo: Algo::ParMce,
            rank: RankStrategy::Degree,
            threads: 4,
            ingest_threads: None,
            mem_budget: None,
            deadline: Duration::from_secs(3600),
            parttt: ParTttConfig::default(),
            sink: SinkSpec::Count,
            seeded_rankings: Vec::new(),
        }
    }
}

impl SessionBuilder {
    /// A builder with all-default knobs (same as [`Default::default`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Graph source: an owned CSR graph.
    pub fn graph(mut self, g: CsrGraph) -> Self {
        self.graph = Some(Arc::new(g));
        self
    }

    /// Graph source: a shared CSR graph (no copy).
    pub fn graph_arc(mut self, g: Arc<CsrGraph>) -> Self {
        self.graph = Some(g);
        self
    }

    /// Graph source: an edge list over `n` vertices.
    pub fn edges(self, n: usize, edges: &[Edge]) -> Self {
        self.graph(CsrGraph::from_edges(n, edges))
    }

    /// Graph source: a synthetic dataset analog at the given scale.
    pub fn dataset(self, d: Dataset, scale: Scale) -> Self {
        self.graph(d.graph(scale))
    }

    /// Default algorithm for [`MceSession::run`] (default: `ParMce`).
    pub fn algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    /// Vertex ranking for the rank-decomposed algorithms (default:
    /// `Degree` — the paper's best overall configuration).
    pub fn rank_strategy(mut self, rank: RankStrategy) -> Self {
        self.rank = rank;
        self
    }

    /// Worker threads for the work-stealing pool (default: 4). The pool
    /// spawns lazily, so sequential-only sessions never pay for it.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Worker threads for the ingest/ranking pre-pass (parallel CSR
    /// build, triangle counting, core decomposition).  Defaults to the
    /// enumeration [`threads`](Self::threads) value, in which case the
    /// pre-pass reuses the enumeration pool; `1` forces the sequential
    /// reference path.  The parallel pre-pass is exact-equal to the
    /// sequential one, so this knob changes wall-clock only, never
    /// results (see `DESIGN.md`, "Ingest & ranking pipeline").
    pub fn ingest_threads(mut self, threads: usize) -> Self {
        self.ingest_threads = Some(threads.max(1));
        self
    }

    /// Cooperative memory budget for the memory-bound baselines
    /// (default: unlimited). Exceeding it yields
    /// [`super::RunOutcome::OutOfMemory`].
    pub fn mem_budget_bytes(mut self, bytes: usize) -> Self {
        self.mem_budget = Some(bytes);
        self
    }

    /// Wall-clock deadline for the deadline-aware baselines (default:
    /// one hour). Exceeding it yields [`super::RunOutcome::TimedOut`].
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = deadline;
        self
    }

    /// ParTTT granularity control (default: [`ParTttConfig::default`]).
    pub fn parttt_config(mut self, cfg: ParTttConfig) -> Self {
        self.parttt = cfg;
        self
    }

    /// Shorthand for the sequential cutoff of [`ParTttConfig`].
    pub fn seq_cutoff(mut self, cutoff: usize) -> Self {
        self.parttt.seq_cutoff = cutoff;
        self
    }

    /// Shorthand for the bitset hand-off threshold of [`ParTttConfig`]:
    /// subproblems whose `|cand| + |fini|` is at or below it finish in
    /// the dense bit-parallel kernel ([`crate::mce::bitkernel`]); 0
    /// disables the kernel (slice-only recursion).
    pub fn bitset_cutoff(mut self, cutoff: usize) -> Self {
        self.parttt.bitset_cutoff = cutoff;
        self
    }

    /// Default sink shape for [`MceSession::run`] (default: `Count`).
    pub fn sink(mut self, sink: SinkSpec) -> Self {
        self.sink = sink;
        self
    }

    /// Shorthand for [`SinkSpec::Stream`]: stream every clique emitted
    /// by [`MceSession::run`] to `path`.
    pub fn stream(mut self, path: impl Into<PathBuf>, format: WriterFormat) -> Self {
        self.sink = SinkSpec::Stream {
            path: path.into(),
            format,
        };
        self
    }

    /// Seed the ranking cache with an externally computed ranking —
    /// the path for the PJRT/Pallas triangle backend, whose client is
    /// not `Sync` and therefore cannot live inside the context.
    pub fn ranking(mut self, ranking: Arc<Ranking>) -> Self {
        self.seeded_rankings.push(ranking);
        self
    }

    /// Finalize the builder.  Fails only when no graph source was given.
    pub fn build(self) -> Result<MceSession> {
        let g = self.graph.ok_or_else(|| {
            anyhow!("SessionBuilder: no graph source (use .graph/.graph_arc/.edges/.dataset)")
        })?;
        let ctx = ExecContext::new(
            self.threads,
            self.ingest_threads.unwrap_or(self.threads),
            self.rank,
            self.mem_budget,
            self.deadline,
            self.parttt,
        );
        for r in self.seeded_rankings {
            ctx.seed_ranking(&g, r);
        }
        Ok(MceSession {
            g,
            algo: self.algo,
            sink: self.sink,
            ctx,
        })
    }
}

/// Output of one [`MceSession::run`]: the report plus whatever the
/// configured [`SinkSpec`] materialized.
pub struct SessionRun {
    /// The uniform run report (count, wall time, outcome, telemetry).
    pub report: RunReport,
    /// Canonical clique list (`SinkSpec::Collect` only).
    pub cliques: Option<Vec<Vec<Vertex>>>,
    /// Size histogram (`SinkSpec::Histogram` only).
    pub histogram: Option<SizeHistogram>,
    /// Materialized-output stats (`SinkSpec::Stream` only).
    pub output: Option<OutputStats>,
}

/// [`WriterStats`] → the report-layer [`OutputStats`].
fn output_stats(w: WriterStats) -> OutputStats {
    OutputStats {
        bytes_written: w.bytes,
        cliques_written: w.cliques,
        flushes: w.flushes,
        dropped: w.dropped,
    }
}

/// A static-graph enumeration session: one graph, one shared
/// [`ExecContext`], any number of algorithm runs.
pub struct MceSession {
    g: Arc<CsrGraph>,
    algo: Algo,
    sink: SinkSpec,
    ctx: ExecContext,
}

impl MceSession {
    /// Entry point: a fresh [`SessionBuilder`].
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// The graph every run of this session enumerates.
    pub fn graph(&self) -> &Arc<CsrGraph> {
        &self.g
    }

    /// The shared execution context (pools, caches, limits, history).
    pub fn ctx(&self) -> &ExecContext {
        &self.ctx
    }

    /// The enumeration thread pool (spawned on first use).
    pub fn pool(&self) -> &ThreadPool {
        self.ctx.pool()
    }

    /// The algorithm [`run`](Self::run) defaults to.
    pub fn algo(&self) -> Algo {
        self.algo
    }

    /// Run the session's configured algorithm into its configured sink.
    pub fn run(&self) -> SessionRun {
        self.run_algo(self.algo)
    }

    /// Run `algo` into the session's configured sink.
    ///
    /// I/O failures of a [`SinkSpec::Stream`] sink do not panic: the run
    /// degrades to a report with [`RunOutcome::SinkFailed`] whose
    /// [`RunReport::partial`] accounts what reached the output before
    /// the fault (ISSUE 9).  Use [`MceSession::stream_to`] when you want
    /// the failure as a `Result` instead.
    pub fn run_algo(&self, algo: Algo) -> SessionRun {
        match &self.sink {
            SinkSpec::Count => SessionRun {
                report: self.count(algo),
                cliques: None,
                histogram: None,
                output: None,
            },
            SinkSpec::Collect => {
                let (cliques, report) = self.collect(algo);
                SessionRun {
                    report,
                    cliques: Some(cliques),
                    histogram: None,
                    output: None,
                }
            }
            SinkSpec::Histogram { max_size } => {
                let (hist, report) = self.histogram(algo, *max_size);
                SessionRun {
                    report,
                    cliques: None,
                    histogram: Some(hist),
                    output: None,
                }
            }
            SinkSpec::Stream { path, format } => self.stream_run(algo, path, *format),
        }
    }

    /// Run `algo` with an O(1)-memory counting sink. The run harness's
    /// sharded counter already counts every emit for the report, so the
    /// sink itself is a no-op — zero shared state on the emit path.
    pub fn count(&self, algo: Algo) -> RunReport {
        let sink: Arc<dyn CliqueSink> = Arc::new(NullSink::new());
        self.run_with_sink(algo, &sink)
    }

    /// Run `algo` collecting every clique in canonical order
    /// (worker-sharded buffers, merged after the run).
    pub fn collect(&self, algo: Algo) -> (Vec<Vec<Vertex>>, RunReport) {
        let collect = Arc::new(ShardedCollectSink::new(self.ctx.threads()));
        let sink: Arc<dyn CliqueSink> = Arc::clone(&collect);
        let report = self.run_with_sink(algo, &sink);
        drop(sink);
        let cliques = Arc::into_inner(collect)
            .expect("collect sink still shared after run")
            .into_canonical();
        (cliques, report)
    }

    /// Run `algo` into a worker-sharded size histogram, merged into a
    /// [`SizeHistogram`] with `max_size` regular bins after the run.
    pub fn histogram(&self, algo: Algo, max_size: usize) -> (SizeHistogram, RunReport) {
        let hist = Arc::new(ShardedHistogramSink::new(self.ctx.threads()));
        let sink: Arc<dyn CliqueSink> = Arc::clone(&hist);
        let report = self.run_with_sink(algo, &sink);
        drop(sink);
        let hist = Arc::into_inner(hist)
            .expect("histogram sink still shared after run")
            .into_histogram(max_size);
        (hist, report)
    }

    /// Run `algo` streaming every clique to `path` — the at-scale
    /// alternative to [`collect`](Self::collect) (Orkut's 2.27B cliques
    /// fit on disk, not in memory).  The writer's byte budget is tied to
    /// the session memory limit: a session built with
    /// [`SessionBuilder::mem_budget_bytes`] truncates the file there and
    /// reports the rejected cliques in [`WriterStats::dropped`] instead
    /// of filling the disk.
    pub fn stream_to(
        &self,
        algo: Algo,
        path: impl AsRef<Path>,
        format: WriterFormat,
    ) -> Result<(RunReport, WriterStats)> {
        let cfg = WriterConfig {
            format,
            byte_budget: self.ctx.mem_budget_bytes().map(|b| b as u64),
            ..WriterConfig::default()
        };
        let writer = StreamWriterSink::create(path, self.ctx.threads(), cfg)?;
        self.stream_with(algo, writer)
    }

    /// Run `algo` into a pre-configured [`StreamWriterSink`] (custom
    /// formats, budgets, buffer sizes, or non-file outputs).
    pub fn stream_with(
        &self,
        algo: Algo,
        writer: StreamWriterSink,
    ) -> Result<(RunReport, WriterStats)> {
        let writer = Arc::new(writer);
        let sink: Arc<dyn CliqueSink> = Arc::clone(&writer);
        let report = self.run_with_sink(algo, &sink);
        drop(sink);
        let stats = Arc::into_inner(writer)
            .expect("writer sink still shared after run")
            .finish()?;
        Ok((report, stats))
    }

    /// [`SinkSpec::Stream`] under the infallible [`run`](Self::run)
    /// contract: writer failures (create or mid-run I/O) degrade to a
    /// synthesized [`RunOutcome::SinkFailed`] report carrying
    /// [`PartialProgress`] instead of panicking.
    fn stream_run(&self, algo: Algo, path: &Path, format: WriterFormat) -> SessionRun {
        let cfg = WriterConfig {
            format,
            byte_budget: self.ctx.mem_budget_bytes().map(|b| b as u64),
            ..WriterConfig::default()
        };
        let writer = match StreamWriterSink::create(path, self.ctx.threads(), cfg) {
            Ok(w) => w,
            Err(e) => {
                // nothing ran: a zero-progress failed report
                let report = RunReport {
                    algo,
                    cliques: 0,
                    wall: Duration::ZERO,
                    outcome: RunOutcome::SinkFailed {
                        message: format!("clique writer create failed: {e}"),
                    },
                    telemetry: None,
                    partial: Some(PartialProgress::default()),
                };
                self.ctx.record(report.clone());
                return SessionRun {
                    report,
                    cliques: None,
                    histogram: None,
                    output: None,
                };
            }
        };
        let writer = Arc::new(writer);
        let sink: Arc<dyn CliqueSink> = Arc::clone(&writer);
        let mut report = algo.enumerator().enumerate(&self.ctx, &self.g, &sink);
        drop(sink);
        let writer = Arc::into_inner(writer).expect("writer sink still shared after run");
        let output = match writer.finish() {
            Ok(stats) => output_stats(stats),
            Err(e) => {
                let message = e.to_string();
                let flushed: u64 = e.per_worker_bytes.iter().sum();
                let stats = e.stats;
                // enumeration may itself have failed (e.g. a worker
                // panic); keep the first fault, it subsumes the sink's
                if report.outcome == RunOutcome::Completed {
                    report.outcome = RunOutcome::SinkFailed { message };
                }
                report.partial = Some(PartialProgress {
                    cliques_emitted: report.cliques,
                    batches_applied: 0,
                    bytes_flushed: flushed,
                });
                output_stats(stats)
            }
        };
        self.ctx.record(report.clone());
        SessionRun {
            report,
            cliques: None,
            histogram: None,
            output: Some(output),
        }
    }

    /// Run `algo` into a caller-provided sink.
    pub fn run_with_sink(&self, algo: Algo, sink: &Arc<dyn CliqueSink>) -> RunReport {
        let report = algo.enumerator().enumerate(&self.ctx, &self.g, sink);
        self.ctx.record(report.clone());
        report
    }

    /// The (cached) ranking for `strategy` on this session's graph.
    pub fn ranking(&self, strategy: RankStrategy) -> Arc<Ranking> {
        self.ctx.ranking(&self.g, strategy)
    }

    /// Measured per-vertex subproblem costs under `strategy` (cached).
    pub fn subproblems(&self, strategy: RankStrategy) -> Arc<Vec<Subproblem>> {
        self.ctx.subproblems(&self.g, strategy)
    }

    /// Subproblem costs under an ad-hoc ranking (not cached) — for
    /// ablations that test non-paper orderings.
    pub fn subproblems_with(&self, ranking: &Ranking) -> Vec<Subproblem> {
        subproblems_timed(&self.g, ranking)
    }

    /// Per-vertex subproblem skew measured from a real *parallel* ParMCE
    /// run: each root carries a [`crate::telemetry::SubCell`] that its
    /// whole task tree feeds (cliques via the sink wrapper, CPU time per
    /// task), so the Figure-2 skew analysis
    /// ([`crate::coordinator::stats::share_curve`]) can be driven by
    /// production scheduling instead of the sequential
    /// [`subproblems`](Self::subproblems) methodology.  Not cached (each
    /// call re-measures under current load); uses the session's rank
    /// strategy and ParTTT config.
    pub fn subproblems_parallel(&self) -> Vec<Subproblem> {
        let ranking = self.ctx.ranking(&self.g, self.ctx.rank_strategy());
        let sink: Arc<dyn CliqueSink> = Arc::new(NullSink::new());
        let cfg = ParMceConfig {
            parttt: self.ctx.parttt_config(),
        };
        parmce_with_subproblems(self.ctx.pool(), &self.g, &ranking, &sink, cfg)
    }

    /// Measured ParMCE task tree under `strategy` for the scheduler
    /// simulator; returns the trace and the clique count it covered.
    pub fn parmce_trace(&self, strategy: RankStrategy) -> (Trace, u64) {
        let ranking = self.ctx.ranking(&self.g, strategy);
        let sink = CountSink::new();
        let tr = trace(&self.g, &ranking, &sink);
        (tr, sink.count())
    }

    /// Measured ParTTT task tree (single root over the whole graph).
    pub fn parttt_trace(&self) -> (Trace, u64) {
        let sink = CountSink::new();
        let tr = trace_parttt(&self.g, &sink);
        (tr, sink.count())
    }

    /// Price the GP exchange cost model at `workers` MPI nodes using the
    /// session's cached subproblem measurements (Table 9).
    pub fn simulate_gp(&self, workers: usize, cfg: GpConfig) -> GpOutcome {
        let subs = self.ctx.subproblems(&self.g, self.ctx.rank_strategy());
        simulate_gp(&self.g, &subs, workers, cfg)
    }

    /// Set the cooperative cancellation flag: subsequent runs report
    /// [`super::RunOutcome::Cancelled`] without starting.
    pub fn cancel(&self) {
        self.ctx.cancel();
    }

    /// Undo [`cancel`](Self::cancel) so the session can run again.
    pub fn clear_cancel(&self) {
        self.ctx.clear_cancel();
    }

    /// Every run this session has executed, in order.
    pub fn history(&self) -> Vec<RunReport> {
        self.ctx.history()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::session::RunOutcome;

    #[test]
    fn builder_requires_a_graph() {
        assert!(MceSession::builder().build().is_err());
    }

    #[test]
    fn ingest_threads_knob_plumbs_to_context() {
        let g = generators::gnp(10, 0.3, 1);
        let s = MceSession::builder()
            .graph(g.clone())
            .threads(2)
            .ingest_threads(4)
            .build()
            .unwrap();
        assert_eq!(s.ctx().threads(), 2);
        assert_eq!(s.ctx().ingest_threads(), 4);
        // default: ingest pool mirrors the enumeration pool size
        let d = MceSession::builder().graph(g).threads(3).build().unwrap();
        assert_eq!(d.ctx().ingest_threads(), 3);
    }

    #[test]
    fn count_and_collect_agree_with_each_other() {
        let g = generators::gnp(20, 0.4, 9);
        let s = MceSession::builder().graph(g).threads(2).build().unwrap();
        let report = s.count(Algo::Ttt);
        assert_eq!(report.outcome, RunOutcome::Completed);
        let (cliques, r2) = s.collect(Algo::Ttt);
        assert_eq!(cliques.len() as u64, report.cliques);
        assert_eq!(r2.cliques, report.cliques);
        assert_eq!(s.history().len(), 2);
    }

    #[test]
    fn run_honors_sink_spec() {
        let g = generators::gnp(18, 0.4, 4);
        let s = MceSession::builder()
            .graph(g)
            .algo(Algo::Ttt)
            .sink(SinkSpec::Histogram { max_size: 32 })
            .build()
            .unwrap();
        let run = s.run();
        let hist = run.histogram.expect("histogram requested");
        assert_eq!(hist.count(), run.report.cliques);
        assert!(run.cliques.is_none());
    }

    #[test]
    fn stream_sink_writes_one_line_per_clique() {
        let dir = std::env::temp_dir().join("parmce_builder_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cliques.ndjson");

        let g = generators::gnp(20, 0.4, 7);
        let s = MceSession::builder()
            .graph(g)
            .algo(Algo::ParTtt)
            .threads(2)
            .stream(&path, WriterFormat::Ndjson)
            .build()
            .unwrap();
        let want = s.count(Algo::Ttt).cliques;
        let run = s.run();
        assert_eq!(run.report.cliques, want);
        let out = run.output.expect("stream sink stats");
        assert_eq!(out.cliques_written, want);
        assert_eq!(out.dropped, 0);
        assert!(out.complete());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count() as u64, want);
        assert_eq!(out.bytes_written as usize, text.len());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stream_to_honors_the_session_memory_budget() {
        let dir = std::env::temp_dir().join("parmce_builder_budget_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("truncated.txt");

        let g = generators::moon_moser(4); // 81 cliques of size 4
        let s = MceSession::builder()
            .graph(g)
            .mem_budget_bytes(64) // a few lines at most
            .build()
            .unwrap();
        let (report, stats) = s
            .stream_to(Algo::Ttt, &path, WriterFormat::Text)
            .unwrap();
        assert_eq!(report.cliques, 81, "enumeration itself is unaffected");
        assert!(stats.dropped > 0, "budget must reject the overflow");
        assert_eq!(stats.cliques + stats.dropped, 81);
        assert!(stats.bytes <= 64 + 16, "soft cap overshoot stays small");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn seeded_ranking_is_served_from_cache() {
        let g = generators::gnp(16, 0.3, 2);
        let pre = Arc::new(Ranking::compute(&g, RankStrategy::Triangle));
        let s = MceSession::builder()
            .graph(g)
            .rank_strategy(RankStrategy::Triangle)
            .ranking(Arc::clone(&pre))
            .build()
            .unwrap();
        assert!(Arc::ptr_eq(&s.ranking(RankStrategy::Triangle), &pre));
    }

    #[test]
    fn parallel_subproblem_capture_counts_every_clique() {
        let g = generators::gnp(24, 0.35, 6);
        let s = MceSession::builder().graph(g).threads(3).build().unwrap();
        let want = s.count(Algo::Ttt).cliques;
        let subs = s.subproblems_parallel();
        assert_eq!(subs.len(), s.graph().n());
        assert_eq!(subs.iter().map(|p| p.cliques).sum::<u64>(), want);
    }

    #[test]
    fn reports_carry_a_telemetry_delta() {
        let g = generators::gnp(20, 0.4, 9);
        let s = MceSession::builder().graph(g).threads(2).build().unwrap();
        let report = s.count(Algo::ParTtt);
        let snap = report.telemetry.as_ref().expect("run harness attaches telemetry");
        // under telemetry-off the delta exists but reads zero
        if cfg!(feature = "telemetry-off") {
            assert_eq!(
                snap.counter(crate::telemetry::names::CLIQUES_EMITTED),
                Some(0)
            );
        } else {
            // the window's own emits are visible (other parallel tests may
            // add more, but never subtract)
            assert!(
                snap.counter(crate::telemetry::names::CLIQUES_EMITTED).unwrap()
                    >= report.cliques
            );
        }
    }

    #[test]
    fn traces_cover_the_full_enumeration() {
        let g = generators::gnp(24, 0.35, 6);
        let s = MceSession::builder().graph(g).build().unwrap();
        let want = s.count(Algo::Ttt).cliques;
        let (tr, n) = s.parmce_trace(RankStrategy::Degree);
        assert_eq!(n, want);
        assert!(!tr.is_empty());
        let (tr2, n2) = s.parttt_trace();
        assert_eq!(n2, want);
        assert!(!tr2.is_empty());
    }
}
