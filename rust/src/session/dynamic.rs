//! [`DynamicSession`]: incremental maximal clique maintenance behind one
//! `apply_batch` verb.
//!
//! Wraps the epoch-snapshotted [`SnapshotGraph`], the concurrent
//! [`CliqueRegistry`] and the IMCE / ParIMCE batch engines (paper §5) so
//! callers choose an algorithm once and stream edge batches — the
//! Figure 4 pipeline — without hand-wiring pools or registries.  Every
//! applied batch publishes one graph epoch; [`current_graph`] hands out
//! the published `Arc<GraphSnapshot>` with no adjacency rebuild.  The
//! decremental reduction (§5.3) rides along as
//! [`DynamicSession::remove_batch`].
//!
//! [`current_graph`]: DynamicSession::current_graph

use crate::util::sync::Arc;
use std::time::Instant;

use crate::coordinator::pool::ThreadPool;
use crate::dynamic::imce::{imce_batch_with_cutoff, BatchTimings};
use crate::dynamic::par_imce::par_imce_batch_with_cutoff;
use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::stream::{imce_remove_batch, BatchRecord, EdgeStream};
use crate::dynamic::BatchResult;
use crate::graph::csr::CsrGraph;
use crate::graph::snapshot::{GraphSnapshot, SnapshotGraph};
use crate::graph::{Edge, Vertex};
use crate::mce::bitkernel::DEFAULT_BITSET_CUTOFF;

/// Which incremental engine a [`DynamicSession`] applies batches with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DynAlgo {
    /// Sequential IMCE (VLDB 2019 baseline).
    Imce,
    /// ParIMCE (paper Algorithms 5–7) on the work-stealing pool.
    ParImce,
}

impl DynAlgo {
    /// Display name used in reports and CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            DynAlgo::Imce => "IMCE",
            DynAlgo::ParImce => "ParIMCE",
        }
    }

    /// Parse a CLI-style name (`imce`, `parimce`/`par-imce`/`par_imce`).
    pub fn parse(s: &str) -> Option<DynAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "imce" => Some(DynAlgo::Imce),
            "parimce" | "par-imce" | "par_imce" => Some(DynAlgo::ParImce),
            _ => None,
        }
    }

    /// Default pool width: sequential engines get 1, ParIMCE gets 4.
    pub fn default_threads(&self) -> usize {
        match self {
            DynAlgo::Imce => 1,
            DynAlgo::ParImce => 4,
        }
    }
}

/// Which kind of mutation a [`BatchEvent`] reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// Edge insertions (IMCE / ParIMCE).
    Insert,
    /// Edge removals (§5.3 decremental reduction).
    Remove,
}

/// One applied batch, as seen by a [`BatchObserver`]: the change set plus
/// its position in the session's batch sequence and the graph snapshot
/// the change set was computed against.  `seq` equals
/// [`DynamicSession::batches_applied`] at notification time, so an
/// observer that publishes per-batch snapshots gets a dense epoch counter
/// for free; for sessions constructed at graph epoch 0 (all of them),
/// `graph.epoch() == seq as u64`.
pub struct BatchEvent<'a> {
    /// Insert or remove.
    pub kind: BatchKind,
    /// 1-based batch sequence number within this session.
    pub seq: usize,
    /// The canonical change set (Λⁿᵉʷ, Λᵈᵉˡ) the batch produced.
    pub result: &'a BatchResult,
    /// The post-batch graph epoch snapshot — exactly the graph the
    /// engine enumerated `result` against.  Observers that serve queries
    /// clone the `Arc` and pin it next to the clique set.
    pub graph: &'a Arc<GraphSnapshot>,
}

impl BatchEvent<'_> {
    /// Epoch of the post-batch graph snapshot.
    pub fn graph_epoch(&self) -> u64 {
        self.graph.epoch()
    }
}

/// Hook fired after *every* applied batch (insert or remove), including
/// the ones [`DynamicSession::replay`] drives internally — the seam the
/// [`crate::service`] layer uses to publish epoch snapshots the moment a
/// batch lands.  Runs on the caller's thread, after the registry has
/// advanced to the post-batch C(G).
pub type BatchObserver = Arc<dyn Fn(&BatchEvent<'_>) + Send + Sync>;

/// A batch that was rejected *before* any mutation: the graph, registry
/// and epoch counter are exactly as they were after
/// [`batches_applied`](DynamicSession::batches_applied) batches — the
/// precise rollback boundary a caller can retry or resume from (ISSUE 9).
///
/// Today the only producer is the `dynamic-apply` failpoint
/// ([`crate::util::failpoints::Site::DynamicApply`], `error` action);
/// the type is the seam where real admission failures (e.g. a batch
/// validator) would surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchApplyError {
    /// Batches fully applied before the rejected one — the session state
    /// still reflects exactly this many.
    pub batches_applied: usize,
    /// Failure description (failpoint-formatted for injected faults, so
    /// [`crate::session::RunOutcome::from_panic`] can parse the site).
    pub message: String,
}

impl std::fmt::Display for BatchApplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ({} batches already applied)",
            self.message, self.batches_applied
        )
    }
}

impl std::error::Error for BatchApplyError {}

/// A dynamic-graph session: the graph, its maximal clique set C(G), and
/// the chosen batch engine. Every mutation keeps the registry exact.
///
/// ```
/// use parmce::session::{DynAlgo, DynamicSession};
///
/// let mut s = DynamicSession::from_empty(4, DynAlgo::Imce);
/// s.apply_batch(&[(0, 1), (1, 2), (0, 2)]);
/// assert_eq!(s.clique_count(), 2); // the triangle {0,1,2} and {3}
/// ```
pub struct DynamicSession {
    graph: SnapshotGraph,
    registry: CliqueRegistry,
    algo: DynAlgo,
    threads: usize,
    pool: Option<ThreadPool>,
    bitset_cutoff: usize,
    batches_applied: usize,
    total_new: u64,
    total_subsumed: u64,
    observer: Option<BatchObserver>,
}

impl DynamicSession {
    /// Start from the edgeless graph on `n` vertices (the §6 replay
    /// methodology); C(G) = the n singleton cliques.
    pub fn from_empty(n: usize, algo: DynAlgo) -> DynamicSession {
        let registry = CliqueRegistry::new();
        for v in 0..n as Vertex {
            registry.insert_canonical(&[v]);
        }
        DynamicSession {
            graph: SnapshotGraph::empty(n),
            registry,
            algo,
            threads: algo.default_threads(),
            pool: None,
            bitset_cutoff: DEFAULT_BITSET_CUTOFF,
            batches_applied: 0,
            total_new: 0,
            total_subsumed: 0,
            observer: None,
        }
    }

    /// Start from an existing static graph with the engine's default
    /// thread count (1 for IMCE, 4 for ParIMCE); C(G) is bootstrapped
    /// in parallel whenever more than one thread is configured.
    pub fn from_graph(g: &CsrGraph, algo: DynAlgo) -> DynamicSession {
        Self::from_graph_threads(g, algo, algo.default_threads())
    }

    /// Start from an existing static graph with an explicit thread
    /// count.  With `threads > 1` the pool spawns eagerly and C(G) is
    /// bootstrapped with ParTTT straight into the sharded registry;
    /// otherwise sequential TTT is used.
    pub fn from_graph_threads(g: &CsrGraph, algo: DynAlgo, threads: usize) -> DynamicSession {
        let threads = threads.max(1);
        // one adjacency copy: the snapshot writer chunks the CSR (in
        // parallel when a pool is configured — identical blocks either
        // way), then the bootstrap enumerates straight off the published
        // epoch-0 snapshot (previously this path copied the graph twice)
        let (graph, registry, pool) = if threads > 1 {
            let pool = ThreadPool::new(threads);
            let graph = SnapshotGraph::from_csr_parallel(g, &pool);
            let snap = graph.current();
            let registry = CliqueRegistry::from_graph_parallel(&snap, &pool);
            (graph, registry, Some(pool))
        } else {
            let graph = SnapshotGraph::from_csr(g);
            let snap = graph.current();
            (graph, CliqueRegistry::from_graph(snap.as_ref()), None)
        };
        DynamicSession {
            graph,
            registry,
            algo,
            threads,
            pool,
            bitset_cutoff: DEFAULT_BITSET_CUTOFF,
            batches_applied: 0,
            total_new: 0,
            total_subsumed: 0,
            observer: None,
        }
    }

    /// Worker threads for the ParIMCE pool (the pool spawns lazily on the
    /// first parallel batch).  Dropping to a different count discards an
    /// already-spawned pool so batches never run on a stale size.
    pub fn with_threads(mut self, threads: usize) -> DynamicSession {
        self.threads = threads.max(1);
        if self.pool.as_ref().is_some_and(|p| p.num_threads() != self.threads) {
            self.pool = None;
        }
        self
    }

    /// Share an existing pool instead of spawning one.
    pub fn with_pool(mut self, pool: ThreadPool) -> DynamicSession {
        self.pool = Some(pool);
        self
    }

    /// Bitset hand-off threshold for the TTT-exclude recompute calls
    /// inside every insert batch: working sets at or below it run in the
    /// dense bit-parallel kernel ([`crate::mce::bitkernel`]); 0 keeps
    /// the recursion on the sorted-slice path.  Applies to batches from
    /// this call on — the bootstrap enumeration `from_graph*` already
    /// ran uses the default hand-off (the clique set is identical either
    /// way; the knob only changes execution strategy).
    pub fn with_bitset_cutoff(mut self, cutoff: usize) -> DynamicSession {
        self.bitset_cutoff = cutoff;
        self
    }

    /// The configured bitset hand-off threshold.
    pub fn bitset_cutoff(&self) -> usize {
        self.bitset_cutoff
    }

    /// Overlay size (total neighbour entries) above which the graph
    /// compacts its delta overlay back into CSR blocks at the next
    /// publish; see [`SnapshotGraph::with_compact_threshold`].
    pub fn with_graph_compact_threshold(mut self, nbrs: usize) -> DynamicSession {
        self.graph.set_compact_threshold(nbrs);
        self
    }

    /// The batch engine this session applies mutations with.
    pub fn algo(&self) -> DynAlgo {
        self.algo
    }

    /// Install the per-batch hook (replacing any previous one); see
    /// [`BatchObserver`].
    pub fn set_batch_observer(&mut self, observer: BatchObserver) {
        self.observer = Some(observer);
    }

    /// Remove the per-batch hook installed by
    /// [`set_batch_observer`](Self::set_batch_observer).
    pub fn clear_batch_observer(&mut self) {
        self.observer = None;
    }

    fn notify(&self, kind: BatchKind, result: &BatchResult) {
        if let Some(obs) = &self.observer {
            let graph = self.graph.current();
            obs(&BatchEvent {
                kind,
                seq: self.batches_applied,
                result,
                graph: &graph,
            });
        }
    }

    /// The `dynamic-apply` admission check, shared by every batch verb.
    /// Runs *before* any mutation, so a rejected batch leaves the
    /// session at an exact batch boundary.
    fn admit_batch(&self) -> Result<(), BatchApplyError> {
        if crate::util::failpoints::hit(crate::util::failpoints::Site::DynamicApply) {
            return Err(BatchApplyError {
                batches_applied: self.batches_applied,
                message: "failpoint dynamic-apply: injected batch error".to_string(),
            });
        }
        Ok(())
    }

    /// Apply one batch of edge insertions; returns the canonical change
    /// set (Λⁿᵉʷ, Λᵈᵉˡ). The registry advances to C(G + H).
    pub fn apply_batch(&mut self, edges: &[Edge]) -> BatchResult {
        self.apply_batch_timed(edges).0
    }

    /// As [`apply_batch`](Self::apply_batch), also returning per-task
    /// phase timings for the scheduler simulation (Figures 8/9).
    ///
    /// Panics if the batch is rejected at admission (only possible with
    /// the `dynamic-apply` failpoint armed); fault-aware callers use
    /// [`try_apply_batch_timed`](Self::try_apply_batch_timed).
    pub fn apply_batch_timed(&mut self, edges: &[Edge]) -> (BatchResult, BatchTimings) {
        match self.try_apply_batch_timed(edges) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`apply_batch`](Self::apply_batch): a rejected batch
    /// mutates nothing and reports the exact boundary in
    /// [`BatchApplyError::batches_applied`].
    pub fn try_apply_batch(&mut self, edges: &[Edge]) -> Result<BatchResult, BatchApplyError> {
        Ok(self.try_apply_batch_timed(edges)?.0)
    }

    /// Fallible [`apply_batch_timed`](Self::apply_batch_timed).
    pub fn try_apply_batch_timed(
        &mut self,
        edges: &[Edge],
    ) -> Result<(BatchResult, BatchTimings), BatchApplyError> {
        self.admit_batch()?;
        Ok(self.apply_batch_inner(edges))
    }

    fn apply_batch_inner(&mut self, edges: &[Edge]) -> (BatchResult, BatchTimings) {
        let batch_span = crate::telemetry::SpanTimer::start();
        let (result, timings) = match self.algo {
            DynAlgo::Imce => imce_batch_with_cutoff(
                &mut self.graph,
                &self.registry,
                edges,
                self.bitset_cutoff,
            ),
            DynAlgo::ParImce => {
                if self.pool.is_none() {
                    self.pool = Some(ThreadPool::new(self.threads));
                }
                let pool = self.pool.as_ref().expect("pool just ensured");
                par_imce_batch_with_cutoff(
                    pool,
                    &mut self.graph,
                    &self.registry,
                    edges,
                    self.bitset_cutoff,
                )
            }
        };
        self.batches_applied += 1;
        self.total_new += result.new_cliques.len() as u64;
        self.total_subsumed += result.subsumed.len() as u64;
        // per-batch phase telemetry: both engines (and every replay-driven
        // batch) flow through this one choke point
        let t = crate::telemetry::global();
        t.dynamic_batches.inc();
        t.dynamic_new_cliques.add(result.new_cliques.len() as u64);
        t.dynamic_subsumed_cliques.add(result.subsumed.len() as u64);
        t.dynamic_batch_ns.record(batch_span.elapsed_ns());
        for &ns in &timings.new_task_ns {
            t.dynamic_new_task_ns.record(ns);
        }
        for &ns in &timings.sub_task_ns {
            t.dynamic_sub_task_ns.record(ns);
        }
        self.notify(BatchKind::Insert, &result);
        (result, timings)
    }

    /// Apply one batch of edge removals (§5.3 decremental reduction).
    ///
    /// Panics if the batch is rejected at admission (only possible with
    /// the `dynamic-apply` failpoint armed); fault-aware callers use
    /// [`try_remove_batch`](Self::try_remove_batch).
    pub fn remove_batch(&mut self, edges: &[Edge]) -> BatchResult {
        match self.try_remove_batch(edges) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`remove_batch`](Self::remove_batch): a rejected batch
    /// mutates nothing and reports the exact boundary in
    /// [`BatchApplyError::batches_applied`].
    pub fn try_remove_batch(&mut self, edges: &[Edge]) -> Result<BatchResult, BatchApplyError> {
        self.admit_batch()?;
        let batch_span = crate::telemetry::SpanTimer::start();
        let result = imce_remove_batch(&mut self.graph, &self.registry, edges);
        self.batches_applied += 1;
        self.total_new += result.new_cliques.len() as u64;
        self.total_subsumed += result.subsumed.len() as u64;
        let t = crate::telemetry::global();
        t.dynamic_batches.inc();
        t.dynamic_new_cliques.add(result.new_cliques.len() as u64);
        t.dynamic_subsumed_cliques.add(result.subsumed.len() as u64);
        t.dynamic_batch_ns.record(batch_span.elapsed_ns());
        self.notify(BatchKind::Remove, &result);
        Ok(result)
    }

    /// Stream `stream` through the session in batches, recording
    /// per-batch change sizes and task timings (the Table 6 / Figure 8/9
    /// methodology). `max_batches` truncates long streams.
    pub fn replay(
        &mut self,
        stream: &EdgeStream,
        batch_size: usize,
        max_batches: Option<usize>,
    ) -> Vec<BatchRecord> {
        let mut records = Vec::new();
        for (i, batch) in stream.batches(batch_size).enumerate() {
            if let Some(cap) = max_batches {
                if i >= cap {
                    break;
                }
            }
            let t0 = Instant::now();
            let (result, timings) = self.apply_batch_timed(batch);
            records.push(BatchRecord {
                batch_index: i,
                new_cliques: result.new_cliques.len(),
                subsumed: result.subsumed.len(),
                ns: t0.elapsed().as_nanos() as u64,
                new_task_ns: timings.new_task_ns,
                sub_task_ns: timings.sub_task_ns,
            });
        }
        records
    }

    /// |C(G)| right now.
    pub fn clique_count(&self) -> usize {
        self.registry.len()
    }

    /// The epoch-snapshotted graph the session mutates.
    pub fn graph(&self) -> &SnapshotGraph {
        &self.graph
    }

    /// The exact maximal clique set C(G), kept current by every batch.
    pub fn registry(&self) -> &CliqueRegistry {
        &self.registry
    }

    /// The most recently published graph snapshot — the exact graph the
    /// last batch's change set was enumerated against.  An `Arc` clone;
    /// no adjacency is rebuilt or copied.
    pub fn current_graph(&self) -> Arc<GraphSnapshot> {
        self.graph.current()
    }

    /// Materialize the current graph as a standalone [`CsrGraph`] —
    /// export/verification only (tests cross-check against from-scratch
    /// enumeration).  Live readers want [`current_graph`]
    /// (no O(n + m) rebuild).
    ///
    /// [`current_graph`]: Self::current_graph
    pub fn csr(&self) -> CsrGraph {
        self.graph.to_csr()
    }

    /// How many batches (insert and remove) have been applied.
    pub fn batches_applied(&self) -> usize {
        self.batches_applied
    }

    /// Cumulative (Λⁿᵉʷ, Λᵈᵉˡ) totals across all batches.
    pub fn change_totals(&self) -> (u64, u64) {
        (self.total_new, self.total_subsumed)
    }

    /// Tear down into the raw graph + registry.
    pub fn into_parts(self) -> (SnapshotGraph, CliqueRegistry) {
        (self.graph, self.registry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;

    #[test]
    fn from_empty_seeds_singletons() {
        let s = DynamicSession::from_empty(5, DynAlgo::Imce);
        assert_eq!(s.clique_count(), 5);
        assert_eq!(s.batches_applied(), 0);
    }

    #[test]
    fn apply_batch_tracks_from_scratch_state() {
        let target = generators::gnp(14, 0.5, 8);
        let mut s = DynamicSession::from_empty(14, DynAlgo::Imce);
        for chunk in target.edges().chunks(9) {
            s.apply_batch(chunk);
        }
        let want = oracle::maximal_cliques(&s.csr());
        assert_eq!(s.clique_count(), want.len());
        let (new, sub) = s.change_totals();
        assert!(new > 0);
        let _ = sub;
    }

    #[test]
    fn parallel_session_matches_sequential_per_batch() {
        let target = generators::gnp(12, 0.5, 3);
        let mut seq = DynamicSession::from_empty(12, DynAlgo::Imce);
        let mut par = DynamicSession::from_empty(12, DynAlgo::ParImce).with_threads(3);
        for chunk in target.edges().chunks(5) {
            assert_eq!(seq.apply_batch(chunk), par.apply_batch(chunk));
        }
        assert_eq!(seq.clique_count(), par.clique_count());
    }

    #[test]
    fn bitset_cutoff_values_agree_across_batches() {
        let target = generators::gnp(13, 0.5, 21);
        let mut slice = DynamicSession::from_empty(13, DynAlgo::Imce).with_bitset_cutoff(0);
        let mut bit = DynamicSession::from_empty(13, DynAlgo::Imce).with_bitset_cutoff(4);
        let mut par_bit = DynamicSession::from_empty(13, DynAlgo::ParImce)
            .with_threads(3)
            .with_bitset_cutoff(usize::MAX);
        for chunk in target.edges().chunks(6) {
            let want = slice.apply_batch(chunk);
            assert_eq!(bit.apply_batch(chunk), want);
            assert_eq!(par_bit.apply_batch(chunk), want);
        }
        assert_eq!(slice.clique_count(), bit.clique_count());
        assert_eq!(slice.clique_count(), par_bit.clique_count());
    }

    #[test]
    fn remove_batch_keeps_registry_exact() {
        let g = generators::complete(6);
        let mut s = DynamicSession::from_graph(&g, DynAlgo::Imce);
        assert_eq!(s.clique_count(), 1);
        let r = s.remove_batch(&[(0, 1)]);
        assert_eq!(r.subsumed.len(), 1);
        assert_eq!(r.new_cliques.len(), 2);
        assert_eq!(
            s.clique_count(),
            oracle::maximal_cliques(&s.csr()).len()
        );
    }

    #[test]
    fn parallel_bootstrap_matches_sequential_bootstrap() {
        let g = generators::planted_cliques(36, 0.08, 3, 4, 6, 4);
        let seq = DynamicSession::from_graph_threads(&g, DynAlgo::Imce, 1);
        let par = DynamicSession::from_graph_threads(&g, DynAlgo::ParImce, 3);
        assert_eq!(seq.clique_count(), par.clique_count());
        let want = oracle::maximal_cliques(&g);
        assert_eq!(par.clique_count(), want.len());
        for c in &want {
            assert!(par.registry().contains(c));
        }
    }

    #[test]
    fn observer_sees_every_batch_in_order() {
        use crate::util::sync::Mutex;
        let target = generators::gnp(12, 0.5, 17);
        let mut s = DynamicSession::from_empty(12, DynAlgo::Imce);
        let log: Arc<Mutex<Vec<(BatchKind, usize, usize, usize)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&log);
        s.set_batch_observer(Arc::new(move |ev: &BatchEvent<'_>| {
            // the event's snapshot is the post-batch graph epoch, aligned
            // with the session sequence (constructed at epoch 0)
            assert_eq!(ev.graph_epoch(), ev.seq as u64);
            crate::util::sync::plock(&sink).push((
                ev.kind,
                ev.seq,
                ev.result.new_cliques.len(),
                ev.result.subsumed.len(),
            ));
        }));
        let edges = target.edges();
        for chunk in edges.chunks(7) {
            s.apply_batch(chunk);
        }
        s.remove_batch(&edges[..3.min(edges.len())]);
        let log = crate::util::sync::plock(&log);
        assert_eq!(log.len(), s.batches_applied());
        for (i, &(kind, seq, _, _)) in log.iter().enumerate() {
            assert_eq!(seq, i + 1, "dense 1-based sequence");
            let want = if i + 1 == log.len() {
                BatchKind::Remove
            } else {
                BatchKind::Insert
            };
            assert_eq!(kind, want);
        }
        // replay-driven batches notify too
        let mut s2 = DynamicSession::from_empty(12, DynAlgo::Imce);
        let count = Arc::new(crate::util::sync::atomic::AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        s2.set_batch_observer(Arc::new(move |_: &BatchEvent<'_>| {
            c2.fetch_add(1, crate::util::sync::atomic::Ordering::SeqCst);
        }));
        let stream = EdgeStream::permuted(&target, 3);
        let records = s2.replay(&stream, 5, None);
        assert_eq!(
            count.load(crate::util::sync::atomic::Ordering::SeqCst),
            records.len()
        );
    }

    #[test]
    fn batches_feed_dynamic_telemetry() {
        use crate::telemetry::{names, snapshot};
        let before = snapshot();
        let target = generators::gnp(10, 0.5, 9);
        let mut s = DynamicSession::from_empty(10, DynAlgo::Imce);
        let mut applied = 0u64;
        for chunk in target.edges().chunks(6) {
            s.apply_batch(chunk);
            applied += 1;
        }
        let d = snapshot().delta(&before);
        if cfg!(feature = "telemetry-off") {
            assert_eq!(d.counter(names::DYNAMIC_BATCHES), Some(0));
        } else {
            // other tests may run batches concurrently: at least ours
            assert!(d.counter(names::DYNAMIC_BATCHES).unwrap() >= applied);
            assert!(d.counter(names::DYNAMIC_NEW_CLIQUES).unwrap() > 0);
            let h = d.histogram(names::DYNAMIC_BATCH_NS).unwrap();
            assert!(h.count() >= applied);
        }
    }

    #[test]
    fn replay_records_every_batch() {
        let g = generators::gnp(16, 0.35, 6);
        let stream = EdgeStream::permuted(&g, 11);
        let mut s = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
        let records = s.replay(&stream, 7, Some(3));
        assert_eq!(records.len(), 3);
        assert_eq!(s.batches_applied(), 3);
        let all = s.replay(&stream, stream.edges.len().max(1), None);
        let _ = all;
        assert_eq!(s.graph().m(), g.m());
    }
}
