//! [`DynamicSession`]: incremental maximal clique maintenance behind one
//! `apply_batch` verb.
//!
//! Wraps the mutable [`DynGraph`], the concurrent [`CliqueRegistry`] and
//! the IMCE / ParIMCE batch engines (paper §5) so callers choose an
//! algorithm once and stream edge batches — the Figure 4 pipeline —
//! without hand-wiring pools or registries.  The decremental reduction
//! (§5.3) rides along as [`DynamicSession::remove_batch`].

use std::time::Instant;

use crate::coordinator::pool::ThreadPool;
use crate::dynamic::imce::{imce_batch, BatchTimings};
use crate::dynamic::par_imce::par_imce_batch;
use crate::dynamic::registry::CliqueRegistry;
use crate::dynamic::stream::{imce_remove_batch, BatchRecord, EdgeStream};
use crate::dynamic::BatchResult;
use crate::graph::adj::DynGraph;
use crate::graph::csr::CsrGraph;
use crate::graph::{Edge, Vertex};

/// Which incremental engine a [`DynamicSession`] applies batches with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DynAlgo {
    /// Sequential IMCE (VLDB 2019 baseline).
    Imce,
    /// ParIMCE (paper Algorithms 5–7) on the work-stealing pool.
    ParImce,
}

impl DynAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            DynAlgo::Imce => "IMCE",
            DynAlgo::ParImce => "ParIMCE",
        }
    }
}

/// A dynamic-graph session: the graph, its maximal clique set C(G), and
/// the chosen batch engine. Every mutation keeps the registry exact.
pub struct DynamicSession {
    graph: DynGraph,
    registry: CliqueRegistry,
    algo: DynAlgo,
    threads: usize,
    pool: Option<ThreadPool>,
    batches_applied: usize,
    total_new: u64,
    total_subsumed: u64,
}

impl DynamicSession {
    /// Start from the edgeless graph on `n` vertices (the §6 replay
    /// methodology); C(G) = the n singleton cliques.
    pub fn from_empty(n: usize, algo: DynAlgo) -> DynamicSession {
        let registry = CliqueRegistry::new();
        for v in 0..n as Vertex {
            registry.insert(&[v]);
        }
        DynamicSession {
            graph: DynGraph::new(n),
            registry,
            algo,
            threads: 4,
            pool: None,
            batches_applied: 0,
            total_new: 0,
            total_subsumed: 0,
        }
    }

    /// Start from an existing static graph; C(G) is bootstrapped with
    /// sequential TTT.
    pub fn from_graph(g: &CsrGraph, algo: DynAlgo) -> DynamicSession {
        DynamicSession {
            graph: DynGraph::from_csr(g),
            registry: CliqueRegistry::from_graph(g),
            algo,
            threads: 4,
            pool: None,
            batches_applied: 0,
            total_new: 0,
            total_subsumed: 0,
        }
    }

    /// Worker threads for the ParIMCE pool (default 4; the pool spawns
    /// lazily on the first parallel batch).
    pub fn with_threads(mut self, threads: usize) -> DynamicSession {
        self.threads = threads.max(1);
        self
    }

    /// Share an existing pool instead of spawning one.
    pub fn with_pool(mut self, pool: ThreadPool) -> DynamicSession {
        self.pool = Some(pool);
        self
    }

    pub fn algo(&self) -> DynAlgo {
        self.algo
    }

    /// Apply one batch of edge insertions; returns the canonical change
    /// set (Λⁿᵉʷ, Λᵈᵉˡ). The registry advances to C(G + H).
    pub fn apply_batch(&mut self, edges: &[Edge]) -> BatchResult {
        self.apply_batch_timed(edges).0
    }

    /// As [`apply_batch`](Self::apply_batch), also returning per-task
    /// phase timings for the scheduler simulation (Figures 8/9).
    pub fn apply_batch_timed(&mut self, edges: &[Edge]) -> (BatchResult, BatchTimings) {
        let (result, timings) = match self.algo {
            DynAlgo::Imce => imce_batch(&mut self.graph, &self.registry, edges),
            DynAlgo::ParImce => {
                if self.pool.is_none() {
                    self.pool = Some(ThreadPool::new(self.threads));
                }
                let pool = self.pool.as_ref().expect("pool just ensured");
                par_imce_batch(pool, &mut self.graph, &self.registry, edges)
            }
        };
        self.batches_applied += 1;
        self.total_new += result.new_cliques.len() as u64;
        self.total_subsumed += result.subsumed.len() as u64;
        (result, timings)
    }

    /// Apply one batch of edge removals (§5.3 decremental reduction).
    pub fn remove_batch(&mut self, edges: &[Edge]) -> BatchResult {
        let result = imce_remove_batch(&mut self.graph, &self.registry, edges);
        self.batches_applied += 1;
        self.total_new += result.new_cliques.len() as u64;
        self.total_subsumed += result.subsumed.len() as u64;
        result
    }

    /// Stream `stream` through the session in batches, recording
    /// per-batch change sizes and task timings (the Table 6 / Figure 8/9
    /// methodology). `max_batches` truncates long streams.
    pub fn replay(
        &mut self,
        stream: &EdgeStream,
        batch_size: usize,
        max_batches: Option<usize>,
    ) -> Vec<BatchRecord> {
        let mut records = Vec::new();
        for (i, batch) in stream.batches(batch_size).enumerate() {
            if let Some(cap) = max_batches {
                if i >= cap {
                    break;
                }
            }
            let t0 = Instant::now();
            let (result, timings) = self.apply_batch_timed(batch);
            records.push(BatchRecord {
                batch_index: i,
                new_cliques: result.new_cliques.len(),
                subsumed: result.subsumed.len(),
                ns: t0.elapsed().as_nanos() as u64,
                new_task_ns: timings.new_task_ns,
                sub_task_ns: timings.sub_task_ns,
            });
        }
        records
    }

    /// |C(G)| right now.
    pub fn clique_count(&self) -> usize {
        self.registry.len()
    }

    pub fn graph(&self) -> &DynGraph {
        &self.graph
    }

    pub fn registry(&self) -> &CliqueRegistry {
        &self.registry
    }

    /// Immutable CSR snapshot of the current graph.
    pub fn csr(&self) -> CsrGraph {
        self.graph.to_csr()
    }

    pub fn batches_applied(&self) -> usize {
        self.batches_applied
    }

    /// Cumulative (Λⁿᵉʷ, Λᵈᵉˡ) totals across all batches.
    pub fn change_totals(&self) -> (u64, u64) {
        (self.total_new, self.total_subsumed)
    }

    /// Tear down into the raw graph + registry.
    pub fn into_parts(self) -> (DynGraph, CliqueRegistry) {
        (self.graph, self.registry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mce::oracle;

    #[test]
    fn from_empty_seeds_singletons() {
        let s = DynamicSession::from_empty(5, DynAlgo::Imce);
        assert_eq!(s.clique_count(), 5);
        assert_eq!(s.batches_applied(), 0);
    }

    #[test]
    fn apply_batch_tracks_from_scratch_state() {
        let target = generators::gnp(14, 0.5, 8);
        let mut s = DynamicSession::from_empty(14, DynAlgo::Imce);
        for chunk in target.edges().chunks(9) {
            s.apply_batch(chunk);
        }
        let want = oracle::maximal_cliques(&s.csr());
        assert_eq!(s.clique_count(), want.len());
        let (new, sub) = s.change_totals();
        assert!(new > 0);
        let _ = sub;
    }

    #[test]
    fn parallel_session_matches_sequential_per_batch() {
        let target = generators::gnp(12, 0.5, 3);
        let mut seq = DynamicSession::from_empty(12, DynAlgo::Imce);
        let mut par = DynamicSession::from_empty(12, DynAlgo::ParImce).with_threads(3);
        for chunk in target.edges().chunks(5) {
            assert_eq!(seq.apply_batch(chunk), par.apply_batch(chunk));
        }
        assert_eq!(seq.clique_count(), par.clique_count());
    }

    #[test]
    fn remove_batch_keeps_registry_exact() {
        let g = generators::complete(6);
        let mut s = DynamicSession::from_graph(&g, DynAlgo::Imce);
        assert_eq!(s.clique_count(), 1);
        let r = s.remove_batch(&[(0, 1)]);
        assert_eq!(r.subsumed.len(), 1);
        assert_eq!(r.new_cliques.len(), 2);
        assert_eq!(
            s.clique_count(),
            oracle::maximal_cliques(&s.csr()).len()
        );
    }

    #[test]
    fn replay_records_every_batch() {
        let g = generators::gnp(16, 0.35, 6);
        let stream = EdgeStream::permuted(&g, 11);
        let mut s = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
        let records = s.replay(&stream, 7, Some(3));
        assert_eq!(records.len(), 3);
        assert_eq!(s.batches_applied(), 3);
        let all = s.replay(&stream, stream.edges.len().max(1), None);
        let _ = all;
        assert_eq!(s.graph().m(), g.m());
    }
}
