//! Shared execution context: the one place a session keeps its thread
//! pool, resource limits, cached rankings, run history, and cancellation
//! flag — the state every call site used to wire up by hand.
//!
//! Caches are keyed by `(graph identity, strategy)` so a context handed a
//! different graph (e.g. through a raw [`super::Enumerator`] call) never
//! serves a stale ranking.  The pool is created lazily: purely sequential
//! sessions (TTT, the sequential baselines) never spawn worker threads.

use std::collections::HashMap;
use std::time::Duration;

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{plock, Arc, Mutex, OnceLock};

use crate::coordinator::pool::ThreadPool;
use crate::coordinator::stats::Subproblem;
use crate::graph::csr::CsrGraph;
use crate::mce::parmce::subproblems_timed;
use crate::mce::ranking::{RankStrategy, Ranking};
use crate::mce::ParTttConfig;
use crate::util::membudget::MemBudget;

use super::report::RunReport;

type RankKey = (usize, RankStrategy);

fn graph_key(g: &Arc<CsrGraph>) -> usize {
    Arc::as_ptr(g) as usize
}

/// Cache entry pinning the graph it was computed for: holding the
/// `Arc<CsrGraph>` keeps the allocation alive, so the pointer key can
/// never be reused by a different graph (no ABA).
struct Cached<T> {
    graph: Arc<CsrGraph>,
    value: Arc<T>,
}

/// Shared per-session execution state: pool(s), limits, caches, and the
/// cancellation flag.  Built by
/// [`SessionBuilder`](crate::session::SessionBuilder), shared by every
/// run verb on the session.
pub struct ExecContext {
    threads: usize,
    ingest_threads: usize,
    pool: OnceLock<ThreadPool>,
    ingest_pool: OnceLock<ThreadPool>,
    rank_strategy: RankStrategy,
    /// `None` = unlimited (baselines run to completion).
    mem_budget_bytes: Option<usize>,
    deadline: Duration,
    parttt: ParTttConfig,
    cancelled: AtomicBool,
    rankings: Mutex<HashMap<RankKey, Cached<Ranking>>>,
    subproblems: Mutex<HashMap<RankKey, Cached<Vec<Subproblem>>>>,
    history: Mutex<Vec<RunReport>>,
}

impl ExecContext {
    /// Assemble a context; `threads` drives the enumeration pool,
    /// `ingest_threads` the ranking/ingest pre-pass (both clamped to
    /// ≥ 1; when equal, one pool serves both roles).
    pub fn new(
        threads: usize,
        ingest_threads: usize,
        rank_strategy: RankStrategy,
        mem_budget_bytes: Option<usize>,
        deadline: Duration,
        parttt: ParTttConfig,
    ) -> ExecContext {
        ExecContext {
            threads: threads.max(1),
            ingest_threads: ingest_threads.max(1),
            pool: OnceLock::new(),
            ingest_pool: OnceLock::new(),
            rank_strategy,
            mem_budget_bytes,
            deadline,
            parttt,
            cancelled: AtomicBool::new(false),
            rankings: Mutex::new(HashMap::new()),
            subproblems: Mutex::new(HashMap::new()),
            history: Mutex::new(Vec::new()),
        }
    }

    /// The work-stealing pool, spawned on first use.
    pub fn pool(&self) -> &ThreadPool {
        self.pool.get_or_init(|| ThreadPool::new(self.threads))
    }

    /// The pool the ingest/ranking pre-pass runs on, spawned on first
    /// use.  When `ingest_threads == threads` this is the enumeration
    /// pool itself (pools are cheaply clonable handles to one worker
    /// set), so a session never runs two full-size pools.
    pub fn ingest_pool(&self) -> &ThreadPool {
        self.ingest_pool.get_or_init(|| {
            if self.ingest_threads == self.threads {
                self.pool().clone()
            } else {
                ThreadPool::new(self.ingest_threads)
            }
        })
    }

    /// Enumeration pool size.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Ingest/ranking pool size.
    pub fn ingest_threads(&self) -> usize {
        self.ingest_threads
    }

    /// The vertex-ranking strategy runs default to.
    pub fn rank_strategy(&self) -> RankStrategy {
        self.rank_strategy
    }

    /// A fresh budget for one run (budgets are consumed, not shared).
    pub fn mem_budget(&self) -> MemBudget {
        match self.mem_budget_bytes {
            Some(cap) => MemBudget::new(cap),
            None => MemBudget::unlimited(),
        }
    }

    /// Configured memory cap (`None` = unlimited).
    pub fn mem_budget_bytes(&self) -> Option<usize> {
        self.mem_budget_bytes
    }

    /// Wall-clock deadline each run starts with.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }

    /// Tuning knobs for the ParTTT/ParMCE kernels.
    pub fn parttt_config(&self) -> ParTttConfig {
        self.parttt
    }

    /// Cooperative cancellation: checked before a run starts and between
    /// coarse units of session-level work (e.g. GP's per-vertex loop).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    /// Undo [`cancel`](Self::cancel) so the context can run again.
    pub fn clear_cancel(&self) {
        self.cancelled.store(false, Ordering::SeqCst);
    }

    /// Has [`cancel`](Self::cancel) been called (and not cleared)?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// The ranking for `(g, strategy)`, computed once and cached.  With
    /// `ingest_threads > 1` the metric pre-pass fans out over the ingest
    /// pool ([`Ranking::compute_parallel`]), which is exact-equal to the
    /// sequential computation — the cache holds one canonical ranking
    /// either way.
    pub fn ranking(&self, g: &Arc<CsrGraph>, strategy: RankStrategy) -> Arc<Ranking> {
        let key = (graph_key(g), strategy);
        let mut cache = plock(&self.rankings);
        if let Some(c) = cache.get(&key) {
            debug_assert!(Arc::ptr_eq(&c.graph, g));
            return Arc::clone(&c.value);
        }
        let r = if self.ingest_threads > 1 {
            Arc::new(Ranking::compute_parallel(g, strategy, self.ingest_pool()))
        } else {
            Arc::new(Ranking::compute(g, strategy))
        };
        cache.insert(
            key,
            Cached {
                graph: Arc::clone(g),
                value: Arc::clone(&r),
            },
        );
        r
    }

    /// Seed the ranking cache with an externally computed ranking (e.g.
    /// the PJRT/Pallas triangle backend, which is not `Sync` and so lives
    /// outside the context).
    pub fn seed_ranking(&self, g: &Arc<CsrGraph>, ranking: Arc<Ranking>) {
        let key = (graph_key(g), ranking.strategy());
        plock(&self.rankings).insert(
            key,
            Cached {
                graph: Arc::clone(g),
                value: ranking,
            },
        );
    }

    /// Measured per-vertex subproblem costs under `strategy` (Figure 2's
    /// methodology), computed once and cached — the input shared by the
    /// GP simulation, PECO's flat-task model, and the skew experiments.
    pub fn subproblems(&self, g: &Arc<CsrGraph>, strategy: RankStrategy) -> Arc<Vec<Subproblem>> {
        let key = (graph_key(g), strategy);
        if let Some(c) = plock(&self.subproblems).get(&key) {
            debug_assert!(Arc::ptr_eq(&c.graph, g));
            return Arc::clone(&c.value);
        }
        // measure outside the lock: enumeration is expensive
        let ranking = self.ranking(g, strategy);
        let subs = Arc::new(subproblems_timed(g, &ranking));
        self.seed_subproblems(g, strategy, Arc::clone(&subs));
        subs
    }

    /// Seed the subproblem cache with measurements taken elsewhere (the
    /// GP enumerator measures the same decomposition while emitting).
    pub fn seed_subproblems(
        &self,
        g: &Arc<CsrGraph>,
        strategy: RankStrategy,
        subs: Arc<Vec<Subproblem>>,
    ) {
        plock(&self.subproblems).insert(
            (graph_key(g), strategy),
            Cached {
                graph: Arc::clone(g),
                value: subs,
            },
        );
    }

    /// Append to the session's run history.
    pub fn record(&self, report: RunReport) {
        plock(&self.history).push(report);
    }

    /// Every run this context has executed, in order.
    pub fn history(&self) -> Vec<RunReport> {
        plock(&self.history).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn ctx() -> ExecContext {
        ExecContext::new(
            2,
            1,
            RankStrategy::Degree,
            None,
            Duration::from_secs(60),
            ParTttConfig::default(),
        )
    }

    #[test]
    fn ranking_cache_returns_same_arc() {
        let g = Arc::new(generators::gnp(30, 0.3, 1));
        let c = ctx();
        let a = c.ranking(&g, RankStrategy::Degree);
        let b = c.ranking(&g, RankStrategy::Degree);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        let tri = c.ranking(&g, RankStrategy::Triangle);
        assert!(!Arc::ptr_eq(&a, &tri));
    }

    #[test]
    fn distinct_graphs_do_not_share_cache_entries() {
        let g1 = Arc::new(generators::gnp(20, 0.3, 1));
        let g2 = Arc::new(generators::gnp(20, 0.3, 2));
        let c = ctx();
        let r1 = c.ranking(&g1, RankStrategy::Degree);
        let r2 = c.ranking(&g2, RankStrategy::Degree);
        assert!(!Arc::ptr_eq(&r1, &r2));
    }

    #[test]
    fn subproblems_cached_and_cover_all_vertices() {
        let g = Arc::new(generators::gnp(25, 0.3, 3));
        let c = ctx();
        let a = c.subproblems(&g, RankStrategy::Degree);
        assert_eq!(a.len(), 25);
        let b = c.subproblems(&g, RankStrategy::Degree);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn cancellation_flag_round_trips() {
        let c = ctx();
        assert!(!c.is_cancelled());
        c.cancel();
        assert!(c.is_cancelled());
        c.clear_cancel();
        assert!(!c.is_cancelled());
    }

    #[test]
    fn budget_construction_matches_config() {
        let c = ExecContext::new(
            1,
            1,
            RankStrategy::Degree,
            Some(1000),
            Duration::from_secs(1),
            ParTttConfig::default(),
        );
        let b = c.mem_budget();
        assert_eq!(b.cap(), 1000);
        assert_eq!(ctx().mem_budget().cap(), usize::MAX);
    }

    #[test]
    fn parallel_ingest_context_serves_identical_rankings() {
        let g = Arc::new(generators::gnp(80, 0.15, 9));
        let seq = ctx();
        let par = ExecContext::new(
            2,
            4,
            RankStrategy::Degree,
            None,
            Duration::from_secs(60),
            ParTttConfig::default(),
        );
        for s in [RankStrategy::Degree, RankStrategy::Triangle, RankStrategy::Degeneracy] {
            let a = seq.ranking(&g, s);
            let b = par.ranking(&g, s);
            for v in 0..80u32 {
                for w in 0..80u32 {
                    assert_eq!(a.higher(v, w), b.higher(v, w), "{s:?}");
                }
            }
        }
    }

    #[test]
    fn ingest_pool_is_shared_when_sizes_match() {
        let c = ExecContext::new(
            3,
            3,
            RankStrategy::Degree,
            None,
            Duration::from_secs(60),
            ParTttConfig::default(),
        );
        assert_eq!(c.ingest_pool().num_threads(), c.pool().num_threads());
        let d = ExecContext::new(
            2,
            4,
            RankStrategy::Degree,
            None,
            Duration::from_secs(60),
            ParTttConfig::default(),
        );
        assert_eq!(d.ingest_pool().num_threads(), 4);
        assert_eq!(d.pool().num_threads(), 2);
    }
}
