//! The engine's front door: build a session, run any algorithm.
//!
//! The paper's point is that one framework (TTT → ParTTT → ParMCE →
//! ParIMCE) covers static *and* dynamic MCE with shared ranking and
//! load-balancing machinery.  This module is that framework's API seam:
//!
//! * [`SessionBuilder`] → [`MceSession`] — one builder for every static
//!   algorithm and baseline ([`Algo`]), owning a shared [`ExecContext`]
//!   (lazy thread pool, cached rankings and subproblem measurements, run
//!   history, cancellation flag).
//! * [`Enumerator`] — the object-safe trait each algorithm implements;
//!   all runs return a uniform [`RunReport`] whose [`RunOutcome`]
//!   normalizes the baselines' out-of-memory / timeout failure modes.
//! * [`DynamicSession`] — incremental maintenance (IMCE / ParIMCE)
//!   behind one `apply_batch`, plus stream replay and the decremental
//!   reduction.
//!
//! ```
//! use parmce::graph::generators;
//! use parmce::session::{Algo, MceSession};
//!
//! let g = generators::gnp(60, 0.2, 7);
//! let session = MceSession::builder()
//!     .graph(g)
//!     .algo(Algo::ParMce)
//!     .threads(4)
//!     .build()
//!     .unwrap();
//! let run = session.run();
//! assert_eq!(run.report.cliques, session.count(Algo::Ttt).cliques);
//! ```
#![warn(missing_docs)]

pub mod builder;
pub mod context;
pub mod dynamic;
pub mod enumerators;
pub mod report;

pub use builder::{MceSession, SessionBuilder, SessionRun, SinkSpec};
pub use context::ExecContext;
pub use dynamic::{
    BatchApplyError, BatchEvent, BatchKind, BatchObserver, DynAlgo, DynamicSession,
};
pub use enumerators::{Algo, Enumerator};
pub use report::{OutputStats, PartialProgress, RunOutcome, RunReport};

// the streaming sink vocabulary, re-exported so `SinkSpec::Stream` /
// `stream_to` callers need only the session module
pub use crate::mce::sink::{WriterConfig, WriterFormat, WriterStats};
