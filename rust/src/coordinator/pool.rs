//! Work-stealing thread pool.
//!
//! The paper delegates dynamic load balancing to TBB's work-stealing
//! scheduler (§1, §6.2; Blumofe–Leiserson [3,4]).  TBB is unavailable here,
//! so this is a faithful reimplementation of the scheduling discipline the
//! paper relies on: per-worker deques (LIFO for the owner — depth-first
//! execution order, small working sets), FIFO stealing from victims
//! (breadth-first theft of the *largest* pending subproblems, exactly the
//! property that makes recursive MCE splitting balance itself), and a
//! global injector for external submissions.
//!
//! Deques are mutex-guarded rather than lock-free Chase–Lev; on this
//! testbed (1 hardware thread) contention is nil and the scheduling
//! *semantics* — which task runs where and when — are what the experiments
//! measure.  The API mirrors what ParTTT/ParMCE need: fork-only tasks
//! joined by a [`ScopeHandle`] wait-group (tasks never block, so pool
//! threads cannot deadlock).
//!
//! **Panic safety (ISSUE 9).**  Every job runs inside `catch_unwind`: a
//! panicking subproblem can neither kill its worker thread nor strand its
//! scope.  The first panic payload per scope is captured in the wait-group
//! and re-raised on the *caller* thread at scope join ([`ThreadPool::scope`])
//! or returned as a value ([`ThreadPool::scope_catch`], which the session
//! layer maps to `RunOutcome::Panicked`); sibling tasks always drain first,
//! so the `ScopeShare` borrow contract holds even on the unwind path.  All
//! locks go through the poison-immune [`plock`]/[`pwait_timeout`] seam —
//! with unwinds caught at the job boundary, `std`'s lock poisoning would
//! only convert one contained panic into a cascade.  Worker-thread spawn
//! failure (real, or injected at the `pool-spawn` failpoint) degrades to a
//! smaller pool — down to zero workers, where the scope caller's help loop
//! (`try_run_one`) still drains every job sequentially.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};

use crate::telemetry;
use crate::util::failpoints;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{plock, pwait_timeout, Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// First panic payload captured from a fire-and-forget job (scope jobs
/// record into their wait-group instead).  Only diagnostic: the job had no
/// join point, so there is nowhere to re-raise.
fn note_job_panic() {
    telemetry::global().pool_jobs_panicked.inc();
}

/// Run one job inside the unwind boundary shared by workers and helping
/// scope callers.  Returns the payload instead of unwinding so a worker
/// thread survives any job.
fn run_job_caught(job: Job) -> Result<(), Box<dyn Any + Send>> {
    panic::catch_unwind(AssertUnwindSafe(job))
}

/// Telemetry hook for every successful dequeue (own pop, injector pop, or
/// steal) — pairs with the enqueue-side `add(1)` in `spawn_internal` so
/// the depth gauge reads the live backlog.
#[inline]
fn note_dequeue() {
    let t = telemetry::global();
    t.pool_jobs_dequeued.inc();
    t.pool_queue_depth.sub(1);
}

/// Shared pool state.
///
/// Memory-ordering contract (audited by the loom models in
/// `rust/tests/loom_models.rs`; see DESIGN.md "Concurrency contracts"):
///
/// * **Job payloads** are published exclusively through the deque/injector
///   mutexes — no atomic on this struct carries job data.
/// * **`pending`** is a wakeup hint, not a synchronization edge.  Producers
///   `fetch_add(1, Release)` *after* pushing under the queue mutex; a
///   parked worker re-checks it with `Acquire` under `sleep_lock` before
///   sleeping, so a worker that observes the increment finds the job via
///   the mutex.  The decrement on dequeue is `Relaxed`: the dequeuer
///   already synchronized through the queue mutex, and an under-read of
///   `pending` by a sleeper is recovered by the bounded `wait_timeout`
///   below (the timeout is load-bearing: producer does not hold
///   `sleep_lock` while notifying, so a notify can land between a
///   sleeper's check and its wait).
/// * **`shutdown`** is `SeqCst` on both sides: it races with `pending`
///   traffic during drop-while-jobs-pending (regression model
///   `pool_shutdown_with_pending_jobs`) and the strongest ordering keeps
///   the check-then-park protocol obviously monotone.
/// * **`steals`/`spawned`** are observability counters, `Relaxed` by
///   design (allowlisted in `cargo xtask lint-invariants`).
/// * **Telemetry mirrors** — the [`crate::telemetry`] registry's
///   `pool_jobs_spawned` / `pool_jobs_dequeued` / `pool_wakeups` counters
///   and the `pool_queue_depth` gauge shadow `spawned`/`pending` with the
///   same `Relaxed` argument (observability, never synchronization; the
///   queue mutex publishes job payloads).  The depth gauge pairs one
///   `add(1)` per enqueue with one `sub(1)` per dequeue, so a sweep reads
///   the instantaneous backlog across every live pool; per-worker busy
///   time is accumulated around job execution in `worker_loop`, where the
///   thread-local worker slot routes the add to that worker's shard.
struct PoolState {
    /// per-worker deques: owner pushes/pops the back, thieves pop the front
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// submissions from non-worker threads
    injector: Mutex<VecDeque<Job>>,
    /// sleep/wake coordination
    sleep_lock: Mutex<()>,
    sleep_cv: Condvar,
    shutdown: AtomicBool,
    /// monotone count of pending jobs (approximate, for wakeup hygiene)
    pending: AtomicUsize,
    /// steal counter (scheduler observability, printed by experiments)
    steals: AtomicU64,
    spawned: AtomicU64,
}

thread_local! {
    /// (pool address, worker index) when running on a pool thread
    static WORKER: std::cell::Cell<Option<(usize, usize)>> =
        const { std::cell::Cell::new(None) };
}

/// Worker index of the current thread, if it is a pool worker — the
/// shard-binding hook for the sharded sinks in [`crate::mce::sink`].
///
/// Pool-agnostic by design: a sink sized for one pool can be fed from
/// another pool's workers (or from no pool at all); callers must treat
/// the returned index as a *routing hint* and clamp out-of-range values
/// to a shared fallback shard.  Returns `None` on non-pool threads,
/// including a caller thread that executes tasks while waiting inside
/// [`ThreadPool::scope`].
pub fn current_worker_slot() -> Option<usize> {
    WORKER.with(|w| w.get().map(|(_, idx)| idx))
}

/// Cloneable handle to a work-stealing pool.
#[derive(Clone)]
pub struct ThreadPool {
    state: Arc<PoolState>,
    threads: Arc<Vec<std::thread::JoinHandle<()>>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Spin up `n` worker threads (n ≥ 1 requested).
    ///
    /// Thread-spawn failure is not fatal: each worker that cannot start
    /// (OS limit, or the `pool-spawn` failpoint) is logged and counted in
    /// `pool_spawn_failures`, and the pool runs with the workers it got —
    /// in the limit with zero, where every scope degrades to sequential
    /// execution on the caller thread via its help loop.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let state = Arc::new(PoolState {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            injector: Mutex::new(VecDeque::new()),
            sleep_lock: Mutex::new(()),
            sleep_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            spawned: AtomicU64::new(0),
        });
        let mut threads = Vec::with_capacity(n);
        for idx in 0..n {
            let st = Arc::clone(&state);
            let spawned = if failpoints::hit(failpoints::Site::PoolSpawn) {
                Err(std::io::Error::other(
                    "failpoint pool-spawn: injected spawn failure",
                ))
            } else {
                std::thread::Builder::new()
                    .name(format!("parmce-worker-{idx}"))
                    .spawn(move || worker_loop(st, idx))
            };
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    telemetry::global().pool_spawn_failures.inc();
                    eprintln!(
                        "parmce: failed to spawn worker {idx} ({e}); \
                         continuing with {} of {n} workers",
                        threads.len()
                    );
                }
            }
        }
        ThreadPool {
            state,
            threads: Arc::new(threads),
            n_threads: n,
        }
    }

    /// Worker threads actually running (≤ [`num_threads`](Self::num_threads)
    /// when some spawns failed).
    pub fn live_workers(&self) -> usize {
        self.threads.len()
    }

    pub fn num_threads(&self) -> usize {
        self.n_threads
    }

    /// Total tasks spawned and total successful steals since creation.
    pub fn scheduler_counters(&self) -> (u64, u64) {
        (
            self.state.spawned.load(Ordering::Relaxed),
            self.state.steals.load(Ordering::Relaxed),
        )
    }

    /// Submit a job. From a worker thread it lands on that worker's deque
    /// (LIFO, depth-first); otherwise on the injector.
    ///
    /// Fire-and-forget: a panic in `job` is contained at the executing
    /// worker (counted in `pool_jobs_panicked`) but not reported anywhere —
    /// use [`scope`](Self::scope)/[`scope_catch`](Self::scope_catch) when
    /// the caller needs to observe failure.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.spawn_internal(Box::new(move || {
            let _ = failpoints::hit(failpoints::Site::PoolDequeue);
            job();
        }));
    }

    /// Worker index if the current thread belongs to *this* pool (the
    /// strict form of [`current_worker_slot`], which ignores identity).
    pub fn current_worker_id(&self) -> Option<usize> {
        self.current_worker()
    }

    /// Worker index if the current thread belongs to this pool.
    fn current_worker(&self) -> Option<usize> {
        WORKER.with(|w| match w.get() {
            Some((pool_addr, idx)) if pool_addr == Arc::as_ptr(&self.state) as usize => Some(idx),
            _ => None,
        })
    }

    /// Run `f` with a scope handle; returns when every task spawned through
    /// the handle (transitively) has completed.
    ///
    /// If any task (or `f` itself) panicked, the first captured payload is
    /// re-raised here on the caller thread — *after* the join, so sibling
    /// tasks have drained and every `ScopedPtr` borrow is dead.
    pub fn scope(&self, f: impl FnOnce(&ScopeHandle)) {
        if let Err(payload) = self.scope_catch(f) {
            panic::resume_unwind(payload);
        }
    }

    /// [`scope`](Self::scope) that returns the first panic payload as a
    /// value instead of unwinding — the session layer's entry point for
    /// converting worker panics into `RunOutcome::Panicked` (ISSUE 9).
    ///
    /// The join is unconditional: even when `f` panics before returning,
    /// every already-spawned task completes before this returns (the
    /// `ScopeShare` lifetime contract does not bend on the unwind path).
    pub fn scope_catch(
        &self,
        f: impl FnOnce(&ScopeHandle),
    ) -> Result<(), Box<dyn Any + Send>> {
        let handle = ScopeHandle {
            pool: self.clone(),
            wg: Arc::new(WaitGroup::new()),
        };
        let caller = panic::catch_unwind(AssertUnwindSafe(|| f(&handle)));
        handle.wg.wait(|| self.try_run_one());
        match caller {
            Err(payload) => Err(payload),
            Ok(()) => match handle.wg.take_panic() {
                Some(payload) => Err(payload),
                None => Ok(()),
            },
        }
    }

    /// Try to execute one pending job on the current thread (used by the
    /// scope waiter so a blocked caller contributes instead of idling).
    fn try_run_one(&self) -> bool {
        if let Some(job) = self.find_job(None) {
            // Panics are already contained per-job (scope jobs record into
            // their wait-group); a stray payload from a fire-and-forget
            // job must not unwind into the waiting caller.
            if run_job_caught(job).is_err() {
                note_job_panic();
            }
            true
        } else {
            false
        }
    }

    fn find_job(&self, own: Option<usize>) -> Option<Job> {
        let st = &self.state;
        // 1. own deque, LIFO
        if let Some(idx) = own {
            if let Some(j) = plock(&st.queues[idx]).pop_back() {
                st.pending.fetch_sub(1, Ordering::Relaxed);
                note_dequeue();
                return Some(j);
            }
        }
        // 2. injector, FIFO
        if let Some(j) = plock(&st.injector).pop_front() {
            st.pending.fetch_sub(1, Ordering::Relaxed);
            note_dequeue();
            return Some(j);
        }
        // 3. steal: FIFO from victims, round-robin
        let n = st.queues.len();
        let start = own.unwrap_or(0);
        for off in 1..=n {
            let victim = (start + off) % n;
            if Some(victim) == own {
                continue;
            }
            if let Some(j) = plock(&st.queues[victim]).pop_front() {
                st.pending.fetch_sub(1, Ordering::Relaxed);
                st.steals.fetch_add(1, Ordering::Relaxed);
                note_dequeue();
                return Some(j);
            }
        }
        None
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Shut down when the final handle drops. The final drop can happen
        // ON a pool worker (tasks hold ScopeHandle → ThreadPool clones and
        // may outlive the caller's handle by a beat); a worker must not
        // join itself (EDEADLK), so in that case the threads are left to
        // exit on the shutdown flag, detached.
        if Arc::strong_count(&self.threads) == 1 {
            self.state.shutdown.store(true, Ordering::SeqCst);
            self.state.sleep_cv.notify_all();
            if self.current_worker().is_none() {
                if let Some(threads) = Arc::get_mut(&mut self.threads) {
                    for t in threads.drain(..) {
                        let _ = t.join();
                    }
                }
            }
        }
    }
}

fn worker_loop(state: Arc<PoolState>, idx: usize) {
    WORKER.with(|w| w.set(Some((Arc::as_ptr(&state) as usize, idx))));
    loop {
        // fast path: find work
        let job = find_job_worker(&state, idx);
        match job {
            Some(j) => {
                // busy-time span: this thread IS worker `idx`, so the
                // counter add routes to that worker's shard
                let span = telemetry::SpanTimer::start();
                // unwind boundary: the worker thread outlives any
                // panicking job (scope jobs also record the payload into
                // their wait-group inside `j` itself)
                if run_job_caught(j).is_err() {
                    note_job_panic();
                }
                telemetry::global().pool_worker_busy_ns.add(span.elapsed_ns());
            }
            None => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // sleep until notified (timeout guards lost wakeups)
                let guard = plock(&state.sleep_lock);
                if state.pending.load(Ordering::Acquire) == 0
                    && !state.shutdown.load(Ordering::SeqCst)
                {
                    let _ = pwait_timeout(
                        &state.sleep_cv,
                        guard,
                        std::time::Duration::from_millis(1),
                    );
                    // parked worker resumed (notify or timeout)
                    telemetry::global().pool_wakeups.inc();
                }
            }
        }
    }
}

fn find_job_worker(state: &Arc<PoolState>, idx: usize) -> Option<Job> {
    // own deque LIFO
    if let Some(j) = plock(&state.queues[idx]).pop_back() {
        state.pending.fetch_sub(1, Ordering::Relaxed);
        note_dequeue();
        return Some(j);
    }
    // injector
    if let Some(j) = plock(&state.injector).pop_front() {
        state.pending.fetch_sub(1, Ordering::Relaxed);
        note_dequeue();
        return Some(j);
    }
    // steal round-robin
    let n = state.queues.len();
    for off in 1..n {
        let victim = (idx + off) % n;
        if let Some(j) = plock(&state.queues[victim]).pop_front() {
            state.pending.fetch_sub(1, Ordering::Relaxed);
            state.steals.fetch_add(1, Ordering::Relaxed);
            note_dequeue();
            return Some(j);
        }
    }
    None
}

/// Wait-group: counts outstanding tasks in a scope, and holds the first
/// panic payload any of them produced (re-raised or returned at join).
struct WaitGroup {
    count: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
    /// First panic payload from any task in the scope.  Later panics are
    /// dropped (counted in `pool_jobs_panicked`): one fault explains the
    /// run, and payload 1 is causally first by this mutex's order.
    first_panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl WaitGroup {
    fn new() -> Self {
        WaitGroup {
            count: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
            first_panic: Mutex::new(None),
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = plock(&self.first_panic);
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        plock(&self.first_panic).take()
    }

    fn add(&self) {
        // Relaxed: `add` runs on the spawning thread *before* the job is
        // published under the queue mutex, so any thread that can run the
        // job (and hence call `done`) already observes the increment via
        // that mutex acquisition — no extra edge needed here.
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn done(&self) {
        // Release, paired with the Acquire load in `wait`: when the waiter
        // reads 0 it must observe every task's side effects.  Each `done`
        // is an RMW, so intermediate decrements extend the release
        // sequence and the final Acquire load synchronizes with *all* of
        // them, not just the last (audited by `pool_scope_runs_all_tasks`).
        if self.count.fetch_sub(1, Ordering::Release) == 1 {
            let _g = plock(&self.lock);
            self.cv.notify_all();
        }
    }

    /// Wait for zero; `help` is called to run pool jobs while waiting.
    fn wait(&self, mut help: impl FnMut() -> bool) {
        loop {
            if self.count.load(Ordering::Acquire) == 0 {
                return;
            }
            if help() {
                continue; // made progress, re-check
            }
            let guard = plock(&self.lock);
            if self.count.load(Ordering::Acquire) == 0 {
                return;
            }
            let _ = pwait_timeout(&self.cv, guard, std::time::Duration::from_millis(1));
        }
    }
}

/// Handle for spawning tasks inside a [`ThreadPool::scope`]; cloneable and
/// passable into tasks so they can spawn recursively.
#[derive(Clone)]
pub struct ScopeHandle {
    pool: ThreadPool,
    wg: Arc<WaitGroup>,
}

impl ScopeHandle {
    /// Spawn a task tracked by this scope. The task receives a clone of the
    /// handle so it can fork further subtasks into the same scope.
    ///
    /// A panicking task is caught right here — the payload lands in the
    /// scope's wait-group (first wins) and `done()` still runs, so the
    /// join can never hang on a lost decrement.  The `pool-dequeue`
    /// failpoint fires inside the same boundary, making an injected panic
    /// indistinguishable from a real one.
    pub fn spawn(&self, f: impl FnOnce(&ScopeHandle) + Send + 'static) {
        self.wg.add();
        let child = self.clone();
        self.pool.spawn_internal(Box::new(move || {
            let result = panic::catch_unwind(AssertUnwindSafe(|| {
                let _ = failpoints::hit(failpoints::Site::PoolDequeue);
                f(&child);
            }));
            if let Err(payload) = result {
                note_job_panic();
                child.wg.record_panic(payload);
            }
            child.wg.done();
        }));
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Worker index of the current thread within this scope's pool
    /// (`None` when called from the scope's blocked caller thread).
    pub fn worker_id(&self) -> Option<usize> {
        self.pool.current_worker_id()
    }
}

impl ThreadPool {
    fn spawn_internal(&self, job: Job) {
        let state = &self.state;
        state.spawned.fetch_add(1, Ordering::Relaxed);
        let t = telemetry::global();
        t.pool_jobs_spawned.inc();
        t.pool_queue_depth.add(1);
        match self.current_worker() {
            Some(idx) => plock(&state.queues[idx]).push_back(job),
            None => plock(&state.injector).push_back(job),
        }
        state.pending.fetch_add(1, Ordering::Release);
        state.sleep_cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_spawned_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                s.spawn(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn recursive_spawns_complete() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));

        fn fanout(s: &ScopeHandle, depth: usize, counter: Arc<AtomicUsize>) {
            counter.fetch_add(1, Ordering::Relaxed);
            if depth > 0 {
                for _ in 0..3 {
                    let c = Arc::clone(&counter);
                    s.spawn(move |s2| fanout(s2, depth - 1, c));
                }
            }
        }

        pool.scope(|s| {
            let c = Arc::clone(&counter);
            s.spawn(move |s2| fanout(s2, 4, c));
        });
        // 1 + 3 + 9 + 27 + 81 = 121
        assert_eq!(counter.load(Ordering::Relaxed), 121);
    }

    #[test]
    fn scope_waits_for_all() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicBool::new(false));
        pool.scope(|s| {
            let f = Arc::clone(&flag);
            s.spawn(move |_| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                f.store(true, Ordering::SeqCst);
            });
        });
        assert!(flag.load(Ordering::SeqCst), "scope returned before task finished");
    }

    #[test]
    fn multiple_scopes_sequential() {
        let pool = ThreadPool::new(2);
        for round in 0..5 {
            let counter = Arc::new(AtomicUsize::new(0));
            pool.scope(|s| {
                for _ in 0..10 {
                    let c = Arc::clone(&counter);
                    s.spawn(move |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(counter.load(Ordering::Relaxed), 10, "round {round}");
        }
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                s.spawn(move |s2| {
                    let c2 = Arc::clone(&c);
                    c.fetch_add(1, Ordering::Relaxed);
                    s2.spawn(move |_| {
                        c2.fetch_add(1, Ordering::Relaxed);
                    });
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn worker_slots_are_in_range_and_stable() {
        let pool = ThreadPool::new(3);
        assert_eq!(current_worker_slot(), None, "caller is not a worker");
        assert_eq!(pool.current_worker_id(), None);
        let seen = Arc::new(Mutex::new(Vec::new()));
        pool.scope(|s| {
            for _ in 0..50 {
                let seen = Arc::clone(&seen);
                s.spawn(move |s2| {
                    // on a worker thread both views agree; the scope
                    // caller helping out reports None for both
                    let slot = current_worker_slot();
                    assert_eq!(slot, s2.worker_id());
                    if let Some(idx) = slot {
                        assert!(idx < 3, "slot {idx} out of range");
                        plock(&seen).push(idx);
                    }
                });
            }
        });
        // tasks may also run on the blocked caller; whatever did run on
        // workers must have reported valid indices
        for &idx in plock(&seen).iter() {
            assert!(idx < 3);
        }
    }

    #[test]
    fn counters_track_spawns() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            for _ in 0..20 {
                s.spawn(|_| {});
            }
        });
        let (spawned, _steals) = pool.scheduler_counters();
        assert_eq!(spawned, 20);
    }

    #[test]
    fn zero_job_scope_returns_immediately() {
        // WaitGroup starts at 0; `wait` must return without a single
        // `done` ever firing (no phantom decrement, no 1ms parks stacking).
        let pool = ThreadPool::new(2);
        for _ in 0..100 {
            pool.scope(|_| {});
        }
        let (spawned, _) = pool.scheduler_counters();
        assert_eq!(spawned, 0);
    }

    #[test]
    fn nested_scope_from_worker_completes() {
        // A worker task opens a *new* scope on the same pool: the inner
        // `wait` runs on a pool thread, which must help (try_run_one) and
        // not deadlock even on a 1-thread pool.
        for n in [1, 2, 4] {
            let pool = ThreadPool::new(n);
            let counter = Arc::new(AtomicUsize::new(0));
            pool.scope(|s| {
                for _ in 0..4 {
                    let c = Arc::clone(&counter);
                    let inner_pool = s.pool().clone();
                    s.spawn(move |_| {
                        inner_pool.scope(|inner| {
                            for _ in 0..8 {
                                let c2 = Arc::clone(&c);
                                inner.spawn(move |_| {
                                    c2.fetch_add(1, Ordering::Relaxed);
                                });
                            }
                        });
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(counter.load(Ordering::Relaxed), 4 * (8 + 1), "n={n}");
        }
    }

    #[test]
    fn panicking_task_surfaces_at_join_after_siblings_drain() {
        let pool = ThreadPool::new(4);
        let ran = Arc::new(AtomicUsize::new(0));
        let result = pool.scope_catch(|s| {
            for i in 0..50 {
                let ran = Arc::clone(&ran);
                s.spawn(move |_| {
                    if i == 17 {
                        panic!("task 17 exploded");
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        let payload = result.expect_err("scope must report the panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "task 17 exploded");
        // every sibling drained before the join returned
        assert_eq!(ran.load(Ordering::SeqCst), 49);
    }

    #[test]
    fn scope_reraises_task_panic_on_caller() {
        let pool = ThreadPool::new(2);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|_| panic!("boom"));
            });
        }))
        .expect_err("scope must re-raise");
        assert_eq!(err.downcast_ref::<&str>(), Some(&"boom"));
        // the pool survives: locks unpoisoned, workers alive
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                s.spawn(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn panicking_caller_closure_still_joins_spawned_tasks() {
        // ScopeShare soundness on the unwind path: tasks spawned before
        // the caller closure panics must complete before scope_catch
        // returns the payload.
        let pool = ThreadPool::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let result = pool.scope_catch(|s| {
            for _ in 0..10 {
                let ran = Arc::clone(&ran);
                s.spawn(move |_| {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
            panic!("caller gave up");
        });
        assert!(result.is_err());
        assert_eq!(ran.load(Ordering::SeqCst), 10, "join must precede unwind");
    }

    #[test]
    fn first_panic_wins_across_many() {
        let pool = ThreadPool::new(3);
        let result = pool.scope_catch(|s| {
            for _ in 0..8 {
                s.spawn(|_| panic!("one of many"));
            }
        });
        let payload = result.expect_err("at least one panic must surface");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"one of many"));
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn injected_spawn_failure_degrades_to_smaller_pool() {
        use crate::util::failpoints as fp;
        let _x = fp::exclusive();
        // every spawn fails: zero workers, but scopes still complete on
        // the caller's help loop
        fp::clear();
        fp::configure(
            fp::Site::PoolSpawn,
            fp::SiteConfig {
                action: fp::Action::ReturnError,
                trigger: fp::Trigger::Always,
                seed: 0,
            },
        );
        let pool = ThreadPool::new(4);
        fp::clear();
        assert_eq!(pool.live_workers(), 0);
        assert_eq!(pool.num_threads(), 4);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..30 {
                let c = Arc::clone(&counter);
                s.spawn(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 30);
        drop(pool);

        // exactly the second spawn fails: 3 of 4 workers survive
        fp::configure(
            fp::Site::PoolSpawn,
            fp::SiteConfig {
                action: fp::Action::ReturnError,
                trigger: fp::Trigger::OnHit(2),
                seed: 0,
            },
        );
        let pool = ThreadPool::new(4);
        fp::clear();
        assert_eq!(pool.live_workers(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..30 {
                let c = Arc::clone(&counter);
                s.spawn(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn injected_dequeue_panic_is_contained() {
        use crate::util::failpoints as fp;
        let _x = fp::exclusive();
        fp::clear();
        fp::configure(
            fp::Site::PoolDequeue,
            fp::SiteConfig {
                action: fp::Action::Panic,
                trigger: fp::Trigger::OnHit(5),
                seed: 0,
            },
        );
        let pool = ThreadPool::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let result = pool.scope_catch(|s| {
            for _ in 0..20 {
                let ran = Arc::clone(&ran);
                s.spawn(move |_| {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        fp::clear();
        let payload = result.expect_err("injected panic must surface at join");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert_eq!(msg, "failpoint pool-dequeue: injected panic");
        assert_eq!(ran.load(Ordering::SeqCst), 19, "siblings drain");
    }

    #[test]
    fn cancellation_racing_shutdown_does_not_hang() {
        // Fire-and-forget jobs poll a cancel flag; the pool is dropped
        // while many are still queued.  Workers must drain the backlog on
        // the shutdown path (no job leaked un-run, no join hang), and
        // cancelled jobs must be cheap no-ops.
        for _ in 0..20 {
            let pool = ThreadPool::new(3);
            let cancel = Arc::new(AtomicBool::new(false));
            let ran = Arc::new(AtomicUsize::new(0));
            for _ in 0..200 {
                let cancel = Arc::clone(&cancel);
                let ran = Arc::clone(&ran);
                pool.spawn(move || {
                    if !cancel.load(Ordering::SeqCst) {
                        std::hint::spin_loop();
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
            cancel.store(true, Ordering::SeqCst);
            drop(pool); // joins workers; must not deadlock with the backlog
            assert_eq!(ran.load(Ordering::SeqCst), 200, "shutdown leaked queued jobs");
        }
    }
}
