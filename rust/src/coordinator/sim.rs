//! Trace-replay work-stealing makespan simulator.
//!
//! The paper evaluates speedup on a 32-core Xeon; this testbed has one
//! hardware thread, so Figures 6/7/9 are reproduced by *measuring* the real
//! task decomposition (every recursive MCE call records its exclusive time
//! and parent) and *replaying* the trace through a deterministic greedy
//! scheduler with p virtual workers.  speedup(p) = Σwork / makespan(p) —
//! the quantity Brent's theorem bounds (paper §3, Corollary 1), including
//! the critical-path ceiling that a real scheduler would also hit.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// One task in a recorded execution trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceTask {
    /// parent task index (children become ready when the parent finishes)
    pub parent: Option<u32>,
    /// exclusive duration (excluding children), nanoseconds
    pub excl_ns: u64,
}

/// A recorded task-decomposition trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub tasks: Vec<TraceTask>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { tasks: Vec::new() }
    }

    /// Record a task; returns its id for children to reference.
    pub fn push(&mut self, parent: Option<u32>, excl_ns: u64) -> u32 {
        let id = self.tasks.len() as u32;
        if let Some(p) = parent {
            debug_assert!((p as usize) < self.tasks.len(), "parent must precede child");
        }
        self.tasks.push(TraceTask { parent, excl_ns });
        id
    }

    /// Total work T₁ = Σ exclusive durations.
    pub fn work_ns(&self) -> u64 {
        self.tasks.iter().map(|t| t.excl_ns).sum()
    }

    /// Critical path T∞ (span): longest root-to-leaf chain of exclusive
    /// durations.  Children start only after the whole parent finishes.
    pub fn span_ns(&self) -> u64 {
        let mut finish = vec![0u64; self.tasks.len()];
        let mut max = 0;
        for (i, t) in self.tasks.iter().enumerate() {
            let start = t.parent.map(|p| finish[p as usize]).unwrap_or(0);
            finish[i] = start + t.excl_ns;
            max = max.max(finish[i]);
        }
        max
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Result of simulating a trace on p workers.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    pub p: usize,
    pub makespan_ns: u64,
    pub work_ns: u64,
    pub span_ns: u64,
}

impl SimResult {
    /// Speedup over the 1-worker execution of the same trace.
    pub fn speedup(&self) -> f64 {
        self.work_ns as f64 / self.makespan_ns.max(1) as f64
    }

    /// Fraction of p·makespan actually spent working.
    pub fn utilization(&self) -> f64 {
        self.work_ns as f64 / (self.p as f64 * self.makespan_ns.max(1) as f64)
    }
}

/// Greedy list scheduling of the trace on `p` identical workers.
/// `overhead_ns` models per-task scheduling cost (spawn + steal), charged
/// to every task — set from the measured pool overhead.
pub fn simulate(trace: &Trace, p: usize, overhead_ns: u64) -> SimResult {
    assert!(p >= 1);
    let n = trace.tasks.len();
    let mut children: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut ready: VecDeque<u32> = VecDeque::new();
    for (i, t) in trace.tasks.iter().enumerate() {
        match t.parent {
            Some(par) => children[par as usize].push(i as u32),
            None => ready.push_back(i as u32),
        }
    }

    // event-driven: (finish_time, task) min-heap
    let mut running: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    let mut now = 0u64;
    let mut busy = 0usize;
    let mut makespan = 0u64;
    let mut done = 0usize;

    loop {
        while busy < p {
            let Some(t) = ready.pop_front() else { break };
            let dur = trace.tasks[t as usize].excl_ns + overhead_ns;
            running.push(Reverse((now + dur, t)));
            busy += 1;
        }
        let Some(Reverse((finish, t))) = running.pop() else {
            break;
        };
        now = finish;
        makespan = makespan.max(finish);
        busy -= 1;
        done += 1;
        for &c in &children[t as usize] {
            ready.push_back(c);
        }
        // drain all tasks finishing at the same instant before refilling
        while let Some(&Reverse((f2, _))) = running.peek() {
            if f2 != now {
                break;
            }
            let Reverse((_, t2)) = running.pop().unwrap();
            busy -= 1;
            done += 1;
            for &c in &children[t2 as usize] {
                ready.push_back(c);
            }
        }
    }
    assert_eq!(done, n, "simulator must complete every task");

    SimResult {
        p,
        makespan_ns: makespan,
        work_ns: trace.work_ns() + overhead_ns * n as u64,
        span_ns: trace.span_ns(),
    }
}

/// Speedup curve over the usual thread counts (paper Figures 6/9).
pub fn speedup_curve(trace: &Trace, ps: &[usize], overhead_ns: u64) -> Vec<SimResult> {
    ps.iter().map(|&p| simulate(trace, p, overhead_ns)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// trace: root with k independent equal children
    fn flat_trace(k: usize, dur: u64) -> Trace {
        let mut t = Trace::new();
        let root = t.push(None, 1);
        for _ in 0..k {
            t.push(Some(root), dur);
        }
        t
    }

    #[test]
    fn work_and_span() {
        let t = flat_trace(4, 100);
        assert_eq!(t.work_ns(), 401);
        assert_eq!(t.span_ns(), 101);
    }

    #[test]
    fn single_worker_equals_work() {
        let t = flat_trace(8, 50);
        let r = simulate(&t, 1, 0);
        assert_eq!(r.makespan_ns, t.work_ns());
        assert!((r.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn perfect_parallel_flat_trace() {
        let t = flat_trace(16, 1000);
        let r = simulate(&t, 16, 0);
        // root (1ns) then all 16 children in parallel
        assert_eq!(r.makespan_ns, 1001);
        let s = r.speedup();
        assert!(s > 15.0, "speedup {s}");
    }

    #[test]
    fn speedup_bounded_by_span() {
        // chain of 10 tasks: no parallelism available
        let mut t = Trace::new();
        let mut parent = None;
        for _ in 0..10 {
            parent = Some(t.push(parent, 100));
        }
        for p in [1, 2, 8, 32] {
            let r = simulate(&t, p, 0);
            assert_eq!(r.makespan_ns, 1000, "chain cannot go below span at p={p}");
        }
    }

    #[test]
    fn speedup_monotone_in_p() {
        // imbalanced two-level tree
        let mut t = Trace::new();
        let root = t.push(None, 10);
        for i in 0..32 {
            let c = t.push(Some(root), 100 + i * 37);
            for j in 0..(i % 5) {
                t.push(Some(c), 50 + j * 11);
            }
        }
        let mut last = 0.0;
        for p in [1, 2, 4, 8, 16, 32] {
            let s = simulate(&t, p, 0).speedup();
            assert!(s + 1e-9 >= last, "speedup should not decrease: {s} < {last}");
            last = s;
        }
    }

    #[test]
    fn overhead_reduces_speedup() {
        let t = flat_trace(32, 1000);
        let no = simulate(&t, 8, 0).speedup();
        let hi = simulate(&t, 8, 0);
        let with = simulate(&t, 8, 500);
        // same p: utilization with overhead ≤ without
        assert!(with.makespan_ns > hi.makespan_ns);
        assert!(no > 0.0);
    }

    #[test]
    fn utilization_at_most_one() {
        let t = flat_trace(100, 10);
        for p in [1, 3, 7] {
            let r = simulate(&t, p, 1);
            assert!(r.utilization() <= 1.0 + 1e-9);
        }
    }
}
