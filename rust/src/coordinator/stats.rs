//! Per-subproblem instrumentation: the data behind Figure 2 (subproblem
//! imbalance) and the load-balance diagnostics of §4.2.

use crate::graph::Vertex;

/// Measured cost of one per-vertex subproblem (all maximal cliques whose
/// lowest-ranked member is `vertex`).
#[derive(Clone, Copy, Debug)]
pub struct Subproblem {
    pub vertex: Vertex,
    pub cliques: u64,
    pub ns: u64,
}

/// Skew summary: what fraction of subproblems carries `share` of the total?
#[derive(Clone, Copy, Debug)]
pub struct SkewPoint {
    /// target cumulative share of the metric (e.g. 0.9)
    pub share: f64,
    /// fraction of subproblems (sorted descending by metric) needed
    pub subproblem_fraction: f64,
}

/// Fraction of subproblems (largest first) needed to reach `share` of the
/// total of `metric`. Paper Fig. 2: As-Skitter needs 0.022% of subproblems
/// for 90% of runtime.
pub fn fraction_for_share(mut values: Vec<u64>, share: f64) -> f64 {
    assert!((0.0..=1.0).contains(&share));
    let total: u128 = values.iter().map(|&v| v as u128).sum();
    if total == 0 || values.is_empty() {
        return 0.0;
    }
    values.sort_unstable_by(|a, b| b.cmp(a));
    let target = (total as f64 * share).ceil() as u128;
    let mut acc: u128 = 0;
    for (i, &v) in values.iter().enumerate() {
        acc += v as u128;
        if acc >= target {
            return (i + 1) as f64 / values.len() as f64;
        }
    }
    1.0
}

/// Full cumulative-share curve (Lorenz-style, descending), sampled at the
/// given subproblem fractions — the series plotted in Fig. 2.
pub fn share_curve(mut values: Vec<u64>, fractions: &[f64]) -> Vec<(f64, f64)> {
    values.sort_unstable_by(|a, b| b.cmp(a));
    let total: u128 = values.iter().map(|&v| v as u128).sum();
    let mut prefix: Vec<u128> = Vec::with_capacity(values.len() + 1);
    prefix.push(0);
    for &v in &values {
        prefix.push(prefix.last().unwrap() + v as u128);
    }
    fractions
        .iter()
        .map(|&f| {
            let k = ((values.len() as f64 * f).round() as usize).min(values.len());
            let share = if total == 0 {
                0.0
            } else {
                prefix[k] as f64 / total as f64
            };
            (f, share)
        })
        .collect()
}

/// Summary statistics of the subproblem cost distribution.
#[derive(Clone, Copy, Debug)]
pub struct ImbalanceSummary {
    pub count: usize,
    pub total_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
    /// coefficient of variation (σ/µ) — the paper's imbalance driver
    pub cv: f64,
    /// fraction of subproblems for 90% of runtime (Fig. 2c/2d)
    pub frac_for_90_time: f64,
    /// fraction of subproblems for 90% of cliques (Fig. 2a/2b)
    pub frac_for_90_cliques: f64,
}

pub fn summarize(subs: &[Subproblem]) -> ImbalanceSummary {
    let count = subs.len();
    let total_ns: u64 = subs.iter().map(|s| s.ns).sum();
    let max_ns = subs.iter().map(|s| s.ns).max().unwrap_or(0);
    let mean = if count == 0 {
        0.0
    } else {
        total_ns as f64 / count as f64
    };
    let var = if count == 0 {
        0.0
    } else {
        subs.iter()
            .map(|s| {
                let d = s.ns as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / count as f64
    };
    ImbalanceSummary {
        count,
        total_ns,
        max_ns,
        mean_ns: mean,
        cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        frac_for_90_time: fraction_for_share(subs.iter().map(|s| s.ns).collect(), 0.9),
        frac_for_90_cliques: fraction_for_share(subs.iter().map(|s| s.cliques).collect(), 0.9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_values_need_ninety_percent() {
        let f = fraction_for_share(vec![10; 100], 0.9);
        assert!((f - 0.9).abs() < 0.011, "got {f}");
    }

    #[test]
    fn extreme_skew_needs_few() {
        // one subproblem carries ~all the work
        let mut v = vec![1u64; 999];
        v.push(1_000_000);
        let f = fraction_for_share(v, 0.9);
        assert!(f <= 0.002, "got {f}");
    }

    #[test]
    fn zero_and_empty() {
        assert_eq!(fraction_for_share(vec![], 0.9), 0.0);
        assert_eq!(fraction_for_share(vec![0, 0], 0.9), 0.0);
    }

    #[test]
    fn share_curve_monotone() {
        let v: Vec<u64> = (1..=100).collect();
        let curve = share_curve(v, &[0.0, 0.1, 0.5, 1.0]);
        assert_eq!(curve[0].1, 0.0);
        assert!((curve[3].1 - 1.0).abs() < 1e-12);
        assert!(curve[1].1 > 0.1, "descending sort front-loads the share");
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn summary_on_skewed_input() {
        let subs: Vec<Subproblem> = (0..100)
            .map(|i| Subproblem {
                vertex: i,
                cliques: if i == 0 { 10_000 } else { 1 },
                ns: if i == 0 { 1_000_000 } else { 10 },
            })
            .collect();
        let s = summarize(&subs);
        assert_eq!(s.count, 100);
        assert!(s.cv > 5.0, "cv {}", s.cv);
        assert!(s.frac_for_90_time <= 0.01);
        assert!(s.frac_for_90_cliques <= 0.01);
    }
}
