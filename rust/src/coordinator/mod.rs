//! L3 coordination: the work-stealing thread pool, per-subproblem
//! instrumentation, and the trace-replay makespan simulator used to
//! reproduce the paper's multi-core scaling figures on this 1-core testbed.

pub mod pool;
pub mod sim;
pub mod stats;
