//! Runtime bridge to the AOT-compiled L1/L2 artifacts: a PJRT CPU client
//! wrapper ([`engine::Engine`]) and the triangle-ranking offload that
//! feeds ParMCETri ([`tri_rank::PjrtTriangleBackend`]).  Python never runs
//! here — artifacts are HLO text produced once by `make artifacts`.

pub mod engine;
pub mod tri_rank;
