//! PJRT execution engine: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and runs them on the PJRT CPU client.
//!
//! This is the only place Python output crosses into the Rust runtime —
//! as HLO *text* (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos;
//! see /opt/xla-example/README.md).  Executables are compiled once at
//! load and cached; execution is Mutex-serialized (the CPU PJRT client is
//! the resource, not a bottleneck for the build-time-sized kernels here).
//!
//! The real engine needs the vendored `xla` crate and is gated behind the
//! `pjrt` cargo feature; the default build ships a stub whose `load`
//! fails with a clear error, so every PJRT call site (CLI subcommands,
//! examples, Table 5's offload column) compiles and degrades gracefully.

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use crate::util::sync::{plock, Mutex};

    use anyhow::{anyhow, bail, Context, Result};

    use crate::util::json::{self, Json};

    pub struct Engine {
        client: xla::PjRtClient,
        execs: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
        dir: PathBuf,
        manifest: Json,
    }

    impl Engine {
        /// Open the artifacts directory (must contain `manifest.json`).
        /// Executables compile lazily on first use.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("read {manifest_path:?} — run `make artifacts` first"))?;
            let manifest = json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Engine {
                client,
                execs: Mutex::new(HashMap::new()),
                dir,
                manifest,
            })
        }

        /// Default artifacts location relative to the repo root, overridable
        /// via PARMCE_ARTIFACTS.
        pub fn load_default() -> Result<Engine> {
            Engine::load(super::default_artifacts_dir())
        }

        /// Shape-contract constant exported by the L2 model (e.g. "TILE_B").
        pub fn constant(&self, name: &str) -> Result<usize> {
            self.manifest
                .get("constants")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("constant {name} missing from manifest"))
        }

        fn ensure_compiled(&self, name: &str) -> Result<()> {
            let mut execs = plock(&self.execs);
            if execs.contains_key(name) {
                return Ok(());
            }
            let file = self
                .manifest
                .get(name)
                .and_then(|e| e.get("file"))
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("load HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            execs.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` with f32 inputs of the given shapes;
        /// returns the flattened f32 output (the exported fns return 1-tuples).
        pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            self.ensure_compiled(name)?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let numel: i64 = shape.iter().product();
                if numel as usize != data.len() {
                    bail!(
                        "artifact {name}: input length {} != shape {:?}",
                        data.len(),
                        shape
                    );
                }
                literals.push(xla::Literal::vec1(data).reshape(shape)?);
            }
            let execs = plock(&self.execs);
            let exe = execs.get(name).expect("compiled above");
            let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            drop(execs);
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Artifact names available in the manifest (excluding "constants").
        pub fn artifact_names(&self) -> Vec<String> {
            match &self.manifest {
                Json::Obj(m) => m.keys().filter(|k| *k != "constants").cloned().collect(),
                _ => Vec::new(),
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::Engine;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    /// Stub engine compiled when the `pjrt` feature is off.  It cannot be
    /// constructed — `load` fails — so all other methods are unreachable
    /// in practice but keep the call sites compiling.
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn load(_dir: impl AsRef<Path>) -> Result<Engine> {
            bail!(
                "parmce was built without the `pjrt` feature; the PJRT/Pallas \
                 offload is unavailable (rebuild with --features pjrt and the \
                 vendored xla crate — see DESIGN.md)"
            )
        }

        pub fn load_default() -> Result<Engine> {
            Engine::load(super::default_artifacts_dir())
        }

        pub fn constant(&self, _name: &str) -> Result<usize> {
            bail!("pjrt feature disabled")
        }

        pub fn execute_f32(&self, _name: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            bail!("pjrt feature disabled")
        }

        pub fn artifact_names(&self) -> Vec<String> {
            Vec::new()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

/// Default artifacts location relative to the repo root, overridable via
/// PARMCE_ARTIFACTS (tests run from target dirs, so search upward).
fn default_artifacts_dir() -> String {
    std::env::var("PARMCE_ARTIFACTS").unwrap_or_else(|_| {
        let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
        loop {
            let cand = d.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand.to_string_lossy().into_owned();
            }
            if !d.pop() {
                return "artifacts".into();
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` AND the `pjrt` feature; they
    // are the rust-side half of the L1/L2 correctness story (the python
    // half is python/tests/). Skipped gracefully when unavailable.
    fn engine() -> Option<Engine> {
        Engine::load_default().ok()
    }

    #[test]
    fn manifest_constants_present() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built or pjrt feature off");
            return;
        };
        assert_eq!(e.constant("TILE_B").unwrap(), 256);
        assert_eq!(e.constant("FULL_N").unwrap(), 512);
        assert!(e.constant("NOPE").is_err());
        let names = e.artifact_names();
        assert!(names.iter().any(|n| n == "rank_tri_tile"), "{names:?}");
    }

    #[test]
    fn tile_kernel_runs_and_matches_semantics() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built or pjrt feature off");
            return;
        };
        let b = e.constant("TILE_B").unwrap();
        // A_ik = A_kj = A_ij = all-ones ⇒ out[r] = Σ_j Σ_k 1 = b per row
        let ones = vec![1.0f32; b * b];
        let shape = [b as i64, b as i64];
        let out = e
            .execute_f32(
                "rank_tri_tile",
                &[(&ones, &shape), (&ones, &shape), (&ones, &shape)],
            )
            .unwrap();
        assert_eq!(out.len(), b);
        for &x in &out {
            assert!((x - (b * b) as f32).abs() < 1e-3, "got {x}");
        }
    }

    #[test]
    fn bad_input_shape_rejected() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built or pjrt feature off");
            return;
        };
        let out = e.execute_f32("rank_tri_tile", &[(&[1.0f32], &[1])]);
        assert!(out.is_err());
    }

    #[test]
    fn artifacts_dir_search_terminates() {
        let dir = default_artifacts_dir();
        assert!(!dir.is_empty());
    }
}
