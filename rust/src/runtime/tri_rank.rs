//! Triangle-count vertex ranking on the AOT Pallas kernel (L1/L2 offload).
//!
//! Two schedules, chosen by graph size:
//! * **full** — n ≤ FULL_N: zero-pad the dense adjacency and make one
//!   `rank_tri_full` call (the whole blocked masked-matmul grid runs
//!   inside the kernel).
//! * **tiled** — larger graphs: partition the adjacency into B×B tiles
//!   (B = TILE_B), materialize only the *non-empty* tiles, and drive the
//!   single-tile-triple artifact over every (i,j,k) whose three tiles are
//!   all non-empty.  Skipping empty triples is exact (zero tiles
//!   contribute zero — asserted by the python test suite) and is the
//!   sparsity lever that makes a dense-kernel schedule viable on sparse
//!   graphs, exactly how the L3 coordinator is supposed to feed an MXU.
//!
//! Counts are exact in f32 for < 2²⁴ triangles per vertex — far beyond
//! the synthetic analogs; debug builds assert agreement with the CPU path.

use std::collections::HashMap;

use anyhow::Result;

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::mce::ranking::TriangleBackend;
use crate::runtime::engine::Engine;

pub struct PjrtTriangleBackend<'e> {
    engine: &'e Engine,
}

impl<'e> PjrtTriangleBackend<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        PjrtTriangleBackend { engine }
    }

    fn full_path(&self, g: &CsrGraph, full_n: usize) -> Result<Vec<u64>> {
        let n = g.n();
        let mut dense = vec![0.0f32; full_n * full_n];
        for u in g.vertices() {
            for &v in g.neighbors(u) {
                dense[u as usize * full_n + v as usize] = 1.0;
            }
        }
        let shape = [full_n as i64, full_n as i64];
        let out = self
            .engine
            .execute_f32("rank_tri_full", &[(&dense, &shape)])?;
        Ok(out[..n].iter().map(|&x| x.round() as u64).collect())
    }

    fn tiled_path(&self, g: &CsrGraph, b: usize) -> Result<Vec<u64>> {
        let n = g.n();
        let nb = n.div_ceil(b);
        // materialize non-empty B×B tiles (both orientations of each edge)
        let mut tiles: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
        for u in g.vertices() {
            for &v in g.neighbors(u) {
                let (bi, bj) = (u as usize / b, v as usize / b);
                let tile = tiles
                    .entry((bi, bj))
                    .or_insert_with(|| vec![0.0f32; b * b]);
                tile[(u as usize % b) * b + (v as usize % b)] = 1.0;
            }
        }
        let shape = [b as i64, b as i64];
        let mut counts2 = vec![0.0f64; n]; // accumulates 2×tri(v)
        // row blocks i: for each (i, j, k) with all three tiles present
        for bi in 0..nb {
            for bj in 0..nb {
                let Some(a_ij) = tiles.get(&(bi, bj)) else {
                    continue;
                };
                for bk in 0..nb {
                    let (Some(a_ik), Some(a_kj)) = (tiles.get(&(bi, bk)), tiles.get(&(bk, bj)))
                    else {
                        continue;
                    };
                    let partial = self.engine.execute_f32(
                        "rank_tri_tile",
                        &[(a_ik, &shape), (a_kj, &shape), (a_ij, &shape)],
                    )?;
                    for (r, &x) in partial.iter().enumerate() {
                        let v = bi * b + r;
                        if v < n {
                            counts2[v] += x as f64;
                        }
                    }
                }
            }
        }
        Ok(counts2.iter().map(|&x| (x / 2.0).round() as u64).collect())
    }
}

impl TriangleBackend for PjrtTriangleBackend<'_> {
    fn per_vertex(&self, g: &CsrGraph) -> Result<Vec<u64>> {
        let full_n = self.engine.constant("FULL_N")?;
        let b = self.engine.constant("TILE_B")?;
        let counts = if g.n() <= full_n {
            self.full_path(g, full_n)?
        } else {
            self.tiled_path(g, b)?
        };
        debug_assert_eq!(
            counts,
            crate::graph::triangles::per_vertex(g),
            "PJRT kernel disagrees with CPU forward algorithm"
        );
        Ok(counts)
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}

/// Force the tiled schedule regardless of size (ablation / tests).
pub struct PjrtTiledBackend<'e>(pub PjrtTriangleBackend<'e>);

impl TriangleBackend for PjrtTiledBackend<'_> {
    fn per_vertex(&self, g: &CsrGraph) -> Result<Vec<u64>> {
        let b = self.0.engine.constant("TILE_B")?;
        self.0.tiled_path(g, b)
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas-tiled"
    }
}

/// Count the non-empty tile triples the tiled schedule would execute —
/// the cost model used by the Table 5 discussion (and a cheap way to
/// decide full vs tiled at runtime).
pub fn tile_triples(g: &CsrGraph, b: usize) -> (usize, usize) {
    let nb = g.n().div_ceil(b);
    let mut present = std::collections::HashSet::new();
    for u in g.vertices() {
        for &v in g.neighbors(u) {
            present.insert((u as usize / b, v as usize / b));
        }
    }
    let mut nonempty = 0usize;
    for bi in 0..nb {
        for bj in 0..nb {
            if !present.contains(&(bi, bj)) {
                continue;
            }
            for bk in 0..nb {
                if present.contains(&(bi, bk)) && present.contains(&(bk, bj)) {
                    nonempty += 1;
                }
            }
        }
    }
    (nonempty, nb * nb * nb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::triangles;
    use crate::mce::ranking::TriangleBackend as _;

    fn engine() -> Option<Engine> {
        Engine::load_default().ok()
    }

    #[test]
    fn full_path_matches_cpu() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let backend = PjrtTriangleBackend::new(&e);
        for (n, p, seed) in [(40usize, 0.2, 1u64), (200, 0.05, 2), (512, 0.01, 3)] {
            let g = generators::gnp(n, p, seed);
            let got = backend.per_vertex(&g).unwrap();
            assert_eq!(got, triangles::per_vertex(&g), "n={n}");
        }
    }

    #[test]
    fn tiled_path_matches_cpu_across_boundary() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // force tiling even under FULL_N so tests stay fast, including an
        // n that is NOT a multiple of TILE_B (exercises edge padding)
        let backend = PjrtTiledBackend(PjrtTriangleBackend::new(&e));
        for (n, p, seed) in [(300usize, 0.05, 4u64), (520, 0.01, 5)] {
            let g = generators::gnp(n, p, seed);
            let got = backend.per_vertex(&g).unwrap();
            assert_eq!(got, triangles::per_vertex(&g), "n={n}");
        }
    }

    #[test]
    fn tile_triples_sparsity_skipping() {
        // two far-apart cliques: only diagonal-ish tiles are non-empty
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
                edges.push((u + 600, v + 600));
            }
        }
        let g = crate::graph::csr::CsrGraph::from_edges(700, &edges);
        let (nonempty, total) = tile_triples(&g, 256);
        assert!(nonempty < total, "{nonempty} < {total}");
        assert!(nonempty >= 2);
    }
}
