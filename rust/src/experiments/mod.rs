//! Experiment harness: one entry point per table/figure of the paper's §6
//! (see DESIGN.md per-experiment index).  Each experiment returns rendered
//! markdown (also written to `results/`) with the same rows/series the
//! paper reports; EXPERIMENTS.md records paper-vs-measured.
//!
//! Multi-core scaling columns are produced by the trace-replay scheduler
//! simulator (`coordinator::sim`) — this testbed exposes one hardware
//! thread (DESIGN.md "Substitutions" item 1).

pub mod ablation;
pub mod compare;
pub mod dynamic;
pub mod fixtures;
pub mod statics;

use anyhow::{bail, Result};

use crate::graph::datasets::Scale;

/// Per-task scheduling overhead charged in simulations (spawn + steal on
/// the pool, measured in `benches/scaling.rs`; a conservative round value).
pub const SIM_OVERHEAD_NS: u64 = 500;

/// Thread counts used across scaling figures (paper: up to 32 cores).
pub const THREADS: [usize; 6] = [1, 2, 4, 8, 16, 32];

pub fn run(id: &str, scale: Scale, out_dir: &str) -> Result<String> {
    let md = match id {
        "table3" => statics::table3(scale),
        "table4" => statics::table4(scale),
        "table5" => statics::table5(scale),
        "fig2" => statics::fig2(scale),
        "fig5" => statics::fig5(scale),
        "fig6" => statics::fig6(scale),
        "fig7" => statics::fig7(scale),
        "table6" => dynamic::table6(scale),
        "fig8" => dynamic::fig8(scale),
        "fig9" => dynamic::fig9(scale),
        "table7" => compare::table7(scale),
        "table8" => compare::table8(scale),
        "table9" => compare::table9(scale),
        "table10" => compare::table10(scale),
        "ablation" => ablation::all(scale),
        _ => bail!(
            "unknown experiment {id}; known: table3-10, fig2, fig5-9, ablation, all"
        ),
    }?;
    let path = format!("{out_dir}/{id}.md");
    if let Some(parent) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&path, &md)?;
    eprintln!("wrote {path}");
    Ok(md)
}

pub const ALL_IDS: [&str; 15] = [
    "table3", "fig2", "fig5", "table4", "table5", "fig6", "fig7", "table6", "fig8", "fig9",
    "table7", "table8", "table9", "table10", "ablation",
];

pub fn run_all(scale: Scale, out_dir: &str) -> Result<String> {
    let mut out = String::new();
    for id in ALL_IDS {
        eprintln!("=== running {id} ===");
        out.push_str(&run(id, scale, out_dir)?);
        out.push('\n');
    }
    Ok(out)
}
