//! Ablations on the design choices DESIGN.md calls out (beyond the
//! paper's own tables):
//!
//! 1. pivoting on/off — the TTT ingredient (recursive-call counts);
//! 2. ParTTT sequential cutoff — task granularity vs schedulable
//!    parallelism;
//! 3. rank direction — the paper's "higher rank ⇒ smaller share" versus
//!    the inverted assignment (shows the load-balancing choice matters);
//! 4. ParIMCE batch size — the §6.2 choice of 1000 (10 for dense).

use crate::util::sync::Arc;

use anyhow::Result;

use crate::coordinator::sim::simulate;
use crate::coordinator::stats;
use crate::dynamic::stream::EdgeStream;
use crate::graph::csr::CsrGraph;
use crate::graph::datasets::{Dataset, Scale};
use crate::graph::Vertex;
use crate::mce::ranking::{RankStrategy, Ranking};
use crate::mce::sink::CountSink;
use crate::mce::ttt::{ttt_from_metered, TttMetrics};
use crate::session::{Algo, DynAlgo, DynamicSession};
use crate::util::table::{fmt_count, fmt_secs, fmt_speedup, Table};

use super::fixtures::{secs, session};
use super::SIM_OVERHEAD_NS;

pub fn all(scale: Scale) -> Result<String> {
    let mut out = String::new();
    out.push_str(&pivot_ablation(scale)?);
    out.push('\n');
    out.push_str(&cutoff_ablation(scale)?);
    out.push('\n');
    out.push_str(&rank_direction_ablation(scale)?);
    out.push('\n');
    out.push_str(&batch_size_ablation(scale)?);
    Ok(out)
}

/// 1. Pivot vs no pivot: recursive calls and wall time.
pub fn pivot_ablation(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Ablation 1 — pivoting (TTT) vs none (BK): recursive calls and time",
        &["Dataset", "TTT calls", "TTT(s)", "BK-noPivot(s)", "pivot gain"],
    );
    // sparse analogs + the clique-dense worst case: pivoting's win is a
    // *pruning* win, so it only pays where unpruned search explodes
    let mm = crate::graph::generators::moon_moser(6);
    let named: Vec<(String, CsrGraph)> = vec![
        ("as-skitter-like".into(), Dataset::AsSkitterLike.graph(scale)),
        ("ca-cit-hepth-like".into(), Dataset::CaCitHepThLike.graph(scale)),
        ("moon-moser-18".into(), mm),
    ];
    for (name, g) in named {
        let sink = CountSink::new();
        let mut m = TttMetrics::default();
        let mut k = Vec::new();
        let (_, ttt_s) = secs(|| {
            ttt_from_metered(
                &g,
                &mut k,
                (0..g.n() as Vertex).collect(),
                Vec::new(),
                &sink,
                &mut m,
            )
        });
        let s = session(&g, 1);
        let bk = s.count(Algo::BkBasic);
        assert_eq!(sink.count(), bk.cliques);
        t.row(vec![
            name,
            fmt_count(m.calls),
            fmt_secs(ttt_s),
            fmt_secs(bk.secs()),
            fmt_speedup(bk.secs() / ttt_s),
        ]);
    }
    Ok(t.render())
}

/// 2. ParTTT sequential cutoff sweep: tasks spawned vs simulated makespan.
pub fn cutoff_ablation(scale: Scale) -> Result<String> {
    let d = Dataset::WikipediaLike;
    let g = d.graph(scale);
    let s = session(&g, 1);
    // full-resolution trace once; coarser cutoffs = collapsing subtrees.
    // We emulate cutoff by capping trace depth: tasks deeper than the cut
    // are merged into their ancestors (their time becomes exclusive time
    // of the ancestor at the cut).
    let (tr, _) = s.parmce_trace(RankStrategy::Degree);
    let mut depth = vec![0u32; tr.len()];
    for (i, task) in tr.tasks.iter().enumerate() {
        depth[i] = task.parent.map(|p| depth[p as usize] + 1).unwrap_or(0);
    }
    let mut t = Table::new(
        format!("Ablation 2 — task granularity (depth cut), {}", d.name()),
        &["max task depth", "#tasks", "sim@32 (s)", "speedup vs depth0"],
    );
    let full_work = tr.work_ns() as f64 / 1e9;
    for cut in [0u32, 1, 2, 4, 8, u32::MAX] {
        // merge deep tasks upward
        let mut merged = crate::coordinator::sim::Trace::new();
        let mut map: Vec<Option<u32>> = vec![None; tr.len()];
        for (i, task) in tr.tasks.iter().enumerate() {
            if depth[i] <= cut {
                let parent = task.parent.and_then(|p| map[p as usize]);
                map[i] = Some(merged.push(parent, task.excl_ns));
            } else {
                // fold into nearest kept ancestor
                let mut a = task.parent.unwrap() as usize;
                while depth[a] > cut {
                    a = tr.tasks[a].parent.unwrap() as usize;
                }
                let kept = map[a].unwrap();
                merged.tasks[kept as usize].excl_ns += task.excl_ns;
                map[i] = Some(kept);
            }
        }
        let r = simulate(&merged, 32, SIM_OVERHEAD_NS);
        let sim_s = r.makespan_ns as f64 / 1e9;
        t.row(vec![
            if cut == u32::MAX { "∞".into() } else { cut.to_string() },
            fmt_count(merged.len() as u64),
            fmt_secs(sim_s),
            fmt_speedup(full_work / sim_s),
        ]);
    }
    Ok(t.render())
}

/// 3. Rank direction: paper's choice vs inverted (big shares to big
/// vertices) — compare subproblem imbalance.
pub fn rank_direction_ablation(scale: Scale) -> Result<String> {
    let d = Dataset::WikiTalkLike;
    let g = d.graph(scale);
    let s = session(&g, 1);
    let mut t = Table::new(
        format!(
            "Ablation 3 — rank direction, {} (paper: higher degree ⇒ higher rank ⇒ smaller share)",
            d.name()
        ),
        &["assignment", "CV(time)", "max task(ms)", "sim@32 (s)"],
    );
    let rows = [
        ("paper (degree asc share)", s.subproblems(RankStrategy::Degree)),
        ("inverted (id-only)", s.subproblems(RankStrategy::Id)),
        (
            "inverted (neg degree)",
            Arc::new(s.subproblems_with(&inverted_degree_ranking(&g))),
        ),
    ];
    for (name, subs) in rows {
        let summary = stats::summarize(&subs);
        let mut tr = crate::coordinator::sim::Trace::new();
        let root = tr.push(None, 0);
        for sub in subs.iter() {
            tr.push(Some(root), sub.ns);
        }
        let sim = simulate(&tr, 32, SIM_OVERHEAD_NS);
        t.row(vec![
            name.into(),
            format!("{:.2}", summary.cv),
            format!("{:.2}", summary.max_ns as f64 / 1e6),
            fmt_secs(sim.makespan_ns as f64 / 1e9),
        ]);
    }
    Ok(t.render())
}

/// Inverted degree ranking: low degree ⇒ high rank (the anti-paper order).
fn inverted_degree_ranking(g: &CsrGraph) -> Ranking {
    Ranking::from_metric(
        (0..g.n())
            .map(|v| (g.max_degree() - g.degree(v as Vertex)) as u64)
            .collect(),
    )
}

/// 4. ParIMCE batch size sweep on the dense analog.
pub fn batch_size_ablation(scale: Scale) -> Result<String> {
    let d = Dataset::CaCitHepThLike;
    let g = d.graph(scale);
    let stream = EdgeStream::permuted(&g, 7);
    let mut t = Table::new(
        format!("Ablation 4 — ParIMCE batch size, {}", d.name()),
        &["batch size", "#batches", "IMCE(s)", "ParIMCE@32(s)", "speedup"],
    );
    for bs in [10usize, 50, 200] {
        let cap = Some((1500 / bs).clamp(4, 40));
        let mut dyn_session = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
        let records = dyn_session.replay(&stream, bs, cap);
        let seq: f64 = records.iter().map(|r| r.ns as f64 / 1e9).sum();
        let par: f64 = records
            .iter()
            .map(|r| {
                let mk = |ns: &[u64]| {
                    let mut tr = crate::coordinator::sim::Trace::new();
                    let root = tr.push(None, 0);
                    for &x in ns {
                        tr.push(Some(root), x);
                    }
                    simulate(&tr, 32, SIM_OVERHEAD_NS).makespan_ns
                };
                (mk(&r.new_task_ns) + mk(&r.sub_task_ns)) as f64 / 1e9
            })
            .sum();
        t.row(vec![
            bs.to_string(),
            records.len().to_string(),
            fmt_secs(seq),
            fmt_secs(par),
            fmt_speedup(seq / par.max(1e-12)),
        ]);
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_render() {
        let md = all(Scale::Tiny).unwrap();
        assert!(md.contains("Ablation 1"));
        assert!(md.contains("Ablation 4"));
    }
}
