//! Dynamic-graph experiments: Table 6 (cumulative IMCE vs ParIMCE),
//! Figure 8 (speedup vs size of change), Figure 9 (speedup vs threads).
//!
//! Methodology (§6.2): start from the empty graph, add edges in batches of
//! 1000 (10 for the dense ca-cit-hepth analog).  Replay runs through a
//! [`DynamicSession`]; ParIMCE's multi-worker time is simulated per phase
//! from measured task durations: the two phases are barrier-separated
//! (Λⁿᵉʷ must be complete before ParIMCESub), so
//! time(p) = makespan_new(p) + makespan_sub(p), summed over batches.

use anyhow::Result;

use crate::coordinator::sim::{simulate, Trace};
use crate::dynamic::stream::{BatchRecord, EdgeStream};
use crate::graph::datasets::{Dataset, Scale, DYNAMIC_DATASETS};
use crate::session::{DynAlgo, DynamicSession};
use crate::util::table::{fmt_count, fmt_secs, fmt_speedup, Table};

use super::SIM_OVERHEAD_NS;
use super::THREADS;

fn batch_size_for(d: Dataset, scale: Scale) -> usize {
    // paper: 1000 for all graphs, 10 for Ca-Cit-HepTh; scaled to analog size
    let base = match scale {
        Scale::Tiny => 100,
        Scale::Small => 400,
        Scale::Full => 1000,
    };
    if d == Dataset::CaCitHepThLike {
        base / 10
    } else {
        base
    }
}

fn max_batches_for(scale: Scale) -> Option<usize> {
    match scale {
        Scale::Tiny => Some(30),
        Scale::Small => Some(40),
        Scale::Full => None,
    }
}

/// One-phase flat trace from per-task durations.
fn flat_trace(task_ns: &[u64]) -> Trace {
    let mut t = Trace::new();
    let root = t.push(None, 0);
    for &ns in task_ns {
        t.push(Some(root), ns);
    }
    t
}

/// Simulated ParIMCE seconds for a batch at p workers (phase barrier).
fn batch_sim_secs(rec: &BatchRecord, p: usize) -> f64 {
    let new = simulate(&flat_trace(&rec.new_task_ns), p, SIM_OVERHEAD_NS);
    let sub = simulate(&flat_trace(&rec.sub_task_ns), p, SIM_OVERHEAD_NS);
    (new.makespan_ns + sub.makespan_ns) as f64 / 1e9
}

fn stream_for(d: Dataset, scale: Scale) -> EdgeStream {
    EdgeStream::permuted(&d.graph(scale), 0xD15EA5E)
}

/// Sequential replay of `d`'s stream through a fresh [`DynamicSession`].
fn replay_records(d: Dataset, scale: Scale) -> (EdgeStream, usize, Vec<BatchRecord>) {
    let stream = stream_for(d, scale);
    let bs = batch_size_for(d, scale);
    let mut session = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
    let records = session.replay(&stream, bs, max_batches_for(scale));
    (stream, bs, records)
}

/// Table 6: cumulative runtime of IMCE vs ParIMCE (32 workers).
pub fn table6(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Table 6 — cumulative incremental runtime; paper speedups 3.6x-19.1x on 32 cores",
        &[
            "Dataset", "#edges", "batch", "IMCE(s)", "ParIMCE@32(s)", "speedup",
            "Σchange",
        ],
    );
    for d in DYNAMIC_DATASETS {
        let (stream, bs, records) = replay_records(d, scale);
        let seq_total: f64 = records.iter().map(|r| r.ns as f64 / 1e9).sum();
        let par_total: f64 = records.iter().map(|r| batch_sim_secs(r, 32)).sum();
        let change: u64 = records.iter().map(|r| r.change_size() as u64).sum();
        let edges: usize = records.len() * bs.min(stream.edges.len());
        t.row(vec![
            d.name().into(),
            fmt_count(edges.min(stream.edges.len()) as u64),
            bs.to_string(),
            fmt_secs(seq_total),
            fmt_secs(par_total),
            fmt_speedup(seq_total / par_total.max(1e-12)),
            fmt_count(change),
        ]);
    }
    Ok(t.render())
}

/// Figure 8: per-batch speedup vs size of change (bucketed scatter).
pub fn fig8(scale: Scale) -> Result<String> {
    let mut out = String::new();
    for d in DYNAMIC_DATASETS {
        let (_, _, records) = replay_records(d, scale);
        // bucket batches by change size (powers of 4)
        let mut buckets: std::collections::BTreeMap<u64, (f64, f64, usize)> =
            std::collections::BTreeMap::new();
        for r in &records {
            let c = r.change_size() as u64;
            let bucket = if c == 0 { 0 } else { 1 << (63 - c.leading_zeros()) };
            let seq = r.ns as f64 / 1e9;
            let par = batch_sim_secs(r, 32);
            let e = buckets.entry(bucket).or_insert((0.0, 0.0, 0));
            e.0 += seq;
            e.1 += par;
            e.2 += 1;
        }
        let mut t = Table::new(
            format!(
                "Figure 8 — ParIMCE speedup vs size of change, {} (paper: speedup grows with change size)",
                d.name()
            ),
            &["change-size bucket", "#batches", "speedup@32"],
        );
        for (bucket, (seq, par, n)) in buckets {
            t.row(vec![
                format!("~{bucket}"),
                n.to_string(),
                fmt_speedup(seq / par.max(1e-12)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    Ok(out)
}

/// Figure 9: cumulative ParIMCE speedup vs thread count.
pub fn fig9(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Figure 9 — ParIMCE speedup over IMCE vs threads (cumulative over batches)",
        &["Dataset", "p=1", "p=2", "p=4", "p=8", "p=16", "p=32"],
    );
    for d in DYNAMIC_DATASETS {
        let (_, _, records) = replay_records(d, scale);
        let seq_total: f64 = records.iter().map(|r| r.ns as f64 / 1e9).sum();
        let mut cells = vec![d.name().to_string()];
        for &p in &THREADS {
            let par: f64 = records.iter().map(|r| batch_sim_secs(r, p)).sum();
            cells.push(fmt_speedup(seq_total / par.max(1e-12)));
        }
        t.row(cells);
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_renders() {
        let md = table6(Scale::Tiny).unwrap();
        assert!(md.contains("ca-cit-hepth-like"));
        assert!(md.contains("speedup"));
    }

    #[test]
    fn fig9_monotone_speedups() {
        let md = fig9(Scale::Tiny).unwrap();
        assert!(md.contains("p=32"));
    }
}
