//! Static-graph experiments: Table 3 (datasets), Figure 2 (imbalance),
//! Figure 5 (clique-size histograms), Tables 4/5 (runtimes & ranking
//! breakdown), Figures 6/7 (scaling).  All measurement routes through
//! the [`crate::session`] API.

use anyhow::Result;

use crate::coordinator::stats::{self, fraction_for_share};
use crate::graph::datasets::{Dataset, Scale, STATIC_DATASETS};
use crate::mce::ranking::RankStrategy;
use crate::session::MceSession;
use crate::util::table::{fmt_count, fmt_secs, fmt_speedup, Table};

use super::fixtures::*;
use super::THREADS;

/// Table 3: dataset statistics (ours + the paper's published values).
pub fn table3(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Table 3 — synthetic analogs vs paper datasets",
        &[
            "Dataset", "n", "m", "#MaxCliques", "AvgSize", "MaxSize",
            "paper n", "paper m", "paper #cliques",
        ],
    );
    for d in Dataset::all() {
        let g = d.graph(scale);
        let (hist, _) = run_ttt_hist(&g, 512);
        let p = d.paper_stats();
        t.row(vec![
            d.name().into(),
            fmt_count(g.n() as u64),
            fmt_count(g.m() as u64),
            fmt_count(hist.count()),
            format!("{:.1}", hist.avg_size()),
            hist.max_size().to_string(),
            fmt_count(p.vertices),
            fmt_count(p.edges),
            p.maximal_cliques
                .map(fmt_count)
                .unwrap_or_else(|| "> 400B".into()),
        ]);
    }
    Ok(t.render())
}

/// Figure 2: subproblem imbalance on the skewed analogs.
pub fn fig2(scale: Scale) -> Result<String> {
    let sessions: Vec<(Dataset, MceSession)> = [Dataset::AsSkitterLike, Dataset::WikiTalkLike]
        .into_iter()
        .map(|d| (d, session(&d.graph(scale), 1)))
        .collect();

    let mut t = Table::new(
        "Figure 2 — per-vertex subproblem skew (paper: As-Skitter 0.022% of subproblems = 90% of runtime; Wiki-Talk 0.004%)",
        &[
            "Dataset", "subproblems", "CV(time)",
            "% subs for 90% cliques", "% subs for 90% time",
        ],
    );
    for (d, s) in &sessions {
        let subs = s.subproblems(RankStrategy::Id); // "natural" split
        let sum = stats::summarize(&subs);
        t.row(vec![
            d.name().into(),
            sum.count.to_string(),
            format!("{:.2}", sum.cv),
            format!("{:.3}%", 100.0 * sum.frac_for_90_cliques),
            format!("{:.3}%", 100.0 * sum.frac_for_90_time),
        ]);
    }
    // the full cumulative curves, as plotted in the figure (subproblem
    // measurements are served from the session cache — one pass total)
    let mut out = t.render();
    for (d, s) in &sessions {
        let subs = s.subproblems(RankStrategy::Id);
        let fracs = [0.0001, 0.001, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0];
        let cliques = stats::share_curve(subs.iter().map(|s| s.cliques).collect(), &fracs);
        let time = stats::share_curve(subs.iter().map(|s| s.ns).collect(), &fracs);
        let mut c = Table::new(
            format!("Fig 2 curve — {}", d.name()),
            &["frac subproblems", "share of cliques", "share of time"],
        );
        for (i, &f) in fracs.iter().enumerate() {
            c.row(vec![
                format!("{f}"),
                format!("{:.4}", cliques[i].1),
                format!("{:.4}", time[i].1),
            ]);
        }
        out.push('\n');
        out.push_str(&c.render());
    }
    Ok(out)
}

/// Figure 5: frequency distribution of maximal clique sizes.
pub fn fig5(scale: Scale) -> Result<String> {
    let mut out = String::new();
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let (hist, _) = run_ttt_hist(&g, 512);
        let mut t = Table::new(
            format!(
                "Figure 5 — clique sizes, {} (count {}, max {})",
                d.name(),
                fmt_count(hist.count()),
                hist.max_size()
            ),
            &["size", "count"],
        );
        for (size, count) in hist.nonzero_bins() {
            t.row(vec![size.to_string(), fmt_count(count)]);
        }
        // cliques beyond the binned range: keep the rows summing to the
        // header count instead of silently dropping the tail
        if hist.overflow() > 0 {
            t.row(vec![
                format!(">{}", hist.max_binned_size()),
                fmt_count(hist.overflow()),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    Ok(out)
}

/// Table 4: TTT vs ParTTT vs ParMCE{Degree,Degen,Tri} (32 simulated
/// workers, ranking time excluded — as in the paper).
pub fn table4(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Table 4 — enumeration runtime, 32 workers (simulated from measured traces); paper speedups: ParTTT 5-14x, ParMCE 15-21x",
        &[
            "Dataset", "TTT(s)", "ParTTT(s)", "ParMCEDegree(s)", "ParMCEDegen(s)",
            "ParMCETri(s)", "best speedup",
        ],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        let (count, ttt_s) = run_ttt(&s);
        let (c2, pt) = parttt_sim_secs(&s, 32);
        assert_eq!(count, c2, "{}", d.name());
        let mut cells = vec![d.name().to_string(), fmt_secs(ttt_s), fmt_secs(pt)];
        let mut best = ttt_s / pt;
        for strat in [RankStrategy::Degree, RankStrategy::Degeneracy, RankStrategy::Triangle] {
            let (c3, sim_s) = parmce_sim_secs(&s, strat, 32);
            assert_eq!(count, c3);
            best = best.max(ttt_s / sim_s);
            cells.push(fmt_secs(sim_s));
        }
        cells.push(fmt_speedup(best));
        t.row(cells);
    }
    Ok(t.render())
}

/// Table 5: Total Runtime = Ranking Time + Enumeration Time, per strategy.
/// Adds the PJRT/Pallas triangle backend as an extra ranking column when
/// artifacts are available.
pub fn table5(scale: Scale) -> Result<String> {
    let engine = crate::runtime::engine::Engine::load_default().ok();
    let mut t = Table::new(
        "Table 5 — TR = RT + ET (32 simulated workers). RT(Tri) columns: CPU forward algorithm vs AOT Pallas kernel via PJRT",
        &[
            "Dataset", "Degree ET", "Degen RT", "Degen ET", "Degen TR",
            "Tri RT(cpu)", "Tri RT(pjrt)", "Tri ET", "Tri TR(cpu)",
        ],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        // degree: ranking is free (available as the graph is read)
        let (_, deg_et) = parmce_sim_secs(&s, RankStrategy::Degree, 32);
        // degeneracy: the first cache fill is the ranking cost
        let (_, degen_rt) = secs(|| s.ranking(RankStrategy::Degeneracy));
        let (_, degen_et) = parmce_sim_secs(&s, RankStrategy::Degeneracy, 32);
        // triangle: CPU backend
        let (_, tri_rt_cpu) = secs(|| s.ranking(RankStrategy::Triangle));
        let (_, tri_et) = parmce_sim_secs(&s, RankStrategy::Triangle, 32);
        // triangle: PJRT backend (fair comparison of the offload)
        let tri_rt_pjrt = engine.as_ref().map(|e| {
            let backend = crate::runtime::tri_rank::PjrtTriangleBackend::new(e);
            let (_, rt) = secs(|| {
                crate::mce::ranking::Ranking::compute_with(
                    &g,
                    RankStrategy::Triangle,
                    &backend,
                )
                .unwrap()
            });
            rt
        });
        t.row(vec![
            d.name().into(),
            fmt_secs(deg_et),
            fmt_secs(degen_rt),
            fmt_secs(degen_et),
            fmt_secs(degen_rt + degen_et),
            fmt_secs(tri_rt_cpu),
            tri_rt_pjrt.map(fmt_secs).unwrap_or_else(|| "n/a".into()),
            fmt_secs(tri_et),
            fmt_secs(tri_rt_cpu + tri_et),
        ]);
    }
    Ok(t.render())
}

/// Figure 6: parallel speedup over TTT vs thread count.
pub fn fig6(scale: Scale) -> Result<String> {
    scaling_tables(scale, true)
}

/// Figure 7: runtime vs thread count.
pub fn fig7(scale: Scale) -> Result<String> {
    scaling_tables(scale, false)
}

fn scaling_tables(scale: Scale, as_speedup: bool) -> Result<String> {
    let mut out = String::new();
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        let (_, ttt_s) = run_ttt(&s);
        let title = if as_speedup {
            format!("Figure 6 — speedup over TTT vs threads, {}", d.name())
        } else {
            format!("Figure 7 — runtime (ms) vs threads, {}", d.name())
        };
        let mut t = Table::new(
            title,
            &["algorithm", "p=1", "p=2", "p=4", "p=8", "p=16", "p=32"],
        );
        // one trace per algorithm, evaluated across p
        let (pt_trace, _) = s.parttt_trace();
        let mut rows: Vec<(String, Vec<(usize, f64)>)> = vec![(
            "ParTTT".into(),
            sim_curve(&pt_trace, &THREADS),
        )];
        for strat in [RankStrategy::Degree, RankStrategy::Degeneracy, RankStrategy::Triangle] {
            let (tr, _) = s.parmce_trace(strat);
            rows.push((format!("ParMCE{}", strat.name()), sim_curve(&tr, &THREADS)));
        }
        for (name, curve) in rows {
            let mut cells = vec![name];
            for (_, sim_s) in curve {
                cells.push(if as_speedup {
                    fmt_speedup(ttt_s / sim_s)
                } else {
                    format!("{:.1}", sim_s * 1e3)
                });
            }
            t.row(cells);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    Ok(out)
}

/// Support function shared with table7/9: raw speedup fraction helper.
pub fn skew_pct(values: Vec<u64>, share: f64) -> f64 {
    100.0 * fraction_for_share(values, share)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_renders_all_datasets() {
        let md = table3(Scale::Tiny).unwrap();
        for d in Dataset::all() {
            assert!(md.contains(d.name()), "{md}");
        }
    }

    #[test]
    fn fig2_reports_skew() {
        let md = fig2(Scale::Tiny).unwrap();
        assert!(md.contains("wiki-talk-like"));
        assert!(md.contains("% subs for 90% time"));
    }

    #[test]
    fn table4_and_scaling_render() {
        let md = table4(Scale::Tiny).unwrap();
        assert!(md.contains("ParMCEDegree"));
        let f6 = fig6(Scale::Tiny).unwrap();
        assert!(f6.contains("p=32"));
    }
}
