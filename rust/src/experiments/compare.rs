//! Prior-work comparisons: Table 7 (PECO), Table 8 (shared-memory
//! parallel: Hashing / CliqueEnumerator / Peamc), Table 9 (GP), Table 10
//! (sequential: BKDegeneracy / GreedyBB).  Every baseline runs through
//! the session API; budget/deadline outcomes surface as [`RunOutcome`]s.

use crate::util::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::baselines::gp::{GpConfig, GpOutcome};
use crate::coordinator::sim::{simulate, Trace};
use crate::coordinator::stats::Subproblem;
use crate::graph::datasets::{Scale, STATIC_DATASETS};
use crate::mce::ranking::RankStrategy;
use crate::session::{Algo, MceSession, RunOutcome, RunReport};
use crate::util::table::{fmt_secs, fmt_speedup, Table};

use super::fixtures::*;
use super::SIM_OVERHEAD_NS;

/// PECO's multi-worker time: per-vertex tasks are atomic (no inner
/// parallelism) — simulate the flat task set.
fn peco_sim_secs(subs: &[Subproblem], p: usize) -> f64 {
    let mut tr = Trace::new();
    let root = tr.push(None, 0);
    for s in subs {
        tr.push(Some(root), s.ns);
    }
    simulate(&tr, p, SIM_OVERHEAD_NS).makespan_ns as f64 / 1e9
}

/// Render a budget/deadline-aware run as a paper-style table cell.
fn outcome_cell(r: RunReport) -> String {
    match &r.outcome {
        RunOutcome::Completed => fmt_secs(r.secs()),
        RunOutcome::OutOfMemory => format!("OOM in {}", fmt_secs(r.secs())),
        RunOutcome::TimedOut => format!("timeout ({})", fmt_secs(r.secs())),
        RunOutcome::Cancelled => "cancelled".into(),
        RunOutcome::Panicked { site, .. } => format!("panicked at {site}"),
        RunOutcome::SinkFailed { .. } => "sink failed".into(),
    }
}

/// Table 7: ParMCE vs shared-memory PECO under all three rankings (32
/// workers).  ParMCE's advantage is the *nested* parallelism: both use the
/// same subproblems, but PECO cannot split a monster subproblem.
pub fn table7(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Table 7 — PECO (shared-memory) vs ParMCE, 32 workers",
        &[
            "Dataset", "PECODegree", "ParMCEDegree", "PECODegen", "ParMCEDegen",
            "PECOTri", "ParMCETri",
        ],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        let mut cells = vec![d.name().to_string()];
        for strat in [RankStrategy::Degree, RankStrategy::Degeneracy, RankStrategy::Triangle] {
            let subs = s.subproblems(strat);
            let peco_s = peco_sim_secs(&subs, 32);
            let (_, parmce_s) = parmce_sim_secs(&s, strat, 32);
            cells.push(fmt_secs(peco_s));
            cells.push(fmt_secs(parmce_s));
        }
        t.row(cells);
    }
    Ok(t.render())
}

/// Table 8: ParMCE vs Hashing / CliqueEnumerator / Peamc.  The baselines
/// run under a scaled memory budget / deadline reproducing the paper's
/// "Out of memory" and "Not complete in 5 hours" cells.
pub fn table8(scale: Scale) -> Result<String> {
    // budget scaled so completions are possible only on trivial inputs —
    // mirrors 1TB being insufficient in the paper
    let budget_bytes: usize = match scale {
        Scale::Tiny => 96 << 10,
        Scale::Small => 1 << 20,
        Scale::Full => 16 << 20,
    };
    let deadline = match scale {
        Scale::Tiny => Duration::from_millis(300),
        Scale::Small => Duration::from_secs(2),
        Scale::Full => Duration::from_secs(30),
    };
    let mut t = Table::new(
        format!(
            "Table 8 — vs prior shared-memory parallel MCE (budget {} KiB, deadline {:?}); paper: all three fail on every input",
            budget_bytes >> 10,
            deadline
        ),
        &["Dataset", "ParMCEDegree", "Hashing", "CliqueEnumerator", "Peamc"],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = MceSession::builder()
            .graph(g)
            .threads(4)
            .mem_budget_bytes(budget_bytes)
            .deadline(deadline)
            .build()?;
        let (_, parmce_s) = parmce_sim_secs(&s, RankStrategy::Degree, 32);
        t.row(vec![
            d.name().into(),
            fmt_secs(parmce_s),
            outcome_cell(s.count(Algo::Hashing)),
            outcome_cell(s.count(Algo::CliqueEnumerator)),
            outcome_cell(s.count(Algo::Peamc)),
        ]);
    }
    Ok(t.render())
}

/// Table 9: speedup factor of ParMCEDegree over simulated GP at matched
/// worker counts.
pub fn table9(scale: Scale) -> Result<String> {
    let mut t = Table::new(
        "Table 9 — speedup of ParMCEDegree over GP (simulated MPI) and over PECODegree; >1 means ParMCE faster; × = GP OOM",
        &[
            "Dataset", "GP 2*", "GP 4*", "GP 8*", "GP 16*", "GP 32*",
            "PECO 2t", "PECO 8t", "PECO 32t",
        ],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        let subs = s.subproblems(RankStrategy::Degree);
        let (tr, _) = s.parmce_trace(RankStrategy::Degree);
        let parmce_at = |p: usize| simulate(&tr, p, SIM_OVERHEAD_NS).makespan_ns as f64 / 1e9;
        let mut cells = vec![d.name().to_string()];
        for p in [2usize, 4, 8, 16, 32] {
            let cell = match s.simulate_gp(p, GpConfig::default()) {
                GpOutcome::Finished { makespan_ns, .. } => {
                    fmt_speedup(makespan_ns as f64 / 1e9 / parmce_at(p))
                }
                GpOutcome::OutOfMemory { .. } => "×".into(),
            };
            cells.push(cell);
        }
        for p in [2usize, 8, 32] {
            cells.push(fmt_speedup(peco_sim_secs(&subs, p) / parmce_at(p)));
        }
        t.row(cells);
    }
    Ok(t.render())
}

/// Table 10: ParMCE vs sequential BKDegeneracy and GreedyBB.
pub fn table10(scale: Scale) -> Result<String> {
    let budget: usize = match scale {
        Scale::Tiny => 256 << 10,
        Scale::Small => 4 << 20,
        Scale::Full => 64 << 20,
    };
    let deadline = match scale {
        Scale::Tiny => Duration::from_secs(2),
        Scale::Small => Duration::from_secs(10),
        Scale::Full => Duration::from_secs(120),
    };
    let mut t = Table::new(
        "Table 10 — vs sequential baselines (BKDegeneracy ≈ TTT; GreedyBB much worse, OOM on large inputs)",
        &[
            "Dataset", "TTT(s)", "BKDegeneracy(s)", "GreedyBB", "ParMCEDegree@32",
        ],
    );
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 4);
        let (_, ttt_s) = run_ttt(&s);
        let bkd_s = s.count(Algo::BkDegeneracy).secs();
        let gbb = MceSession::builder()
            .graph_arc(Arc::clone(s.graph()))
            .mem_budget_bytes(budget)
            .deadline(deadline)
            .build()?;
        let gbb_cell = outcome_cell(gbb.count(Algo::GreedyBb));
        let (_, parmce_s) = parmce_sim_secs(&s, RankStrategy::Degree, 32);
        t.row(vec![
            d.name().into(),
            fmt_secs(ttt_s),
            fmt_secs(bkd_s),
            gbb_cell,
            fmt_secs(parmce_s),
        ]);
    }
    Ok(t.render())
}

/// Correctness gate used by integration tests: PECO and ParMCE agree.
pub fn peco_parmce_agree(scale: Scale) -> Result<bool> {
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = session(&g, 2);
        let peco_count = s.count(Algo::Peco).cliques;
        let (seq, _) = run_ttt(&s);
        if peco_count != seq {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_and_9_render() {
        let md = table7(Scale::Tiny).unwrap();
        assert!(md.contains("PECODegree"));
        let md9 = table9(Scale::Tiny).unwrap();
        assert!(md9.contains("GP 32*"));
    }

    #[test]
    fn peco_agrees_with_ttt() {
        assert!(peco_parmce_agree(Scale::Tiny).unwrap());
    }
}
