//! Shared measurement helpers for the experiment harness.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::pool::ThreadPool;
use crate::coordinator::sim::{simulate, Trace};
use crate::graph::csr::CsrGraph;
use crate::mce::ranking::{RankStrategy, Ranking};
use crate::mce::sink::{CliqueSink, CountSink, SizeHistogram};
use crate::mce::{parmce, parttt, ttt, ParMceConfig, ParTttConfig};

use super::SIM_OVERHEAD_NS;

/// Wall-clock seconds of a closure.
pub fn secs<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Sequential TTT: (clique count, seconds).
pub fn run_ttt(g: &CsrGraph) -> (u64, f64) {
    let sink = CountSink::new();
    let (_, s) = secs(|| ttt::ttt(g, &sink));
    (sink.count(), s)
}

/// Full histogram in one sequential pass.
pub fn run_ttt_hist(g: &CsrGraph, max_size: usize) -> (SizeHistogram, f64) {
    let hist = SizeHistogram::new(max_size);
    let (_, s) = secs(|| ttt::ttt(g, &hist));
    (hist, s)
}

/// Measured ParTTT trace → simulated seconds at `p` workers.
pub fn parttt_sim_secs(g: &CsrGraph, p: usize) -> (u64, f64) {
    let sink = CountSink::new();
    let tr = crate::mce::parmce::trace_parttt(g, &sink);
    let r = simulate(&tr, p, SIM_OVERHEAD_NS);
    (sink.count(), r.makespan_ns as f64 / 1e9)
}

/// Measured ParMCE trace (per-vertex subproblems + inner recursion) →
/// simulated seconds at `p` workers.
pub fn parmce_sim_secs(g: &CsrGraph, ranking: &Ranking, p: usize) -> (u64, f64) {
    let sink = CountSink::new();
    let tr = crate::mce::parmce::trace(g, ranking, &sink);
    let r = simulate(&tr, p, SIM_OVERHEAD_NS);
    (sink.count(), r.makespan_ns as f64 / 1e9)
}

/// The same trace evaluated across thread counts (one measurement pass).
pub fn sim_curve(tr: &Trace, threads: &[usize]) -> Vec<(usize, f64)> {
    threads
        .iter()
        .map(|&p| (p, simulate(tr, p, SIM_OVERHEAD_NS).makespan_ns as f64 / 1e9))
        .collect()
}

/// Real pool execution of ParMCE (wall clock, oversubscribed on 1 core —
/// used to verify parallel overhead, not speedup).
pub fn parmce_wall_secs(g: &CsrGraph, strategy: RankStrategy, threads: usize) -> (u64, f64) {
    let pool = ThreadPool::new(threads);
    let ranking = Arc::new(Ranking::compute(g, strategy));
    let g = Arc::new(g.clone());
    let sink = Arc::new(CountSink::new());
    let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
    let (_, s) = secs(|| parmce(&pool, &g, &ranking, &dyn_sink, ParMceConfig::default()));
    (sink.count(), s)
}

/// Real pool execution of ParTTT (wall clock).
pub fn parttt_wall_secs(g: &CsrGraph, threads: usize) -> (u64, f64) {
    let pool = ThreadPool::new(threads);
    let g = Arc::new(g.clone());
    let sink = Arc::new(CountSink::new());
    let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
    let (_, s) = secs(|| parttt(&pool, &g, &dyn_sink, ParTttConfig::default()));
    (sink.count(), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn sim_and_wall_agree_on_counts() {
        let g = generators::planted_cliques(120, 0.03, 4, 5, 8, 3);
        let (seq, _) = run_ttt(&g);
        let ranking = Ranking::compute(&g, RankStrategy::Degree);
        let (sim_count, sim_secs) = parmce_sim_secs(&g, &ranking, 32);
        let (wall_count, _) = parmce_wall_secs(&g, RankStrategy::Degree, 2);
        let (pt_count, _) = parttt_sim_secs(&g, 32);
        assert_eq!(seq, sim_count);
        assert_eq!(seq, wall_count);
        assert_eq!(seq, pt_count);
        assert!(sim_secs > 0.0);
    }
}
