//! Shared measurement helpers for the experiment harness, built on the
//! [`crate::session`] API — no experiment wires pools, rankings or sinks
//! by hand anymore.

use crate::util::sync::Arc;
use std::time::Instant;

use crate::coordinator::sim::{simulate, Trace};
use crate::graph::csr::CsrGraph;
use crate::mce::ranking::RankStrategy;
use crate::mce::sink::{CliqueSink, SizeHistogram};
use crate::session::{Algo, MceSession};

use super::SIM_OVERHEAD_NS;

/// Wall-clock seconds of a closure.
pub fn secs<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// One session per graph: the pool spawns lazily, rankings and
/// subproblem measurements are cached across every helper below.
pub fn session(g: &CsrGraph, threads: usize) -> MceSession {
    MceSession::builder()
        .graph(g.clone())
        .threads(threads)
        .build()
        .expect("session over an explicit graph cannot fail")
}

/// Sequential TTT: (clique count, seconds).
pub fn run_ttt(s: &MceSession) -> (u64, f64) {
    let r = s.count(Algo::Ttt);
    (r.cliques, r.secs())
}

/// Full histogram in one sequential pass.
pub fn run_ttt_hist(g: &CsrGraph, max_size: usize) -> (SizeHistogram, f64) {
    let s = session(g, 1);
    let hist = Arc::new(SizeHistogram::new(max_size));
    let sink: Arc<dyn CliqueSink> = Arc::clone(&hist);
    let r = s.run_with_sink(Algo::Ttt, &sink);
    drop(sink);
    let hist = Arc::into_inner(hist).expect("histogram still shared");
    (hist, r.secs())
}

/// Measured ParTTT trace → simulated seconds at `p` workers.
pub fn parttt_sim_secs(s: &MceSession, p: usize) -> (u64, f64) {
    let (tr, count) = s.parttt_trace();
    let r = simulate(&tr, p, SIM_OVERHEAD_NS);
    (count, r.makespan_ns as f64 / 1e9)
}

/// Measured ParMCE trace (per-vertex subproblems + inner recursion)
/// under `strategy` → simulated seconds at `p` workers.
pub fn parmce_sim_secs(s: &MceSession, strategy: RankStrategy, p: usize) -> (u64, f64) {
    let (tr, count) = s.parmce_trace(strategy);
    let r = simulate(&tr, p, SIM_OVERHEAD_NS);
    (count, r.makespan_ns as f64 / 1e9)
}

/// The same trace evaluated across thread counts (one measurement pass).
pub fn sim_curve(tr: &Trace, threads: &[usize]) -> Vec<(usize, f64)> {
    threads
        .iter()
        .map(|&p| (p, simulate(tr, p, SIM_OVERHEAD_NS).makespan_ns as f64 / 1e9))
        .collect()
}

/// Real pool execution of ParMCE (wall clock, oversubscribed on 1 core —
/// used to verify parallel overhead, not speedup).
pub fn parmce_wall_secs(g: &CsrGraph, strategy: RankStrategy, threads: usize) -> (u64, f64) {
    let s = MceSession::builder()
        .graph(g.clone())
        .rank_strategy(strategy)
        .threads(threads)
        .build()
        .expect("session");
    let r = s.count(Algo::ParMce);
    (r.cliques, r.secs())
}

/// Real pool execution of ParTTT (wall clock).
pub fn parttt_wall_secs(g: &CsrGraph, threads: usize) -> (u64, f64) {
    let s = session(g, threads);
    let r = s.count(Algo::ParTtt);
    (r.cliques, r.secs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn sim_and_wall_agree_on_counts() {
        let g = generators::planted_cliques(120, 0.03, 4, 5, 8, 3);
        let s = session(&g, 2);
        let (seq, _) = run_ttt(&s);
        let (sim_count, sim_secs) = parmce_sim_secs(&s, RankStrategy::Degree, 32);
        let (wall_count, _) = parmce_wall_secs(&g, RankStrategy::Degree, 2);
        let (pt_count, _) = parttt_sim_secs(&s, 32);
        assert_eq!(seq, sim_count);
        assert_eq!(seq, wall_count);
        assert_eq!(seq, pt_count);
        assert!(sim_secs > 0.0);
    }
}
