//! Graph substrate: immutable CSR graphs, dynamic adjacency, generators,
//! synthetic dataset analogs, degeneracy/core decomposition, triangle
//! counting, and edge-list I/O.
//!
//! Everything the enumerators consume flows through here.  The static
//! path is [`edgelist`] → [`csr::CsrGraph`] → the [`degeneracy`] /
//! [`triangles`] rankings; the dynamic path snapshots the same CSR into
//! [`snapshot::SnapshotGraph`] epochs.  Each of those stages has both a
//! sequential and a pool-parallel implementation with bit-identical
//! output (see `DESIGN.md`, "Ingest & ranking pipeline").
#![warn(missing_docs)]

pub mod adj;
pub mod csr;
pub mod datasets;
pub mod degeneracy;
pub mod edgelist;
pub mod generators;
pub mod snapshot;
pub mod stats;
pub mod triangles;

/// Vertex identifier. Graphs here are simple and undirected.
pub type Vertex = u32;

/// An undirected edge, stored with u < v after normalization.
pub type Edge = (Vertex, Vertex);

/// Normalize an edge to (min, max); `None` for self-loops.
#[inline]
pub fn norm_edge(u: Vertex, v: Vertex) -> Option<Edge> {
    use std::cmp::Ordering::*;
    match u.cmp(&v) {
        Less => Some((u, v)),
        Greater => Some((v, u)),
        Equal => None,
    }
}

/// Split `0..n` items into up to `parts` contiguous ranges of roughly
/// equal mass, where `prefix` is the exclusive mass prefix sum
/// (`prefix[i]` = total mass of items before `i`, so `prefix.len() ==
/// n + 1`).  The ranges tile `0..n` in order; some may be empty when
/// the mass is skewed.  Shared by the parallel ingest stages to balance
/// per-worker work by degree/forward mass rather than raw vertex count.
pub(crate) fn balanced_ranges(prefix: &[usize], parts: usize) -> Vec<(usize, usize)> {
    let n = prefix.len() - 1;
    let total = prefix[n];
    let parts = parts.max(1);
    let mut ranges = Vec::with_capacity(parts);
    let mut lo = 0usize;
    for r in 0..parts {
        let target = total * (r + 1) / parts;
        let mut hi = lo;
        while hi < n && prefix[hi] < target {
            hi += 1;
        }
        if r == parts - 1 {
            hi = n;
        }
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// Read-only adjacency access with *sorted* neighbour slices — the shape
/// the TTT-family set algebra needs.  Implemented by the static
/// [`csr::CsrGraph`], the epoch-snapshotted [`snapshot::GraphSnapshot`] /
/// [`snapshot::SnapshotGraph`] pair the dynamic stack runs on, and the
/// legacy [`adj::DynGraph`], so every enumerator runs unchanged on all of
/// them (the incremental algorithms of §5 enumerate inside a graph that
/// mutates between batches).
pub trait AdjacencyGraph: Sync {
    /// Number of vertices.
    fn n(&self) -> usize;

    /// Sorted neighbour slice of `v`.
    fn neighbors(&self, v: Vertex) -> &[Vertex];

    /// Number of neighbours of `v`.
    #[inline]
    fn degree(&self, v: Vertex) -> usize {
        self.neighbors(v).len()
    }
}

impl AdjacencyGraph for csr::CsrGraph {
    #[inline]
    fn n(&self) -> usize {
        csr::CsrGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        csr::CsrGraph::neighbors(self, v)
    }
}

impl AdjacencyGraph for adj::DynGraph {
    #[inline]
    fn n(&self) -> usize {
        adj::DynGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        adj::DynGraph::neighbors(self, v)
    }
}

impl AdjacencyGraph for snapshot::GraphSnapshot {
    #[inline]
    fn n(&self) -> usize {
        snapshot::GraphSnapshot::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        snapshot::GraphSnapshot::neighbors(self, v)
    }
}

impl AdjacencyGraph for snapshot::SnapshotGraph {
    #[inline]
    fn n(&self) -> usize {
        snapshot::SnapshotGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        snapshot::SnapshotGraph::neighbors(self, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_edge_orders_and_drops_loops() {
        assert_eq!(norm_edge(3, 7), Some((3, 7)));
        assert_eq!(norm_edge(7, 3), Some((3, 7)));
        assert_eq!(norm_edge(5, 5), None);
    }

    #[test]
    fn balanced_ranges_tile_and_balance() {
        // uniform mass: every range gets its share
        let prefix: Vec<usize> = (0..=12).collect();
        let ranges = balanced_ranges(&prefix, 4);
        assert_eq!(ranges, vec![(0, 3), (3, 6), (6, 9), (9, 12)]);

        // skewed mass: one heavy item, ranges stay contiguous and tile
        let prefix = vec![0, 100, 100, 100, 101];
        let ranges = balanced_ranges(&prefix, 3);
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, 4);
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }

        // zero mass and empty domains don't panic
        assert_eq!(balanced_ranges(&[0, 0, 0], 2).last().unwrap().1, 2);
        assert_eq!(balanced_ranges(&[0], 3).last().unwrap().1, 0);
    }
}
