//! Graph substrate: immutable CSR graphs, dynamic adjacency, generators,
//! synthetic dataset analogs, degeneracy/core decomposition, triangle
//! counting, and edge-list I/O.

pub mod adj;
pub mod csr;
pub mod datasets;
pub mod degeneracy;
pub mod edgelist;
pub mod generators;
pub mod snapshot;
pub mod stats;
pub mod triangles;

/// Vertex identifier. Graphs here are simple and undirected.
pub type Vertex = u32;

/// An undirected edge, stored with u < v after normalization.
pub type Edge = (Vertex, Vertex);

/// Normalize an edge to (min, max); `None` for self-loops.
#[inline]
pub fn norm_edge(u: Vertex, v: Vertex) -> Option<Edge> {
    use std::cmp::Ordering::*;
    match u.cmp(&v) {
        Less => Some((u, v)),
        Greater => Some((v, u)),
        Equal => None,
    }
}

/// Read-only adjacency access with *sorted* neighbour slices — the shape
/// the TTT-family set algebra needs.  Implemented by the static
/// [`csr::CsrGraph`], the epoch-snapshotted [`snapshot::GraphSnapshot`] /
/// [`snapshot::SnapshotGraph`] pair the dynamic stack runs on, and the
/// legacy [`adj::DynGraph`], so every enumerator runs unchanged on all of
/// them (the incremental algorithms of §5 enumerate inside a graph that
/// mutates between batches).
pub trait AdjacencyGraph: Sync {
    fn n(&self) -> usize;
    fn neighbors(&self, v: Vertex) -> &[Vertex];

    #[inline]
    fn degree(&self, v: Vertex) -> usize {
        self.neighbors(v).len()
    }
}

impl AdjacencyGraph for csr::CsrGraph {
    #[inline]
    fn n(&self) -> usize {
        csr::CsrGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        csr::CsrGraph::neighbors(self, v)
    }
}

impl AdjacencyGraph for adj::DynGraph {
    #[inline]
    fn n(&self) -> usize {
        adj::DynGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        adj::DynGraph::neighbors(self, v)
    }
}

impl AdjacencyGraph for snapshot::GraphSnapshot {
    #[inline]
    fn n(&self) -> usize {
        snapshot::GraphSnapshot::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        snapshot::GraphSnapshot::neighbors(self, v)
    }
}

impl AdjacencyGraph for snapshot::SnapshotGraph {
    #[inline]
    fn n(&self) -> usize {
        snapshot::SnapshotGraph::n(self)
    }

    #[inline]
    fn neighbors(&self, v: Vertex) -> &[Vertex] {
        snapshot::SnapshotGraph::neighbors(self, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_edge_orders_and_drops_loops() {
        assert_eq!(norm_edge(3, 7), Some((3, 7)));
        assert_eq!(norm_edge(7, 3), Some((3, 7)));
        assert_eq!(norm_edge(5, 5), None);
    }
}
