//! Epoch-snapshotted delta-CSR graph storage — the one adjacency
//! structure both the static and dynamic stacks read through.
//!
//! The paper's dynamic algorithms (§5, Figure 4) alternate an "update
//! graph" step with an "enumerate Λⁿᵉʷ/Λᵈᵉˡ" step.  [`SnapshotGraph`] is
//! the writer for that loop: adjacency lives in fixed-width CSR *blocks*
//! ([`BLOCK_VERTS`] vertices each, every block behind its own `Arc`),
//! plus a small per-vertex *overlay* of freshly mutated neighbour lists.
//! Mutating a vertex copies only its list into the overlay (first touch)
//! or rewrites the overlay entry in place; untouched blocks are never
//! copied — the same pointer-level COW the service store uses for its
//! posting lists.
//!
//! [`SnapshotGraph::publish`] freezes the current state into an immutable
//! [`GraphSnapshot`] (block spine and overlay entries shared by `Arc`
//! clone — O(overlay) refcount bumps, zero adjacency bytes copied) and
//! installs it in the [`GraphCell`], bumping the graph epoch: one epoch
//! per applied batch.  Enumeration then runs against the snapshot, so
//! ParIMCE tasks share a plain `Arc` instead of a lifetime-erased borrow,
//! and service snapshots can pin the *exact* graph their clique set was
//! computed against.
//!
//! When the overlay grows past [`SnapshotGraph::compact_threshold`]
//! (total neighbour entries across overlay lists, checked at publish),
//! `compact` folds it back into the block array, rebuilding only the
//! touched blocks.  Snapshots pinned at older epochs keep their own
//! `Arc`s to the pre-compaction blocks and overlay entries, so they stay
//! byte-identical forever.

use std::collections::HashMap;

use crate::graph::csr::CsrGraph;
use crate::graph::{norm_edge, Edge, Vertex};
use crate::util::chashmap::FxBuildHasher;
use crate::util::failpoints;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{plock, Arc, Mutex};
use crate::util::vset;

/// log₂ of the block width: 128 vertices per CSR block — small enough
/// that a batch touching a handful of vertices copies a few KiB, large
/// enough that the block spine stays short.
pub const BLOCK_SHIFT: usize = 7;
/// Vertices per CSR block.
pub const BLOCK_VERTS: usize = 1 << BLOCK_SHIFT;
const BLOCK_MASK: usize = BLOCK_VERTS - 1;

/// Default overlay size (total neighbour entries across overlay lists)
/// above which `publish` compacts the overlay back into the blocks.
pub const DEFAULT_COMPACT_THRESHOLD: usize = 1 << 15;

/// One fixed-width CSR chunk: local offsets for up to [`BLOCK_VERTS`]
/// vertices plus their concatenated sorted neighbour lists.
#[derive(Clone, Debug)]
struct CsrBlock {
    /// `block_len + 1` local offsets into `nbrs`.
    offsets: Vec<usize>,
    nbrs: Vec<Vertex>,
}

impl CsrBlock {
    fn empty(len: usize) -> CsrBlock {
        CsrBlock {
            offsets: vec![0; len + 1],
            nbrs: Vec::new(),
        }
    }

    #[inline]
    fn neighbors(&self, local: usize) -> &[Vertex] {
        &self.nbrs[self.offsets[local]..self.offsets[local + 1]]
    }
}

/// Build block `b` of `g` — the unit of work both [`SnapshotGraph::from_csr`]
/// paths share, so sequential and parallel construction agree byte for byte.
fn build_block(g: &CsrGraph, b: usize) -> Arc<CsrBlock> {
    let start = b * BLOCK_VERTS;
    let len = (g.n() - start).min(BLOCK_VERTS);
    let mut offsets = Vec::with_capacity(len + 1);
    offsets.push(0usize);
    let mut nbrs: Vec<Vertex> = Vec::new();
    for local in 0..len {
        nbrs.extend_from_slice(g.neighbors((start + local) as Vertex));
        offsets.push(nbrs.len());
    }
    Arc::new(CsrBlock { offsets, nbrs })
}

fn empty_blocks(n: usize) -> Vec<Arc<CsrBlock>> {
    let mut blocks = Vec::with_capacity(n.div_ceil(BLOCK_VERTS));
    let mut start = 0;
    while start < n {
        let len = (n - start).min(BLOCK_VERTS);
        blocks.push(Arc::new(CsrBlock::empty(len)));
        start += len;
    }
    blocks
}

/// Immutable view of the graph at one epoch.  Readers resolve a vertex
/// through the (sorted) overlay first, then its CSR block; both are
/// shared with the writer and with other epochs at the `Arc` level, so a
/// snapshot costs pointer clones, never adjacency bytes.  Implements
/// [`crate::graph::AdjacencyGraph`], so every TTT-family enumerator runs
/// on it unchanged.
pub struct GraphSnapshot {
    epoch: u64,
    n: usize,
    m: usize,
    blocks: Arc<Vec<Arc<CsrBlock>>>,
    /// mutated-since-compaction vertices, sorted by vertex id.
    overlay: Vec<(Vertex, Arc<Vec<Vertex>>)>,
}

impl GraphSnapshot {
    /// The batch boundary this snapshot reflects (0 = initial state).
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        if !self.overlay.is_empty() {
            if let Ok(i) = self.overlay.binary_search_by_key(&v, |e| e.0) {
                return &self.overlay[i].1;
            }
        }
        let idx = v as usize;
        self.blocks[idx >> BLOCK_SHIFT].neighbors(idx & BLOCK_MASK)
    }

    #[inline]
    /// Degree of `v`.
    pub fn degree(&self, v: Vertex) -> usize {
        self.neighbors(v).len()
    }

    #[inline]
    /// Is `{u, v}` an edge? (Binary search on the smaller list.)
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        if u == v {
            return false;
        }
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        vset::contains(self.neighbors(a), b)
    }

    /// Common neighbourhood Γ(u) ∩ Γ(v).
    pub fn common_neighbors(&self, u: Vertex, v: Vertex) -> Vec<Vertex> {
        vset::intersect(self.neighbors(u), self.neighbors(v))
    }

    /// Are `verts` pairwise adjacent?
    pub fn is_clique(&self, verts: &[Vertex]) -> bool {
        for (i, &u) in verts.iter().enumerate() {
            for &v in &verts[i + 1..] {
                if u == v || !self.has_edge(u, v) {
                    return false;
                }
            }
        }
        true
    }

    /// Is `clique` a *maximal* clique of this snapshot — i.e. a clique no
    /// vertex outside it is adjacent to all of?
    pub fn is_maximal_clique(&self, clique: &[Vertex]) -> bool {
        if clique.is_empty() || !self.is_clique(clique) {
            return false;
        }
        let mut sorted = clique.to_vec();
        sorted.sort_unstable();
        let seed = *sorted
            .iter()
            .min_by_key(|&&v| self.degree(v))
            .expect("clique checked non-empty");
        'outer: for &w in self.neighbors(seed) {
            if vset::contains(&sorted, w) {
                continue;
            }
            for &u in &sorted {
                if !self.has_edge(u, w) {
                    continue 'outer;
                }
            }
            return false; // w extends the clique
        }
        true
    }

    /// All edges as normalized (u < v) pairs.
    pub fn edges(&self) -> Vec<Edge> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n as Vertex {
            for &v in self.neighbors(u) {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Materialize a standalone [`CsrGraph`] — export/verification only;
    /// the dynamic hot paths never call this (enumeration runs directly
    /// on the snapshot through `AdjacencyGraph`).
    pub fn to_csr(&self) -> CsrGraph {
        CsrGraph::from_edges(self.n, &self.edges())
    }

    /// Overlay entries not yet compacted into the block array (bench /
    /// test introspection: 0 means every read hits a CSR block).
    pub fn overlay_len(&self) -> usize {
        self.overlay.len()
    }

    /// Minimal synthetic snapshot: the edgeless graph on `n` vertices at
    /// `epoch`.
    ///
    /// Concurrency-harness hook (`rust/tests/loom_models.rs` builds
    /// distinguishable payloads per epoch without running batches);
    /// hidden from docs because real snapshots come from
    /// [`SnapshotGraph::publish`].
    #[doc(hidden)]
    pub fn synthetic(epoch: u64, n: usize) -> GraphSnapshot {
        GraphSnapshot {
            epoch,
            n,
            m: 0,
            blocks: Arc::new(empty_blocks(n)),
            overlay: Vec::new(),
        }
    }
}

/// The single-writer delta-CSR graph: CSR blocks + mutation overlay.
/// Mutation is the single-threaded step between batches (Figure 4);
/// readers hold published [`GraphSnapshot`]s and never touch the writer.
pub struct SnapshotGraph {
    n: usize,
    m: usize,
    /// epoch of the most recently published snapshot.
    epoch: u64,
    blocks: Arc<Vec<Arc<CsrBlock>>>,
    /// freshly mutated neighbour lists, keyed by vertex.  Entries are
    /// `Arc`'d so `publish` shares them with snapshots; `Arc::make_mut`
    /// on the next mutation copies a list only if a snapshot still pins
    /// it.
    overlay: HashMap<Vertex, Arc<Vec<Vertex>>, FxBuildHasher>,
    /// Σ len over overlay lists — the compaction trigger metric.
    overlay_nbrs: usize,
    compact_threshold: usize,
    compactions: u64,
    cell: Arc<GraphCell>,
}

impl SnapshotGraph {
    /// The edgeless graph on `n` vertices; epoch 0 is published
    /// immediately.
    pub fn empty(n: usize) -> SnapshotGraph {
        Self::with_blocks(n, 0, empty_blocks(n))
    }

    /// Chunk an existing static graph into blocks (one adjacency copy —
    /// the only one this structure ever makes); epoch 0 is published
    /// immediately.
    pub fn from_csr(g: &CsrGraph) -> SnapshotGraph {
        let n = g.n();
        let nblocks = n.div_ceil(BLOCK_VERTS);
        let blocks = (0..nblocks).map(|b| build_block(g, b)).collect();
        Self::with_blocks(n, g.m(), blocks)
    }

    /// [`from_csr`](Self::from_csr) with the block construction fanned
    /// out across `pool` — one task per contiguous run of blocks, each
    /// built from the shared CSR into owned `Arc`s and reassembled in
    /// block order at the join.  Blocks are built independently by the
    /// same [`build_block`] routine, so the snapshot's adjacency bytes
    /// are identical to the sequential path for every thread count.
    pub fn from_csr_parallel(g: &CsrGraph, pool: &crate::coordinator::pool::ThreadPool) -> SnapshotGraph {
        let n = g.n();
        let nblocks = n.div_ceil(BLOCK_VERTS);
        let workers = pool.num_threads().max(1);
        if nblocks <= 1 || workers == 1 {
            return Self::from_csr(g);
        }
        let chunk = nblocks.div_ceil(workers).max(1);
        let results: Mutex<Vec<(usize, Vec<Arc<CsrBlock>>)>> =
            Mutex::new(Vec::with_capacity(nblocks.div_ceil(chunk)));
        // SAFETY: `g` and `results` outlive the `pool.scope` call below,
        // which joins every spawned task before returning.
        #[allow(unsafe_code)]
        let share = unsafe { crate::util::sync::ScopeShare::new() };
        let g_p = share.share(g);
        let out = share.share(&results);
        pool.scope(|s| {
            for (idx, b0) in (0..nblocks).step_by(chunk).enumerate() {
                let (g_p, out) = (g_p, out);
                s.spawn(move |_| {
                    let g = g_p.get();
                    let b1 = (b0 + chunk).min(nblocks);
                    let built: Vec<Arc<CsrBlock>> =
                        (b0..b1).map(|b| build_block(g, b)).collect();
                    plock(out.get()).push((idx, built));
                });
            }
        });
        let mut shards = std::mem::take(&mut *plock(&results));
        shards.sort_unstable_by_key(|(idx, _)| *idx);
        let blocks: Vec<Arc<CsrBlock>> =
            shards.into_iter().flat_map(|(_, b)| b).collect();
        Self::with_blocks(n, g.m(), blocks)
    }

    fn with_blocks(n: usize, m: usize, blocks: Vec<Arc<CsrBlock>>) -> SnapshotGraph {
        let blocks = Arc::new(blocks);
        let initial = Arc::new(GraphSnapshot {
            epoch: 0,
            n,
            m,
            blocks: Arc::clone(&blocks),
            overlay: Vec::new(),
        });
        SnapshotGraph {
            n,
            m,
            epoch: 0,
            blocks,
            overlay: HashMap::default(),
            overlay_nbrs: 0,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
            compactions: 0,
            cell: Arc::new(GraphCell::new(initial)),
        }
    }

    /// Overlay size (total neighbour entries) above which `publish`
    /// compacts.  0 compacts on every publish (pure-CSR snapshots);
    /// `usize::MAX` never compacts.
    pub fn with_compact_threshold(mut self, nbrs: usize) -> SnapshotGraph {
        self.compact_threshold = nbrs;
        self
    }

    /// In-place [`with_compact_threshold`](Self::with_compact_threshold).
    pub fn set_compact_threshold(&mut self, nbrs: usize) {
        self.compact_threshold = nbrs;
    }

    /// The configured compaction threshold (overlay neighbour entries).
    pub fn compact_threshold(&self) -> usize {
        self.compact_threshold
    }

    #[inline]
    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    /// Number of undirected edges.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Epoch of the most recently published snapshot.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// How many times the overlay has been folded back into the blocks.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Overlay entries (mutated vertices) not yet compacted.
    pub fn overlay_len(&self) -> usize {
        self.overlay.len()
    }

    /// Total neighbour entries across overlay lists (the compaction
    /// trigger metric).
    pub fn overlay_nbrs(&self) -> usize {
        self.overlay_nbrs
    }

    /// Sorted neighbour slice of `v` — the writer's own (possibly
    /// not-yet-published) view.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        if !self.overlay.is_empty() {
            if let Some(l) = self.overlay.get(&v) {
                return l;
            }
        }
        let idx = v as usize;
        self.blocks[idx >> BLOCK_SHIFT].neighbors(idx & BLOCK_MASK)
    }

    #[inline]
    /// Degree of `v`.
    pub fn degree(&self, v: Vertex) -> usize {
        self.neighbors(v).len()
    }

    #[inline]
    /// Is `{u, v}` an edge? (Binary search on the smaller list.)
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        if u == v {
            return false;
        }
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        vset::contains(self.neighbors(a), b)
    }

    /// Common neighbourhood Γ(u) ∩ Γ(v).
    pub fn common_neighbors(&self, u: Vertex, v: Vertex) -> Vec<Vertex> {
        vset::intersect(self.neighbors(u), self.neighbors(v))
    }

    /// Are `verts` pairwise adjacent?
    pub fn is_clique(&self, verts: &[Vertex]) -> bool {
        for (i, &u) in verts.iter().enumerate() {
            for &v in &verts[i + 1..] {
                if u == v || !self.has_edge(u, v) {
                    return false;
                }
            }
        }
        true
    }

    /// The mutated list of `v`, materialized into the overlay on first
    /// touch (one list copy); `Arc::make_mut` re-copies only while a
    /// published snapshot still pins the entry.
    fn overlay_list(&mut self, v: Vertex) -> &mut Vec<Vertex> {
        if !self.overlay.contains_key(&v) {
            let idx = v as usize;
            let base = self.blocks[idx >> BLOCK_SHIFT]
                .neighbors(idx & BLOCK_MASK)
                .to_vec();
            self.overlay_nbrs += base.len();
            self.overlay.insert(v, Arc::new(base));
        }
        Arc::make_mut(self.overlay.get_mut(&v).expect("entry just ensured"))
    }

    /// Insert an undirected edge; true if the graph changed.
    pub fn insert_edge(&mut self, u: Vertex, v: Vertex) -> bool {
        let Some((a, b)) = norm_edge(u, v) else {
            return false;
        };
        debug_assert!((b as usize) < self.n, "vertex {b} out of range");
        if self.has_edge(a, b) {
            return false;
        }
        vset::insert_sorted(self.overlay_list(a), b);
        vset::insert_sorted(self.overlay_list(b), a);
        self.overlay_nbrs += 2;
        self.m += 1;
        true
    }

    /// Remove an undirected edge; true if the graph changed.
    pub fn remove_edge(&mut self, u: Vertex, v: Vertex) -> bool {
        let Some((a, b)) = norm_edge(u, v) else {
            return false;
        };
        if !self.has_edge(a, b) {
            return false;
        }
        vset::remove_sorted(self.overlay_list(a), b);
        vset::remove_sorted(self.overlay_list(b), a);
        self.overlay_nbrs -= 2;
        self.m -= 1;
        true
    }

    /// Insert a batch; returns the edges that were actually new,
    /// normalized, in batch order.
    pub fn insert_batch(&mut self, edges: &[Edge]) -> Vec<Edge> {
        let mut added = Vec::with_capacity(edges.len());
        for &(u, v) in edges {
            if self.insert_edge(u, v) {
                added.push(norm_edge(u, v).expect("insert_edge rejects self-loops"));
            }
        }
        added
    }

    /// Remove a batch; returns the edges that were actually present,
    /// normalized, in batch order.
    pub fn remove_batch(&mut self, edges: &[Edge]) -> Vec<Edge> {
        let mut removed = Vec::with_capacity(edges.len());
        for &(u, v) in edges {
            if self.remove_edge(u, v) {
                removed.push(norm_edge(u, v).expect("remove_edge rejects self-loops"));
            }
        }
        removed
    }

    /// Fold the overlay back into the block array, rebuilding only the
    /// blocks that contain a mutated vertex.  Snapshots pinned at older
    /// epochs keep their own `Arc`s to the old blocks, so compaction
    /// never changes what they read.
    pub fn compact(&mut self) {
        if self.overlay.is_empty() {
            return;
        }
        let mut touched: Vec<Vertex> = self.overlay.keys().copied().collect();
        touched.sort_unstable();
        // clones the Arc spine (pointer-sized entries) iff a snapshot
        // still shares it; block payloads are only rebuilt when touched
        let blocks = Arc::make_mut(&mut self.blocks);
        let mut i = 0;
        while i < touched.len() {
            let bi = (touched[i] as usize) >> BLOCK_SHIFT;
            let start = bi << BLOCK_SHIFT;
            let len = (self.n - start).min(BLOCK_VERTS);
            let mut offsets = Vec::with_capacity(len + 1);
            offsets.push(0usize);
            let mut nbrs: Vec<Vertex> = Vec::new();
            {
                let old = &blocks[bi];
                for local in 0..len {
                    let v = (start + local) as Vertex;
                    match self.overlay.get(&v) {
                        Some(l) => nbrs.extend_from_slice(l),
                        None => nbrs.extend_from_slice(old.neighbors(local)),
                    }
                    offsets.push(nbrs.len());
                }
            }
            blocks[bi] = Arc::new(CsrBlock { offsets, nbrs });
            while i < touched.len() && (touched[i] as usize) >> BLOCK_SHIFT == bi {
                i += 1;
            }
        }
        self.overlay.clear();
        self.overlay_nbrs = 0;
        self.compactions += 1;
    }

    /// Freeze the current state and publish it as the next epoch.
    /// Compacts first when the overlay exceeds the threshold.  One call
    /// per applied batch keeps graph epochs aligned with batch sequence
    /// numbers.
    pub fn publish(&mut self) -> Arc<GraphSnapshot> {
        if self.overlay_nbrs > self.compact_threshold {
            self.compact();
        }
        self.epoch += 1;
        let snap = Arc::new(self.freeze());
        self.cell.publish(Arc::clone(&snap));
        snap
    }

    /// The most recently published snapshot.
    pub fn current(&self) -> Arc<GraphSnapshot> {
        self.cell.load()
    }

    /// The publish/subscribe cell, for readers that outlive a borrow of
    /// the writer.
    pub fn cell(&self) -> &Arc<GraphCell> {
        &self.cell
    }

    fn freeze(&self) -> GraphSnapshot {
        let mut overlay: Vec<(Vertex, Arc<Vec<Vertex>>)> = self
            .overlay
            .iter()
            .map(|(&v, l)| (v, Arc::clone(l)))
            .collect();
        overlay.sort_unstable_by_key(|e| e.0);
        GraphSnapshot {
            epoch: self.epoch,
            n: self.n,
            m: self.m,
            blocks: Arc::clone(&self.blocks),
            overlay,
        }
    }

    /// All edges as normalized (u < v) pairs — the writer's current view.
    pub fn edges(&self) -> Vec<Edge> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n as Vertex {
            for &v in self.neighbors(u) {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Materialize a standalone [`CsrGraph`] — export/verification only;
    /// the dynamic hot paths never call this.
    pub fn to_csr(&self) -> CsrGraph {
        CsrGraph::from_edges(self.n, &self.edges())
    }
}

/// Single-writer, many-reader graph-snapshot handoff — the same
/// copy-on-publish RCU protocol as [`crate::service::SnapshotCell`]: the
/// epoch tag is stored Release *before* the `Arc` swap under the same
/// mutex, and readers pair it with an Acquire load, so an observed epoch
/// is never newer than the payload a subsequent `load` returns.
pub struct GraphCell {
    /// epoch of `current`, published with Release.
    version: AtomicU64,
    current: Mutex<Arc<GraphSnapshot>>,
}

impl GraphCell {
    /// A cell publishing `initial` as the current epoch.
    pub fn new(initial: Arc<GraphSnapshot>) -> Self {
        GraphCell {
            version: AtomicU64::new(initial.epoch()),
            current: Mutex::new(initial),
        }
    }

    /// Make `snap` the current snapshot. Writer-only; epochs must be
    /// monotone.
    pub fn publish(&self, snap: Arc<GraphSnapshot>) {
        // `graph-publish` failpoint: `panic`/`delay` model a writer
        // dying or stalling inside the publish window; `error` is a
        // no-op here (publishing an already-frozen snapshot cannot
        // fail organically)
        let _ = failpoints::hit(failpoints::Site::GraphPublish);
        let mut cur = plock(&self.current);
        debug_assert!(snap.epoch() >= cur.epoch(), "graph epochs must not go back");
        self.version.store(snap.epoch(), Ordering::Release);
        *cur = snap;
    }

    /// Epoch of the currently published snapshot (one Acquire load —
    /// pairs with the Release store in [`publish`](Self::publish)).
    pub fn published_epoch(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Monitoring-only epoch sample (staleness gauges, bench reporting).
    /// Relaxed: no data is read through this value — the publish handoff
    /// itself is the Release store / Acquire load pair above, and anyone
    /// who needs the payload goes through [`load`](Self::load).
    pub fn epoch_hint(&self) -> u64 {
        self.version.load(Ordering::Relaxed)
    }

    /// Fetch the current snapshot (brief mutex hold: one `Arc` clone).
    pub fn load(&self) -> Arc<GraphSnapshot> {
        Arc::clone(&plock(&self.current))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::AdjacencyGraph;
    use crate::util::rng::Rng;

    fn assert_same_adjacency(s: &SnapshotGraph, g: &CsrGraph) {
        assert_eq!(s.n(), g.n());
        assert_eq!(s.m(), g.m());
        for v in 0..g.n() as Vertex {
            assert_eq!(s.neighbors(v), g.neighbors(v), "vertex {v}");
        }
    }

    #[test]
    fn from_csr_spans_block_boundaries() {
        // n = 300 spans three 128-vertex blocks, the last partial
        let g = generators::gnp(300, 0.02, 9);
        let s = SnapshotGraph::from_csr(&g);
        assert_same_adjacency(&s, &g);
        let snap = s.current();
        assert_eq!(snap.epoch(), 0);
        for v in [0u32, 127, 128, 255, 256, 299] {
            assert_eq!(snap.neighbors(v), g.neighbors(v));
        }
        assert_eq!(snap.to_csr().edges(), g.edges());
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut g = SnapshotGraph::empty(4);
        assert!(g.insert_edge(0, 1));
        assert!(g.insert_edge(1, 2));
        assert!(!g.insert_edge(1, 0), "duplicate rejected");
        assert!(!g.insert_edge(2, 2), "self-loop rejected");
        assert_eq!(g.m(), 2);
        assert!(g.has_edge(0, 1));
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.m(), 1);
        assert!(!g.has_edge(0, 1));
        assert_eq!(g.neighbors(1), &[2]);
    }

    #[test]
    fn batch_apis_report_changes_only() {
        let mut g = SnapshotGraph::empty(5);
        g.insert_edge(0, 1);
        let added = g.insert_batch(&[(1, 0), (2, 3), (3, 2), (4, 4), (0, 4)]);
        assert_eq!(added, vec![(2, 3), (0, 4)]);
        assert_eq!(g.m(), 3);
        let removed = g.remove_batch(&[(3, 2), (2, 3), (1, 4)]);
        assert_eq!(removed, vec![(2, 3)]);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn tracks_dyngraph_under_random_churn() {
        let mut rng = Rng::new(17);
        let n = 160; // two blocks
        let mut snap = SnapshotGraph::empty(n);
        let mut dyng = crate::graph::adj::DynGraph::new(n);
        for step in 0..400 {
            let u = rng.gen_usize(n) as Vertex;
            let v = rng.gen_usize(n) as Vertex;
            if rng.gen_bool(0.7) {
                assert_eq!(snap.insert_edge(u, v), dyng.insert_edge(u, v), "step {step}");
            } else {
                assert_eq!(snap.remove_edge(u, v), dyng.remove_edge(u, v), "step {step}");
            }
            if step % 90 == 0 {
                snap.compact();
            }
        }
        assert_eq!(snap.m(), dyng.m());
        for v in 0..n as Vertex {
            assert_eq!(snap.neighbors(v), dyng.neighbors(v), "vertex {v}");
            assert_eq!(snap.common_neighbors(v, (v + 1) % n as Vertex),
                       dyng.common_neighbors(v, (v + 1) % n as Vertex));
        }
    }

    #[test]
    fn publish_bumps_epochs_and_pins_old_payloads() {
        let g0 = generators::gnp(140, 0.05, 3);
        let mut g = SnapshotGraph::from_csr(&g0).with_compact_threshold(usize::MAX);
        let s0 = g.current();
        let adj0: Vec<Vec<Vertex>> =
            (0..g.n() as Vertex).map(|v| s0.neighbors(v).to_vec()).collect();

        g.insert_batch(&[(0, 130), (1, 131), (0, 1)]);
        let s1 = g.publish();
        assert_eq!(s1.epoch(), 1);
        assert!(s1.overlay_len() > 0, "threshold MAX keeps the overlay");
        let adj1: Vec<Vec<Vertex>> =
            (0..g.n() as Vertex).map(|v| s1.neighbors(v).to_vec()).collect();

        // later batches + a forced compaction must not disturb s0 / s1
        g.remove_batch(&[(0, 1)]);
        g.insert_batch(&[(2, 70), (3, 71)]);
        g.compact();
        let s2 = g.publish();
        assert_eq!(s2.epoch(), 2);
        assert_eq!(s2.overlay_len(), 0, "compacted snapshot reads pure CSR");
        assert_eq!(g.compactions(), 1);

        for v in 0..g.n() as Vertex {
            assert_eq!(s0.neighbors(v), adj0[v as usize], "epoch 0, vertex {v}");
            assert_eq!(s1.neighbors(v), adj1[v as usize], "epoch 1, vertex {v}");
        }
        assert_eq!(s0.epoch(), 0);
        assert_eq!(s0.m(), g0.m());
        assert!(s1.has_edge(0, 1));
        assert!(!s2.has_edge(0, 1));
        assert_eq!(g.current().epoch(), 2);
    }

    #[test]
    fn zero_threshold_compacts_every_publish() {
        let mut g = SnapshotGraph::empty(40).with_compact_threshold(0);
        let mut mirror = crate::graph::adj::DynGraph::new(40);
        let target = generators::gnp(40, 0.3, 5);
        for chunk in target.edges().chunks(11) {
            g.insert_batch(chunk);
            mirror.insert_batch(chunk);
            let s = g.publish();
            assert_eq!(s.overlay_len(), 0);
            assert_eq!(g.overlay_len(), 0);
            for v in 0..40u32 {
                assert_eq!(s.neighbors(v), mirror.neighbors(v));
            }
        }
        assert!(g.compactions() > 0);
        assert_eq!(g.to_csr().edges(), target.edges());
    }

    #[test]
    fn snapshot_clique_checks() {
        let mut g = SnapshotGraph::empty(4);
        g.insert_batch(&[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let s = g.publish();
        assert!(s.is_clique(&[0, 1, 2]));
        assert!(!s.is_clique(&[0, 1, 3]));
        assert!(s.is_maximal_clique(&[0, 1, 2]));
        assert!(!s.is_maximal_clique(&[0, 1]));
        assert!(s.is_maximal_clique(&[2, 3]));
        assert!(!s.is_maximal_clique(&[]));
        assert_eq!(s.common_neighbors(0, 1), vec![2]);
    }

    #[test]
    fn adjacency_graph_trait_routes_to_snapshot() {
        let g0 = generators::gnp(50, 0.2, 11);
        let writer = SnapshotGraph::from_csr(&g0);
        let snap = writer.current();
        fn total_degree<G: AdjacencyGraph + ?Sized>(g: &G) -> usize {
            (0..g.n() as Vertex).map(|v| g.neighbors(v).len()).sum()
        }
        assert_eq!(total_degree(snap.as_ref()), 2 * g0.m());
        assert_eq!(total_degree(&writer), 2 * g0.m());
    }

    #[test]
    fn cell_publishes_monotone_epochs() {
        let mut g = SnapshotGraph::empty(8);
        let cell = Arc::clone(g.cell());
        assert_eq!(cell.published_epoch(), 0);
        assert_eq!(cell.epoch_hint(), 0);
        g.insert_edge(0, 1);
        let s = g.publish();
        assert_eq!(cell.published_epoch(), 1);
        assert_eq!(cell.epoch_hint(), 1);
        assert!(Arc::ptr_eq(&cell.load(), &s));
        assert_eq!(g.epoch(), 1);
    }

    #[test]
    fn empty_graph_edge_cases() {
        let mut g = SnapshotGraph::empty(0);
        let s = g.publish();
        assert_eq!(s.n(), 0);
        assert_eq!(s.m(), 0);
        assert!(s.edges().is_empty());
        let synth = GraphSnapshot::synthetic(7, 3);
        assert_eq!(synth.epoch(), 7);
        assert_eq!(synth.neighbors(2), &[] as &[Vertex]);
        assert!(synth.is_maximal_clique(&[1]), "singleton is maximal when isolated");
    }
}
