//! Synthetic graph generators.
//!
//! These stand in for the paper's KONECT/SNAP datasets (no network access —
//! DESIGN.md "Substitutions" item 2) and additionally provide adversarial
//! structures the paper references analytically (Moon–Moser graphs,
//! near-complete graphs) for tests and ablations.

use crate::graph::csr::CsrGraph;
use crate::graph::{norm_edge, Edge, Vertex};
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, p).
pub fn gnp(n: usize, p: f64, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    if p >= 1.0 {
        return complete(n);
    }
    if p > 0.0 {
        // geometric skipping for sparse p
        let log1p = (1.0 - p).ln();
        let total = n * (n - 1) / 2;
        let mut idx: i64 = -1;
        loop {
            let u = rng.gen_f64().max(f64::MIN_POSITIVE);
            let skip = (u.ln() / log1p).floor() as i64 + 1;
            idx += skip.max(1);
            if idx as usize >= total {
                break;
            }
            edges.push(pair_from_index(n, idx as usize));
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Map a linear index in [0, C(n,2)) to the corresponding (u, v), u < v.
fn pair_from_index(n: usize, idx: usize) -> Edge {
    // row-major over the strict upper triangle
    let mut u = 0usize;
    let mut remaining = idx;
    let mut row_len = n - 1;
    while remaining >= row_len {
        remaining -= row_len;
        u += 1;
        row_len -= 1;
    }
    (u as Vertex, (u + 1 + remaining) as Vertex)
}

/// Complete graph K_n.
pub fn complete(n: usize) -> CsrGraph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n as Vertex {
        for v in (u + 1)..n as Vertex {
            edges.push((u, v));
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Moon–Moser graph on n = 3k vertices: complete k-partite with parts of
/// size 3.  Has exactly 3^{n/3} maximal cliques — the worst case for MCE
/// and the paper's exponential-change example for dynamic graphs (§5).
pub fn moon_moser(k: usize) -> CsrGraph {
    let n = 3 * k;
    let mut edges = Vec::new();
    for u in 0..n as Vertex {
        for v in (u + 1)..n as Vertex {
            if u / 3 != v / 3 {
                edges.push((u, v));
            }
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// K_n minus a single edge (the paper's O(1)-change example in §5).
pub fn complete_minus_edge(n: usize) -> CsrGraph {
    let mut edges = Vec::new();
    for u in 0..n as Vertex {
        for v in (u + 1)..n as Vertex {
            if !(u == 0 && v == 1) {
                edges.push((u, v));
            }
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Barabási–Albert preferential attachment: heavy-tailed degrees.
pub fn barabasi_albert(n: usize, m0: usize, seed: u64) -> CsrGraph {
    assert!(m0 >= 1 && n > m0);
    let mut rng = Rng::new(seed);
    let mut edges: Vec<Edge> = Vec::with_capacity(n * m0);
    // endpoints list doubles as the preferential-attachment urn
    let mut urn: Vec<Vertex> = Vec::with_capacity(2 * n * m0);
    // seed clique on m0+1 vertices
    for u in 0..=(m0 as Vertex) {
        for v in (u + 1)..=(m0 as Vertex) {
            edges.push((u, v));
            urn.push(u);
            urn.push(v);
        }
    }
    for v in (m0 + 1)..n {
        let v = v as Vertex;
        // BTreeSet: deterministic iteration (HashSet order varies per
        // process and would make "deterministic" graphs run-dependent)
        let mut targets = std::collections::BTreeSet::new();
        while targets.len() < m0 {
            let t = urn[rng.gen_usize(urn.len())];
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((t, v));
            urn.push(t);
            urn.push(v);
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// RMAT power-law generator (Chakrabarti et al.) — extreme degree skew,
/// our analog for Wiki-Talk-like subproblem imbalance (Fig. 2).
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> CsrGraph {
    let n = 1usize << scale;
    let m_target = n * edge_factor;
    let (a, b, c) = (0.57, 0.19, 0.19); // standard Graph500 parameters
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m_target);
    for _ in 0..m_target {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.gen_f64();
            let (bu, bv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if let Some(e) = norm_edge(u as Vertex, v as Vertex) {
            edges.push(e);
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Sparse background + planted cliques of sizes drawn from [lo, hi]:
/// our analog for social networks with large dense communities
/// (Orkut/LiveJournal-like: many large maximal cliques).
pub fn planted_cliques(
    n: usize,
    background_p: f64,
    num_cliques: usize,
    lo: usize,
    hi: usize,
    seed: u64,
) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let base = gnp(n, background_p, rng.next_u64());
    let mut edges = base.edges();
    for _ in 0..num_cliques {
        let size = lo + rng.gen_usize(hi - lo + 1);
        let members = rng.sample_indices(n, size.min(n));
        for (i, &u) in members.iter().enumerate() {
            for &v in &members[i + 1..] {
                if let Some(e) = norm_edge(u as Vertex, v as Vertex) {
                    edges.push(e);
                }
            }
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Ring of `num` cliques of size `size`, adjacent cliques sharing `overlap`
/// vertices: a DBLP-like collaboration structure with known clique count.
pub fn ring_of_cliques(num: usize, size: usize, overlap: usize) -> CsrGraph {
    assert!(overlap < size, "overlap must be smaller than clique size");
    assert!(num >= 3, "need at least 3 cliques for a ring");
    let stride = size - overlap;
    let n = num * stride;
    let mut edges = Vec::new();
    for c in 0..num {
        let start = c * stride;
        let members: Vec<Vertex> = (0..size).map(|i| ((start + i) % n) as Vertex).collect();
        for (i, &u) in members.iter().enumerate() {
            for &v in &members[i + 1..] {
                if let Some(e) = norm_edge(u, v) {
                    edges.push(e);
                }
            }
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Caveman-ish power-law community graph: power-law community sizes, dense
/// inside, sparse across. Wikipedia-like: many mid-size maximal cliques.
pub fn powerlaw_communities(
    n: usize,
    max_comm: usize,
    intra_p: f64,
    inter_edges_per_vertex: f64,
    seed: u64,
) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    let mut start = 0usize;
    let mut communities = Vec::new();
    while start < n {
        let size = rng.gen_powerlaw(3, max_comm as u64, 2.2) as usize;
        let end = (start + size).min(n);
        communities.push((start, end));
        // dense intra-community block
        for u in start..end {
            for v in (u + 1)..end {
                if rng.gen_bool(intra_p) {
                    edges.push((u as Vertex, v as Vertex));
                }
            }
        }
        start = end;
    }
    let inter = (n as f64 * inter_edges_per_vertex) as usize;
    for _ in 0..inter {
        let u = rng.gen_usize(n) as Vertex;
        let v = rng.gen_usize(n) as Vertex;
        if let Some(e) = norm_edge(u, v) {
            edges.push(e);
        }
    }
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gnp_extremes() {
        assert_eq!(gnp(10, 0.0, 1).m(), 0);
        assert_eq!(gnp(10, 1.0, 1).m(), 45);
    }

    #[test]
    fn gnp_edge_count_close_to_expectation() {
        let n = 200;
        let p = 0.1;
        let g = gnp(n, p, 42);
        let expect = (n * (n - 1) / 2) as f64 * p;
        let got = g.m() as f64;
        assert!(
            (got - expect).abs() < 4.0 * expect.sqrt() + 10.0,
            "m={got} expect≈{expect}"
        );
    }

    #[test]
    fn gnp_deterministic() {
        assert_eq!(gnp(50, 0.2, 7).edges(), gnp(50, 0.2, 7).edges());
        assert_ne!(gnp(50, 0.2, 7).edges(), gnp(50, 0.2, 8).edges());
    }

    #[test]
    fn pair_from_index_bijective() {
        let n = 7;
        let total = n * (n - 1) / 2;
        let mut seen = std::collections::HashSet::new();
        for idx in 0..total {
            let (u, v) = pair_from_index(n, idx);
            assert!(u < v && (v as usize) < n);
            assert!(seen.insert((u, v)));
        }
        assert_eq!(seen.len(), total);
    }

    #[test]
    fn moon_moser_structure() {
        let g = moon_moser(3); // 9 vertices, parts {0,1,2},{3,4,5},{6,7,8}
        assert_eq!(g.n(), 9);
        assert!(!g.has_edge(0, 1), "intra-part non-edge");
        assert!(g.has_edge(0, 3), "inter-part edge");
        // every vertex connects to all 6 vertices of the other parts
        assert_eq!(g.degree(4), 6);
    }

    #[test]
    fn complete_minus_edge_shape() {
        let g = complete_minus_edge(6);
        assert_eq!(g.m(), 14);
        assert!(!g.has_edge(0, 1));
        assert!(g.has_edge(0, 2));
    }

    #[test]
    fn ba_degrees_heavy_tailed() {
        let g = barabasi_albert(500, 3, 5);
        assert_eq!(g.n(), 500);
        assert!(g.m() >= 3 * (500 - 4));
        // hubs exist: max degree should far exceed the attachment constant
        assert!(g.max_degree() > 20, "max degree {}", g.max_degree());
    }

    #[test]
    fn rmat_skew() {
        let g = rmat(9, 8, 11);
        assert_eq!(g.n(), 512);
        assert!(g.m() > 512, "m={}", g.m());
        assert!(g.max_degree() > 30, "rmat should produce hubs");
    }

    #[test]
    fn ring_of_cliques_counts() {
        // 5 cliques of size 6 sharing 2: maximal cliques = exactly the 5 cliques
        let g = ring_of_cliques(5, 6, 2);
        assert_eq!(g.n(), 20);
        for c in 0..5usize {
            let start = c * 4;
            let members: Vec<Vertex> = (0..6).map(|i| ((start + i) % 20) as Vertex).collect();
            assert!(g.is_clique(&members), "clique {c}");
        }
    }

    #[test]
    fn planted_cliques_contains_dense_parts() {
        let g = planted_cliques(300, 0.01, 5, 8, 12, 3);
        assert!(g.m() > 300);
        assert!(g.max_degree() >= 7);
    }

    #[test]
    fn powerlaw_communities_shape() {
        let g = powerlaw_communities(400, 30, 0.8, 1.0, 9);
        assert_eq!(g.n(), 400);
        assert!(g.m() > 400);
    }
}
