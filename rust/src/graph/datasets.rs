//! Synthetic analogs of the paper's eight evaluation graphs (Table 3).
//!
//! The real KONECT/SNAP datasets are unavailable offline and far beyond a
//! 1-core time budget; each is mapped to a generator configuration that
//! reproduces the *property the paper uses it for* (DESIGN.md
//! "Substitutions" item 2).  `paper_stats` keeps the published Table 3 row
//! so EXPERIMENTS.md can print paper-vs-measured side by side.

use crate::graph::csr::CsrGraph;
use crate::graph::generators as gen;

/// Size scale for the synthetic analogs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// A few hundred vertices — unit/integration tests.
    Tiny,
    /// A few thousand vertices — the default for experiments.
    Small,
    /// Tens of thousands of vertices — benchmark runs.
    Full,
}

/// Published Table 3 row (for paper-vs-measured reporting).
#[derive(Clone, Copy, Debug)]
pub struct PaperStats {
    /// |V| as published.
    pub vertices: u64,
    /// |E| as published.
    pub edges: u64,
    /// None = the paper reports "> 400 billion / did not finish".
    pub maximal_cliques: Option<u64>,
    /// Average maximal clique size, where reported.
    pub avg_clique_size: Option<f64>,
    /// Largest maximal clique size, where reported.
    pub max_clique_size: Option<u64>,
}

/// The eight evaluation graphs of Table 3, as synthetic analogs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// DBLP-Coauthor: collaboration cliques, some very large (size ≤ 119).
    DblpLike,
    /// Orkut: social network, 2.27B maximal cliques, avg size 20.
    OrkutLike,
    /// As-Skitter: internet topology, extreme subproblem skew (Fig. 2).
    AsSkitterLike,
    /// Wiki-Talk: the paper's most skewed graph (Fig. 2b/2d).
    WikiTalkLike,
    /// Wikipedia hyperlinks: 131M maximal cliques, avg size 6.
    WikipediaLike,
    /// LiveJournal: large cliques (max 214), used for dynamic runs.
    LiveJournalLike,
    /// Flickr: dynamic-only in the paper (> 400B cliques; never finished).
    FlickrLike,
    /// Ca-Cit-HepTh: density 0.01 citation graph — the exponential
    /// change-size regime of Fig. 8 (19.1x dynamic speedup).
    CaCitHepThLike,
}

/// The five graphs of the static experiments (Tables 4–8).
pub const STATIC_DATASETS: [Dataset; 5] = [
    Dataset::DblpLike,
    Dataset::OrkutLike,
    Dataset::AsSkitterLike,
    Dataset::WikiTalkLike,
    Dataset::WikipediaLike,
];

/// The five graphs of the dynamic experiments (§6.3, Fig. 8/9).
pub const DYNAMIC_DATASETS: [Dataset; 5] = [
    Dataset::DblpLike,
    Dataset::FlickrLike,
    Dataset::WikipediaLike,
    Dataset::LiveJournalLike,
    Dataset::CaCitHepThLike,
];

impl Dataset {
    /// CLI spelling (`--dataset` values).
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::DblpLike => "dblp-like",
            Dataset::OrkutLike => "orkut-like",
            Dataset::AsSkitterLike => "as-skitter-like",
            Dataset::WikiTalkLike => "wiki-talk-like",
            Dataset::WikipediaLike => "wikipedia-like",
            Dataset::LiveJournalLike => "livejournal-like",
            Dataset::FlickrLike => "flickr-like",
            Dataset::CaCitHepThLike => "ca-cit-hepth-like",
        }
    }

    /// The dataset's name as printed in the paper.
    pub fn paper_name(&self) -> &'static str {
        match self {
            Dataset::DblpLike => "DBLP-Coauthor",
            Dataset::OrkutLike => "Orkut",
            Dataset::AsSkitterLike => "As-Skitter",
            Dataset::WikiTalkLike => "Wiki-Talk",
            Dataset::WikipediaLike => "Wikipedia",
            Dataset::LiveJournalLike => "LiveJournal",
            Dataset::FlickrLike => "Flickr",
            Dataset::CaCitHepThLike => "Ca-Cit-HepTh",
        }
    }

    /// Every dataset analog, in Table 3 order.
    pub fn all() -> [Dataset; 8] {
        [
            Dataset::DblpLike,
            Dataset::OrkutLike,
            Dataset::AsSkitterLike,
            Dataset::WikiTalkLike,
            Dataset::WikipediaLike,
            Dataset::LiveJournalLike,
            Dataset::FlickrLike,
            Dataset::CaCitHepThLike,
        ]
    }

    /// Published Table 3 numbers.
    pub fn paper_stats(&self) -> PaperStats {
        match self {
            Dataset::DblpLike => PaperStats {
                vertices: 1_282_468,
                edges: 5_179_996,
                maximal_cliques: Some(1_219_320),
                avg_clique_size: Some(3.0),
                max_clique_size: Some(119),
            },
            Dataset::OrkutLike => PaperStats {
                vertices: 3_072_441,
                edges: 117_184_899,
                maximal_cliques: Some(2_270_456_447),
                avg_clique_size: Some(20.0),
                max_clique_size: Some(51),
            },
            Dataset::AsSkitterLike => PaperStats {
                vertices: 1_696_415,
                edges: 11_095_298,
                maximal_cliques: Some(37_322_355),
                avg_clique_size: Some(19.0),
                max_clique_size: Some(67),
            },
            Dataset::WikiTalkLike => PaperStats {
                vertices: 2_394_385,
                edges: 4_659_565,
                maximal_cliques: Some(86_333_306),
                avg_clique_size: Some(13.0),
                max_clique_size: Some(26),
            },
            Dataset::WikipediaLike => PaperStats {
                vertices: 1_870_709,
                edges: 36_532_531,
                maximal_cliques: Some(131_652_971),
                avg_clique_size: Some(6.0),
                max_clique_size: Some(31),
            },
            Dataset::LiveJournalLike => PaperStats {
                vertices: 4_033_137,
                edges: 27_933_062,
                maximal_cliques: Some(38_413_665),
                avg_clique_size: Some(29.0),
                max_clique_size: Some(214),
            },
            Dataset::FlickrLike => PaperStats {
                vertices: 2_302_925,
                edges: 22_838_276,
                maximal_cliques: None,
                avg_clique_size: None,
                max_clique_size: None,
            },
            Dataset::CaCitHepThLike => PaperStats {
                vertices: 22_908,
                edges: 2_444_798,
                maximal_cliques: None,
                avg_clique_size: None,
                max_clique_size: None,
            },
        }
    }

    /// Build the synthetic analog at the requested scale. Deterministic.
    pub fn graph(&self, scale: Scale) -> CsrGraph {
        let s = match scale {
            Scale::Tiny => 0,
            Scale::Small => 1,
            Scale::Full => 2,
        };
        match self {
            // Collaboration cliques: overlapping cliques in a ring, plus a
            // sparse background — small avg clique size, a few big cliques.
            Dataset::DblpLike => {
                let (num, size, ovl) = [(24, 6, 2), (300, 8, 2), (1500, 10, 3)][s];
                let ring = gen::ring_of_cliques(num, size, ovl);
                let mut edges = ring.edges();
                // one oversized "mega-collaboration" clique (paper: size 119)
                let big = [12, 24, 40][s];
                for u in 0..big as u32 {
                    for v in (u + 1)..big as u32 {
                        edges.push((u * 2 % ring.n() as u32, v * 2 % ring.n() as u32));
                    }
                }
                CsrGraph::from_edges(ring.n(), &edges)
            }
            // Social network with many large dense communities.
            Dataset::OrkutLike => {
                let (n, k, lo, hi) = [(400, 14, 8, 14), (3000, 80, 10, 18), (12000, 300, 12, 22)][s];
                gen::planted_cliques(n, 6.0 / n as f64, k, lo, hi, 0x04B0)
            }
            // Internet topology: heavy-tailed, strong core (Fig. 2a/2c skew).
            Dataset::AsSkitterLike => {
                let (n, m0) = [(500, 4), (4000, 5), (20000, 6)][s];
                gen::barabasi_albert(n, m0, 0xA55)
            }
            // Extreme skew: RMAT hubs (Fig. 2b/2d: 0.002% of subproblems
            // carry 90% of the cliques).
            Dataset::WikiTalkLike => {
                let (scale_bits, ef) = [(9, 6), (12, 7), (14, 8)][s];
                gen::rmat(scale_bits, ef, 0x717A)
            }
            // Hyperlink graph: power-law communities, mid-size cliques.
            Dataset::WikipediaLike => {
                let (n, mc) = [(500, 18), (4000, 30), (16000, 40)][s];
                gen::powerlaw_communities(n, mc, 0.7, 1.5, 0x31C1)
            }
            // Social network with very large cliques (paper max 214).
            Dataset::LiveJournalLike => {
                let (n, k, lo, hi) = [(400, 8, 10, 18), (3000, 40, 12, 26), (12000, 150, 14, 34)][s];
                gen::planted_cliques(n, 4.0 / n as f64, k, lo, hi, 0x11FE)
            }
            // Photo-sharing social graph: dense overlapping communities —
            // clique-explosive (paper: > 400B maximal cliques).
            Dataset::FlickrLike => {
                let (n, mc) = [(300, 24), (2000, 40), (8000, 60)][s];
                gen::powerlaw_communities(n, mc, 0.9, 2.0, 0xF11C)
            }
            // Dense citation graph, density ~0.01 in the paper but tiny n;
            // our analog keeps the density so change sizes explode (Fig. 8).
            Dataset::CaCitHepThLike => {
                let n = [120, 400, 1200][s];
                gen::gnp(n, [0.20, 0.10, 0.05][s], 0xCAC1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tiny_analogs_build() {
        for d in Dataset::all() {
            let g = d.graph(Scale::Tiny);
            assert!(g.n() > 50, "{} too small: n={}", d.name(), g.n());
            assert!(g.m() > 50, "{} too sparse: m={}", d.name(), g.m());
        }
    }

    #[test]
    fn deterministic_generation() {
        for d in [Dataset::WikiTalkLike, Dataset::OrkutLike] {
            let a = d.graph(Scale::Tiny);
            let b = d.graph(Scale::Tiny);
            assert_eq!(a.edges(), b.edges(), "{}", d.name());
        }
    }

    #[test]
    fn scales_are_ordered() {
        let d = Dataset::AsSkitterLike;
        assert!(d.graph(Scale::Tiny).n() < d.graph(Scale::Small).n());
    }

    #[test]
    fn paper_stats_present() {
        assert_eq!(Dataset::OrkutLike.paper_stats().maximal_cliques, Some(2_270_456_447));
        assert!(Dataset::FlickrLike.paper_stats().maximal_cliques.is_none());
    }

    #[test]
    fn skewed_analogs_have_hubs() {
        let g = Dataset::WikiTalkLike.graph(Scale::Tiny);
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!(
            g.max_degree() as f64 > 5.0 * avg,
            "wiki-talk-like should be skewed: max={} avg={avg}",
            g.max_degree()
        );
    }
}
