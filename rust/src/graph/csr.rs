//! Immutable CSR (compressed sparse row) graph.
//!
//! The shared read-only structure all threads traverse concurrently — the
//! shared-memory advantage the paper leans on (§1: one copy of the graph,
//! no partitioning).  Neighbour lists are sorted, so set algebra on them
//! uses `util::vset` merge/gallop routines.

use crate::graph::{norm_edge, Edge, Vertex};
use crate::util::vset;

#[derive(Clone, Debug)]
pub struct CsrGraph {
    offsets: Vec<usize>,
    nbrs: Vec<Vertex>,
}

impl CsrGraph {
    /// Build from an edge list; self-loops and duplicates are dropped,
    /// directions ignored (the paper's preprocessing, §6.1).
    pub fn from_edges(n: usize, edges: &[(Vertex, Vertex)]) -> Self {
        let mut norm: Vec<Edge> = edges
            .iter()
            .filter_map(|&(u, v)| norm_edge(u, v))
            .collect();
        norm.sort_unstable();
        norm.dedup();
        for &(u, v) in &norm {
            assert!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u},{v}) out of range for n={n}"
            );
        }
        let mut deg = vec![0usize; n];
        for &(u, v) in &norm {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0);
        for d in &deg {
            offsets.push(offsets.last().unwrap() + d);
        }
        let mut nbrs = vec![0; offsets[n]];
        let mut cursor = offsets.clone();
        for &(u, v) in &norm {
            nbrs[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            nbrs[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // per-vertex neighbour lists are sorted because `norm` was sorted
        // lexicographically — but the (v, u) reversed inserts are not; sort.
        for v in 0..n {
            nbrs[offsets[v]..offsets[v + 1]].sort_unstable();
        }
        CsrGraph { offsets, nbrs }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.nbrs.len() / 2
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        &self.nbrs[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    #[inline]
    pub fn degree(&self, v: Vertex) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    #[inline]
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        vset::contains(self.neighbors(a), b)
    }

    pub fn vertices(&self) -> impl Iterator<Item = Vertex> + '_ {
        0..self.n() as Vertex
    }

    /// All edges as normalized (u < v) pairs.
    pub fn edges(&self) -> Vec<Edge> {
        let mut out = Vec::with_capacity(self.m());
        for u in self.vertices() {
            for &v in self.neighbors(u) {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v as Vertex)).max().unwrap_or(0)
    }

    pub fn density(&self) -> f64 {
        let n = self.n() as f64;
        if n < 2.0 {
            return 0.0;
        }
        2.0 * self.m() as f64 / (n * (n - 1.0))
    }

    /// Is `verts` (sorted or not) a clique in this graph?
    pub fn is_clique(&self, verts: &[Vertex]) -> bool {
        for (i, &u) in verts.iter().enumerate() {
            for &v in &verts[i + 1..] {
                if u == v || !self.has_edge(u, v) {
                    return false;
                }
            }
        }
        true
    }

    /// Is `clique` (a clique) maximal — i.e. no vertex adjacent to all of it?
    pub fn is_maximal_clique(&self, clique: &[Vertex]) -> bool {
        if clique.is_empty() || !self.is_clique(clique) {
            return false;
        }
        // candidates = common neighbourhood of all clique members
        let mut sorted = clique.to_vec();
        sorted.sort_unstable();
        let seed = *sorted
            .iter()
            .min_by_key(|&&v| self.degree(v))
            .unwrap();
        'outer: for &w in self.neighbors(seed) {
            if vset::contains(&sorted, w) {
                continue;
            }
            for &u in &sorted {
                if !self.has_edge(u, w) {
                    continue 'outer;
                }
            }
            return false; // w extends the clique
        }
        true
    }

    /// Approximate heap footprint in bytes.
    pub fn heap_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>() + self.nbrs.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> CsrGraph {
        // 0-1-2 triangle, 2-3 tail
        CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)])
    }

    #[test]
    fn basic_shape() {
        let g = triangle_plus_tail();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn dedup_loops_and_directions() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (1, 1), (0, 1), (1, 2)]);
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn has_edge_symmetric() {
        let g = triangle_plus_tail();
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
        assert!(!g.has_edge(3, 0));
    }

    #[test]
    fn edges_roundtrip() {
        let mut edges = vec![(0, 1), (1, 2), (0, 2), (2, 3)];
        let g = CsrGraph::from_edges(4, &edges);
        let mut out = g.edges();
        out.sort_unstable();
        edges.sort_unstable();
        assert_eq!(out, edges);
        let g2 = CsrGraph::from_edges(4, &out);
        assert_eq!(g2.edges(), out);
    }

    #[test]
    fn clique_checks() {
        let g = triangle_plus_tail();
        assert!(g.is_clique(&[0, 1, 2]));
        assert!(!g.is_clique(&[0, 1, 3]));
        assert!(g.is_maximal_clique(&[0, 1, 2]));
        assert!(!g.is_maximal_clique(&[0, 1])); // extends to the triangle
        assert!(g.is_maximal_clique(&[2, 3]));
        assert!(!g.is_maximal_clique(&[]));
        assert!(!g.is_maximal_clique(&[0, 3])); // not even a clique
    }

    #[test]
    fn density_of_complete_graph_is_one() {
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        let g = CsrGraph::from_edges(5, &edges);
        assert!((g.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        CsrGraph::from_edges(2, &[(0, 5)]);
    }
}
