//! Immutable CSR (compressed sparse row) graph.
//!
//! The shared read-only structure all threads traverse concurrently — the
//! shared-memory advantage the paper leans on (§1: one copy of the graph,
//! no partitioning).  Neighbour lists are sorted, so set algebra on them
//! uses `util::vset` merge/gallop routines.
//!
//! Two constructors build the same graph: [`CsrGraph::from_edges`]
//! (sequential) and [`CsrGraph::from_edges_parallel`] (the ingest
//! pipeline's two-pass counting sort over a worker pool).  Both produce
//! per-vertex **sorted, deduplicated** neighbour lists, so the outputs
//! are bit-identical regardless of thread count or scatter order.

use crate::coordinator::pool::ThreadPool;
use crate::graph::{balanced_ranges, norm_edge, Edge, Vertex};
use crate::telemetry;
use crate::util::sync::{plock, Mutex, ScopeShare};
use crate::util::vset;

/// Compressed-sparse-row adjacency: `offsets[v]..offsets[v+1]` indexes
/// the sorted neighbour list of `v` inside one flat `nbrs` buffer.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    offsets: Vec<usize>,
    nbrs: Vec<Vertex>,
}

/// Which contiguous vertex range owns vertex `v`, given the exclusive
/// range end-points in ascending order (empty ranges have `end == start`
/// of their successor, so lookup goes by end-point, not start-point).
fn range_of(ends: &[usize], v: usize) -> usize {
    ends.partition_point(|&e| e <= v)
}

impl CsrGraph {
    /// Build from an edge list; self-loops and duplicates are dropped,
    /// directions ignored (the paper's preprocessing, §6.1).
    pub fn from_edges(n: usize, edges: &[(Vertex, Vertex)]) -> Self {
        let span = telemetry::SpanTimer::start();
        let mut norm: Vec<Edge> = edges
            .iter()
            .filter_map(|&(u, v)| norm_edge(u, v))
            .collect();
        norm.sort_unstable();
        norm.dedup();
        for &(u, v) in &norm {
            assert!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u},{v}) out of range for n={n}"
            );
        }
        let mut deg = vec![0usize; n];
        for &(u, v) in &norm {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0);
        for d in &deg {
            offsets.push(offsets.last().unwrap() + d);
        }
        let mut nbrs = vec![0; offsets[n]];
        let mut cursor = offsets.clone();
        for &(u, v) in &norm {
            nbrs[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            nbrs[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // per-vertex neighbour lists are sorted because `norm` was sorted
        // lexicographically — but the (v, u) reversed inserts are not; sort.
        for v in 0..n {
            nbrs[offsets[v]..offsets[v + 1]].sort_unstable();
        }
        telemetry::global().ingest_csr_build_ns.record(span.elapsed_ns());
        CsrGraph { offsets, nbrs }
    }

    /// [`from_edges`](Self::from_edges) fanned out across `pool` as a
    /// two-pass counting sort:
    ///
    /// 1. per-worker edge chunks are normalized (self-loops dropped,
    ///    `u < v`) into owned buffers alongside per-worker full-size
    ///    degree histograms, merged into duplicate-inclusive prefix-sum
    ///    offsets at the join;
    /// 2. normalized edges are bucketed by degree-mass-balanced owner
    ///    vertex range;
    /// 3. each range scatters into its own slice of the neighbour
    ///    buffer, then sorts **and dedups** each vertex's list.
    ///
    /// The final per-vertex lists are sorted duplicate-free sets, so the
    /// result is bit-identical to the sequential constructor for every
    /// thread count and scatter interleaving.  Out-of-range edges raise
    /// the same panic as the sequential path (re-raised at the join).
    pub fn from_edges_parallel(n: usize, edges: &[(Vertex, Vertex)], pool: &ThreadPool) -> Self {
        let span = telemetry::SpanTimer::start();
        let workers = pool.num_threads().max(1);

        // SAFETY: every reference shared below (`edges`, the per-phase
        // result mutexes, the shard/offset/end vectors) outlives the
        // `pool.scope` call that observes it; each scope joins all its
        // spawned tasks before returning, so no task holds a ScopedPtr
        // past the borrow's life.
        #[allow(unsafe_code)]
        let share = unsafe { ScopeShare::new() };

        // Phase 1: normalize chunks + per-worker degree histograms.
        struct NormShard {
            idx: usize,
            norm: Vec<Edge>,
            hist: Vec<u32>,
        }
        let chunk = edges.len().div_ceil(workers).max(1);
        let phase1: Mutex<Vec<NormShard>> = Mutex::new(Vec::with_capacity(workers));
        {
            let src = share.share(edges);
            let out = share.share(&phase1);
            pool.scope(|s| {
                for (idx, start) in (0..edges.len()).step_by(chunk).enumerate() {
                    let (src, out) = (src, out);
                    s.spawn(move |_| {
                        let edges = src.get();
                        let slice = &edges[start..(start + chunk).min(edges.len())];
                        let mut hist = vec![0u32; n];
                        let mut norm = Vec::with_capacity(slice.len());
                        for &(u, v) in slice {
                            if let Some((a, b)) = norm_edge(u, v) {
                                assert!(
                                    (b as usize) < n,
                                    "edge ({a},{b}) out of range for n={n}"
                                );
                                hist[a as usize] += 1;
                                hist[b as usize] += 1;
                                norm.push((a, b));
                            }
                        }
                        plock(out.get()).push(NormShard { idx, norm, hist });
                    });
                }
            });
        }
        let mut shards = std::mem::take(&mut *plock(&phase1));
        shards.sort_unstable_by_key(|sh| sh.idx);

        // duplicate-inclusive degrees -> provisional scatter offsets
        let mut tmp_off = Vec::with_capacity(n + 1);
        tmp_off.push(0usize);
        for v in 0..n {
            let d: usize = shards.iter().map(|sh| sh.hist[v] as usize).sum();
            tmp_off.push(tmp_off[v] + d);
        }
        let ranges = balanced_ranges(&tmp_off, workers);
        let ends: Vec<usize> = ranges.iter().map(|&(_, hi)| hi).collect();

        // Phase 2: bucket (owner, nbr) pairs by destination range.
        let phase2: Mutex<Vec<(usize, Vec<Vec<(Vertex, Vertex)>>)>> =
            Mutex::new(Vec::with_capacity(shards.len()));
        {
            let shards_p = share.share(shards.as_slice());
            let ends_p = share.share(ends.as_slice());
            let out = share.share(&phase2);
            pool.scope(|s| {
                for idx in 0..shards.len() {
                    let (shards_p, ends_p, out) = (shards_p, ends_p, out);
                    s.spawn(move |_| {
                        let ends = ends_p.get();
                        let mut buckets: Vec<Vec<(Vertex, Vertex)>> =
                            vec![Vec::new(); ends.len()];
                        for &(a, b) in &shards_p.get()[idx].norm {
                            buckets[range_of(ends, a as usize)].push((a, b));
                            buckets[range_of(ends, b as usize)].push((b, a));
                        }
                        plock(out.get()).push((idx, buckets));
                    });
                }
            });
        }
        let mut bucketed = std::mem::take(&mut *plock(&phase2));
        bucketed.sort_unstable_by_key(|(idx, _)| *idx);

        // Phase 3: per-range scatter, then per-vertex sort + dedup.
        struct RangeOut {
            idx: usize,
            nbrs: Vec<Vertex>,
            deg: Vec<u32>,
        }
        let phase3: Mutex<Vec<RangeOut>> = Mutex::new(Vec::with_capacity(ranges.len()));
        {
            let bucketed_p = share.share(bucketed.as_slice());
            let tmp_off_p = share.share(tmp_off.as_slice());
            let out = share.share(&phase3);
            pool.scope(|s| {
                for (idx, &(lo, hi)) in ranges.iter().enumerate() {
                    let (bucketed_p, tmp_off_p, out) = (bucketed_p, tmp_off_p, out);
                    s.spawn(move |_| {
                        let tmp_off = tmp_off_p.get();
                        let base = tmp_off[lo];
                        let mut buf = vec![0 as Vertex; tmp_off[hi] - base];
                        let mut cursor: Vec<usize> =
                            (lo..hi).map(|v| tmp_off[v] - base).collect();
                        for (_, buckets) in bucketed_p.get() {
                            for &(owner, nbr) in &buckets[idx] {
                                let slot = owner as usize - lo;
                                buf[cursor[slot]] = nbr;
                                cursor[slot] += 1;
                            }
                        }
                        let mut nbrs = Vec::with_capacity(buf.len());
                        let mut deg = Vec::with_capacity(hi - lo);
                        for v in lo..hi {
                            let list = &mut buf[tmp_off[v] - base..tmp_off[v + 1] - base];
                            list.sort_unstable();
                            let before = nbrs.len();
                            let mut prev = None;
                            for &x in list.iter() {
                                if Some(x) != prev {
                                    nbrs.push(x);
                                    prev = Some(x);
                                }
                            }
                            deg.push((nbrs.len() - before) as u32);
                        }
                        plock(out.get()).push(RangeOut { idx, nbrs, deg });
                    });
                }
            });
        }
        let mut range_outs = std::mem::take(&mut *plock(&phase3));
        range_outs.sort_unstable_by_key(|ro| ro.idx);

        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        for ro in &range_outs {
            for &d in &ro.deg {
                offsets.push(offsets.last().unwrap() + d as usize);
            }
        }
        let mut nbrs = Vec::with_capacity(offsets[n]);
        for ro in &mut range_outs {
            nbrs.append(&mut ro.nbrs);
        }
        telemetry::global().ingest_csr_build_ns.record(span.elapsed_ns());
        CsrGraph { offsets, nbrs }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.nbrs.len() / 2
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        &self.nbrs[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Number of neighbours of `v`.
    #[inline]
    pub fn degree(&self, v: Vertex) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Adjacency test via binary search on the smaller neighbour list.
    #[inline]
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        vset::contains(self.neighbors(a), b)
    }

    /// Iterator over all vertex ids, `0..n`.
    pub fn vertices(&self) -> impl Iterator<Item = Vertex> + '_ {
        0..self.n() as Vertex
    }

    /// All edges as normalized (u < v) pairs.
    pub fn edges(&self) -> Vec<Edge> {
        let mut out = Vec::with_capacity(self.m());
        for u in self.vertices() {
            for &v in self.neighbors(u) {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Largest vertex degree (0 for the empty graph).
    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v as Vertex)).max().unwrap_or(0)
    }

    /// Edge density `2m / n(n-1)` (0 for graphs with fewer than 2 vertices).
    pub fn density(&self) -> f64 {
        let n = self.n() as f64;
        if n < 2.0 {
            return 0.0;
        }
        2.0 * self.m() as f64 / (n * (n - 1.0))
    }

    /// Is `verts` (sorted or not) a clique in this graph?
    pub fn is_clique(&self, verts: &[Vertex]) -> bool {
        for (i, &u) in verts.iter().enumerate() {
            for &v in &verts[i + 1..] {
                if u == v || !self.has_edge(u, v) {
                    return false;
                }
            }
        }
        true
    }

    /// Is `clique` (a clique) maximal — i.e. no vertex adjacent to all of it?
    pub fn is_maximal_clique(&self, clique: &[Vertex]) -> bool {
        if clique.is_empty() || !self.is_clique(clique) {
            return false;
        }
        // candidates = common neighbourhood of all clique members
        let mut sorted = clique.to_vec();
        sorted.sort_unstable();
        let seed = *sorted
            .iter()
            .min_by_key(|&&v| self.degree(v))
            .unwrap();
        'outer: for &w in self.neighbors(seed) {
            if vset::contains(&sorted, w) {
                continue;
            }
            for &u in &sorted {
                if !self.has_edge(u, w) {
                    continue 'outer;
                }
            }
            return false; // w extends the clique
        }
        true
    }

    /// Approximate heap footprint in bytes.
    pub fn heap_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>() + self.nbrs.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> CsrGraph {
        // 0-1-2 triangle, 2-3 tail
        CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)])
    }

    #[test]
    fn basic_shape() {
        let g = triangle_plus_tail();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn dedup_loops_and_directions() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (1, 1), (0, 1), (1, 2)]);
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn has_edge_symmetric() {
        let g = triangle_plus_tail();
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
        assert!(!g.has_edge(3, 0));
    }

    #[test]
    fn edges_roundtrip() {
        let mut edges = vec![(0, 1), (1, 2), (0, 2), (2, 3)];
        let g = CsrGraph::from_edges(4, &edges);
        let mut out = g.edges();
        out.sort_unstable();
        edges.sort_unstable();
        assert_eq!(out, edges);
        let g2 = CsrGraph::from_edges(4, &out);
        assert_eq!(g2.edges(), out);
    }

    #[test]
    fn clique_checks() {
        let g = triangle_plus_tail();
        assert!(g.is_clique(&[0, 1, 2]));
        assert!(!g.is_clique(&[0, 1, 3]));
        assert!(g.is_maximal_clique(&[0, 1, 2]));
        assert!(!g.is_maximal_clique(&[0, 1])); // extends to the triangle
        assert!(g.is_maximal_clique(&[2, 3]));
        assert!(!g.is_maximal_clique(&[]));
        assert!(!g.is_maximal_clique(&[0, 3])); // not even a clique
    }

    #[test]
    fn density_of_complete_graph_is_one() {
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        let g = CsrGraph::from_edges(5, &edges);
        assert!((g.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        CsrGraph::from_edges(2, &[(0, 5)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics_in_parallel_build() {
        let pool = ThreadPool::new(2);
        CsrGraph::from_edges_parallel(2, &[(0, 1), (0, 5)], &pool);
    }

    #[test]
    fn parallel_build_matches_sequential() {
        // messy input: duplicates both ways, self-loops, skewed degrees
        let mut edges: Vec<Edge> = Vec::new();
        for v in 1..40u32 {
            edges.push((0, v)); // hub
            edges.push((v, 0)); // reversed duplicate
            edges.push((v, v)); // self-loop
            edges.push((v, (v % 7) + 40));
        }
        let n = 47;
        let seq = CsrGraph::from_edges(n, &edges);
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let par = CsrGraph::from_edges_parallel(n, &edges, &pool);
            assert_eq!(par.n(), seq.n(), "threads={threads}");
            assert_eq!(par.m(), seq.m(), "threads={threads}");
            for v in 0..n as Vertex {
                assert_eq!(par.neighbors(v), seq.neighbors(v), "threads={threads} v={v}");
            }
        }
    }

    #[test]
    fn parallel_build_handles_degenerate_shapes() {
        let pool = ThreadPool::new(4);
        let empty = CsrGraph::from_edges_parallel(0, &[], &pool);
        assert_eq!(empty.n(), 0);
        assert_eq!(empty.m(), 0);
        let isolated = CsrGraph::from_edges_parallel(3, &[], &pool);
        assert_eq!(isolated.n(), 3);
        assert_eq!(isolated.m(), 0);
        assert_eq!(isolated.neighbors(1), &[] as &[Vertex]);
    }

    #[test]
    fn range_of_skips_empty_ranges() {
        // ranges (0,0), (0,2), (2,2), (2,5): lookups must land in the
        // non-empty range containing v, never an empty predecessor
        let ends = [0, 2, 2, 5];
        assert_eq!(range_of(&ends, 0), 1);
        assert_eq!(range_of(&ends, 1), 1);
        assert_eq!(range_of(&ends, 2), 3);
        assert_eq!(range_of(&ends, 4), 3);
    }
}
