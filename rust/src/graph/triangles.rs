//! Per-vertex triangle counting on the CPU (forward / compact-forward
//! algorithm, O(m^{3/2})).
//!
//! The paper builds its ParMCETri ranking with a sequential routine
//! (§6.2: "We compute the degeneracy number and triangle count for each
//! vertex using sequential procedures"); [`per_vertex`] is that oracle,
//! and [`per_vertex_parallel`] goes beyond the paper by striping the
//! same forward counting across the ingest pool — u64 counts merge by
//! exact addition, so the parallel result equals the oracle bit for bit.
//! Both paths share one flat CSR-shaped forward-adjacency arena instead
//! of a `Vec<Vec<Vertex>>` per vertex (one allocation, cache-contiguous
//! lists).  The sequential path also doubles as the oracle for the
//! PJRT-offloaded kernel (`runtime::tri_rank`), which must agree exactly.

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::{balanced_ranges, Vertex};
use crate::util::sync::{plock, Mutex, ScopeShare};
use crate::util::vset;

/// Flat CSR-shaped forward adjacency: `offsets[v]..offsets[v+1]` indexes
/// the id-sorted higher-ranked out-neighbours of `v` in one buffer.
struct ForwardArena {
    offsets: Vec<usize>,
    targets: Vec<Vertex>,
}

impl ForwardArena {
    #[inline]
    fn fwd(&self, v: Vertex) -> &[Vertex] {
        &self.targets[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }
}

/// Degree-based total order: (degree, id) — edges are oriented low→high.
#[inline]
fn rank(g: &CsrGraph, v: Vertex) -> (usize, Vertex) {
    (g.degree(v), v)
}

fn forward_arena(g: &CsrGraph) -> ForwardArena {
    let n = g.n();
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    for u in g.vertices() {
        let fdeg = g
            .neighbors(u)
            .iter()
            .filter(|&&v| rank(g, u) < rank(g, v))
            .count();
        offsets.push(offsets.last().unwrap() + fdeg);
    }
    let mut targets = vec![0 as Vertex; offsets[n]];
    let mut cur = 0usize;
    for u in g.vertices() {
        // neighbours iterate in ascending id, so each forward list lands
        // already sorted by id
        for &v in g.neighbors(u) {
            if rank(g, u) < rank(g, v) {
                targets[cur] = v;
                cur += 1;
            }
        }
    }
    ForwardArena { offsets, targets }
}

/// [`forward_arena`] with both passes (forward-degree count, fill)
/// fanned out over degree-balanced vertex ranges; per-range owned
/// buffers concatenate in range order, so the arena is identical to the
/// sequential build.
fn forward_arena_parallel(g: &CsrGraph, pool: &ThreadPool) -> ForwardArena {
    let n = g.n();
    let workers = pool.num_threads().max(1);
    let mut adj_prefix = Vec::with_capacity(n + 1);
    adj_prefix.push(0usize);
    for v in 0..n {
        adj_prefix.push(adj_prefix[v] + g.degree(v as Vertex));
    }
    let ranges = balanced_ranges(&adj_prefix, workers);

    // SAFETY: `g` and the per-phase result mutexes outlive the
    // `pool.scope` calls below, which join every spawned task before
    // returning.
    #[allow(unsafe_code)]
    let share = unsafe { ScopeShare::new() };
    let g_p = share.share(g);

    // pass 1: forward degrees per range
    let counts: Mutex<Vec<(usize, Vec<usize>)>> = Mutex::new(Vec::with_capacity(ranges.len()));
    {
        let out = share.share(&counts);
        pool.scope(|s| {
            for (idx, &(lo, hi)) in ranges.iter().enumerate() {
                let (g_p, out) = (g_p, out);
                s.spawn(move |_| {
                    let g = g_p.get();
                    let fdegs: Vec<usize> = (lo..hi)
                        .map(|u| {
                            let u = u as Vertex;
                            g.neighbors(u)
                                .iter()
                                .filter(|&&v| rank(g, u) < rank(g, v))
                                .count()
                        })
                        .collect();
                    plock(out.get()).push((idx, fdegs));
                });
            }
        });
    }
    let mut count_shards = std::mem::take(&mut *plock(&counts));
    count_shards.sort_unstable_by_key(|(idx, _)| *idx);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    for (_, fdegs) in &count_shards {
        for &d in fdegs {
            offsets.push(offsets.last().unwrap() + d);
        }
    }

    // pass 2: fill per range into owned buffers, concatenated in order
    let fills: Mutex<Vec<(usize, Vec<Vertex>)>> = Mutex::new(Vec::with_capacity(ranges.len()));
    {
        let out = share.share(&fills);
        pool.scope(|s| {
            for (idx, &(lo, hi)) in ranges.iter().enumerate() {
                let (g_p, out) = (g_p, out);
                s.spawn(move |_| {
                    let g = g_p.get();
                    let mut targets = Vec::new();
                    for u in lo..hi {
                        let u = u as Vertex;
                        for &v in g.neighbors(u) {
                            if rank(g, u) < rank(g, v) {
                                targets.push(v);
                            }
                        }
                    }
                    plock(out.get()).push((idx, targets));
                });
            }
        });
    }
    let mut fill_shards = std::mem::take(&mut *plock(&fills));
    fill_shards.sort_unstable_by_key(|(idx, _)| *idx);
    let mut targets = Vec::with_capacity(offsets[n]);
    for (_, mut t) in fill_shards {
        targets.append(&mut t);
    }
    ForwardArena { offsets, targets }
}

/// Count triangles for the vertices `lo..hi`, crediting all three
/// corners — the shared inner loop of both paths.
fn count_range(arena: &ForwardArena, lo: usize, hi: usize, counts: &mut [u64]) {
    let mut buf = Vec::new();
    for u in lo..hi {
        let fu = arena.fwd(u as Vertex);
        for &v in fu {
            // Triangles with rank(u) < rank(v) < rank(w): w must lie in
            // fwd(u) ∩ fwd(v).  (fwd lists are sorted by id; rank order
            // and id order differ, so we intersect the *whole* fu — each
            // triangle is still counted exactly once because v is the
            // unique middle-ranked member.)
            vset::intersect_into(fu, arena.fwd(v), &mut buf);
            for &w in &buf {
                counts[u] += 1;
                counts[v as usize] += 1;
                counts[w as usize] += 1;
            }
        }
    }
}

/// Per-vertex triangle counts.
pub fn per_vertex(g: &CsrGraph) -> Vec<u64> {
    let n = g.n();
    let arena = forward_arena(g);
    let mut counts = vec![0u64; n];
    count_range(&arena, 0, n, &mut counts);
    counts
}

/// [`per_vertex`] striped across `pool`: vertices are split into
/// forward-mass-balanced ranges, each worker counts into an owned
/// full-size u64 buffer, and the buffers merge by addition at the join —
/// exact integer sums, so the result equals the sequential oracle for
/// every thread count and interleaving.
pub fn per_vertex_parallel(g: &CsrGraph, pool: &ThreadPool) -> Vec<u64> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let arena = forward_arena_parallel(g, pool);
    let workers = pool.num_threads().max(1);
    let ranges = balanced_ranges(&arena.offsets, workers);

    let partials: Mutex<Vec<Vec<u64>>> = Mutex::new(Vec::with_capacity(ranges.len()));
    // SAFETY: `arena` and `partials` outlive the `pool.scope` call
    // below, which joins every spawned task before returning.
    #[allow(unsafe_code)]
    let share = unsafe { ScopeShare::new() };
    let arena_p = share.share(&arena);
    let out = share.share(&partials);
    pool.scope(|s| {
        for &(lo, hi) in &ranges {
            let (arena_p, out) = (arena_p, out);
            s.spawn(move |_| {
                let mut counts = vec![0u64; arena_p.get().offsets.len() - 1];
                count_range(arena_p.get(), lo, hi, &mut counts);
                plock(out.get()).push(counts);
            });
        }
    });
    let mut counts = vec![0u64; n];
    for partial in std::mem::take(&mut *plock(&partials)) {
        for (c, p) in counts.iter_mut().zip(partial) {
            *c += p;
        }
    }
    counts
}

/// Total number of triangles.
pub fn total(g: &CsrGraph) -> u64 {
    per_vertex(g).iter().sum::<u64>() / 3
}

/// Naive O(n·d²) reference used only in tests.
#[cfg(test)]
pub fn per_vertex_naive(g: &CsrGraph) -> Vec<u64> {
    let mut counts = vec![0u64; g.n()];
    for v in g.vertices() {
        let nbrs = g.neighbors(v);
        let mut c = 0u64;
        for (i, &a) in nbrs.iter().enumerate() {
            for &b in &nbrs[i + 1..] {
                if g.has_edge(a, b) {
                    c += 1;
                }
            }
        }
        counts[v as usize] = c;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;

    #[test]
    fn triangle_graph() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(per_vertex(&g), vec![1, 1, 1, 0]);
        assert_eq!(total(&g), 1);
    }

    #[test]
    fn complete_graph_counts() {
        let n = 8;
        let g = generators::complete(n);
        let expect = ((n - 1) * (n - 2) / 2) as u64;
        assert!(per_vertex(&g).iter().all(|&c| c == expect));
        assert_eq!(total(&g), (n * (n - 1) * (n - 2) / 6) as u64);
    }

    #[test]
    fn triangle_free_graph() {
        // star graphs and even cycles are triangle-free
        let star = CsrGraph::from_edges(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        assert_eq!(total(&star), 0);
        let c6 = CsrGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]);
        assert_eq!(total(&c6), 0);
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        prop::forall(
            prop::Config { seed: 77, iters: 30 },
            |rng, level| {
                let n = 10 + rng.gen_usize(60 >> level);
                let p = 0.05 + 0.4 * rng.gen_f64();
                generators::gnp(n, p, rng.next_u64())
            },
            |g| {
                let fast = per_vertex(g);
                let naive = per_vertex_naive(g);
                if fast == naive {
                    Ok(())
                } else {
                    Err(format!("mismatch: fast={fast:?} naive={naive:?}"))
                }
            },
        );
    }

    #[test]
    fn parallel_matches_sequential() {
        let cases = vec![
            generators::complete(8),
            generators::gnp(150, 0.08, 23),
            generators::moon_moser(4),
            CsrGraph::from_edges(3, &[]), // no edges, no triangles
        ];
        for g in &cases {
            let seq = per_vertex(g);
            for threads in [1, 2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let par = per_vertex_parallel(g, &pool);
                assert_eq!(par, seq, "threads={threads}");
            }
        }
    }

    #[test]
    fn moon_moser_triangles() {
        // every vertex: pick 2 of the other k-1 parts (3 choices each)
        let k = 4;
        let g = generators::moon_moser(k);
        let expect = (9 * (k - 1) * (k - 2) / 2) as u64;
        assert!(per_vertex(&g).iter().all(|&c| c == expect));
    }
}
