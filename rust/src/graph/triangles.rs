//! Per-vertex triangle counting on the CPU (forward / compact-forward
//! algorithm, O(m^{3/2})).
//!
//! This is the sequential routine the paper uses to build the ParMCETri
//! ranking (§6.2: "We compute the degeneracy number and triangle count for
//! each vertex using sequential procedures").  It doubles as the oracle for
//! the PJRT-offloaded kernel path (`runtime::tri_rank`), which must agree
//! exactly.

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::util::vset;

/// Per-vertex triangle counts.
pub fn per_vertex(g: &CsrGraph) -> Vec<u64> {
    let n = g.n();
    let mut counts = vec![0u64; n];
    // degree-based total order: (degree, id) — orient edges low→high
    let rank = |v: Vertex| (g.degree(v), v);
    // forward adjacency: out-neighbours with higher rank, sorted by id
    let mut fwd: Vec<Vec<Vertex>> = vec![Vec::new(); n];
    for u in g.vertices() {
        for &v in g.neighbors(u) {
            if rank(u) < rank(v) {
                fwd[u as usize].push(v);
            }
        }
    }
    let mut buf = Vec::new();
    for u in g.vertices() {
        let fu = &fwd[u as usize];
        for &v in fu.iter() {
            // Triangles with rank(u) < rank(v) < rank(w): w must lie in
            // fwd(u) ∩ fwd(v).  (fwd lists are sorted by id; rank order
            // and id order differ, so we intersect the *whole* fu — each
            // triangle is still counted exactly once because v is the
            // unique middle-ranked member.)
            vset::intersect_into(fu, &fwd[v as usize], &mut buf);
            for &w in &buf {
                counts[u as usize] += 1;
                counts[v as usize] += 1;
                counts[w as usize] += 1;
            }
        }
    }
    counts
}

/// Total number of triangles.
pub fn total(g: &CsrGraph) -> u64 {
    per_vertex(g).iter().sum::<u64>() / 3
}

/// Naive O(n·d²) reference used only in tests.
#[cfg(test)]
pub fn per_vertex_naive(g: &CsrGraph) -> Vec<u64> {
    let mut counts = vec![0u64; g.n()];
    for v in g.vertices() {
        let nbrs = g.neighbors(v);
        let mut c = 0u64;
        for (i, &a) in nbrs.iter().enumerate() {
            for &b in &nbrs[i + 1..] {
                if g.has_edge(a, b) {
                    c += 1;
                }
            }
        }
        counts[v as usize] = c;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;

    #[test]
    fn triangle_graph() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(per_vertex(&g), vec![1, 1, 1, 0]);
        assert_eq!(total(&g), 1);
    }

    #[test]
    fn complete_graph_counts() {
        let n = 8;
        let g = generators::complete(n);
        let expect = ((n - 1) * (n - 2) / 2) as u64;
        assert!(per_vertex(&g).iter().all(|&c| c == expect));
        assert_eq!(total(&g), (n * (n - 1) * (n - 2) / 6) as u64);
    }

    #[test]
    fn triangle_free_graph() {
        // star graphs and even cycles are triangle-free
        let star = CsrGraph::from_edges(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        assert_eq!(total(&star), 0);
        let c6 = CsrGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]);
        assert_eq!(total(&c6), 0);
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        prop::forall(
            prop::Config { seed: 77, iters: 30 },
            |rng, level| {
                let n = 10 + rng.gen_usize(60 >> level);
                let p = 0.05 + 0.4 * rng.gen_f64();
                generators::gnp(n, p, rng.next_u64())
            },
            |g| {
                let fast = per_vertex(g);
                let naive = per_vertex_naive(g);
                if fast == naive {
                    Ok(())
                } else {
                    Err(format!("mismatch: fast={fast:?} naive={naive:?}"))
                }
            },
        );
    }

    #[test]
    fn moon_moser_triangles() {
        // every vertex: pick 2 of the other k-1 parts (3 choices each)
        let k = 4;
        let g = generators::moon_moser(k);
        let expect = (9 * (k - 1) * (k - 2) / 2) as u64;
        assert!(per_vertex(&g).iter().all(|&c| c == expect));
    }
}
