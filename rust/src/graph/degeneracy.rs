//! k-core decomposition / degeneracy ordering (Matula–Beck peeling).
//!
//! Used for (a) the degeneracy-based vertex ranking of ParMCE (§4.2) and
//! (b) the BKDegeneracy baseline of Eppstein–Löffler–Strash (Table 10).
//! O(n + m) bucket peeling.

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;

#[derive(Clone, Debug)]
pub struct CoreDecomposition {
    /// core number (degeneracy number, paper §4.2) per vertex
    pub core: Vec<u32>,
    /// peeling order: position i holds the i-th vertex removed
    pub order: Vec<Vertex>,
    /// position of each vertex in `order` (inverse permutation)
    pub pos: Vec<u32>,
    /// the graph degeneracy = max core number
    pub degeneracy: u32,
}

/// Compute the core decomposition by bucket peeling.
pub fn core_decomposition(g: &CsrGraph) -> CoreDecomposition {
    let n = g.n();
    let mut deg: Vec<u32> = (0..n).map(|v| g.degree(v as Vertex) as u32).collect();
    let max_deg = deg.iter().copied().max().unwrap_or(0) as usize;

    // bucket sort vertices by current degree
    let mut bin = vec![0u32; max_deg + 2];
    for &d in &deg {
        bin[d as usize] += 1;
    }
    let mut start = 0u32;
    for b in bin.iter_mut() {
        let count = *b;
        *b = start;
        start += count;
    }
    let mut vert = vec![0 as Vertex; n];
    let mut pos = vec![0u32; n];
    {
        let mut cursor = bin.clone();
        for v in 0..n {
            let d = deg[v] as usize;
            pos[v] = cursor[d];
            vert[cursor[d] as usize] = v as Vertex;
            cursor[d] += 1;
        }
    }

    let mut core = vec![0u32; n];
    let mut degeneracy = 0u32;
    for i in 0..n {
        let v = vert[i];
        let dv = deg[v as usize];
        degeneracy = degeneracy.max(dv);
        core[v as usize] = degeneracy;
        // lower the degree of unpeeled neighbours
        for &u in g.neighbors(v) {
            let du = deg[u as usize];
            if du > dv && (pos[u as usize] as usize) > i {
                // swap u to the front of its bucket, then shrink its degree
                let pu = pos[u as usize];
                let pw = bin[du as usize];
                let w = vert[pw as usize];
                if u != w {
                    vert[pu as usize] = w;
                    vert[pw as usize] = u;
                    pos[u as usize] = pw;
                    pos[w as usize] = pu;
                }
                bin[du as usize] += 1;
                deg[u as usize] -= 1;
            }
        }
    }

    CoreDecomposition {
        core,
        pos: {
            let mut p = vec![0u32; n];
            for (i, &v) in vert.iter().enumerate() {
                p[v as usize] = i as u32;
            }
            p
        },
        order: vert,
        degeneracy,
    }
}

/// Vertices of the maximal k-core (possibly empty).
pub fn k_core_vertices(decomp: &CoreDecomposition, k: u32) -> Vec<Vertex> {
    decomp
        .core
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= k)
        .map(|(v, _)| v as Vertex)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn complete_graph_core() {
        let g = generators::complete(6);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 5);
        assert!(d.core.iter().all(|&c| c == 5));
    }

    #[test]
    fn path_graph_core_is_one() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 1);
        assert!(d.core.iter().all(|&c| c == 1));
    }

    #[test]
    fn triangle_with_tail() {
        // triangle 0-1-2 (core 2), tail 2-3-4 (core 1)
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 2);
        assert_eq!(&d.core[0..3], &[2, 2, 2]);
        assert_eq!(&d.core[3..5], &[1, 1]);
    }

    #[test]
    fn order_is_permutation_with_correct_pos() {
        let g = generators::gnp(120, 0.08, 4);
        let d = core_decomposition(&g);
        let mut sorted = d.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..120).collect::<Vec<_>>());
        for (i, &v) in d.order.iter().enumerate() {
            assert_eq!(d.pos[v as usize] as usize, i);
        }
    }

    #[test]
    fn degeneracy_order_invariant() {
        // In the peeling order, each vertex has ≤ degeneracy neighbours later
        // in the order — the invariant BKDegeneracy relies on.
        let g = generators::gnp(150, 0.06, 99);
        let d = core_decomposition(&g);
        for (i, &v) in d.order.iter().enumerate() {
            let later = g
                .neighbors(v)
                .iter()
                .filter(|&&u| (d.pos[u as usize] as usize) > i)
                .count();
            assert!(
                later <= d.degeneracy as usize,
                "vertex {v} has {later} later neighbours > degeneracy {}",
                d.degeneracy
            );
        }
    }

    #[test]
    fn k_core_extraction() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(k_core_vertices(&d, 2), vec![0, 1, 2]);
        assert_eq!(k_core_vertices(&d, 1).len(), 5);
        assert!(k_core_vertices(&d, 3).is_empty());
    }

    #[test]
    fn moon_moser_core() {
        let g = generators::moon_moser(4); // 12 vertices, each degree 9
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 9);
    }
}
