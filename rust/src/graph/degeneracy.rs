//! k-core decomposition / degeneracy ordering (Matula–Beck peeling).
//!
//! Used for (a) the degeneracy-based vertex ranking of ParMCE (§4.2) and
//! (b) the BKDegeneracy baseline of Eppstein–Löffler–Strash (Table 10).
//! O(n + m) bucket peeling.
//!
//! Two entry points: [`core_decomposition`] (sequential bucket peeling)
//! and [`core_decomposition_parallel`] (frontier-based level peeling à la
//! ParK, run on the ingest pool).  Both assign the **identical** `core`
//! array and degeneracy — the parallel path peels whole k-shells level by
//! level, which is the same fixpoint the sequential running-max peel
//! computes — and both produce a *valid* degeneracy order (≤ degeneracy
//! later neighbours per vertex), though the two orders generally differ:
//! bucket peeling breaks min-degree ties one vertex at a time, level
//! peeling retires an entire frontier per sub-round (ascending vertex id,
//! so the parallel order is deterministic for every thread count).

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::util::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use crate::util::sync::{plock, Mutex, ScopeShare};

/// Result of peeling a graph to its cores: per-vertex core numbers plus
/// a degeneracy order and its inverse permutation.
#[derive(Clone, Debug)]
pub struct CoreDecomposition {
    /// core number (degeneracy number, paper §4.2) per vertex
    pub core: Vec<u32>,
    /// peeling order: position i holds the i-th vertex removed
    pub order: Vec<Vertex>,
    /// position of each vertex in `order` (inverse permutation)
    pub pos: Vec<u32>,
    /// the graph degeneracy = max core number
    pub degeneracy: u32,
}

/// Compute the core decomposition by bucket peeling.
pub fn core_decomposition(g: &CsrGraph) -> CoreDecomposition {
    let n = g.n();
    let mut deg: Vec<u32> = (0..n).map(|v| g.degree(v as Vertex) as u32).collect();
    let max_deg = deg.iter().copied().max().unwrap_or(0) as usize;

    // bucket sort vertices by current degree
    let mut bin = vec![0u32; max_deg + 2];
    for &d in &deg {
        bin[d as usize] += 1;
    }
    let mut start = 0u32;
    for b in bin.iter_mut() {
        let count = *b;
        *b = start;
        start += count;
    }
    let mut vert = vec![0 as Vertex; n];
    let mut pos = vec![0u32; n];
    {
        let mut cursor = bin.clone();
        for v in 0..n {
            let d = deg[v] as usize;
            pos[v] = cursor[d];
            vert[cursor[d] as usize] = v as Vertex;
            cursor[d] += 1;
        }
    }

    let mut core = vec![0u32; n];
    let mut degeneracy = 0u32;
    for i in 0..n {
        let v = vert[i];
        let dv = deg[v as usize];
        degeneracy = degeneracy.max(dv);
        core[v as usize] = degeneracy;
        // lower the degree of unpeeled neighbours
        for &u in g.neighbors(v) {
            let du = deg[u as usize];
            if du > dv && (pos[u as usize] as usize) > i {
                // swap u to the front of its bucket, then shrink its degree
                let pu = pos[u as usize];
                let pw = bin[du as usize];
                let w = vert[pw as usize];
                if u != w {
                    vert[pu as usize] = w;
                    vert[pw as usize] = u;
                    pos[u as usize] = pw;
                    pos[w as usize] = pu;
                }
                bin[du as usize] += 1;
                deg[u as usize] -= 1;
            }
        }
    }

    CoreDecomposition {
        core,
        pos: {
            let mut p = vec![0u32; n];
            for (i, &v) in vert.iter().enumerate() {
                p[v as usize] = i as u32;
            }
            p
        },
        order: vert,
        degeneracy,
    }
}

/// Below this vertex count [`core_decomposition_parallel`] falls back to
/// the sequential bucket peel: per-level scan overhead only pays off
/// once the graph is large enough to amortize the scope joins.
pub const PAR_PEEL_CUTOFF: usize = 1 << 13;

/// [`core_decomposition`] computed by frontier-based parallel level
/// peeling (the ParK scheme) on `pool`, with the default
/// [`PAR_PEEL_CUTOFF`] fallback.
///
/// The `core` array and `degeneracy` are identical to the sequential
/// result; the `order` is a valid degeneracy order (every vertex has at
/// most `degeneracy` later neighbours) and is deterministic across
/// thread counts, but differs from the sequential tie-breaking — callers
/// that need *the* Matula–Beck order must use [`core_decomposition`].
pub fn core_decomposition_parallel(g: &CsrGraph, pool: &ThreadPool) -> CoreDecomposition {
    core_decomposition_parallel_with_cutoff(g, pool, PAR_PEEL_CUTOFF)
}

/// [`core_decomposition_parallel`] with an explicit sequential-fallback
/// cutoff (tests pass 0 to force the parallel path on small graphs).
pub fn core_decomposition_parallel_with_cutoff(
    g: &CsrGraph,
    pool: &ThreadPool,
    cutoff: usize,
) -> CoreDecomposition {
    let n = g.n();
    if n == 0 || n < cutoff || pool.num_threads() <= 1 {
        return core_decomposition(g);
    }
    let workers = pool.num_threads();

    // Peel state shared with the workers.  Phase boundaries are scope
    // joins, so plain Relaxed atomics suffice: `deg[v]` always equals
    // the number of unpeeled neighbours of an unpeeled `v` at every
    // join, and fetch_sub's RMW atomicity hands exactly one worker the
    // `k+1 -> k` crossing of each vertex per level.
    let deg: Vec<AtomicU32> = (0..n)
        .map(|v| AtomicU32::new(g.degree(v as Vertex) as u32))
        .collect();
    let peeled: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let mut core = vec![0u32; n];
    let mut order: Vec<Vertex> = Vec::with_capacity(n);

    let vchunk = n.div_ceil(workers).max(1);
    let scan_ranges: Vec<(usize, usize)> = (0..n)
        .step_by(vchunk)
        .map(|lo| (lo, (lo + vchunk).min(n)))
        .collect();

    // SAFETY: every reference shared below (`g`, `deg`, `peeled`, the
    // frontier slices and per-phase result mutexes) outlives the
    // `pool.scope` call that observes it; each scope joins all spawned
    // tasks before returning, so no task holds a ScopedPtr past the
    // borrow's life.
    #[allow(unsafe_code)]
    let share = unsafe { ScopeShare::new() };
    let g_p = share.share(g);
    let deg_p = share.share(deg.as_slice());
    let peeled_p = share.share(peeled.as_slice());

    let mut remaining = n;
    let mut degeneracy = 0u32;
    while remaining > 0 {
        // Level jump: parallel min-scan over unpeeled vertices; each
        // range also collects its min-degree vertices so the seed
        // frontier falls out of the same pass (ranges are concatenated
        // in ascending order, so the frontier is sorted by id).
        let scan: Mutex<Vec<(usize, u32, Vec<Vertex>)>> =
            Mutex::new(Vec::with_capacity(scan_ranges.len()));
        {
            let out = share.share(&scan);
            pool.scope(|s| {
                for (idx, &(lo, hi)) in scan_ranges.iter().enumerate() {
                    let (deg_p, peeled_p, out) = (deg_p, peeled_p, out);
                    s.spawn(move |_| {
                        let (deg, peeled) = (deg_p.get(), peeled_p.get());
                        let mut min = u32::MAX;
                        let mut seed = Vec::new();
                        for v in lo..hi {
                            if peeled[v].load(Ordering::Relaxed) {
                                continue;
                            }
                            let d = deg[v].load(Ordering::Relaxed);
                            if d < min {
                                min = d;
                                seed.clear();
                            }
                            if d == min {
                                seed.push(v as Vertex);
                            }
                        }
                        plock(out.get()).push((idx, min, seed));
                    });
                }
            });
        }
        let mut shards = std::mem::take(&mut *plock(&scan));
        shards.sort_unstable_by_key(|(idx, _, _)| *idx);
        let k = shards.iter().map(|&(_, m, _)| m).min().unwrap_or(u32::MAX);
        debug_assert_ne!(k, u32::MAX, "unpeeled vertices must remain");
        let mut frontier: Vec<Vertex> = Vec::new();
        for (_, m, seed) in shards {
            if m == k {
                frontier.extend(seed);
            }
        }
        degeneracy = degeneracy.max(k);

        // Sub-rounds: retire the frontier, then decrement its unpeeled
        // neighbours in parallel.  A neighbour is collected for the next
        // sub-round exactly when its degree crosses k+1 -> k: decrements
        // are unit steps, so the counter passes through every value and
        // the unique fetch_sub return of k+1 fires once per vertex.
        while !frontier.is_empty() {
            for &v in &frontier {
                core[v as usize] = k;
                peeled[v as usize].store(true, Ordering::Relaxed);
            }
            remaining -= frontier.len();
            order.extend_from_slice(&frontier);

            let next: Mutex<Vec<(usize, Vec<Vertex>)>> = Mutex::new(Vec::new());
            let fchunk = frontier.len().div_ceil(workers).max(1);
            {
                let f_p = share.share(frontier.as_slice());
                let out = share.share(&next);
                pool.scope(|s| {
                    for (idx, lo) in (0..frontier.len()).step_by(fchunk).enumerate() {
                        let (g_p, deg_p, peeled_p, f_p, out) =
                            (g_p, deg_p, peeled_p, f_p, out);
                        s.spawn(move |_| {
                            let f = f_p.get();
                            let (deg, peeled) = (deg_p.get(), peeled_p.get());
                            let hi = (lo + fchunk).min(f.len());
                            let mut found = Vec::new();
                            for &v in &f[lo..hi] {
                                for &u in g_p.get().neighbors(v) {
                                    if peeled[u as usize].load(Ordering::Relaxed) {
                                        continue;
                                    }
                                    let prev =
                                        deg[u as usize].fetch_sub(1, Ordering::Relaxed);
                                    if prev == k + 1 {
                                        found.push(u);
                                    }
                                }
                            }
                            plock(out.get()).push((idx, found));
                        });
                    }
                });
            }
            let mut shards = std::mem::take(&mut *plock(&next));
            shards.sort_unstable_by_key(|(idx, _)| *idx);
            let mut nf: Vec<Vertex> = shards.into_iter().flat_map(|(_, f)| f).collect();
            // the crossing *set* is determined by the frontier alone, so
            // sorting makes the order thread-count-independent
            nf.sort_unstable();
            frontier = nf;
        }
    }

    let mut pos = vec![0u32; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v as usize] = i as u32;
    }
    CoreDecomposition {
        core,
        order,
        pos,
        degeneracy,
    }
}

/// Vertices of the maximal k-core (possibly empty).
pub fn k_core_vertices(decomp: &CoreDecomposition, k: u32) -> Vec<Vertex> {
    decomp
        .core
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= k)
        .map(|(v, _)| v as Vertex)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn complete_graph_core() {
        let g = generators::complete(6);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 5);
        assert!(d.core.iter().all(|&c| c == 5));
    }

    #[test]
    fn path_graph_core_is_one() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 1);
        assert!(d.core.iter().all(|&c| c == 1));
    }

    #[test]
    fn triangle_with_tail() {
        // triangle 0-1-2 (core 2), tail 2-3-4 (core 1)
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 2);
        assert_eq!(&d.core[0..3], &[2, 2, 2]);
        assert_eq!(&d.core[3..5], &[1, 1]);
    }

    #[test]
    fn order_is_permutation_with_correct_pos() {
        let g = generators::gnp(120, 0.08, 4);
        let d = core_decomposition(&g);
        let mut sorted = d.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..120).collect::<Vec<_>>());
        for (i, &v) in d.order.iter().enumerate() {
            assert_eq!(d.pos[v as usize] as usize, i);
        }
    }

    #[test]
    fn degeneracy_order_invariant() {
        // In the peeling order, each vertex has ≤ degeneracy neighbours later
        // in the order — the invariant BKDegeneracy relies on.
        let g = generators::gnp(150, 0.06, 99);
        let d = core_decomposition(&g);
        for (i, &v) in d.order.iter().enumerate() {
            let later = g
                .neighbors(v)
                .iter()
                .filter(|&&u| (d.pos[u as usize] as usize) > i)
                .count();
            assert!(
                later <= d.degeneracy as usize,
                "vertex {v} has {later} later neighbours > degeneracy {}",
                d.degeneracy
            );
        }
    }

    #[test]
    fn k_core_extraction() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4)]);
        let d = core_decomposition(&g);
        assert_eq!(k_core_vertices(&d, 2), vec![0, 1, 2]);
        assert_eq!(k_core_vertices(&d, 1).len(), 5);
        assert!(k_core_vertices(&d, 3).is_empty());
    }

    #[test]
    fn moon_moser_core() {
        let g = generators::moon_moser(4); // 12 vertices, each degree 9
        let d = core_decomposition(&g);
        assert_eq!(d.degeneracy, 9);
    }

    #[test]
    fn parallel_core_matches_sequential() {
        let cases = vec![
            generators::complete(6),
            CsrGraph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4)]),
            generators::gnp(200, 0.05, 12),
            generators::moon_moser(4),
            CsrGraph::from_edges(4, &[]), // isolated vertices: core 0
        ];
        for g in &cases {
            let seq = core_decomposition(g);
            for threads in [2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let par = core_decomposition_parallel_with_cutoff(g, &pool, 0);
                assert_eq!(par.core, seq.core, "threads={threads}");
                assert_eq!(par.degeneracy, seq.degeneracy, "threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_order_is_valid_and_thread_count_independent() {
        let g = generators::gnp(180, 0.07, 31);
        let base = {
            let pool = ThreadPool::new(2);
            core_decomposition_parallel_with_cutoff(&g, &pool, 0)
        };
        // validity: ≤ degeneracy later neighbours per vertex
        for (i, &v) in base.order.iter().enumerate() {
            let later = g
                .neighbors(v)
                .iter()
                .filter(|&&u| (base.pos[u as usize] as usize) > i)
                .count();
            assert!(later <= base.degeneracy as usize);
        }
        // permutation + inverse
        let mut sorted = base.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..180).collect::<Vec<_>>());
        for (i, &v) in base.order.iter().enumerate() {
            assert_eq!(base.pos[v as usize] as usize, i);
        }
        // the parallel order is deterministic across thread counts
        for threads in [4, 8] {
            let pool = ThreadPool::new(threads);
            let d = core_decomposition_parallel_with_cutoff(&g, &pool, 0);
            assert_eq!(d.order, base.order, "threads={threads}");
            assert_eq!(d.pos, base.pos);
        }
    }

    #[test]
    fn parallel_cutoff_falls_back_to_sequential() {
        let g = generators::gnp(50, 0.1, 5);
        let pool = ThreadPool::new(4);
        let seq = core_decomposition(&g);
        // below the cutoff the sequential order comes back verbatim
        let par = core_decomposition_parallel_with_cutoff(&g, &pool, usize::MAX);
        assert_eq!(par.order, seq.order);
        assert_eq!(par.core, seq.core);
    }
}
