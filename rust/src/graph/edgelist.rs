//! Edge-list I/O: the paper feeds graphs "in the form of an edge list"
//! (§6.1) and replays dynamic graphs as timestamp-ordered edge streams.
//!
//! Format: one edge per line, `u v` or `u v t` (timestamp), `#`/`%`
//! comments, whitespace-separated — covering SNAP and KONECT conventions.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimedEdge {
    pub u: Vertex,
    pub v: Vertex,
    pub t: u64,
}

/// Parse an edge list from a reader. Vertices are renumbered densely in
/// first-appearance order; returns (edges, n).
pub fn parse(reader: impl BufRead) -> Result<(Vec<TimedEdge>, usize)> {
    let mut ids = std::collections::HashMap::new();
    let mut edges = Vec::new();
    let mut intern = |raw: u64, ids: &mut std::collections::HashMap<u64, Vertex>| -> Vertex {
        let next = ids.len() as Vertex;
        *ids.entry(raw).or_insert(next)
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read error")?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') || trimmed.starts_with('%') {
            continue;
        }
        let mut parts = trimmed.split_whitespace();
        let (Some(a), Some(b)) = (parts.next(), parts.next()) else {
            bail!("line {}: expected at least two fields", lineno + 1);
        };
        let a: u64 = a.parse().with_context(|| format!("line {}: bad vertex", lineno + 1))?;
        let b: u64 = b.parse().with_context(|| format!("line {}: bad vertex", lineno + 1))?;
        let t: u64 = match parts.next() {
            Some(ts) => ts
                .parse()
                .with_context(|| format!("line {}: bad timestamp", lineno + 1))?,
            None => lineno as u64,
        };
        let u = intern(a, &mut ids);
        let v = intern(b, &mut ids);
        edges.push(TimedEdge { u, v, t });
    }
    Ok((edges, ids.len()))
}

/// Load a static graph from a file.
pub fn load_graph(path: impl AsRef<Path>) -> Result<CsrGraph> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let (edges, n) = parse(std::io::BufReader::new(file))?;
    let pairs: Vec<(Vertex, Vertex)> = edges.iter().map(|e| (e.u, e.v)).collect();
    Ok(CsrGraph::from_edges(n, &pairs))
}

/// Load a dynamic stream (sorted by timestamp, stable).
pub fn load_stream(path: impl AsRef<Path>) -> Result<(Vec<TimedEdge>, usize)> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let (mut edges, n) = parse(std::io::BufReader::new(file))?;
    edges.sort_by_key(|e| e.t);
    Ok((edges, n))
}

/// Write a graph as an edge list.
pub fn write_graph(g: &CsrGraph, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# parmce edge list: n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let input = "# comment\n% konect comment\n10 20\n20 30 5\n\n10 30\n";
        let (edges, n) = parse(Cursor::new(input)).unwrap();
        assert_eq!(n, 3);
        assert_eq!(edges.len(), 3);
        assert_eq!(edges[0], TimedEdge { u: 0, v: 1, t: 2 }); // lineno default
        assert_eq!(edges[1], TimedEdge { u: 1, v: 2, t: 5 });
        assert_eq!(edges[2], TimedEdge { u: 0, v: 2, t: 5 });
    }

    #[test]
    fn parse_errors() {
        assert!(parse(Cursor::new("1\n")).is_err());
        assert!(parse(Cursor::new("a b\n")).is_err());
        assert!(parse(Cursor::new("1 2 x\n")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = crate::graph::generators::gnp(40, 0.2, 3);
        let dir = std::env::temp_dir().join("parmce_edgelist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g2.m(), g.m());
        // renumbering is identity here because vertices appear in order
        assert_eq!(g2.edges().len(), g.edges().len());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stream_sorted_by_timestamp() {
        let input = "0 1 9\n1 2 3\n2 3 7\n";
        let dir = std::env::temp_dir().join("parmce_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        std::fs::write(&path, input).unwrap();
        let (edges, n) = load_stream(&path).unwrap();
        assert_eq!(n, 4);
        let ts: Vec<u64> = edges.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![3, 7, 9]);
        let _ = std::fs::remove_dir_all(dir);
    }
}
