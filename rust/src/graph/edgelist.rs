//! Edge-list I/O: the paper feeds graphs "in the form of an edge list"
//! (§6.1) and replays dynamic graphs as timestamp-ordered edge streams.
//!
//! Format: one edge per line, `u v` or `u v t` (timestamp), `#`/`%`
//! comments, whitespace-separated — covering SNAP and KONECT conventions.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimedEdge {
    pub u: Vertex,
    pub v: Vertex,
    pub t: u64,
}

/// Everything [`parse_report`] extracted from an edge list.
#[derive(Clone, Debug)]
pub struct ParseReport {
    pub edges: Vec<TimedEdge>,
    /// Dense vertex count (every id that appeared, including self-loop
    /// endpoints).
    pub n: usize,
    /// Self-loop lines (`u u`) skipped during parsing. The static loader
    /// (`CsrGraph::from_edges`) drops self-loops anyway; skipping them
    /// here keeps dynamic streams consistent with static loads.
    pub self_loops: u64,
}

/// Parse an edge list from a reader. Vertices are renumbered densely in
/// first-appearance order.
///
/// Lines without a timestamp get a synthetic one from a monotone *edge*
/// counter — not the raw file line number, which would leave gaps at
/// comment/blank lines and interleave wrongly with real timestamps under
/// [`load_stream`]'s stable sort.  Self-loop edges are skipped (counted
/// in [`ParseReport::self_loops`]); their endpoints still count toward
/// `n`, matching what the static path's `CsrGraph::from_edges` does.
pub fn parse_report(reader: impl BufRead) -> Result<ParseReport> {
    let mut ids = std::collections::HashMap::new();
    let mut edges: Vec<TimedEdge> = Vec::new();
    let mut self_loops = 0u64;
    let mut intern = |raw: u64, ids: &mut std::collections::HashMap<u64, Vertex>| -> Vertex {
        let next = ids.len() as Vertex;
        *ids.entry(raw).or_insert(next)
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read error")?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') || trimmed.starts_with('%') {
            continue;
        }
        let mut parts = trimmed.split_whitespace();
        let (Some(a), Some(b)) = (parts.next(), parts.next()) else {
            bail!("line {}: expected at least two fields", lineno + 1);
        };
        let a: u64 = a.parse().with_context(|| format!("line {}: bad vertex", lineno + 1))?;
        let b: u64 = b.parse().with_context(|| format!("line {}: bad vertex", lineno + 1))?;
        let t: u64 = match parts.next() {
            Some(ts) => ts
                .parse()
                .with_context(|| format!("line {}: bad timestamp", lineno + 1))?,
            // synthetic timestamp: the number of edges accepted so far
            None => edges.len() as u64,
        };
        let u = intern(a, &mut ids);
        let v = intern(b, &mut ids);
        if u == v {
            self_loops += 1;
            continue;
        }
        edges.push(TimedEdge { u, v, t });
    }
    Ok(ParseReport {
        edges,
        n: ids.len(),
        self_loops,
    })
}

/// Parse an edge list from a reader; returns (edges, n). Thin wrapper
/// over [`parse_report`] for callers that don't need the skip counts.
pub fn parse(reader: impl BufRead) -> Result<(Vec<TimedEdge>, usize)> {
    let r = parse_report(reader)?;
    Ok((r.edges, r.n))
}

fn warn_self_loops(r: &ParseReport, path: &Path) {
    if r.self_loops > 0 {
        eprintln!(
            "warn: {:?}: skipped {} self-loop edge(s)",
            path, r.self_loops
        );
    }
}

/// Load a static graph from a file.
pub fn load_graph(path: impl AsRef<Path>) -> Result<CsrGraph> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let r = parse_report(std::io::BufReader::new(file))?;
    warn_self_loops(&r, path.as_ref());
    let pairs: Vec<(Vertex, Vertex)> = r.edges.iter().map(|e| (e.u, e.v)).collect();
    Ok(CsrGraph::from_edges(r.n, &pairs))
}

/// Load a dynamic stream (sorted by timestamp, stable).
pub fn load_stream(path: impl AsRef<Path>) -> Result<(Vec<TimedEdge>, usize)> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let r = parse_report(std::io::BufReader::new(file))?;
    warn_self_loops(&r, path.as_ref());
    let mut edges = r.edges;
    edges.sort_by_key(|e| e.t);
    Ok((edges, r.n))
}

/// Write a graph as an edge list.
pub fn write_graph(g: &CsrGraph, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# parmce edge list: n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let input = "# comment\n% konect comment\n10 20\n20 30 5\n\n10 30\n";
        let (edges, n) = parse(Cursor::new(input)).unwrap();
        assert_eq!(n, 3);
        assert_eq!(edges.len(), 3);
        // synthetic timestamps count accepted edges, not file lines
        assert_eq!(edges[0], TimedEdge { u: 0, v: 1, t: 0 });
        assert_eq!(edges[1], TimedEdge { u: 1, v: 2, t: 5 });
        assert_eq!(edges[2], TimedEdge { u: 0, v: 2, t: 2 });
    }

    #[test]
    fn synthetic_timestamps_ignore_comment_and_blank_lines() {
        // regression: the old lineno-based default left gaps at comments
        // and blank lines, so later untimed edges jumped *past* real
        // timestamps under load_stream's stable sort
        let input = "0 1\n# gap\n\n% gap\n1 2\n# gap\n2 3\n";
        let (edges, _) = parse(Cursor::new(input)).unwrap();
        let ts: Vec<u64> = edges.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![0, 1, 2], "monotone, gap-free edge counter");
    }

    #[test]
    fn synthetic_timestamps_stay_stable_under_stream_sort() {
        // three untimed edges after a commented preamble plus one real
        // timestamp: with lineno defaults the untimed edges would carry
        // t=4,5,6 and sort after the t=3 edge; the edge counter keeps
        // them at t=0,1,2, before it
        let input = "# header\n# header\n# header\n# header\n0 1\n1 2\n2 3\n3 4 3\n";
        let dir = std::env::temp_dir().join("parmce_synth_ts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        std::fs::write(&path, input).unwrap();
        let (edges, _) = load_stream(&path).unwrap();
        let order: Vec<(Vertex, Vertex)> = edges.iter().map(|e| (e.u, e.v)).collect();
        assert_eq!(order, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn self_loops_are_skipped_and_counted() {
        // regression: self-loops used to pass through into dynamic
        // streams even though CsrGraph::from_edges drops them for static
        // loads — DynamicSession could ingest edges the static path
        // never sees
        let input = "0 0\n0 1\n2 2 7\n1 2\n";
        let r = parse_report(Cursor::new(input)).unwrap();
        assert_eq!(r.self_loops, 2);
        assert_eq!(
            r.edges,
            vec![
                TimedEdge { u: 0, v: 1, t: 0 },
                TimedEdge { u: 1, v: 2, t: 1 },
            ]
        );
        // self-loop-only vertex 2's id still counts toward n, matching
        // the static loader's vertex universe
        assert_eq!(r.n, 3);
    }

    #[test]
    fn parse_errors() {
        assert!(parse(Cursor::new("1\n")).is_err());
        assert!(parse(Cursor::new("a b\n")).is_err());
        assert!(parse(Cursor::new("1 2 x\n")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = crate::graph::generators::gnp(40, 0.2, 3);
        let dir = std::env::temp_dir().join("parmce_edgelist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g2.m(), g.m());
        // renumbering is identity here because vertices appear in order
        assert_eq!(g2.edges().len(), g.edges().len());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stream_sorted_by_timestamp() {
        let input = "0 1 9\n1 2 3\n2 3 7\n";
        let dir = std::env::temp_dir().join("parmce_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        std::fs::write(&path, input).unwrap();
        let (edges, n) = load_stream(&path).unwrap();
        assert_eq!(n, 4);
        let ts: Vec<u64> = edges.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![3, 7, 9]);
        let _ = std::fs::remove_dir_all(dir);
    }
}
