//! Edge-list I/O: the paper feeds graphs "in the form of an edge list"
//! (§6.1) and replays dynamic graphs as timestamp-ordered edge streams.
//!
//! Format: one edge per line, `u v` or `u v t` (timestamp), `#`/`%`
//! comments, whitespace-separated — covering SNAP and KONECT conventions.
//!
//! Two parsing paths share one line grammar and one merge semantics:
//!
//! * [`parse_report`] — sequential, streaming from any [`BufRead`].
//! * [`parse_report_parallel`] — the ingest-pipeline path: the input is
//!   split into per-worker chunks at newline boundaries, each worker
//!   scans its chunk into an owned shard of raw records, and the shards
//!   are merged **in chunk order** on the caller thread.  Interning
//!   (first-appearance renumbering), synthetic timestamps (a monotone
//!   accepted-edge counter) and self-loop counting all happen in the
//!   merge, so the result is byte-identical to the sequential path for
//!   any thread count — including error reporting, where the earliest
//!   faulty line wins with the same message.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::pool::ThreadPool;
use crate::graph::csr::CsrGraph;
use crate::graph::Vertex;
use crate::telemetry;
use crate::util::sync::{plock, Mutex, ScopeShare};

/// One parsed edge with its (possibly synthetic) timestamp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimedEdge {
    /// First endpoint (densely renumbered).
    pub u: Vertex,
    /// Second endpoint (densely renumbered).
    pub v: Vertex,
    /// Timestamp: the third field when present, otherwise the number of
    /// edges accepted before this one.
    pub t: u64,
}

/// Everything [`parse_report`] extracted from an edge list.
#[derive(Clone, Debug)]
pub struct ParseReport {
    /// Accepted edges in input order (self-loops excluded).
    pub edges: Vec<TimedEdge>,
    /// Dense vertex count (every id that appeared, including self-loop
    /// endpoints).
    pub n: usize,
    /// Self-loop lines (`u u`) skipped during parsing. The static loader
    /// (`CsrGraph::from_edges`) drops self-loops anyway; skipping them
    /// here keeps dynamic streams consistent with static loads.
    pub self_loops: u64,
}

/// One accepted data line, before interning: raw ids plus the explicit
/// timestamp if the line carried one.
#[derive(Clone, Copy, Debug)]
struct LineRecord {
    a: u64,
    b: u64,
    t: Option<u64>,
}

/// Why a data line failed to parse.  Carried out of the worker shards so
/// the parallel path can rebuild the exact sequential error (message and
/// source chain) for the earliest faulty line.
#[derive(Clone, Debug)]
enum LineFault {
    MissingFields,
    BadVertex(std::num::ParseIntError),
    BadTimestamp(std::num::ParseIntError),
}

/// The shared line grammar: `Ok(None)` for blank/comment lines,
/// `Ok(Some(..))` for a data line, `Err` for a malformed one.
fn classify_line(trimmed: &str) -> std::result::Result<Option<LineRecord>, LineFault> {
    if trimmed.is_empty() || trimmed.starts_with('#') || trimmed.starts_with('%') {
        return Ok(None);
    }
    let mut parts = trimmed.split_whitespace();
    let (Some(a), Some(b)) = (parts.next(), parts.next()) else {
        return Err(LineFault::MissingFields);
    };
    let a: u64 = a.parse().map_err(LineFault::BadVertex)?;
    let b: u64 = b.parse().map_err(LineFault::BadVertex)?;
    let t: Option<u64> = match parts.next() {
        Some(ts) => Some(ts.parse().map_err(LineFault::BadTimestamp)?),
        None => None,
    };
    Ok(Some(LineRecord { a, b, t }))
}

/// A [`LineFault`] at 1-based line `lineno`, formatted exactly like the
/// sequential path's errors.
fn fault_error(fault: LineFault, lineno: usize) -> anyhow::Error {
    match fault {
        LineFault::MissingFields => anyhow!("line {lineno}: expected at least two fields"),
        LineFault::BadVertex(e) => {
            anyhow::Error::new(e).context(format!("line {lineno}: bad vertex"))
        }
        LineFault::BadTimestamp(e) => {
            anyhow::Error::new(e).context(format!("line {lineno}: bad timestamp"))
        }
    }
}

/// The merge semantics both parsing paths share: first-appearance
/// interning, the accepted-edge synthetic timestamp counter, and
/// self-loop skipping — applied to records **in input order**.
#[derive(Default)]
struct Accumulator {
    ids: std::collections::HashMap<u64, Vertex>,
    edges: Vec<TimedEdge>,
    self_loops: u64,
}

impl Accumulator {
    fn accept(&mut self, rec: LineRecord) {
        let t = match rec.t {
            Some(t) => t,
            // synthetic timestamp: the number of edges accepted so far
            None => self.edges.len() as u64,
        };
        // intern BEFORE the self-loop check: self-loop endpoints still
        // claim a dense id (their vertex exists, it just has no edge yet)
        let next = self.ids.len() as Vertex;
        let u = *self.ids.entry(rec.a).or_insert(next);
        let next = self.ids.len() as Vertex;
        let v = *self.ids.entry(rec.b).or_insert(next);
        if u == v {
            self.self_loops += 1;
            return;
        }
        self.edges.push(TimedEdge { u, v, t });
    }

    fn finish(self) -> ParseReport {
        let report = ParseReport {
            n: self.ids.len(),
            edges: self.edges,
            self_loops: self.self_loops,
        };
        let t = telemetry::global();
        t.ingest_edges_parsed.add(report.edges.len() as u64);
        t.ingest_self_loops.add(report.self_loops);
        report
    }
}

/// Parse an edge list from a reader. Vertices are renumbered densely in
/// first-appearance order.
///
/// Lines without a timestamp get a synthetic one from a monotone *edge*
/// counter — not the raw file line number, which would leave gaps at
/// comment/blank lines and interleave wrongly with real timestamps under
/// [`load_stream`]'s stable sort.  Self-loop edges are skipped (counted
/// in [`ParseReport::self_loops`]); their endpoints still count toward
/// `n`, matching what the static path's `CsrGraph::from_edges` does.
pub fn parse_report(reader: impl BufRead) -> Result<ParseReport> {
    let span = telemetry::SpanTimer::start();
    let mut acc = Accumulator::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read error")?;
        match classify_line(line.trim()) {
            Ok(None) => {}
            Ok(Some(rec)) => acc.accept(rec),
            Err(fault) => return Err(fault_error(fault, lineno + 1)),
        }
    }
    let report = acc.finish();
    telemetry::global().ingest_parse_ns.record(span.elapsed_ns());
    Ok(report)
}

/// One worker's scan of one chunk: accepted records in chunk order, the
/// number of lines scanned, and the first malformed line if any (local
/// 0-based index — rebased to a file line number at the merge).
struct ChunkShard {
    recs: Vec<LineRecord>,
    lines: usize,
    fault: Option<(usize, LineFault)>,
}

fn parse_chunk(chunk: &str) -> ChunkShard {
    let mut recs = Vec::new();
    let mut lines = 0usize;
    let mut fault = None;
    for (i, line) in chunk.lines().enumerate() {
        lines = i + 1;
        match classify_line(line.trim()) {
            Ok(None) => {}
            Ok(Some(rec)) => recs.push(rec),
            Err(f) => {
                // stop at the first fault: nothing after the earliest
                // faulty line can affect the (failed) parse
                fault = Some((i, f));
                break;
            }
        }
    }
    ChunkShard { recs, lines, fault }
}

/// Split `input` into about `want` byte ranges, each ending just past a
/// newline (the last may not), so every line lives in exactly one chunk.
fn chunk_bounds(input: &str, want: usize) -> Vec<(usize, usize)> {
    let len = input.len();
    if len == 0 {
        return Vec::new();
    }
    let target = len.div_ceil(want.max(1)).max(1);
    let bytes = input.as_bytes();
    let mut bounds = Vec::with_capacity(want.max(1));
    let mut start = 0usize;
    while start < len {
        let mut end = (start + target).min(len);
        while end < len && bytes[end - 1] != b'\n' {
            end += 1;
        }
        bounds.push((start, end));
        start = end;
    }
    bounds
}

/// [`parse_report`] with the line scan fanned out across `pool`.
///
/// The input is chunked at newline boundaries (one chunk per pool
/// worker); each worker parses its chunk into an owned [`ChunkShard`],
/// and the shards are merged in chunk order through the same
/// [`Accumulator`] the sequential path uses.  The result — renumbering,
/// synthetic timestamps, self-loop counts, and error messages — is
/// byte-identical to [`parse_report`] for every thread count.
pub fn parse_report_parallel(input: &str, pool: &ThreadPool) -> Result<ParseReport> {
    let span = telemetry::SpanTimer::start();
    let chunks = chunk_bounds(input, pool.num_threads().max(1));
    let results: Mutex<Vec<(usize, ChunkShard)>> = Mutex::new(Vec::with_capacity(chunks.len()));
    // SAFETY: `input` and `results` outlive the `pool.scope` call below,
    // which joins every spawned task before returning.
    #[allow(unsafe_code)]
    let share = unsafe { ScopeShare::new() };
    let text = share.share(input);
    let out = share.share(&results);
    pool.scope(|s| {
        for (idx, (start, end)) in chunks.iter().copied().enumerate() {
            let (text, out) = (text, out);
            s.spawn(move |_| {
                let shard = parse_chunk(&text.get()[start..end]);
                plock(out.get()).push((idx, shard));
            });
        }
    });
    let mut shards = std::mem::take(&mut *plock(&results));
    shards.sort_unstable_by_key(|(idx, _)| *idx);

    // earliest fault wins: chunks are disjoint ordered line ranges, so the
    // first chunk carrying a fault holds the globally first faulty line
    let mut line_base = 0usize;
    for (_, shard) in &shards {
        if let Some((local, fault)) = &shard.fault {
            return Err(fault_error(fault.clone(), line_base + local + 1));
        }
        line_base += shard.lines;
    }

    let mut acc = Accumulator::default();
    for (_, shard) in shards {
        for rec in shard.recs {
            acc.accept(rec);
        }
    }
    let report = acc.finish();
    telemetry::global().ingest_parse_ns.record(span.elapsed_ns());
    Ok(report)
}

/// Parse an edge list from a reader; returns (edges, n). Thin wrapper
/// over [`parse_report`] for callers that don't need the skip counts.
pub fn parse(reader: impl BufRead) -> Result<(Vec<TimedEdge>, usize)> {
    let r = parse_report(reader)?;
    Ok((r.edges, r.n))
}

fn warn_self_loops(r: &ParseReport, path: &Path) {
    if r.self_loops > 0 {
        eprintln!(
            "warn: {:?}: skipped {} self-loop edge(s)",
            path, r.self_loops
        );
    }
}

/// Load a static graph from a file (sequential parse and CSR build).
pub fn load_graph(path: impl AsRef<Path>) -> Result<CsrGraph> {
    load_graph_threads(path, 1)
}

/// Load a static graph from a file with parse and CSR construction
/// fanned out across `threads` ingest workers (1 = the sequential
/// [`load_graph`] path; the resulting graph is identical either way).
pub fn load_graph_threads(path: impl AsRef<Path>, threads: usize) -> Result<CsrGraph> {
    let path = path.as_ref();
    if threads <= 1 {
        let file =
            std::fs::File::open(path).with_context(|| format!("open {:?}", path))?;
        let r = parse_report(std::io::BufReader::new(file))?;
        warn_self_loops(&r, path);
        let pairs: Vec<(Vertex, Vertex)> = r.edges.iter().map(|e| (e.u, e.v)).collect();
        return Ok(CsrGraph::from_edges(r.n, &pairs));
    }
    let text =
        std::fs::read_to_string(path).with_context(|| format!("open {:?}", path))?;
    let pool = ThreadPool::new(threads);
    let r = parse_report_parallel(&text, &pool)?;
    warn_self_loops(&r, path);
    let pairs: Vec<(Vertex, Vertex)> = r.edges.iter().map(|e| (e.u, e.v)).collect();
    Ok(CsrGraph::from_edges_parallel(r.n, &pairs, &pool))
}

/// Load a dynamic stream (sorted by timestamp, stable).
pub fn load_stream(path: impl AsRef<Path>) -> Result<(Vec<TimedEdge>, usize)> {
    load_stream_threads(path, 1)
}

/// [`load_stream`] with the parse fanned out across `threads` ingest
/// workers; the stable timestamp sort runs on the caller, so the stream
/// is identical for every thread count.
pub fn load_stream_threads(
    path: impl AsRef<Path>,
    threads: usize,
) -> Result<(Vec<TimedEdge>, usize)> {
    let path = path.as_ref();
    let r = if threads <= 1 {
        let file =
            std::fs::File::open(path).with_context(|| format!("open {:?}", path))?;
        parse_report(std::io::BufReader::new(file))?
    } else {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("open {:?}", path))?;
        let pool = ThreadPool::new(threads);
        parse_report_parallel(&text, &pool)?
    };
    warn_self_loops(&r, path);
    let mut edges = r.edges;
    edges.sort_by_key(|e| e.t);
    Ok((edges, r.n))
}

/// Write a graph as an edge list.
pub fn write_graph(g: &CsrGraph, path: impl AsRef<Path>) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# parmce edge list: n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let input = "# comment\n% konect comment\n10 20\n20 30 5\n\n10 30\n";
        let (edges, n) = parse(Cursor::new(input)).unwrap();
        assert_eq!(n, 3);
        assert_eq!(edges.len(), 3);
        // synthetic timestamps count accepted edges, not file lines
        assert_eq!(edges[0], TimedEdge { u: 0, v: 1, t: 0 });
        assert_eq!(edges[1], TimedEdge { u: 1, v: 2, t: 5 });
        assert_eq!(edges[2], TimedEdge { u: 0, v: 2, t: 2 });
    }

    #[test]
    fn synthetic_timestamps_ignore_comment_and_blank_lines() {
        // regression: the old lineno-based default left gaps at comments
        // and blank lines, so later untimed edges jumped *past* real
        // timestamps under load_stream's stable sort
        let input = "0 1\n# gap\n\n% gap\n1 2\n# gap\n2 3\n";
        let (edges, _) = parse(Cursor::new(input)).unwrap();
        let ts: Vec<u64> = edges.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![0, 1, 2], "monotone, gap-free edge counter");
    }

    #[test]
    fn synthetic_timestamps_stay_stable_under_stream_sort() {
        // three untimed edges after a commented preamble plus one real
        // timestamp: with lineno defaults the untimed edges would carry
        // t=4,5,6 and sort after the t=3 edge; the edge counter keeps
        // them at t=0,1,2, before it
        let input = "# header\n# header\n# header\n# header\n0 1\n1 2\n2 3\n3 4 3\n";
        let dir = std::env::temp_dir().join("parmce_synth_ts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        std::fs::write(&path, input).unwrap();
        let (edges, _) = load_stream(&path).unwrap();
        let order: Vec<(Vertex, Vertex)> = edges.iter().map(|e| (e.u, e.v)).collect();
        assert_eq!(order, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn self_loops_are_skipped_and_counted() {
        // regression: self-loops used to pass through into dynamic
        // streams even though CsrGraph::from_edges drops them for static
        // loads — DynamicSession could ingest edges the static path
        // never sees
        let input = "0 0\n0 1\n2 2 7\n1 2\n";
        let r = parse_report(Cursor::new(input)).unwrap();
        assert_eq!(r.self_loops, 2);
        assert_eq!(
            r.edges,
            vec![
                TimedEdge { u: 0, v: 1, t: 0 },
                TimedEdge { u: 1, v: 2, t: 1 },
            ]
        );
        // self-loop-only vertex 2's id still counts toward n, matching
        // the static loader's vertex universe
        assert_eq!(r.n, 3);
    }

    #[test]
    fn parse_errors() {
        assert!(parse(Cursor::new("1\n")).is_err());
        assert!(parse(Cursor::new("a b\n")).is_err());
        assert!(parse(Cursor::new("1 2 x\n")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = crate::graph::generators::gnp(40, 0.2, 3);
        let dir = std::env::temp_dir().join("parmce_edgelist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g2.m(), g.m());
        // renumbering is identity here because vertices appear in order
        assert_eq!(g2.edges().len(), g.edges().len());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stream_sorted_by_timestamp() {
        let input = "0 1 9\n1 2 3\n2 3 7\n";
        let dir = std::env::temp_dir().join("parmce_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        std::fs::write(&path, input).unwrap();
        let (edges, n) = load_stream(&path).unwrap();
        assert_eq!(n, 4);
        let ts: Vec<u64> = edges.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![3, 7, 9]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn chunk_bounds_cover_input_at_line_boundaries() {
        let input = "0 1\n22 33\n4 5\n6 7\n8 9";
        for want in 1..8 {
            let bounds = chunk_bounds(input, want);
            assert_eq!(bounds.first().map(|b| b.0), Some(0));
            assert_eq!(bounds.last().map(|b| b.1), Some(input.len()));
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "chunks must tile the input");
                assert_eq!(
                    input.as_bytes()[w[0].1 - 1],
                    b'\n',
                    "interior chunk boundaries must sit just past a newline"
                );
            }
        }
        assert!(chunk_bounds("", 4).is_empty());
    }

    #[test]
    fn parallel_parse_matches_sequential() {
        let input = "# header\n10 20\n7 7\n20 30 5\n\n% mid\n10 30\n30 40\n40 10 2\n9 9 9\n";
        let seq = parse_report(Cursor::new(input)).unwrap();
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let par = parse_report_parallel(input, &pool).unwrap();
            assert_eq!(par.edges, seq.edges, "threads={threads}");
            assert_eq!(par.n, seq.n);
            assert_eq!(par.self_loops, seq.self_loops);
        }
    }

    #[test]
    fn parallel_parse_reports_the_earliest_fault_identically() {
        // faults in different chunks: the earliest line must win, with
        // the sequential path's exact message chain
        let cases = [
            "0 1\n1 2\nbogus x\n2 3\n4 oops\n",
            "0 1\n1\n2 3 zzz\n",
            "0 1 t\n1 2\n",
        ];
        for input in cases {
            let seq_err = format!("{:#}", parse_report(Cursor::new(input)).unwrap_err());
            for threads in [2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let par_err =
                    format!("{:#}", parse_report_parallel(input, &pool).unwrap_err());
                assert_eq!(par_err, seq_err, "threads={threads} input={input:?}");
            }
        }
    }

    #[test]
    fn threaded_loaders_match_sequential_loaders() {
        let g = crate::graph::generators::gnp(60, 0.15, 11);
        let dir = std::env::temp_dir().join("parmce_threaded_load_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_graph(&g, &path).unwrap();
        let seq = load_graph(&path).unwrap();
        let par = load_graph_threads(&path, 4).unwrap();
        assert_eq!(par.n(), seq.n());
        assert_eq!(par.edges(), seq.edges());
        let (es, ns) = load_stream(&path).unwrap();
        let (ep, np) = load_stream_threads(&path, 4).unwrap();
        assert_eq!(ns, np);
        assert_eq!(es, ep);
        let _ = std::fs::remove_dir_all(dir);
    }
}
