//! Structural graph statistics (Table 3 columns that don't need MCE).

use crate::graph::csr::CsrGraph;
use crate::graph::degeneracy;
use crate::graph::triangles;
use crate::util::json::Json;

/// One graph's structural summary (the cheap Table 3 columns).
#[derive(Clone, Debug)]
pub struct GraphStats {
    /// Number of vertices.
    pub n: usize,
    /// Number of undirected edges.
    pub m: usize,
    /// Maximum degree.
    pub max_degree: usize,
    /// Average degree 2m/n.
    pub avg_degree: f64,
    /// Edge density m / C(n, 2).
    pub density: f64,
    /// Degeneracy (maximum core number).
    pub degeneracy: u32,
    /// Total triangle count.
    pub triangles: u64,
}

impl GraphStats {
    /// Compute every statistic (one core decomposition + one triangle
    /// count; no clique enumeration).
    pub fn compute(g: &CsrGraph) -> Self {
        let decomp = degeneracy::core_decomposition(g);
        GraphStats {
            n: g.n(),
            m: g.m(),
            max_degree: g.max_degree(),
            avg_degree: if g.n() == 0 {
                0.0
            } else {
                2.0 * g.m() as f64 / g.n() as f64
            },
            density: g.density(),
            degeneracy: decomp.degeneracy,
            triangles: triangles::total(g),
        }
    }

    /// Serialize for the CLI's JSON output.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("n", Json::num(self.n as f64)),
            ("m", Json::num(self.m as f64)),
            ("max_degree", Json::num(self.max_degree as f64)),
            ("avg_degree", Json::num(self.avg_degree)),
            ("density", Json::num(self.density)),
            ("degeneracy", Json::num(self.degeneracy)),
            ("triangles", Json::num(self.triangles as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn stats_of_complete_graph() {
        let g = generators::complete(10);
        let s = GraphStats::compute(&g);
        assert_eq!(s.n, 10);
        assert_eq!(s.m, 45);
        assert_eq!(s.max_degree, 9);
        assert!((s.avg_degree - 9.0).abs() < 1e-12);
        assert!((s.density - 1.0).abs() < 1e-12);
        assert_eq!(s.degeneracy, 9);
        assert_eq!(s.triangles, 120);
    }

    #[test]
    fn stats_json_shape() {
        let g = generators::gnp(30, 0.2, 1);
        let j = GraphStats::compute(&g).to_json();
        assert!(j.get("n").is_some() && j.get("degeneracy").is_some());
    }
}
