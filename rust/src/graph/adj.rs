//! Legacy dynamic adjacency structure (reference implementation).
//!
//! Neighbour lists are kept as sorted `Vec<u32>` so the same `util::vset`
//! set algebra used on CSR slices works on a graph that changes between
//! batches.  Mutation is single-threaded (between batches, Figure 4's
//! "update graph" step); reads during enumeration are shared.
//!
//! The incremental pipeline itself now runs on the epoch-snapshotted
//! delta-CSR store in [`crate::graph::snapshot`] (DESIGN.md "Graph
//! storage"); `DynGraph` stays as the simplest-possible mirror that the
//! equivalence suite (`tests/graph_snapshot_equivalence.rs`) and the
//! snapshot unit tests check the delta-CSR path against.

use crate::graph::csr::CsrGraph;
use crate::graph::{norm_edge, Edge, Vertex};
use crate::util::vset;

/// Sorted-`Vec` adjacency lists with single-writer mutation — the
/// reference mirror the delta-CSR snapshot store is checked against.
#[derive(Clone, Debug, Default)]
pub struct DynGraph {
    adj: Vec<Vec<Vertex>>,
    m: usize,
}

impl DynGraph {
    /// The edgeless graph on `n` vertices.
    pub fn new(n: usize) -> Self {
        DynGraph {
            adj: vec![Vec::new(); n],
            m: 0,
        }
    }

    /// Copy a static CSR graph into mutable adjacency lists.
    pub fn from_csr(g: &CsrGraph) -> Self {
        DynGraph {
            adj: (0..g.n()).map(|v| g.neighbors(v as Vertex).to_vec()).collect(),
            m: g.m(),
        }
    }

    /// Materialize the current graph as a standalone [`CsrGraph`].
    pub fn to_csr(&self) -> CsrGraph {
        let mut edges = Vec::with_capacity(self.m);
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                if (u as Vertex) < v {
                    edges.push((u as Vertex, v));
                }
            }
        }
        CsrGraph::from_edges(self.n(), &edges)
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        &self.adj[v as usize]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: Vertex) -> usize {
        self.adj[v as usize].len()
    }

    /// Is `{u, v}` an edge? (Binary search on the smaller list.)
    #[inline]
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        if u == v {
            return false;
        }
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        vset::contains(self.neighbors(a), b)
    }

    /// Insert an undirected edge; true if the graph changed.
    pub fn insert_edge(&mut self, u: Vertex, v: Vertex) -> bool {
        let Some((a, b)) = norm_edge(u, v) else {
            return false;
        };
        debug_assert!((b as usize) < self.n(), "vertex {b} out of range");
        if vset::insert_sorted(&mut self.adj[a as usize], b) {
            vset::insert_sorted(&mut self.adj[b as usize], a);
            self.m += 1;
            true
        } else {
            false
        }
    }

    /// Remove an undirected edge; true if the graph changed.
    pub fn remove_edge(&mut self, u: Vertex, v: Vertex) -> bool {
        let Some((a, b)) = norm_edge(u, v) else {
            return false;
        };
        if vset::remove_sorted(&mut self.adj[a as usize], b) {
            vset::remove_sorted(&mut self.adj[b as usize], a);
            self.m -= 1;
            true
        } else {
            false
        }
    }

    /// Insert a batch; returns the edges that were actually new, normalized.
    pub fn insert_batch(&mut self, edges: &[(Vertex, Vertex)]) -> Vec<Edge> {
        let mut added = Vec::with_capacity(edges.len());
        for &(u, v) in edges {
            if self.insert_edge(u, v) {
                added.push(norm_edge(u, v).unwrap());
            }
        }
        added
    }

    /// Common neighbourhood Γ(u) ∩ Γ(v).
    pub fn common_neighbors(&self, u: Vertex, v: Vertex) -> Vec<Vertex> {
        vset::intersect(self.neighbors(u), self.neighbors(v))
    }

    /// Are `verts` pairwise adjacent?
    pub fn is_clique(&self, verts: &[Vertex]) -> bool {
        for (i, &u) in verts.iter().enumerate() {
            for &v in &verts[i + 1..] {
                if !self.has_edge(u, v) {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_roundtrip() {
        let mut g = DynGraph::new(4);
        assert!(g.insert_edge(0, 1));
        assert!(g.insert_edge(1, 2));
        assert!(!g.insert_edge(1, 0), "duplicate rejected");
        assert!(!g.insert_edge(2, 2), "self-loop rejected");
        assert_eq!(g.m(), 2);
        assert!(g.has_edge(0, 1));
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.m(), 1);
        assert!(!g.has_edge(0, 1));
    }

    #[test]
    fn csr_roundtrip() {
        let edges = [(0, 1), (1, 2), (0, 2), (2, 3)];
        let g = CsrGraph::from_edges(4, &edges);
        let d = DynGraph::from_csr(&g);
        assert_eq!(d.m(), 4);
        assert_eq!(d.neighbors(2), g.neighbors(2));
        let back = d.to_csr();
        assert_eq!(back.edges(), g.edges());
    }

    #[test]
    fn insert_batch_reports_new_only() {
        let mut g = DynGraph::new(5);
        g.insert_edge(0, 1);
        let added = g.insert_batch(&[(1, 0), (2, 3), (3, 2), (4, 4), (0, 4)]);
        assert_eq!(added, vec![(2, 3), (0, 4)]);
        assert_eq!(g.m(), 3);
    }

    #[test]
    fn common_neighbors_sorted() {
        let mut g = DynGraph::new(6);
        for (u, v) in [(0, 2), (0, 3), (0, 5), (1, 2), (1, 3), (1, 4)] {
            g.insert_edge(u, v);
        }
        assert_eq!(g.common_neighbors(0, 1), vec![2, 3]);
    }
}
