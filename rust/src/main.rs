//! parmce CLI — the L3 coordinator entry point, routed through the
//! session API.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   parmce exp <id|all> [--scale tiny|small|full] [--out DIR]
//!   parmce enumerate (--dataset NAME | --input FILE) [--algo A] [--threads N]
//!                    [--ingest-threads N] [--scale S] [--rank degree|degen|tri]
//!                    [--budget-kb N] [--deadline-ms M] [--bitset-cutoff W]
//!                    [--out FILE [--format ndjson|text|binary]]
//!                    [--metrics-out FILE] [--metrics-every MS] [--fail-spec SPEC]
//!   parmce serve-replay (--dataset NAME | --input FILE) [--algo imce|parimce]
//!                       [--batch N] [--threads N] [--ingest-threads N] [--readers R]
//!                       [--max-batches M] [--churn K] [--seed X] [--scale S]
//!                       [--bitset-cutoff W] [--metrics-out FILE] [--metrics-every MS]
//!                       [--fail-spec SPEC]
//!   parmce stats [--dataset NAME] [--scale S]
//!   parmce perf [--scale S]
//!   parmce artifacts-check
//!   parmce help

use parmce::util::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use parmce::graph::datasets::{Dataset, Scale};
use parmce::graph::stats::GraphStats;
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::session::{Algo, MceSession, RunOutcome, WriterFormat};
use parmce::util::table::fmt_count;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // PARMCE_FAIL_SPEC arms the failpoint registry before any subcommand
    // runs; a spec on a build without the feature is a startup error.
    if let Err(e) = parmce::util::failpoints::init_from_env() {
        eprintln!("error: PARMCE_FAIL_SPEC: {e}");
        std::process::exit(1);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn parse_scale(args: &[String]) -> Result<Scale> {
    match flag(args, "--scale").as_deref() {
        None | Some("small") => Ok(Scale::Small),
        Some("tiny") => Ok(Scale::Tiny),
        Some("full") => Ok(Scale::Full),
        Some(s) => bail!("unknown scale {s} (tiny|small|full)"),
    }
}

fn parse_dataset(name: &str) -> Result<Dataset> {
    Dataset::all()
        .into_iter()
        .find(|d| d.name() == name || d.paper_name().eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow!(
                "unknown dataset {name}; known: {}",
                Dataset::all().map(|d| d.name()).join(", ")
            )
        })
}

/// CLI algorithm spelling → (Algo, ranking, wants-PJRT-ranking).
/// Accepts both the session spellings (`parmce`, `bk`, `hashing`, …) and
/// the legacy combined forms (`parmce-degree`, `parmce-tri-pjrt`).
fn parse_algo_spec(a: &str) -> Result<(Algo, RankStrategy, bool)> {
    let spec = match a {
        "parmce-degree" => (Algo::ParMce, RankStrategy::Degree, false),
        "parmce-degen" => (Algo::ParMce, RankStrategy::Degeneracy, false),
        "parmce-tri" => (Algo::ParMce, RankStrategy::Triangle, false),
        "parmce-tri-pjrt" => (Algo::ParMce, RankStrategy::Triangle, true),
        other => match Algo::parse(other) {
            Some(algo) => (algo, RankStrategy::Degree, false),
            None => bail!(
                "unknown algo {other} (ttt|parttt|parmce[-degree|-degen|-tri|-tri-pjrt]|\
                 bk|bk-basic|bk-degeneracy|peco|peamc|gp|greedybb|clique-enumerator|hashing)"
            ),
        },
    };
    Ok(spec)
}

/// `--metrics-out FILE`: dump the process-cumulative telemetry registry
/// (JSON when FILE ends in `.json`, Prometheus text exposition otherwise;
/// `cargo xtask check-prom` validates the latter in CI).
fn write_metrics(args: &[String]) -> Result<()> {
    if let Some(path) = flag(args, "--metrics-out") {
        let snap = parmce::telemetry::snapshot();
        std::fs::write(&path, parmce::telemetry::render_for_path(&snap, &path))?;
        println!("metrics written to {path}");
    }
    Ok(())
}

/// `--metrics-every MS`: start the live sampler thread (a one-line
/// progress report on stderr each period); stops when the handle drops.
fn start_sampler(args: &[String]) -> Result<Option<parmce::telemetry::Sampler>> {
    Ok(match flag(args, "--metrics-every") {
        Some(ms) => Some(parmce::telemetry::Sampler::start(Duration::from_millis(
            ms.parse()?,
        ))),
        None => None,
    })
}

/// `--fail-spec SPEC`: arm the deterministic failpoint registry (ISSUE 9
/// chaos testing).  Errors on a malformed spec, and — loudly, rather than
/// silently not injecting — on builds without the `failpoints` feature.
fn arm_failpoints(args: &[String]) -> Result<()> {
    if let Some(spec) = flag(args, "--fail-spec") {
        parmce::util::failpoints::configure_from_spec(&spec)
            .map_err(|e| anyhow!("--fail-spec: {e}"))?;
    }
    Ok(())
}

/// Report a faulted run on stderr (partial progress first, so CI smoke
/// tests can assert on it) and convert it to a nonzero exit.
fn fault_to_error(outcome: &RunOutcome, partial: Option<&parmce::session::PartialProgress>) -> Result<()> {
    match outcome {
        RunOutcome::Panicked { site, message } => {
            if let Some(p) = partial {
                eprintln!(
                    "partial: {} cliques emitted, {} batches applied, {} bytes flushed",
                    p.cliques_emitted, p.batches_applied, p.bytes_flushed
                );
            }
            bail!("run panicked at failpoint site `{site}`: {message}")
        }
        RunOutcome::SinkFailed { message } => {
            if let Some(p) = partial {
                eprintln!(
                    "partial: {} cliques emitted, {} batches applied, {} bytes flushed",
                    p.cliques_emitted, p.batches_applied, p.bytes_flushed
                );
            }
            bail!("output sink failed: {message}")
        }
        _ => Ok(()),
    }
}

fn parse_rank(args: &[String], default: RankStrategy) -> Result<RankStrategy> {
    Ok(match flag(args, "--rank").as_deref() {
        None => default,
        Some("id") => RankStrategy::Id,
        Some("degree") => RankStrategy::Degree,
        Some("degen") | Some("degeneracy") => RankStrategy::Degeneracy,
        Some("tri") | Some("triangle") => RankStrategy::Triangle,
        Some(s) => bail!("unknown rank strategy {s} (id|degree|degen|tri)"),
    })
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            let scale = parse_scale(args)?;
            let out = flag(args, "--out").unwrap_or_else(|| "results".into());
            let md = if id == "all" {
                parmce::experiments::run_all(scale, &out)?
            } else {
                parmce::experiments::run(id, scale, &out)?
            };
            println!("{md}");
            Ok(())
        }
        Some("enumerate") => {
            let scale = parse_scale(args)?;
            arm_failpoints(args)?;
            let algo_str = flag(args, "--algo").unwrap_or_else(|| "parmce-degree".into());
            let (algo, default_rank, pjrt) = parse_algo_spec(&algo_str)?;
            let rank = parse_rank(args, default_rank)?;
            if pjrt && rank != RankStrategy::Triangle {
                bail!(
                    "--algo parmce-tri-pjrt ranks on the PJRT triangle kernel; \
                     it cannot be combined with --rank {rank:?}"
                );
            }
            let threads: usize = flag(args, "--threads")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or(4);
            // ingest/ranking pre-pass width; defaults to the enumeration
            // width (same pool).  Results are identical at any setting.
            let ingest_threads: usize = flag(args, "--ingest-threads")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or(threads);
            // --input FILE parses an on-disk edge list (chunked across the
            // ingest threads); --dataset builds a synthetic analog
            let (g, source) = match flag(args, "--input") {
                Some(path) => {
                    let g = parmce::graph::edgelist::load_graph_threads(&path, ingest_threads)?;
                    (g, path)
                }
                None => {
                    let dataset = flag(args, "--dataset")
                        .ok_or_else(|| anyhow!("--dataset or --input required"))?;
                    let d = parse_dataset(&dataset)?;
                    (d.graph(scale), d.name().to_string())
                }
            };
            println!(
                "dataset {source} (n={}, m={}), algo {algo_str}, {threads} threads \
                 ({ingest_threads} ingest)",
                fmt_count(g.n() as u64),
                fmt_count(g.m() as u64)
            );

            let mut builder = MceSession::builder()
                .graph(g.clone())
                .algo(algo)
                .rank_strategy(rank)
                .threads(threads)
                .ingest_threads(ingest_threads);
            if let Some(kb) = flag(args, "--budget-kb") {
                builder = builder.mem_budget_bytes(kb.parse::<usize>()? << 10);
            }
            if let Some(ms) = flag(args, "--deadline-ms") {
                builder = builder.deadline(Duration::from_millis(ms.parse()?));
            }
            // dense-kernel hand-off threshold (0 disables the bit kernel)
            if let Some(w) = flag(args, "--bitset-cutoff") {
                builder = builder.bitset_cutoff(w.parse()?);
            }
            if pjrt {
                // rank on the AOT Pallas kernel, seed the session cache
                let engine = parmce::runtime::engine::Engine::load_default()?;
                let backend = parmce::runtime::tri_rank::PjrtTriangleBackend::new(&engine);
                let ranking = Ranking::compute_with(&g, RankStrategy::Triangle, &backend)?;
                builder = builder.ranking(Arc::new(ranking));
            }
            let session = builder.build()?;
            let sampler = start_sampler(args)?;
            // --out FILE streams every clique to disk instead of counting
            let report = match flag(args, "--out") {
                Some(out) => {
                    let format = match flag(args, "--format") {
                        None => WriterFormat::Ndjson,
                        Some(f) => WriterFormat::parse(&f).ok_or_else(|| {
                            anyhow!("unknown format {f} (ndjson|text|binary)")
                        })?,
                    };
                    let (report, stats) = session.stream_to(algo, &out, format)?;
                    println!(
                        "wrote {} cliques ({} bytes, {} flushes{}) to {out} [{}]",
                        fmt_count(stats.cliques),
                        fmt_count(stats.bytes),
                        stats.flushes,
                        if stats.dropped > 0 {
                            format!(", {} dropped by budget", fmt_count(stats.dropped))
                        } else {
                            String::new()
                        },
                        format.name()
                    );
                    report
                }
                None => session.run().report,
            };
            match &report.outcome {
                RunOutcome::Completed => println!(
                    "{} maximal cliques in {:.3}s ({:.0} cliques/s)",
                    fmt_count(report.cliques),
                    report.secs(),
                    report.cliques_per_sec()
                ),
                other => println!(
                    "run ended with {other:?} after {:.3}s ({} cliques emitted)",
                    report.secs(),
                    fmt_count(report.cliques)
                ),
            }
            drop(sampler); // stop + join before the final registry sweep
            write_metrics(args)?;
            fault_to_error(&report.outcome, report.partial.as_ref())
        }
        Some("serve-replay") => {
            // the serving pipeline: replay a dynamic stream while reader
            // tasks query the published epoch snapshots concurrently
            use parmce::coordinator::pool::ThreadPool;
            use parmce::dynamic::stream::EdgeStream;
            use parmce::service::{serve_replay, CliqueService, DriverConfig};
            use parmce::session::{DynAlgo, DynamicSession};

            let scale = parse_scale(args)?;
            arm_failpoints(args)?;
            let algo = match flag(args, "--algo").as_deref() {
                None => DynAlgo::ParImce,
                Some(a) => DynAlgo::parse(a)
                    .ok_or_else(|| anyhow!("unknown dynamic algo {a} (imce|parimce)"))?,
            };
            let threads: usize = flag(args, "--threads")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or_else(|| algo.default_threads());
            let ingest_threads: usize = flag(args, "--ingest-threads")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or(threads);
            let readers: usize = flag(args, "--readers")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or(2);
            let seed: u64 = flag(args, "--seed")
                .map(|t| t.parse())
                .transpose()?
                .unwrap_or(1);
            let cfg = DriverConfig {
                batch_size: flag(args, "--batch")
                    .map(|t| t.parse())
                    .transpose()?
                    .unwrap_or(100),
                max_batches: flag(args, "--max-batches").map(|t| t.parse()).transpose()?,
                readers,
                churn_every: flag(args, "--churn").map(|t| t.parse()).transpose()?,
                seed,
                ..DriverConfig::default()
            };

            // --input FILE replays a timestamped on-disk edge list (parsed
            // across the ingest threads, replayed in timestamp order);
            // --dataset permutes a synthetic analog's edges
            let (stream, source) = match flag(args, "--input") {
                Some(path) => {
                    let (timed, n) =
                        parmce::graph::edgelist::load_stream_threads(&path, ingest_threads)?;
                    (EdgeStream::from_timed(timed, n), path)
                }
                None => {
                    let dataset = flag(args, "--dataset")
                        .ok_or_else(|| anyhow!("--dataset or --input required"))?;
                    let d = parse_dataset(&dataset)?;
                    let g = d.graph(scale);
                    (EdgeStream::permuted(&g, seed), d.name().to_string())
                }
            };
            println!(
                "serving {source} (n={}, m={}) with {} ({threads} writer threads), \
                 batch {}, {} readers",
                fmt_count(stream.n as u64),
                fmt_count(stream.edges.len() as u64),
                algo.name(),
                cfg.batch_size,
                cfg.readers,
            );
            let mut session = DynamicSession::from_empty(stream.n, algo).with_threads(threads);
            if let Some(w) = flag(args, "--bitset-cutoff") {
                session = session.with_bitset_cutoff(w.parse()?);
            }
            let mut svc = CliqueService::wrap(session);
            // a dedicated reader pool: the session's ParIMCE pool must not
            // be occupied by long-lived query loops
            let pool = ThreadPool::new(readers.max(1));
            let sampler = start_sampler(args)?;
            let report = serve_replay(&mut svc, &stream, &pool, &cfg);
            drop(sampler);
            println!("{}", report.summary());
            write_metrics(args)?;
            anyhow::ensure!(
                report.consistency_violations == 0,
                "snapshot isolation violated"
            );
            fault_to_error(&report.outcome, report.partial.as_ref())
        }
        Some("stats") => {
            let scale = parse_scale(args)?;
            let datasets: Vec<Dataset> = match flag(args, "--dataset") {
                Some(name) => vec![parse_dataset(&name)?],
                None => Dataset::all().to_vec(),
            };
            for d in datasets {
                let g = d.graph(scale);
                let s = GraphStats::compute(&g);
                println!("{}: {}", d.name(), s.to_json());
            }
            Ok(())
        }
        Some("perf") => {
            // L3 hot-path breakdown: TTT cost attribution (pivot vs set
            // updates) on the two heaviest static analogs — the input to
            // the EXPERIMENTS.md §Perf iteration log.
            let scale = parse_scale(args)?;
            for d in [Dataset::WikiTalkLike, Dataset::AsSkitterLike, Dataset::WikipediaLike] {
                let g = d.graph(scale);
                let sink = parmce::mce::sink::CountSink::new();
                let mut m = parmce::mce::ttt::TttMetrics::default();
                let mut k = Vec::new();
                let t0 = std::time::Instant::now();
                parmce::mce::ttt::ttt_from_metered(
                    &g,
                    &mut k,
                    (0..g.n() as u32).collect(),
                    Vec::new(),
                    &sink,
                    &mut m,
                );
                let total = t0.elapsed().as_nanos() as u64;
                println!(
                    "{}: total {:.1}ms | calls {} | pivot {:.1}ms ({:.0}%) | updates {:.1}ms ({:.0}%) | cliques {}",
                    d.name(),
                    total as f64 / 1e6,
                    m.calls,
                    m.pivot_ns as f64 / 1e6,
                    100.0 * m.pivot_ns as f64 / total as f64,
                    m.update_ns as f64 / 1e6,
                    100.0 * m.update_ns as f64 / total as f64,
                    fmt_count(sink.count()),
                );
            }
            Ok(())
        }
        Some("artifacts-check") => {
            let engine = parmce::runtime::engine::Engine::load_default()?;
            println!("artifacts: {:?}", engine.artifact_names());
            println!(
                "TILE_B={} FULL_N={} PIVOT_N={}",
                engine.constant("TILE_B")?,
                engine.constant("FULL_N")?,
                engine.constant("PIVOT_N")?
            );
            // smoke-execute the tile kernel
            let b = engine.constant("TILE_B")?;
            let ones = vec![1.0f32; b * b];
            let shape = [b as i64, b as i64];
            let out = engine.execute_f32(
                "rank_tri_tile",
                &[(&ones, &shape), (&ones, &shape), (&ones, &shape)],
            )?;
            anyhow::ensure!(out.len() == b && (out[0] - (b * b) as f32).abs() < 1e-3);
            println!("PJRT round-trip OK ({} outputs)", out.len());
            Ok(())
        }
        Some("help") | None => {
            println!(
                "parmce — shared-memory parallel maximal clique enumeration\n\
                 \n\
                 USAGE:\n\
                 \x20 parmce exp <table3..table10|fig2|fig5..fig9|ablation|all> [--scale tiny|small|full] [--out DIR]\n\
                 \x20 parmce enumerate (--dataset NAME | --input FILE) [--algo A] [--rank id|degree|degen|tri]\n\
                 \x20                  [--threads N] [--ingest-threads N] [--scale S] [--budget-kb N]\n\
                 \x20                  [--deadline-ms M] [--bitset-cutoff W]\n\
                 \x20                  [--out FILE [--format ndjson|text|binary]]\n\
                 \x20                  [--metrics-out FILE] [--metrics-every MS] [--fail-spec SPEC]\n\
                 \x20 parmce serve-replay (--dataset NAME | --input FILE) [--algo imce|parimce]\n\
                 \x20                     [--batch N] [--threads N] [--ingest-threads N] [--readers R]\n\
                 \x20                     [--max-batches M] [--churn K] [--seed X] [--scale S]\n\
                 \x20                     [--bitset-cutoff W] [--metrics-out FILE] [--metrics-every MS]\n\
                 \x20                     [--fail-spec SPEC]\n\
                 \n\
                 \x20 --input parses a whitespace-separated edge list (u v [timestamp]; # and %\n\
                 \x20 comments) instead of generating a dataset analog.  --ingest-threads N sets\n\
                 \x20 the parse/CSR/ranking pre-pass width (default: --threads); any value\n\
                 \x20 produces identical results — it only changes ingest wall-clock.\n\
                 \n\
                 \x20 --metrics-out writes the telemetry registry at exit (.json = JSON dump,\n\
                 \x20 anything else = Prometheus text exposition); --metrics-every MS prints a\n\
                 \x20 live progress line to stderr each period.\n\
                 \x20 --fail-spec arms deterministic fault injection (builds with\n\
                 \x20 `--features failpoints` only): comma-separated site=action[:prob][:@K][:seed],\n\
                 \x20 actions panic|error|delay(ms), sites pool-spawn, pool-dequeue, sink-emit,\n\
                 \x20 sink-merge, sink-flush, membudget-charge, graph-publish, service-freeze,\n\
                 \x20 dynamic-apply; PARMCE_FAIL_SPEC in the environment does the same.\n\
                 \x20 parmce stats [--dataset NAME] [--scale S]\n\
                 \x20 parmce perf [--scale S]\n\
                 \x20 parmce artifacts-check\n\
                 \n\
                 Algorithms: ttt, parttt, parmce[-degree|-degen|-tri|-tri-pjrt], bk, bk-basic,\n\
                 \x20 bk-degeneracy, peco, peamc, gp, greedybb, clique-enumerator, hashing\n\
                 Datasets: {}",
                Dataset::all().map(|d| d.name()).join(", ")
            );
            Ok(())
        }
        Some(other) => bail!("unknown command {other}; see `parmce help`"),
    }
}
