//! Bench: telemetry overhead guard (ISSUE 8 satellite).
//!
//! Two numbers matter:
//!
//! 1. **Raw metric cost** — a `Counter::add` is one Relaxed `fetch_add`
//!    on a cache-padded per-worker shard; `Histogram::record` adds one
//!    bucket index computation.  Measured here per-op.
//! 2. **End-to-end TTT cost** — the instrumented sequential/parallel
//!    enumerators on a dense fixture.  Run this bench twice to compare:
//!
//!    ```text
//!    cargo bench --bench telemetry
//!    cargo bench --bench telemetry --features telemetry-off
//!    ```
//!
//!    Under `telemetry-off` every metric type is zero-sized and every
//!    method an empty inline body, so the second run is the true
//!    zero-cost baseline; the first shows the enabled-but-unread price
//!    (budget: single-digit ns per emitted clique, invisible next to
//!    the Tomita pivot loop).
//! `cargo bench --bench telemetry`

use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::generators;
use parmce::mce::sink::{CliqueSink, ShardedCountSink};
use parmce::mce::{parttt, ttt};
use parmce::telemetry::{Counter, Histogram, SpanTimer};
use parmce::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let enabled = !cfg!(feature = "telemetry-off");
    println!(
        "telemetry feature state: {}",
        if enabled { "ENABLED" } else { "telemetry-off" }
    );

    // --- raw metric primitives (per-op cost) ------------------------------
    let ops = 1_000_000u64;
    let counter = Counter::new();
    let ns = b.bench("telemetry/counter_add/1M", || {
        for i in 0..ops {
            counter.add(i & 1);
        }
    });
    println!("  -> {:.2}ns per Counter::add", ns as f64 / ops as f64);

    let hist = Histogram::new();
    let ns = b.bench("telemetry/histogram_record/1M", || {
        for i in 0..ops {
            hist.record(i);
        }
    });
    println!("  -> {:.2}ns per Histogram::record", ns as f64 / ops as f64);

    let ns = b.bench("telemetry/span_timer/1M", || {
        let mut acc = 0u64;
        for _ in 0..ops {
            let t = SpanTimer::start();
            acc = acc.wrapping_add(t.elapsed_ns());
        }
        acc
    });
    println!("  -> {:.2}ns per SpanTimer round-trip", ns as f64 / ops as f64);

    // --- instrumented TTT / ParTTT on a dense fixture ---------------------
    // Dense G(n,p) maximizes cliques-per-edge, i.e. maximizes how often
    // the instrumented emit/hand-off paths run relative to real work.
    let g = Arc::new(generators::gnp(300, 0.25, 42));

    let sink = ShardedCountSink::new(1);
    b.bench("telemetry/ttt/gnp300_p25", || {
        ttt::ttt(&g, &sink);
    });

    for threads in [2usize, 4] {
        let pool = ThreadPool::new(threads);
        let sink: Arc<dyn CliqueSink> = Arc::new(ShardedCountSink::new(threads));
        b.bench(format!("telemetry/parttt/gnp300_p25/t{threads}"), || {
            parttt::parttt(&pool, &g, &sink, Default::default());
        });
    }

    // Absolute sanity: the global registry agrees the runs happened (only
    // meaningful in the enabled build).
    if enabled {
        let snap = parmce::telemetry::snapshot();
        let tasks = snap
            .counter(parmce::telemetry::names::PARTTT_TASKS_SPAWNED)
            .unwrap_or(0);
        let handoffs = snap
            .counter(parmce::telemetry::names::BITKERNEL_HANDOFFS)
            .unwrap_or(0);
        println!("  -> registry saw {tasks} ParTTT tasks, {handoffs} bitkernel hand-offs");
    }

    b.dump_json(if enabled {
        "results/bench_telemetry_enabled.json"
    } else {
        "results/bench_telemetry_off.json"
    });
}
