//! Bench: Tables 7/8/10 — baseline algorithms vs TTT/ParMCE on a common
//! workload, all through the session API.  OOM/timeout baselines run
//! under a budgeted session.  `cargo bench --bench baselines`

use parmce::experiments::fixtures;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::mce::ranking::RankStrategy;
use parmce::session::{Algo, MceSession, RunOutcome};
use parmce::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    for d in [Dataset::AsSkitterLike, Dataset::WikipediaLike] {
        let g = d.graph(Scale::Tiny);
        let s = fixtures::session(&g, 4);
        let budgeted = MceSession::builder()
            .graph_arc(std::sync::Arc::clone(s.graph()))
            .mem_budget_bytes(8 << 20)
            .build()
            .expect("session");
        b.bench(format!("baseline/{}/ttt", d.name()), || fixtures::run_ttt(&s));
        b.bench(format!("baseline/{}/bk_pivot", d.name()), || {
            s.count(Algo::Bk).cliques
        });
        b.bench(format!("baseline/{}/bk_degeneracy", d.name()), || {
            s.count(Algo::BkDegeneracy).cliques
        });
        b.bench(format!("baseline/{}/greedybb_unbounded", d.name()), || {
            let r = s.count(Algo::GreedyBb);
            assert_eq!(r.outcome, RunOutcome::Completed);
            r.cliques
        });
        b.bench(format!("baseline/{}/hashing_budgeted", d.name()), || {
            budgeted.count(Algo::Hashing).outcome
        });
        b.bench(
            format!("baseline/{}/clique_enumerator_budgeted", d.name()),
            || budgeted.count(Algo::CliqueEnumerator).outcome,
        );
        b.bench(format!("baseline/{}/parmce_degree_sim32", d.name()), || {
            fixtures::parmce_sim_secs(&s, RankStrategy::Degree, 32)
        });
    }
    b.dump_json("results/bench_baselines.json");
}
