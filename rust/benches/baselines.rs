//! Bench: Tables 7/8/10 — baseline algorithms vs TTT/ParMCE on a common
//! workload.  OOM/timeout baselines run under their budget guards.
//! `cargo bench --bench baselines`

use std::time::Duration;

use parmce::baselines::{bk, clique_enumerator, greedybb, hashing};
use parmce::experiments::fixtures;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::mce::sink::CountSink;
use parmce::util::bench::Bencher;
use parmce::util::membudget::MemBudget;

fn main() {
    let mut b = Bencher::from_env();
    for d in [Dataset::AsSkitterLike, Dataset::WikipediaLike] {
        let g = d.graph(Scale::Tiny);
        b.bench(format!("baseline/{}/ttt", d.name()), || fixtures::run_ttt(&g));
        b.bench(format!("baseline/{}/bk_pivot", d.name()), || {
            let s = CountSink::new();
            bk::bk_pivot(&g, &s);
            s.count()
        });
        b.bench(format!("baseline/{}/bk_degeneracy", d.name()), || {
            let s = CountSink::new();
            bk::bk_degeneracy(&g, &s);
            s.count()
        });
        b.bench(format!("baseline/{}/greedybb_unbounded", d.name()), || {
            let s = CountSink::new();
            greedybb::greedybb(&g, &s, &MemBudget::unlimited(), Duration::from_secs(120)).unwrap();
            s.count()
        });
        b.bench(format!("baseline/{}/hashing_budgeted", d.name()), || {
            let s = CountSink::new();
            let _ = hashing::hashing(&g, &s, &MemBudget::new(8 << 20));
        });
        b.bench(format!("baseline/{}/clique_enumerator_budgeted", d.name()), || {
            let s = CountSink::new();
            let _ = clique_enumerator::clique_enumerator(&g, &s, &MemBudget::new(8 << 20));
        });
        let ranking = Ranking::compute(&g, RankStrategy::Degree);
        b.bench(format!("baseline/{}/parmce_degree_sim32", d.name()), || {
            fixtures::parmce_sim_secs(&g, &ranking, 32)
        });
    }
    b.dump_json("results/bench_baselines.json");
}
