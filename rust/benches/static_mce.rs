//! Bench: Table 4 — TTT vs ParTTT vs ParMCE variants on the five static
//! dataset analogs, routed through one `MceSession` per graph.
//! `cargo bench --bench static_mce` (set PARMCE_BENCH_FAST=1 for a quick
//! pass).

use parmce::experiments::fixtures;
use parmce::graph::datasets::{Scale, STATIC_DATASETS};
use parmce::mce::ranking::RankStrategy;
use parmce::util::bench::Bencher;

fn main() {
    let scale = if std::env::var("PARMCE_BENCH_FAST").as_deref() == Ok("1") {
        Scale::Tiny
    } else {
        Scale::Small
    };
    let mut b = Bencher::from_env();
    for d in STATIC_DATASETS {
        let g = d.graph(scale);
        let s = fixtures::session(&g, 4);
        b.bench(format!("table4/{}/ttt", d.name()), || fixtures::run_ttt(&s));
        b.bench(format!("table4/{}/parttt_sim32", d.name()), || {
            fixtures::parttt_sim_secs(&s, 32)
        });
        for strat in [
            RankStrategy::Degree,
            RankStrategy::Degeneracy,
            RankStrategy::Triangle,
        ] {
            b.bench(
                format!("table4/{}/parmce_{}_sim32", d.name(), strat.name()),
                || fixtures::parmce_sim_secs(&s, strat, 32),
            );
        }
        // real pool wall-clock (oversubscribed on this 1-core testbed):
        // measures parallel-overhead, not speedup
        b.bench(format!("table4/{}/parmce_degree_wall_t4", d.name()), || {
            fixtures::parmce_wall_secs(&g, RankStrategy::Degree, 4)
        });
    }
    b.dump_json("results/bench_static_mce.json");
}
