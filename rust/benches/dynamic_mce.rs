//! Bench: Table 6 / Figures 8-9 — IMCE vs ParIMCE batch replay on the
//! dynamic dataset analogs through `DynamicSession`.
//! `cargo bench --bench dynamic_mce`

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::datasets::{Dataset, Scale, DYNAMIC_DATASETS};
use parmce::session::{DynAlgo, DynamicSession};
use parmce::util::bench::Bencher;

fn main() {
    let fast = std::env::var("PARMCE_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { Scale::Tiny } else { Scale::Small };
    let cap = Some(if fast { 8 } else { 25 });
    let mut b = Bencher::from_env();
    let pool = ThreadPool::new(4);
    for d in DYNAMIC_DATASETS {
        let stream = EdgeStream::permuted(&d.graph(scale), 3);
        let bs = if d == Dataset::CaCitHepThLike { 10 } else { 100 };
        b.bench(format!("table6/{}/imce_seq", d.name()), || {
            let mut s = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
            s.replay(&stream, bs, cap)
        });
        b.bench(format!("table6/{}/parimce_wall_t4", d.name()), || {
            let mut s =
                DynamicSession::from_empty(stream.n, DynAlgo::ParImce).with_pool(pool.clone());
            s.replay(&stream, bs, cap)
        });
    }
    b.dump_json("results/bench_dynamic_mce.json");
}
