//! Bench: the ingest & ranking pipeline (ISSUE 10) — sequential vs
//! pool-parallel edge-list parsing, CSR construction, triangle counting
//! and core decomposition at 1–8 threads on the clustered generator.
//! Every parallel stage is exact-equal to its sequential reference, so
//! these rows measure wall-clock only.  `cargo bench --bench ingest`

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::csr::CsrGraph;
use parmce::graph::{degeneracy, edgelist, generators, triangles};
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    // the clustered fixture: dense planted communities over a sparse
    // background — enough triangle/core mass for ranking to matter
    let g = generators::planted_cliques(3000, 0.0015, 30, 6, 16, 7);
    let edges = g.edges();
    let text = {
        let mut t = String::with_capacity(edges.len() * 12);
        for (u, v) in &edges {
            t.push_str(&format!("{u} {v}\n"));
        }
        t
    };

    b.bench("ingest/parse/seq", || {
        edgelist::parse_report(text.as_bytes()).unwrap().edges.len()
    });
    b.bench("ingest/csr/seq", || CsrGraph::from_edges(g.n(), &edges).m());
    b.bench("ingest/tri/seq", || triangles::per_vertex(&g).len());
    b.bench("ingest/degen/seq", || {
        degeneracy::core_decomposition(&g).degeneracy
    });
    b.bench("ingest/rank_tri/seq", || {
        Ranking::compute(&g, RankStrategy::Triangle).strategy()
    });

    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        b.bench(format!("ingest/parse/t{threads}"), || {
            edgelist::parse_report_parallel(&text, &pool)
                .unwrap()
                .edges
                .len()
        });
        b.bench(format!("ingest/csr/t{threads}"), || {
            CsrGraph::from_edges_parallel(g.n(), &edges, &pool).m()
        });
        b.bench(format!("ingest/tri/t{threads}"), || {
            triangles::per_vertex_parallel(&g, &pool).len()
        });
        b.bench(format!("ingest/degen/t{threads}"), || {
            // cutoff 0: always exercise the level-peeling path
            degeneracy::core_decomposition_parallel_with_cutoff(&g, &pool, 0).degeneracy
        });
        b.bench(format!("ingest/rank_tri/t{threads}"), || {
            Ranking::compute_parallel(&g, RankStrategy::Triangle, &pool).strategy()
        });
    }
    b.dump_json("results/bench_ingest.json");
}
