//! Bench: dynamic graph storage — batch-apply latency and neighbor-scan
//! throughput, legacy `DynGraph` vs delta-CSR `SnapshotGraph` (fresh
//! overlay vs post-compaction), 1–8 scan threads on a clustered fixture.
//! The scan number is the one that matters: enumeration reads dominate a
//! batch, so the snapshot's chunked CSR must not cost reads what the
//! overlay saves on writes.  `cargo bench --bench dyngraph`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::adj::DynGraph;
use parmce::graph::generators;
use parmce::graph::snapshot::SnapshotGraph;
use parmce::graph::{AdjacencyGraph, Edge, Vertex};
use parmce::util::bench::Bencher;
use parmce::util::rng::Rng;

/// Random edges absent from `base`, deduplicated.
fn fresh_edges(base: &parmce::graph::csr::CsrGraph, count: usize, seed: u64) -> Vec<Edge> {
    let mut rng = Rng::new(seed);
    let n = base.n();
    let mut out: Vec<Edge> = Vec::with_capacity(count);
    let mut seen = std::collections::BTreeSet::new();
    while out.len() < count {
        let u = rng.gen_usize(n) as Vertex;
        let v = rng.gen_usize(n) as Vertex;
        if u == v || base.has_edge(u, v) {
            continue;
        }
        let e = if u < v { (u, v) } else { (v, u) };
        if seen.insert(e) {
            out.push(e);
        }
    }
    out
}

/// Striped parallel sweep summing every neighbor id through the
/// `AdjacencyGraph` trait; returns the checksum so variants can be
/// cross-checked (and the read is not optimized away).
fn scan<G: AdjacencyGraph + Send + Sync + 'static>(
    pool: &ThreadPool,
    g: &Arc<G>,
    threads: usize,
) -> u64 {
    let total = Arc::new(AtomicU64::new(0));
    let n = g.n();
    pool.scope(|s| {
        for t in 0..threads {
            let g = Arc::clone(g);
            let total = Arc::clone(&total);
            s.spawn(move |_| {
                let mut acc = 0u64;
                let mut v = t;
                while v < n {
                    for &w in g.neighbors(v as Vertex) {
                        acc = acc.wrapping_add(w as u64 + 1);
                    }
                    v += threads;
                }
                total.fetch_add(acc, Ordering::Relaxed);
            });
        }
    });
    total.load(Ordering::Relaxed)
}

fn main() {
    let mut b = Bencher::from_env();

    // clustered fixture: sparse background + planted dense communities
    let base = generators::planted_cliques(3000, 0.0015, 30, 6, 16, 7);
    let churn = fresh_edges(&base, 800, 99);
    let chunk = 200usize;
    println!(
        "fixture: n={} m={} churn={} (chunks of {chunk})",
        base.n(),
        base.m(),
        churn.len()
    );

    // --- batch-apply latency: insert+remove round trips -------------------
    // each iteration applies every chunk and then undoes it, so the timed
    // body is steady-state (no per-iteration graph rebuild in the loop)
    {
        let mut g = DynGraph::from_csr(&base);
        let dyn_ns = b.bench("apply/dyngraph/roundtrip", || {
            for c in churn.chunks(chunk) {
                g.insert_batch(c);
                for &(u, v) in c {
                    g.remove_edge(u, v);
                }
            }
        });

        let mut s = SnapshotGraph::from_csr(&base); // default threshold
        let snap_ns = b.bench("apply/snapshot/roundtrip", || {
            for c in churn.chunks(chunk) {
                s.insert_batch(c);
                let _ = s.publish();
                s.remove_batch(c);
                let _ = s.publish();
            }
        });
        assert_eq!(s.m(), base.m(), "round trips must restore the fixture");
        println!(
            "  -> apply: snapshot {:.2}x of dyngraph ({} compactions over the run)",
            snap_ns as f64 / dyn_ns.max(1) as f64,
            s.compactions()
        );
    }

    // --- neighbor-scan throughput, 1..8 threads ---------------------------
    // all three variants hold the same logical graph: base + full churn
    let dyn_graph = {
        let mut g = DynGraph::from_csr(&base);
        g.insert_batch(&churn);
        Arc::new(g)
    };
    let overlay_snap = {
        let mut s = SnapshotGraph::from_csr(&base).with_compact_threshold(usize::MAX);
        s.insert_batch(&churn);
        s.publish() // overlay kept: reads take the overlay-first path
    };
    let compacted_snap = {
        let mut s = SnapshotGraph::from_csr(&base).with_compact_threshold(0);
        s.insert_batch(&churn);
        s.publish() // overlay folded into the COW blocks
    };
    assert!(overlay_snap.overlay_len() > 0);
    assert_eq!(compacted_snap.overlay_len(), 0);

    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let want = scan(&pool, &dyn_graph, threads);

        let dyn_ns = b.bench(format!("scan/dyngraph/t{threads}"), || {
            assert_eq!(scan(&pool, &dyn_graph, threads), want);
        });
        let overlay_ns = b.bench(format!("scan/snapshot_overlay/t{threads}"), || {
            assert_eq!(scan(&pool, &overlay_snap, threads), want);
        });
        let compact_ns = b.bench(format!("scan/snapshot_compacted/t{threads}"), || {
            assert_eq!(scan(&pool, &compacted_snap, threads), want);
        });

        println!(
            "  -> t{threads}: vs dyngraph — overlay {:.2}x, compacted {:.2}x",
            dyn_ns as f64 / overlay_ns.max(1) as f64,
            dyn_ns as f64 / compact_ns.max(1) as f64,
        );
    }

    b.dump_json("results/bench_dyngraph.json");
}
