//! Bench: the CliqueService serving path.
//!
//! 1. Snapshot read scaling — `r` concurrent readers each issuing a
//!    fixed query mix against the published snapshot, via the cached
//!    `SnapshotReader` hot path (one atomic load per revalidation).
//!    Per-query cost should stay flat as readers are added: reads share
//!    nothing mutable, so there is no lock to collapse on.  The
//!    `load-per-query` variant re-fetches the `Arc` through the cell
//!    mutex on every query, for contrast.
//! 2. Update-to-visibility — a full `serve_replay` run reporting epoch
//!    lag and publish→first-seen latency while updates land.
//!
//! `cargo bench --bench service` (PARMCE_BENCH_FAST=1 for CI).

use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::generators;
use parmce::graph::Vertex;
use parmce::service::{serve_replay, CliqueService, DriverConfig, ServiceHandle};
use parmce::session::{DynAlgo, DynamicSession};
use parmce::util::bench::Bencher;
use parmce::util::rng::Rng;

/// The per-reader query mix (mirrors the driver's hot queries).
fn query_round(snap: &parmce::service::CliqueSnapshot, rng: &mut Rng, n: u64) -> u64 {
    let mut acc = 0u64;
    let v = rng.gen_range(n) as Vertex;
    acc += snap.ids_containing(v).len() as u64;
    let u = rng.gen_range(n) as Vertex;
    let w = rng.gen_range(n) as Vertex;
    acc += snap.ids_containing_all(&[u, w]).len() as u64;
    acc += snap.top_k_largest(4).len() as u64;
    acc += snap.count() as u64;
    acc
}

fn hammer_readers(
    pool: &ThreadPool,
    handle: &ServiceHandle,
    readers: usize,
    rounds: u64,
    cached: bool,
) -> u64 {
    let total = Arc::new(AtomicU64::new(0));
    pool.scope(|s| {
        for r in 0..readers {
            let mut reader = handle.reader();
            let handle = handle.clone();
            let total = Arc::clone(&total);
            s.spawn(move |_| {
                let mut rng = Rng::new(0xbe7 ^ r as u64);
                let mut acc = 0u64;
                for _ in 0..rounds {
                    let snap = if cached {
                        Arc::clone(reader.current())
                    } else {
                        handle.snapshot() // cell mutex on every round
                    };
                    let n = snap.n().max(1) as u64;
                    acc += query_round(&snap, &mut rng, n);
                }
                total.fetch_add(acc, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    total.load(std::sync::atomic::Ordering::Relaxed)
}

fn main() {
    let mut b = Bencher::from_env();
    let fast = std::env::var("PARMCE_BENCH_FAST").as_deref() == Ok("1");
    let rounds: u64 = if fast { 2_000 } else { 20_000 };

    // a served graph with clique structure worth querying
    let g = generators::planted_cliques(400, 0.02, 10, 4, 8, 77);
    let svc = CliqueService::wrap(DynamicSession::from_graph_threads(&g, DynAlgo::Imce, 1));
    let handle = svc.handle();
    println!(
        "serving n={} cliques={} (4 queries per round, {rounds} rounds per reader)",
        g.n(),
        svc.snapshot().count()
    );

    // --- 1. read scaling: cached reader vs per-query cell load ------------
    let mut baseline_ns_per_q = 0.0;
    for readers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(readers);
        let queries = readers as u64 * rounds * 4;
        let ns = b.bench(format!("service/reads/cached/r{readers}"), || {
            hammer_readers(&pool, &handle, readers, rounds, true)
        });
        let per_q = ns as f64 / queries as f64;
        if readers == 1 {
            baseline_ns_per_q = per_q;
        }
        let ns_load = b.bench(format!("service/reads/load-per-query/r{readers}"), || {
            hammer_readers(&pool, &handle, readers, rounds, false)
        });
        println!(
            "  -> r{readers}: {:.0}ns/query cached ({:.2}x vs 1 reader), {:.0}ns/query re-loading",
            per_q,
            per_q / baseline_ns_per_q.max(1e-9),
            ns_load as f64 / queries as f64,
        );
    }

    // --- 2. update-to-visibility epoch lag under live replay --------------
    let g2 = generators::gnp(260, 0.04, 42);
    let stream = EdgeStream::permuted(&g2, 9);
    let cfg = DriverConfig {
        batch_size: if fast { 120 } else { 40 },
        readers: 2,
        queries_per_round: 8,
        churn_every: Some(5),
        seed: 3,
        max_batches: None,
    };
    let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
    let pool = ThreadPool::new(cfg.readers);
    let report = serve_replay(&mut svc, &stream, &pool, &cfg);
    assert_eq!(report.consistency_violations, 0, "isolation violated");
    println!("service/replay: {}", report.summary());
    println!(
        "  -> update-to-visibility: mean {:.3}ms over {} epochs; \
         reader epoch lag mean {:.2} max {}",
        report.mean_visibility_ns as f64 / 1e6,
        report.epochs_observed,
        report.mean_epoch_lag(),
        report.max_epoch_lag,
    );

    b.dump_json("results/bench_service.json");
}
