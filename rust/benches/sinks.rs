//! Bench: the output pipeline — shared-atomic vs worker-sharded count
//! sinks under multi-threaded emit storms, and streaming-writer encode
//! throughput.  MCE is output-dominated (Orkut: 2.27B cliques), so the
//! per-emit cost under contention is a first-class number.
//! `cargo bench --bench sinks`

use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::mce::sink::{
    CliqueSink, CountSink, ShardedCountSink, StreamWriterSink, WriterConfig, WriterFormat,
};
use parmce::util::bench::Bencher;

/// Emit `emits` cliques from each of `tasks` pool tasks into `sink`.
fn hammer(pool: &ThreadPool, sink: &Arc<dyn CliqueSink>, tasks: usize, emits: u64) {
    pool.scope(|s| {
        for _ in 0..tasks {
            let sink = Arc::clone(sink);
            s.spawn(move |_| {
                let clique = [1u32, 2, 3, 4];
                for _ in 0..emits {
                    sink.emit(&clique);
                }
            });
        }
    });
}

fn main() {
    let mut b = Bencher::from_env();
    let emits_per_task = 100_000u64;

    // --- shared atomic vs sharded counting, 1..8 threads ------------------
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let tasks = threads * 4;
        let total = tasks as u64 * emits_per_task;

        let shared_ns = b.bench(format!("count/shared_atomic/t{threads}"), || {
            let sink = Arc::new(CountSink::new());
            let dyn_sink: Arc<dyn CliqueSink> = Arc::clone(&sink);
            hammer(&pool, &dyn_sink, tasks, emits_per_task);
            assert_eq!(sink.count(), total);
        });

        let sharded_ns = b.bench(format!("count/sharded/t{threads}"), || {
            let sink = Arc::new(ShardedCountSink::new(threads));
            let dyn_sink: Arc<dyn CliqueSink> = Arc::clone(&sink);
            hammer(&pool, &dyn_sink, tasks, emits_per_task);
            assert_eq!(sink.count(), total);
        });

        println!(
            "  -> t{threads}: {:.1}M emits, sharded {:.2}x vs shared atomic ({:.1}ns vs {:.1}ns per emit)",
            total as f64 / 1e6,
            shared_ns as f64 / sharded_ns.max(1) as f64,
            sharded_ns as f64 / total as f64,
            shared_ns as f64 / total as f64,
        );
    }

    // --- streaming writer encode throughput (discarding output) -----------
    for format in [WriterFormat::Ndjson, WriterFormat::Text, WriterFormat::Binary] {
        let pool = ThreadPool::new(4);
        let tasks = 16;
        let emits = 50_000u64;
        let total = tasks as u64 * emits;
        let ns = b.bench(format!("writer/{}/t4", format.name()), || {
            let sink = Arc::new(StreamWriterSink::from_writer(
                std::io::sink(),
                4,
                WriterConfig {
                    format,
                    ..WriterConfig::default()
                },
            ));
            let dyn_sink: Arc<dyn CliqueSink> = Arc::clone(&sink);
            hammer(&pool, &dyn_sink, tasks, emits);
            drop(dyn_sink);
            let stats = Arc::into_inner(sink).unwrap().finish().unwrap();
            assert_eq!(stats.cliques, total);
        });
        println!(
            "  -> {}: {:.0}ns per encoded clique",
            format.name(),
            ns as f64 / total as f64
        );
    }

    b.dump_json("results/bench_sinks.json");
}
