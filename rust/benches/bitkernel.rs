//! Bench: slice-path vs bitset-path subproblem throughput — the cutoff
//! crossover measurement behind the `--bitset-cutoff` default (see
//! EXPERIMENTS.md §Perf).  `cargo bench --bench bitkernel`
//!
//! Sequential rows sweep the hand-off threshold on one dense and one
//! sparse graph (cutoff 0 = slice-only recursion); parallel rows run
//! ParTTT at 1–8 threads with the kernel off vs on, showing the hand-off
//! composes with task spawning rather than serializing it.

use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::generators;
use parmce::mce::parttt::parttt;
use parmce::mce::sink::{CliqueSink, CountSink, NullSink};
use parmce::mce::ttt;
use parmce::mce::ParTttConfig;
use parmce::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();

    // --- sequential crossover sweep ---------------------------------------
    // dense: deep recursions live almost entirely under small cutoffs
    // sparse: subproblems are tiny, so the kernel engages immediately
    for (name, g) in [
        ("gnp180_p35", generators::gnp(180, 0.35, 7)),
        ("planted300", generators::planted_cliques(300, 0.02, 8, 6, 10, 13)),
    ] {
        for cutoff in [0usize, 16, 64, 128, 512] {
            b.bench(format!("ttt/{name}/cutoff{cutoff}"), || {
                let sink = CountSink::new();
                ttt::ttt_with_cutoff(&g, &sink, cutoff);
                sink.count()
            });
        }
    }

    // --- parallel: kernel under the ParTTT task tree ----------------------
    let g = Arc::new(generators::planted_cliques(600, 0.015, 10, 7, 12, 3));
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        for cutoff in [0usize, 128] {
            let cfg = ParTttConfig {
                bitset_cutoff: cutoff,
                ..ParTttConfig::default()
            };
            b.bench(format!("parttt/planted600/t{threads}/cutoff{cutoff}"), || {
                let sink: Arc<dyn CliqueSink> = Arc::new(NullSink::new());
                parttt(&pool, &g, &sink, cfg);
            });
        }
    }

    b.dump_json("results/bench_bitkernel.json");
}
