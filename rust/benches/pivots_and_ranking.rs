//! Bench: Table 5 ingredients — pivot selection and vertex-ranking costs,
//! including the CPU-vs-PJRT triangle backends and the vset hot-path
//! primitives the perf pass optimizes.  `cargo bench --bench pivots_and_ranking`

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::graph::generators;
use parmce::mce::pivot::{choose_pivot, par_pivot};
use parmce::mce::ranking::{CpuTriangleBackend, RankStrategy, Ranking, TriangleBackend};
use parmce::runtime::engine::Engine;
use parmce::runtime::tri_rank::PjrtTriangleBackend;
use parmce::util::bench::Bencher;
use parmce::util::vset;

fn main() {
    let mut b = Bencher::from_env();

    // --- vset primitives (the TTT inner loop) ---------------------------
    let a: Vec<u32> = (0..4096).step_by(2).collect();
    let c: Vec<u32> = (0..4096).step_by(3).collect();
    let small: Vec<u32> = (0..4096).step_by(97).collect();
    b.bench("vset/intersect_balanced_2k", || vset::intersect(&a, &c));
    b.bench("vset/intersect_gallop_42_vs_2k", || vset::intersect(&small, &a));
    // clustered small side: the exponential-search cursor pays off most
    // when consecutive probes land close together (log(gap), not log(big))
    let clustered: Vec<u32> = (2000..2084).step_by(2).collect();
    b.bench("vset/intersect_gallop_clustered_42_vs_2k", || {
        vset::intersect(&clustered, &a)
    });
    b.bench("vset/intersection_count_balanced", || {
        vset::intersection_count(&a, &c)
    });
    b.bench("vset/difference", || vset::difference(&a, &c));

    // --- pivot selection --------------------------------------------------
    for (name, g) in [
        ("gnp2000_p01", generators::gnp(2000, 0.01, 1)),
        ("wiki_talk_like", Dataset::WikiTalkLike.graph(Scale::Small)),
    ] {
        let cand: Vec<u32> = (0..g.n() as u32).collect();
        b.bench(format!("pivot/seq/{name}"), || {
            choose_pivot(&g, &cand, &[])
        });
        // ParPivot now borrows cand/fini (no per-call Arc clones); this
        // is the number that regressed under the old allocation churn
        let pool = ThreadPool::new(4);
        b.bench(format!("pivot/par4/{name}"), || {
            par_pivot(&pool, &g, &cand, &[])
        });
    }

    // --- ranking strategies (Table 5 RT column) ---------------------------
    for d in [Dataset::AsSkitterLike, Dataset::WikipediaLike] {
        let g = d.graph(Scale::Small);
        b.bench(format!("rank/{}/degree", d.name()), || {
            Ranking::compute(&g, RankStrategy::Degree)
        });
        b.bench(format!("rank/{}/degeneracy", d.name()), || {
            Ranking::compute(&g, RankStrategy::Degeneracy)
        });
        b.bench(format!("rank/{}/tri_cpu", d.name()), || {
            CpuTriangleBackend.per_vertex(&g).unwrap()
        });
    }

    // --- PJRT kernel backend (L1 offload) ---------------------------------
    if let Ok(engine) = Engine::load_default() {
        for d in [Dataset::DblpLike, Dataset::AsSkitterLike] {
            let g = d.graph(Scale::Tiny);
            let backend = PjrtTriangleBackend::new(&engine);
            b.bench(format!("rank/{}/tri_pjrt", d.name()), || {
                backend.per_vertex(&g).unwrap()
            });
        }
    } else {
        eprintln!("artifacts missing — skipping PJRT benches");
    }

    b.dump_json("results/bench_pivots_and_ranking.json");
}
