//! Bench: Figures 6/7/9 inputs — pool scheduling overhead (the
//! SIM_OVERHEAD_NS calibration) and trace-simulation speedup curves.
//! `cargo bench --bench scaling`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::coordinator::sim::simulate;
use parmce::experiments::fixtures;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::mce::ranking::RankStrategy;
use parmce::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();

    // --- pool overhead calibration: ns per spawned no-op task -------------
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);
        let n_tasks = 10_000u64;
        let med = b.bench(format!("pool/spawn_noop_t{threads}_x10k"), || {
            let c = Arc::new(AtomicU64::new(0));
            pool.scope(|s| {
                for _ in 0..n_tasks {
                    let c = Arc::clone(&c);
                    s.spawn(move |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(c.load(Ordering::Relaxed), n_tasks);
        });
        println!(
            "  -> per-task overhead ≈ {}ns (SIM_OVERHEAD_NS = {})",
            med / n_tasks,
            parmce::experiments::SIM_OVERHEAD_NS
        );
    }

    // --- simulated speedup curves (Figure 6 series) -----------------------
    for d in [Dataset::WikiTalkLike, Dataset::WikipediaLike] {
        let g = d.graph(Scale::Tiny);
        let s = fixtures::session(&g, 1);
        let (tr, _) = s.parmce_trace(RankStrategy::Degree);
        let t1 = tr.work_ns();
        for p in [1usize, 4, 16, 32] {
            b.bench(format!("simcurve/{}/p{p}", d.name()), || {
                simulate(&tr, p, parmce::experiments::SIM_OVERHEAD_NS)
            });
        }
        let s32 = simulate(&tr, 32, parmce::experiments::SIM_OVERHEAD_NS);
        println!(
            "  -> {}: work {:.1}ms span {:.2}ms speedup@32 {:.1}x util {:.0}%",
            d.name(),
            t1 as f64 / 1e6,
            tr.span_ns() as f64 / 1e6,
            s32.speedup(),
            100.0 * s32.utilization()
        );
    }

    b.dump_json("results/bench_scaling.json");
}
