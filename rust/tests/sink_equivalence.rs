//! Cross-sink equivalence: for every [`Algo`], the four sink shapes —
//! count, collect, histogram, streaming writer — must observe the same
//! enumeration.  Complements `session_equivalence.rs` (which pins the
//! clique *sets*) by pinning the *output pipeline*: sharded merge,
//! histogram binning, and writer line counts all reconcile with the
//! counted total, including under full parallel recursion
//! (`seq_cutoff: 0`) where every task emits concurrently.

use std::path::PathBuf;

use parmce::graph::csr::CsrGraph;
use parmce::graph::generators;
use parmce::session::{Algo, MceSession, RunOutcome, WriterFormat};

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("parmce_sink_equiv").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Count / collect / histogram / writer must agree for `algo` on `g`.
fn check_all_sinks(g: &CsrGraph, algo: Algo, threads: usize, seq_cutoff: usize, tag: &str) {
    let dir = temp_dir(tag);
    let s = MceSession::builder()
        .graph(g.clone())
        .threads(threads)
        .seq_cutoff(seq_cutoff)
        .build()
        .unwrap();

    let count_report = s.count(algo);
    assert_eq!(
        count_report.outcome,
        RunOutcome::Completed,
        "{tag}/{}: count run",
        algo.name()
    );
    let want = count_report.cliques;
    assert!(want > 0, "{tag}/{}: empty enumeration", algo.name());

    let (cliques, collect_report) = s.collect(algo);
    assert_eq!(
        cliques.len() as u64,
        want,
        "{tag}/{}: collect len vs count",
        algo.name()
    );
    assert_eq!(collect_report.cliques, want);

    let (hist, hist_report) = s.histogram(algo, 64);
    assert_eq!(hist.count(), want, "{tag}/{}: histogram count", algo.name());
    assert_eq!(hist.overflow(), 0, "{tag}/{}: unexpected overflow", algo.name());
    let binned: u64 = hist.nonzero_bins().iter().map(|&(_, c)| c).sum();
    assert_eq!(binned, want, "{tag}/{}: histogram bins", algo.name());
    assert_eq!(hist_report.cliques, want);

    let path = dir.join(format!("{}.txt", algo.name()));
    let (stream_report, stats) = s.stream_to(algo, &path, WriterFormat::Text).unwrap();
    assert_eq!(stream_report.cliques, want);
    assert_eq!(stats.cliques, want, "{tag}/{}: writer cliques", algo.name());
    assert_eq!(stats.dropped, 0);
    let lines = std::fs::read_to_string(&path).unwrap().lines().count() as u64;
    assert_eq!(lines, want, "{tag}/{}: writer line count", algo.name());
}

#[test]
fn every_algo_agrees_across_all_sink_shapes() {
    let graphs = [
        ("gnp", generators::gnp(20, 0.4, 11)),
        ("planted", generators::planted_cliques(36, 0.06, 3, 4, 6, 9)),
        ("moon_moser", generators::moon_moser(3)),
    ];
    for (tag, g) in &graphs {
        for &algo in Algo::all() {
            check_all_sinks(g, algo, 3, 32, tag);
        }
        // tests in this binary run concurrently: clean only our subdirs
        let _ = std::fs::remove_dir_all(temp_dir(tag));
    }
}

#[test]
fn sharded_merge_loses_nothing_under_full_parallel_recursion() {
    // seq_cutoff 0: every recursive call is its own pool task, so every
    // emit races every other — the stress case for shard routing and
    // merge-at-join
    let g = generators::planted_cliques(70, 0.05, 4, 4, 7, 5);
    let want = MceSession::builder()
        .graph(g.clone())
        .threads(1)
        .build()
        .unwrap()
        .count(Algo::Ttt)
        .cliques;
    assert!(want > 0);
    for &algo in &[Algo::ParTtt, Algo::ParMce] {
        check_all_sinks(&g, algo, 8, 0, "stress");
    }
    // the parallel collect must also reproduce the sequential set
    let s = MceSession::builder()
        .graph(g.clone())
        .threads(8)
        .seq_cutoff(0)
        .build()
        .unwrap();
    let (cliques, _) = s.collect(Algo::ParTtt);
    assert_eq!(cliques.len() as u64, want);
    let (seq_cliques, _) = MceSession::builder()
        .graph(g)
        .threads(1)
        .build()
        .unwrap()
        .collect(Algo::Ttt);
    assert_eq!(cliques, seq_cliques, "canonical sets diverge");
    let _ = std::fs::remove_dir_all(temp_dir("stress"));
}

#[test]
fn parallel_stream_writer_under_full_recursion_writes_every_clique() {
    let dir = temp_dir("stream_stress");
    let g = generators::moon_moser(4); // 81 cliques, heavy task fan-out
    let s = MceSession::builder()
        .graph(g)
        .threads(8)
        .seq_cutoff(0)
        .build()
        .unwrap();
    let path = dir.join("mm4.ndjson");
    let (report, stats) = s.stream_to(Algo::ParTtt, &path, WriterFormat::Ndjson).unwrap();
    assert_eq!(report.cliques, 81);
    assert_eq!(stats.cliques, 81);
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 81);
    // every line is a 4-member JSON array
    for line in text.lines() {
        assert!(line.starts_with('[') && line.ends_with(']'), "{line}");
        assert_eq!(line.matches(',').count(), 3, "{line}");
    }
    let _ = std::fs::remove_dir_all(temp_dir("stream_stress"));
}
