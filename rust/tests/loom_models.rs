//! Concurrency models for the four riskiest protocols in the crate,
//! written against the loom API shape and compiled only under
//! `RUSTFLAGS="--cfg loom"`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models --release
//! ```
//!
//! Under `--cfg loom` the whole crate builds against the instrumented
//! primitives in `util::loom_shim`, which perturb the scheduler at every
//! synchronization edge; `util::sync::model` re-runs each body across many
//! seeded schedules (`LOOM_MAX_ITERS`, default 64).  See the shim's module
//! docs for why this is a seeded stress explorer rather than the real loom
//! (offline build, no vendored crates) and what that does and does not
//! prove.
//!
//! Each model pins one protocol invariant:
//! * pool pending-counter / sleep-CV wakeup — every scope task runs, the
//!   scope join never hangs, task effects are visible after the join;
//! * pool shutdown-while-jobs-pending — dropping the pool with a queued
//!   backlog neither hangs the join nor leaks a job (regression for the
//!   ordering audit in `coordinator/pool.rs`);
//! * chashmap single-stripe insert/remove/contains — per-key linearizable
//!   win accounting under maximal stripe contention;
//! * SnapshotCell publish — a reader never observes a published version
//!   newer than the snapshot payload it loads;
//! * GraphCell publish — the same RCU handoff for delta-CSR graph
//!   snapshots: an observed graph epoch is never newer than the payload a
//!   subsequent load returns;
//! * sharded-sink merge-at-scope-join — per-worker shard counts merge to
//!   the exact emit total once the scope has joined;
//! * telemetry counter sweep — Relaxed per-shard adds from pool tasks
//!   sweep (Acquire) to the exact total after the scope join, the
//!   protocol every registry metric relies on.

#![cfg(loom)]

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::snapshot::{GraphCell, GraphSnapshot};
use parmce::mce::sink::{CliqueSink, ShardedCountSink};
use parmce::service::{CliqueSnapshot, SnapshotCell};
use parmce::util::chashmap::ConcurrentSet;
use parmce::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use parmce::util::sync::{model, Arc};

#[test]
fn pool_scope_runs_all_tasks() {
    model(|| {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&counter);
                s.spawn(move |_| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        // the Release fetch_sub chain in WaitGroup::done must make every
        // task's effect visible after the Acquire-observed join
        assert_eq!(counter.load(Ordering::Relaxed), 4, "scope lost a task");
    });
}

#[test]
fn pool_wakeup_is_not_lost() {
    model(|| {
        // one worker, tasks submitted from outside while the worker may be
        // parked on the sleep CV: the pending increment + notify must wake
        // it (or the bounded wait_timeout must recover) — a hang here is a
        // lost wakeup
        let pool = ThreadPool::new(1);
        let hits = Arc::new(AtomicUsize::new(0));
        for round in 0..3 {
            let h = Arc::clone(&hits);
            pool.scope(|s| {
                s.spawn(move |_| {
                    h.fetch_add(1, Ordering::Relaxed);
                });
            });
            assert_eq!(hits.load(Ordering::Relaxed), round + 1);
        }
    });
}

#[test]
fn pool_shutdown_with_pending_jobs() {
    model(|| {
        // regression: drop the last handle while fire-and-forget jobs are
        // still queued; workers must drain the backlog before exiting on
        // the shutdown flag, and the joining drop must not hang
        let pool = ThreadPool::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        for _ in 0..6 {
            let ran = Arc::clone(&ran);
            let stop = Arc::clone(&stop);
            pool.spawn(move || {
                if !stop.load(Ordering::SeqCst) {
                    std::hint::spin_loop();
                }
                ran.fetch_add(1, Ordering::SeqCst);
            });
        }
        stop.store(true, Ordering::SeqCst);
        drop(pool);
        assert_eq!(ran.load(Ordering::SeqCst), 6, "shutdown leaked a queued job");
    });
}

#[test]
fn chashmap_single_stripe_insert_remove() {
    model(|| {
        // all threads fight over ONE key, i.e. one stripe of the sharded
        // map: insert wins and remove wins must interleave as a strict
        // alternation per key (linearizable set semantics)
        let set: Arc<ConcurrentSet<u64>> = Arc::new(ConcurrentSet::new());
        let ins = Arc::new(AtomicUsize::new(0));
        let del = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..3)
            .map(|t| {
                let set = Arc::clone(&set);
                let ins = Arc::clone(&ins);
                let del = Arc::clone(&del);
                std::thread::spawn(move || {
                    for i in 0..4u64 {
                        if (t + i) % 2 == 0 {
                            if set.insert(7) {
                                ins.fetch_add(1, Ordering::SeqCst);
                            }
                        } else if set.remove(&7) {
                            del.fetch_add(1, Ordering::SeqCst);
                        }
                        // membership must always be a plain bool, never a
                        // torn state (this is the contains leg of the model)
                        let _ = set.contains(&7);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let i = ins.load(Ordering::SeqCst);
        let d = del.load(Ordering::SeqCst);
        let live = usize::from(set.contains(&7));
        assert_eq!(i, d + live, "{i} insert wins vs {d} remove wins, live={live}");
    });
}

#[test]
fn snapshot_cell_version_never_leads_payload() {
    model(|| {
        // writer publishes epochs 1..=3; a concurrent reader that observes
        // published_epoch() == e must then load a snapshot with epoch >= e
        // (the version tag is stored Release *before* the Arc swap under
        // the same mutex; the reader's Acquire load pairs with it)
        let cell = Arc::new(SnapshotCell::new(Arc::new(CliqueSnapshot::synthetic(0, 1))));
        let writer = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                for e in 1..=3u64 {
                    cell.publish(Arc::new(CliqueSnapshot::synthetic(e, 1)));
                }
            })
        };
        let reader = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..6 {
                    let e = cell.published_epoch();
                    let snap = cell.load();
                    assert!(
                        snap.epoch() >= e,
                        "reader saw version {e} but payload epoch {}",
                        snap.epoch()
                    );
                    assert!(e >= last, "published_epoch went backwards");
                    last = e;
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
    });
}

#[test]
fn graph_cell_epoch_never_leads_payload() {
    model(|| {
        // the graph-side twin of the SnapshotCell model: the batch writer
        // publishes graph epochs 1..=3 while an enumeration-side reader
        // samples published_epoch() then loads; the epoch it observed must
        // never be newer than the snapshot payload it gets (Release store
        // before the Arc swap under the same mutex, paired Acquire load)
        let cell = Arc::new(GraphCell::new(Arc::new(GraphSnapshot::synthetic(0, 2))));
        let writer = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                for e in 1..=3u64 {
                    cell.publish(Arc::new(GraphSnapshot::synthetic(e, 2)));
                }
            })
        };
        let reader = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..6 {
                    let e = cell.published_epoch();
                    let snap = cell.load();
                    assert!(
                        snap.epoch() >= e,
                        "reader saw graph epoch {e} but payload epoch {}",
                        snap.epoch()
                    );
                    assert!(e >= last, "published graph epoch went backwards");
                    last = e;
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
    });
}

#[test]
fn sharded_sink_merges_exactly_at_scope_join() {
    model(|| {
        let pool = ThreadPool::new(2);
        let sink = Arc::new(ShardedCountSink::for_pool(&pool));
        pool.scope(|s| {
            for _ in 0..4 {
                let sink = Arc::clone(&sink);
                s.spawn(move |_| {
                    for _ in 0..3 {
                        sink.emit(&[1, 2]);
                    }
                });
            }
        });
        // after the join the per-shard Relaxed counters must merge exactly
        assert_eq!(sink.count(), 12, "shard merge lost emits");
    });
}

#[test]
fn telemetry_counter_sweep_exact_after_join() {
    model(|| {
        // the registry metric protocol: Relaxed fetch_adds on per-worker
        // shards, Acquire sweep on snapshot.  While tasks run the sweep is
        // a lower bound; after the scope join (WaitGroup done=Release /
        // wait=Acquire) every shard write happens-before the sweep, so the
        // total must be exact — a loss here means a metric dropped counts
        let pool = ThreadPool::new(2);
        let c = Arc::new(parmce::telemetry::Counter::with_shards(3));
        pool.scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&c);
                s.spawn(move |_| {
                    for _ in 0..3 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 12, "telemetry sweep lost increments");
        assert_eq!(c.per_shard().iter().sum::<u64>(), 12);
    });
}
