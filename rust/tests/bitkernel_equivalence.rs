//! Bit-kernel equivalence: the dense bit-parallel subproblem kernel is a
//! pure execution-strategy change, so for every algorithm and every
//! hand-off threshold the enumerated clique set must be identical to the
//! slice-only path (`--bitset-cutoff 0`).  Cutoff 4 forces the hand-off
//! deep in the recursion, 64 mid-way, and the huge value runs entire
//! enumerations inside the kernel.

use parmce::graph::csr::CsrGraph;
use parmce::graph::{generators, Vertex};
use parmce::session::{Algo, DynAlgo, DynamicSession, MceSession};

fn fixtures() -> Vec<(&'static str, CsrGraph)> {
    vec![
        (
            "triangle_tail",
            CsrGraph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]),
        ),
        ("complete8", generators::complete(8)),
        ("moon_moser3", generators::moon_moser(3)),
        ("gnp24", generators::gnp(24, 0.45, 11)),
        ("planted", generators::planted_cliques(60, 0.04, 4, 4, 7, 5)),
        ("ring", generators::ring_of_cliques(5, 5, 2)),
        (
            "with_isolated",
            CsrGraph::from_edges(6, &[(0, 1), (1, 2), (0, 2)]),
        ),
        // non-contiguous live ids in a mostly-empty id space: the
        // relabel map must round-trip global ids exactly
        (
            "sparse_ids",
            CsrGraph::from_edges(64, &[(3, 17), (3, 29), (17, 29), (29, 41), (41, 57)]),
        ),
    ]
}

fn collect_at(g: &CsrGraph, algo: Algo, cutoff: usize) -> Vec<Vec<Vertex>> {
    let s = MceSession::builder()
        .graph(g.clone())
        .threads(3)
        .bitset_cutoff(cutoff)
        .build()
        .expect("session over an explicit graph");
    s.collect(algo).0
}

#[test]
fn all_algorithms_agree_across_bitset_cutoffs() {
    for (name, g) in fixtures() {
        for &algo in Algo::all() {
            let want = collect_at(&g, algo, 0);
            for cutoff in [4usize, 64, 1 << 20] {
                let got = collect_at(&g, algo, cutoff);
                assert_eq!(
                    got, want,
                    "{name}/{algo:?}: cutoff {cutoff} diverged from slice path"
                );
            }
        }
    }
}

#[test]
fn kernel_output_matches_the_oracle() {
    // not just self-consistent: the kernel-heavy configuration must also
    // match the independent reference enumerator
    for (name, g) in fixtures() {
        let want = parmce::mce::oracle::maximal_cliques(&g);
        let got = collect_at(&g, Algo::ParMce, 1 << 20);
        assert_eq!(got, want, "{name}");
    }
}

#[test]
fn dynamic_engines_agree_across_bitset_cutoffs() {
    let target = generators::gnp(14, 0.5, 33);
    let edges = target.edges();
    for algo in [DynAlgo::Imce, DynAlgo::ParImce] {
        let mut slice = DynamicSession::from_empty(14, algo).with_bitset_cutoff(0);
        let mut small = DynamicSession::from_empty(14, algo).with_bitset_cutoff(4);
        let mut huge = DynamicSession::from_empty(14, algo).with_bitset_cutoff(usize::MAX);
        for chunk in edges.chunks(6) {
            let want = slice.apply_batch(chunk);
            assert_eq!(small.apply_batch(chunk), want, "{algo:?} cutoff 4");
            assert_eq!(huge.apply_batch(chunk), want, "{algo:?} huge cutoff");
        }
        assert_eq!(slice.clique_count(), small.clique_count());
        assert_eq!(slice.clique_count(), huge.clique_count());
    }
}
