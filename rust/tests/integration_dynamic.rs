//! Dynamic-pipeline integration: streamed incremental maintenance must
//! converge to from-scratch enumeration on every dynamic dataset analog,
//! sequentially and in parallel, through growth and shrinkage.

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::registry::CliqueRegistry;
use parmce::dynamic::stream::{imce_remove_batch, replay, EdgeStream, Engine};
use parmce::graph::datasets::{Dataset, Scale};
use parmce::graph::snapshot::SnapshotGraph;
use parmce::mce::sink::CountSink;
use parmce::mce::ttt;

fn from_scratch(g: &parmce::graph::csr::CsrGraph) -> u64 {
    let s = CountSink::new();
    ttt::ttt(g, &s);
    s.count()
}

#[test]
fn replay_converges_on_all_dynamic_datasets() {
    for d in [
        Dataset::DblpLike,
        Dataset::WikipediaLike,
        Dataset::LiveJournalLike,
    ] {
        let g = d.graph(Scale::Tiny);
        let stream = EdgeStream::permuted(&g, 17);
        let (records, graph, registry) = replay(&stream, 50, Engine::Sequential, None);
        assert!(!records.is_empty());
        assert_eq!(
            registry.len() as u64,
            from_scratch(&graph.to_csr()),
            "{}",
            d.name()
        );
    }
}

#[test]
fn parallel_and_sequential_replay_identical_per_batch() {
    let d = Dataset::CaCitHepThLike; // the exponential-change regime
    let g = d.graph(Scale::Tiny);
    let stream = EdgeStream::permuted(&g, 23);
    let (seq, _, rs) = replay(&stream, 20, Engine::Sequential, Some(25));
    let pool = ThreadPool::new(4);
    let (par, _, rp) = replay(&stream, 20, Engine::Parallel(&pool), Some(25));
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.new_cliques, b.new_cliques, "batch {}", a.batch_index);
        assert_eq!(a.subsumed, b.subsumed, "batch {}", a.batch_index);
    }
    assert_eq!(rs.drain_canonical(), rp.drain_canonical());
}

#[test]
fn grow_then_shrink_roundtrip() {
    // add everything in batches, then remove half in batches; registry
    // must track from-scratch state at every checkpoint.
    let g = Dataset::DblpLike.graph(Scale::Tiny);
    let stream = EdgeStream::permuted(&g, 31);
    let (_, mut graph, registry) = replay(&stream, 60, Engine::Sequential, None);
    assert_eq!(registry.len() as u64, from_scratch(&graph.to_csr()));

    let mut removed = 0;
    for chunk in stream.edges.chunks(40) {
        imce_remove_batch(&mut graph, &registry, chunk);
        removed += chunk.len();
        assert_eq!(
            registry.len() as u64,
            from_scratch(&graph.to_csr()),
            "after removing {removed} edges"
        );
        if removed >= stream.edges.len() / 2 {
            break;
        }
    }
}

#[test]
fn change_size_extremes_from_paper_section5() {
    // O(1) change: near-complete graph completion
    let g = parmce::graph::generators::complete_minus_edge(12);
    let mut graph = SnapshotGraph::from_csr(&g);
    let registry = CliqueRegistry::from_graph(&g);
    let (r, _) = parmce::dynamic::imce_batch(&mut graph, &registry, &[(0, 1)]);
    assert_eq!(r.change_size(), 3, "paper §5: exactly 3");

    // exponential change: Moon–Moser + one edge
    let g = parmce::graph::generators::moon_moser(4); // 81 cliques
    let mut graph = SnapshotGraph::from_csr(&g);
    let registry = CliqueRegistry::from_graph(&g);
    let (r, _) = parmce::dynamic::imce_batch(&mut graph, &registry, &[(0, 1)]);
    // 27 new ({0,1} × one per other part³), 54 subsumed (all with 0 or 1)
    assert_eq!(r.new_cliques.len(), 27);
    assert_eq!(r.subsumed.len(), 54);
}
