//! Delta-CSR equivalence: a `SnapshotGraph` driven through randomized
//! mixed insert/remove batch traces must stay indistinguishable from the
//! legacy `DynGraph` — identical adjacency after every batch, identical
//! per-batch clique change sets (IMCE and ParIMCE vs an oracle diff of
//! from-scratch enumerations), and every published epoch's snapshot must
//! remain byte-identical after later batches and forced compactions.
//!
//! Each trace runs at both compaction extremes: `usize::MAX` (the overlay
//! is never folded, so reads always take the overlay-first path) and `0`
//! (every publish compacts, exercising the COW block rewrite).

use std::collections::BTreeSet;
use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::registry::CliqueRegistry;
use parmce::dynamic::stream::imce_remove_batch;
use parmce::dynamic::{imce_batch, par_imce_batch, BatchResult};
use parmce::graph::adj::DynGraph;
use parmce::graph::csr::CsrGraph;
use parmce::graph::generators;
use parmce::graph::snapshot::{GraphSnapshot, SnapshotGraph};
use parmce::graph::{Edge, Vertex};
use parmce::mce::oracle;
use parmce::util::rng::Rng;

enum Engine<'p> {
    Sequential,
    Parallel(&'p ThreadPool),
}

fn oracle_set(g: &CsrGraph) -> BTreeSet<Vec<Vertex>> {
    oracle::maximal_cliques(g).into_iter().collect()
}

/// Sample a batch of up to `k` distinct edges from the universe: absent
/// edges when inserting, present edges when removing.
fn sample_batch(
    rng: &mut Rng,
    universe: &[Edge],
    present: &BTreeSet<Edge>,
    insert: bool,
    k: usize,
) -> Vec<Edge> {
    let mut pool: Vec<Edge> = universe
        .iter()
        .copied()
        .filter(|e| present.contains(e) != insert)
        .collect();
    rng.shuffle(&mut pool);
    pool.truncate(k);
    pool
}

/// Drive one randomized trace and check every invariant per batch.
fn run_trace(engine: Engine<'_>, compact_threshold: usize, seed: u64) {
    let n = 26usize;
    let target = generators::gnp(n, 0.4, seed ^ 0x9e37);
    let universe = target.edges();
    assert!(universe.len() > 40, "fixture too sparse to be interesting");

    let mut rng = Rng::new(seed);
    let mut graph = SnapshotGraph::empty(n).with_compact_threshold(compact_threshold);
    let mut mirror = DynGraph::new(n);
    let registry = CliqueRegistry::new();
    for v in 0..n as Vertex {
        registry.insert_canonical(&[v]); // C(empty graph) = the singletons
    }

    let mut present: BTreeSet<Edge> = BTreeSet::new();
    let mut before = oracle_set(&mirror.to_csr());
    // every published epoch, pinned together with the adjacency it served
    let mut pinned: Vec<(Arc<GraphSnapshot>, Vec<Vec<Vertex>>)> = Vec::new();
    let mut batches = 0u64;

    for step in 0..16 {
        let insert = present.len() == universe.len()
            || (present.len() < universe.len() / 4)
            || rng.gen_bool(0.6);
        let insert = insert && present.len() < universe.len();
        let k = 1 + rng.gen_usize(7);
        let batch = sample_batch(&mut rng, &universe, &present, insert, k);
        if batch.is_empty() {
            continue;
        }

        // legacy mirror first: it is the independent source of truth
        if insert {
            mirror.insert_batch(&batch);
            present.extend(batch.iter().copied());
        } else {
            for &(u, v) in &batch {
                mirror.remove_edge(u, v);
                present.remove(&(u, v));
            }
        }

        let result: BatchResult = if insert {
            match engine {
                Engine::Sequential => imce_batch(&mut graph, &registry, &batch).0,
                Engine::Parallel(pool) => par_imce_batch(pool, &mut graph, &registry, &batch).0,
            }
        } else {
            imce_remove_batch(&mut graph, &registry, &batch)
        };
        batches += 1;

        // adjacency equivalence, writer view and published snapshot alike
        let snap = graph.current();
        assert_eq!(graph.epoch(), batches, "one publish per batch (step {step})");
        assert_eq!(snap.epoch(), batches);
        assert_eq!(graph.m(), mirror.m(), "edge count diverged at step {step}");
        for v in 0..n as Vertex {
            assert_eq!(
                graph.neighbors(v),
                mirror.neighbors(v),
                "writer adjacency of {v} diverged at step {step}"
            );
            assert_eq!(
                snap.neighbors(v),
                mirror.neighbors(v),
                "snapshot adjacency of {v} diverged at step {step}"
            );
        }

        // clique change set equivalence against the oracle diff
        let after = oracle_set(&mirror.to_csr());
        let got_new: BTreeSet<Vec<Vertex>> = result.new_cliques.iter().cloned().collect();
        let got_sub: BTreeSet<Vec<Vertex>> = result.subsumed.iter().cloned().collect();
        let want_new: BTreeSet<Vec<Vertex>> = after.difference(&before).cloned().collect();
        let want_sub: BTreeSet<Vec<Vertex>> = before.difference(&after).cloned().collect();
        assert_eq!(got_new, want_new, "Λnew wrong at step {step} (insert={insert})");
        assert_eq!(got_sub, want_sub, "Λdel wrong at step {step} (insert={insert})");
        assert_eq!(registry.len(), after.len(), "registry size at step {step}");
        for c in &after {
            assert!(registry.contains_canonical(c), "registry lost {c:?} at step {step}");
        }

        let adjacency: Vec<Vec<Vertex>> = (0..n as Vertex)
            .map(|v| snap.neighbors(v).to_vec())
            .collect();
        pinned.push((snap, adjacency));
        before = after;
    }

    assert!(batches >= 8, "trace too short to exercise the overlay");
    if compact_threshold == 0 {
        // every publish with a non-empty overlay folds it into the blocks
        assert!(
            graph.compactions() >= batches / 2,
            "threshold 0 barely compacted: {} compactions over {batches} batches",
            graph.compactions()
        );
        assert_eq!(graph.overlay_len(), 0, "threshold 0 leaves no overlay behind");
    } else {
        assert_eq!(graph.compactions(), 0, "usize::MAX threshold must never compact");
    }

    // a final forced compaction must not disturb any pinned epoch
    graph.compact();
    let _ = graph.publish();
    for (i, (snap, adjacency)) in pinned.iter().enumerate() {
        assert_eq!(snap.epoch(), (i + 1) as u64, "pinned epochs are dense");
        for v in 0..n as Vertex {
            assert_eq!(
                snap.neighbors(v),
                adjacency[v as usize].as_slice(),
                "pinned epoch {} changed retroactively at vertex {v}",
                snap.epoch()
            );
        }
    }
}

#[test]
fn imce_trace_matches_legacy_overlay_only() {
    run_trace(Engine::Sequential, usize::MAX, 11);
}

#[test]
fn imce_trace_matches_legacy_compact_every_batch() {
    run_trace(Engine::Sequential, 0, 12);
}

#[test]
fn par_imce_trace_matches_legacy_overlay_only() {
    let pool = ThreadPool::new(3);
    run_trace(Engine::Parallel(&pool), usize::MAX, 13);
}

#[test]
fn par_imce_trace_matches_legacy_compact_every_batch() {
    let pool = ThreadPool::new(3);
    run_trace(Engine::Parallel(&pool), 0, 14);
}
