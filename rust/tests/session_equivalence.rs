//! API-equivalence suite for the session layer: every [`Algo`] variant
//! routed through `MceSession` must produce the canonical clique set of
//! sequential TTT; budget/deadline/cancellation outcomes must surface as
//! [`RunOutcome`]s; and `DynamicSession`'s sequential and parallel
//! engines must produce identical [`BatchResult`]s over a replayed
//! stream.

use std::time::Duration;

use parmce::dynamic::stream::EdgeStream;
use parmce::dynamic::BatchResult;
use parmce::graph::csr::CsrGraph;
use parmce::graph::generators;
use parmce::graph::Vertex;
use parmce::session::{Algo, DynAlgo, DynamicSession, MceSession, RunOutcome, SinkSpec};

fn canonical(g: &CsrGraph, algo: Algo) -> Vec<Vec<Vertex>> {
    let s = MceSession::builder()
        .graph(g.clone())
        .threads(3)
        .build()
        .unwrap();
    let (cliques, report) = s.collect(algo);
    assert_eq!(
        report.outcome,
        RunOutcome::Completed,
        "{} did not complete",
        algo.name()
    );
    assert_eq!(
        report.cliques as usize,
        cliques.len(),
        "{}: report count vs collected count",
        algo.name()
    );
    cliques
}

#[test]
fn every_algo_variant_matches_ttt() {
    let graphs = vec![
        generators::gnp(22, 0.4, 11),
        generators::gnp(16, 0.65, 5),
        generators::moon_moser(3),
        generators::planted_cliques(40, 0.06, 3, 4, 6, 9),
        CsrGraph::from_edges(5, &[(0, 1)]), // isolated vertices
    ];
    for (i, g) in graphs.iter().enumerate() {
        let want = canonical(g, Algo::Ttt);
        assert!(!want.is_empty(), "graph {i}");
        for &algo in Algo::all() {
            assert_eq!(
                canonical(g, algo),
                want,
                "graph {i}: {} diverges from TTT",
                algo.name()
            );
        }
    }
}

#[test]
fn clique_enumerator_oom_surfaces_in_report() {
    // moon_moser(5): 243 maximal cliques on 15 vertices — 4 KiB is far
    // too small for per-clique bit vectors (the Table 8 OOM regime)
    let g = generators::moon_moser(5);
    let s = MceSession::builder()
        .graph(g)
        .mem_budget_bytes(4 * 1024)
        .build()
        .unwrap();
    let r = s.count(Algo::CliqueEnumerator);
    assert_eq!(r.outcome, RunOutcome::OutOfMemory);
}

#[test]
fn hashing_oom_on_intermediate_explosion() {
    // one 18-clique spawns ~2^18 intermediate subsets on the way up
    let g = generators::complete(18);
    let s = MceSession::builder()
        .graph(g)
        .mem_budget_bytes(64 * 1024)
        .build()
        .unwrap();
    let r = s.count(Algo::Hashing);
    assert_eq!(r.outcome, RunOutcome::OutOfMemory);
}

#[test]
fn peamc_deadline_surfaces_timeout() {
    let g = generators::moon_moser(7);
    let s = MceSession::builder()
        .graph(g)
        .threads(2)
        .deadline(Duration::from_micros(50))
        .build()
        .unwrap();
    let r = s.count(Algo::Peamc);
    assert_eq!(r.outcome, RunOutcome::TimedOut);
}

#[test]
fn cancelled_session_reports_cancelled() {
    let g = generators::gnp(20, 0.3, 1);
    let s = MceSession::builder().graph(g).build().unwrap();
    s.cancel();
    let r = s.count(Algo::Ttt);
    assert_eq!(r.outcome, RunOutcome::Cancelled);
    assert_eq!(r.cliques, 0);
    s.clear_cancel();
    assert_eq!(s.count(Algo::Ttt).outcome, RunOutcome::Completed);
    assert_eq!(s.history().len(), 2);
}

#[test]
fn sink_spec_controls_run_output() {
    let g = generators::gnp(18, 0.4, 3);
    let count = MceSession::builder()
        .graph(g.clone())
        .algo(Algo::Ttt)
        .build()
        .unwrap()
        .run();
    assert!(count.cliques.is_none() && count.histogram.is_none());

    let collect = MceSession::builder()
        .graph(g.clone())
        .algo(Algo::Ttt)
        .sink(SinkSpec::Collect)
        .build()
        .unwrap()
        .run();
    assert_eq!(
        collect.cliques.expect("collect sink").len() as u64,
        count.report.cliques
    );

    let hist = MceSession::builder()
        .graph(g)
        .algo(Algo::Ttt)
        .sink(SinkSpec::Histogram { max_size: 64 })
        .build()
        .unwrap()
        .run();
    assert_eq!(
        hist.histogram.expect("histogram sink").count(),
        count.report.cliques
    );
}

#[test]
fn batch_result_canonicalize_sorts_members_and_lists() {
    let mut r = BatchResult {
        new_cliques: vec![vec![3, 1, 2], vec![0, 2, 1]],
        subsumed: vec![vec![5, 4], vec![2, 0]],
    };
    r.canonicalize();
    assert_eq!(r.new_cliques, vec![vec![0, 1, 2], vec![1, 2, 3]]);
    assert_eq!(r.subsumed, vec![vec![0, 2], vec![4, 5]]);
    assert_eq!(r.change_size(), 4);
}

#[test]
fn dynamic_session_seq_and_par_agree_on_replayed_stream() {
    let g = generators::gnp(18, 0.45, 77);
    let stream = EdgeStream::permuted(&g, 13);
    let mut seq = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
    let mut par = DynamicSession::from_empty(stream.n, DynAlgo::ParImce).with_threads(3);
    for (i, batch) in stream.edges.chunks(6).enumerate() {
        let a = seq.apply_batch(batch);
        let b = par.apply_batch(batch);
        assert_eq!(a, b, "batch {i}: sequential vs parallel change set");
    }
    assert_eq!(seq.clique_count(), par.clique_count());
    // converged state equals from-scratch enumeration via the static API
    let want = MceSession::builder()
        .graph(seq.csr())
        .threads(1)
        .build()
        .unwrap()
        .count(Algo::Ttt)
        .cliques;
    assert_eq!(seq.clique_count() as u64, want);
}

#[test]
fn dynamic_session_replay_and_remove_roundtrip() {
    let g = generators::planted_cliques(30, 0.06, 3, 4, 6, 4);
    let stream = EdgeStream::permuted(&g, 3);
    let mut s = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
    let records = s.replay(&stream, 10, None);
    assert!(!records.is_empty());
    assert_eq!(s.graph().m(), g.m());
    let (new_total, _) = s.change_totals();
    assert!(new_total > 0);

    let removed: Vec<_> = stream.edges[..5.min(stream.edges.len())].to_vec();
    s.remove_batch(&removed);
    let want = MceSession::builder()
        .graph(s.csr())
        .threads(1)
        .build()
        .unwrap()
        .count(Algo::Ttt)
        .cliques;
    assert_eq!(s.clique_count() as u64, want);
}
