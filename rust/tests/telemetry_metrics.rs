//! End-to-end telemetry reconciliation: the acceptance gates for the
//! observability layer, run in their own test binary (own process) so the
//! process-global registry can be asserted *exactly*.
//!
//! Every test takes the shared `serial()` lock: the registry is global,
//! and exact-equality assertions (delta == sink count) only hold when no
//! other enumeration is concurrently bumping the same counters.  Library
//! unit tests stay `>=`-style for that reason; the exact checks live here.
//!
//! Under `--features telemetry-off` the same tests assert the inverse
//! contract: every metric reads zero while results stay correct.

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::generators;
use parmce::mce::sink::{CliqueSink, ShardedCountSink};
use parmce::service::{serve_replay, CliqueService, DriverConfig};
use parmce::session::{Algo, DynAlgo, DynamicSession, MceSession};
use parmce::telemetry::{self, names, WORKER_SHARDS};
use parmce::util::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Serialize the tests in this binary: the registry is process-global.
fn serial() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

const OFF: bool = cfg!(feature = "telemetry-off");

#[test]
fn enumerate_delta_equals_sink_count_exactly() {
    let _gate = serial();
    let g = generators::planted_cliques(200, 0.04, 6, 5, 9, 13);
    let session = MceSession::builder()
        .graph(g)
        .algo(Algo::ParMce)
        .threads(4)
        .build()
        .unwrap();

    let sink = Arc::new(ShardedCountSink::new(4));
    let dyn_sink: Arc<dyn CliqueSink> = sink.clone();
    let report = session.run_with_sink(Algo::ParMce, &dyn_sink);

    let d = report.telemetry.as_ref().expect("run harness attaches telemetry");
    if OFF {
        assert_eq!(d.counter(names::CLIQUES_EMITTED), Some(0));
        return;
    }
    // the headline acceptance gate: the metric and the sink agree exactly
    assert_eq!(report.cliques, sink.count());
    assert_eq!(d.counter(names::CLIQUES_EMITTED), Some(report.cliques));
    assert!(d.counter(names::PARTTT_TASKS_SPAWNED).unwrap() > 0);
    // every job the run spawned was dequeued by the time the scope joined
    assert_eq!(
        d.counter(names::POOL_JOBS_SPAWNED),
        d.counter(names::POOL_JOBS_DEQUEUED),
        "a queued job was lost or double-counted"
    );
    // ... and the depth gauge is back to empty (instantaneous, global)
    assert_eq!(telemetry::snapshot().gauge(names::POOL_QUEUE_DEPTH), Some(0));
}

#[test]
fn multi_thread_run_attributes_per_worker_busy_ns() {
    let _gate = serial();
    let g = generators::planted_cliques(240, 0.04, 6, 5, 9, 29);
    let session = MceSession::builder()
        .graph(g)
        .algo(Algo::ParTtt)
        .threads(4)
        .build()
        .unwrap();
    let report = session.count(Algo::ParTtt);
    let d = report.telemetry.as_ref().unwrap();
    let busy = d
        .counters
        .iter()
        .find(|c| c.name == names::POOL_WORKER_BUSY_NS)
        .expect("busy-ns counter present");
    if OFF {
        assert!(busy.shards.is_empty());
        assert_eq!(busy.total, 0);
        return;
    }
    // pool workers (shards 0..WORKER_SHARDS) did the subtree work; the
    // scope caller helping via try_run_one lands in the external shard
    // and must not be the only contributor on a 4-thread run
    let worker_ns: u64 = busy.shards[..WORKER_SHARDS.min(busy.shards.len())]
        .iter()
        .sum();
    assert!(worker_ns > 0, "no pool worker recorded busy time");
    assert!(busy.total >= worker_ns);
}

#[test]
fn serve_replay_lag_gauge_matches_driver_report() {
    let _gate = serial();
    let g = generators::gnp(16, 0.4, 5);
    let stream = EdgeStream::permuted(&g, 3);
    let mut svc = CliqueService::wrap(DynamicSession::from_empty(stream.n, DynAlgo::Imce));
    let pool = ThreadPool::new(2);
    let cfg = DriverConfig {
        batch_size: 6,
        readers: 2,
        queries_per_round: 4,
        seed: 9,
        ..DriverConfig::default()
    };

    let before = telemetry::snapshot();
    let report = serve_replay(&mut svc, &stream, &pool, &cfg);
    let after = telemetry::snapshot();

    if OFF {
        assert_eq!(after.gauge(names::SERVICE_EPOCH_LAG_MAX), Some(0));
        assert_eq!(after.counter(names::SERVICE_QUERIES), Some(0));
        return;
    }
    // the lag high-water gauge only rises (fetch_max), so after the run it
    // is exactly the larger of its prior value and this run's max lag
    let before_max = before.gauge(names::SERVICE_EPOCH_LAG_MAX).unwrap();
    let after_max = after.gauge(names::SERVICE_EPOCH_LAG_MAX).unwrap();
    assert_eq!(after_max, before_max.max(report.max_epoch_lag));

    // serialized process: the replay window's deltas reconcile exactly
    let d = after.delta(&before);
    assert_eq!(d.counter(names::SERVICE_PUBLISHES), Some(report.updates as u64));
    assert_eq!(d.counter(names::SERVICE_QUERIES), Some(report.queries));
    assert_eq!(d.counter(names::SERVICE_EPOCH_LAG_SAMPLES), Some(report.lag_samples));
    assert_eq!(d.counter(names::SERVICE_EPOCH_LAG_SUM), Some(report.lag_sum));
    assert_eq!(
        after.gauge(names::SERVICE_PUBLISHED_EPOCH),
        Some(report.final_epoch)
    );
    assert_eq!(d.counter(names::DYNAMIC_BATCHES), Some(report.updates as u64));

    // the embedded delta says the same thing as our own before/after pair
    let embedded = report.telemetry.as_ref().unwrap();
    assert_eq!(
        embedded.counter(names::SERVICE_QUERIES),
        d.counter(names::SERVICE_QUERIES)
    );
}

#[test]
fn metrics_out_renderings_stay_in_sync() {
    let _gate = serial();
    // run something so the dump is non-trivial, then render both formats
    let g = generators::gnp(30, 0.3, 7);
    let session = MceSession::builder().graph(g).threads(2).build().unwrap();
    let report = session.count(Algo::Ttt);

    let snap = telemetry::snapshot();
    let prom = telemetry::render_for_path(&snap, "metrics.prom");
    let json = telemetry::render_for_path(&snap, "metrics.json");
    assert!(prom.contains("# TYPE parmce_cliques_emitted_total counter"));
    let parsed = parmce::util::json::parse(&json).expect("JSON dump parses");
    let counters = parsed.get("counters").unwrap().as_arr().unwrap();
    let emitted = counters
        .iter()
        .find(|c| c.get("name").unwrap().as_str() == Some(names::CLIQUES_EMITTED))
        .unwrap();
    if OFF {
        assert_eq!(emitted.get("total").unwrap().as_f64(), Some(0.0));
    } else {
        // cumulative registry ≥ this run's cliques; serialized, so the
        // text exposition carries the identical total
        let total = emitted.get("total").unwrap().as_f64().unwrap() as u64;
        assert!(total >= report.cliques);
        assert!(prom.contains(&format!("{} {}", names::CLIQUES_EMITTED, total)));
    }
}
