//! Cross-algorithm integration: every enumerator in the repo must agree
//! on every dataset analog — the strongest correctness statement we can
//! make above unit level (nine independent implementations, one answer).

use std::sync::Arc;
use std::time::Duration;

use parmce::baselines::{bk, clique_enumerator, greedybb, hashing, peco};
use parmce::coordinator::pool::ThreadPool;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::mce::oracle;
use parmce::mce::parmce::parmce;
use parmce::mce::parttt::parttt;
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::mce::sink::{CliqueSink, CountSink};
use parmce::mce::{ttt, ParMceConfig, ParTttConfig};
use parmce::util::membudget::MemBudget;

fn count_ttt(g: &parmce::graph::csr::CsrGraph) -> u64 {
    let s = CountSink::new();
    ttt::ttt(g, &s);
    s.count()
}

#[test]
fn all_enumerators_agree_on_all_tiny_datasets() {
    let pool = ThreadPool::new(3);
    for d in Dataset::all() {
        let g = d.graph(Scale::Tiny);
        let want = count_ttt(&g);
        assert!(want > 0, "{}", d.name());

        // ParTTT
        let ga = Arc::new(g.clone());
        let s = Arc::new(CountSink::new());
        let ds: Arc<dyn CliqueSink> = s.clone();
        parttt(&pool, &ga, &ds, ParTttConfig::default());
        assert_eq!(s.count(), want, "{}: ParTTT", d.name());

        // ParMCE under all rankings
        for strat in [
            RankStrategy::Degree,
            RankStrategy::Degeneracy,
            RankStrategy::Triangle,
        ] {
            let ranking = Arc::new(Ranking::compute(&g, strat));
            let s = Arc::new(CountSink::new());
            let ds: Arc<dyn CliqueSink> = s.clone();
            parmce(&pool, &ga, &ranking, &ds, ParMceConfig::default());
            assert_eq!(s.count(), want, "{}: ParMCE{}", d.name(), strat.name());
        }

        // PECO
        let ranking = Arc::new(Ranking::compute(&g, RankStrategy::Degree));
        let s = Arc::new(CountSink::new());
        let ds: Arc<dyn CliqueSink> = s.clone();
        peco::peco(&pool, &ga, &ranking, &ds, parmce::mce::DEFAULT_BITSET_CUTOFF);
        assert_eq!(s.count(), want, "{}: PECO", d.name());

        // BK family
        let s = CountSink::new();
        bk::bk_pivot(&g, &s);
        assert_eq!(s.count(), want, "{}: bk_pivot", d.name());
        let s = CountSink::new();
        bk::bk_degeneracy(&g, &s);
        assert_eq!(s.count(), want, "{}: bk_degeneracy", d.name());
    }
}

#[test]
fn memory_bound_baselines_agree_when_unbounded() {
    // smaller graph: these baselines are exponential in space/time
    let g = Dataset::DblpLike.graph(Scale::Tiny);
    let want = count_ttt(&g);

    let s = CountSink::new();
    hashing::hashing(&g, &s, &MemBudget::unlimited()).unwrap();
    assert_eq!(s.count(), want, "hashing");

    let s = CountSink::new();
    clique_enumerator::clique_enumerator(&g, &s, &MemBudget::unlimited()).unwrap();
    assert_eq!(s.count(), want, "clique_enumerator");

    let s = CountSink::new();
    greedybb::greedybb(&g, &s, &MemBudget::unlimited(), Duration::from_secs(300)).unwrap();
    assert_eq!(s.count(), want, "greedybb");
}

#[test]
fn emitted_cliques_are_valid_on_moderate_graph() {
    // full validation (clique-ness, maximality, no dup, completeness)
    let g = parmce::graph::generators::planted_cliques(60, 0.06, 3, 5, 7, 99);
    let pool = ThreadPool::new(2);
    let ranking = Arc::new(Ranking::compute(&g, RankStrategy::Degree));
    let ga = Arc::new(g.clone());
    let collect = Arc::new(parmce::mce::sink::CollectSink::new());
    let ds: Arc<dyn CliqueSink> = collect.clone();
    parmce(&pool, &ga, &ranking, &ds, ParMceConfig::default());
    drop(ds);
    let cliques = Arc::try_unwrap(collect).ok().unwrap().into_canonical();
    oracle::validate(&g, &cliques).unwrap();
}

#[test]
fn histogram_consistency_across_algorithms() {
    let g = Dataset::OrkutLike.graph(Scale::Tiny);
    let h1 = parmce::mce::sink::SizeHistogram::new(128);
    ttt::ttt(&g, &h1);

    let pool = ThreadPool::new(3);
    let ga = Arc::new(g);
    let h2 = Arc::new(parmce::mce::sink::SizeHistogram::new(128));
    let ds: Arc<dyn CliqueSink> = h2.clone();
    parttt(&pool, &ga, &ds, ParTttConfig::default());

    assert_eq!(h1.count(), h2.count());
    assert_eq!(h1.max_size(), h2.max_size());
    assert_eq!(h1.nonzero_bins(), h2.nonzero_bins());
}
