//! Chaos suite (ISSUE 9): randomized, seeded fault schedules injected at
//! the failpoint seams, asserting the crate's resilience contract —
//! **every run returns a structured `RunReport`** (no hang, no abort, no
//! poisoned-lock cascade), faults carry partial progress, and schedules
//! that inject nothing leave results bit-identical to the oracle.
//!
//! Compiled only with `--features failpoints`; the registry is
//! process-global, so every test serializes on `failpoints::exclusive()`.

#![cfg(feature = "failpoints")]

use std::sync::mpsc;
use std::time::Duration;

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::generators;
use parmce::mce::oracle;
use parmce::service::{serve_replay, CliqueService, DriverConfig};
use parmce::session::{Algo, DynAlgo, DynamicSession, MceSession, RunOutcome, WriterFormat};
use parmce::util::failpoints as fp;
use parmce::util::rng::Rng;

/// Hard cap on any single chaos run: a fault that hangs a join or strands
/// a reader loop fails loudly here instead of wedging CI.
const WATCHDOG: Duration = Duration::from_secs(120);

/// Run `f` on its own thread; panic if it neither returns nor panics
/// within [`WATCHDOG`].  A panic in `f` is re-raised on the caller so
/// `#[should_panic]`-free tests still report the real failure.
fn with_watchdog<T: Send + 'static>(f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(WATCHDOG) {
        Ok(v) => {
            let _ = worker.join();
            v
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(()) => unreachable!("sender dropped without sending or panicking"),
        },
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: chaos run did not return within {WATCHDOG:?}")
        }
    }
}

fn arm(site: fp::Site, action: fp::Action, trigger: fp::Trigger, seed: u64) {
    fp::configure(
        site,
        fp::SiteConfig {
            action,
            trigger,
            seed,
        },
    );
}

/// Every algorithm × every fault action, on a randomized (but seeded —
/// reruns see the same schedule) hit index at the universal `sink-emit`
/// seam, with `pool-dequeue` armed alongside for the parallel engines.
/// The single assertion that matters: a `RunReport` always comes back,
/// and it carries partial progress exactly when the run did not complete.
#[test]
fn every_algo_survives_every_fault_action() {
    let _x = fp::exclusive();
    let g = generators::gnp(26, 0.3, 9);
    let actions = [
        fp::Action::Panic,
        fp::Action::ReturnError,
        fp::Action::Delay(1),
    ];
    for (ai, &algo) in Algo::ALL.iter().enumerate() {
        for (bi, &action) in actions.iter().enumerate() {
            let mut rng = Rng::new(0xC0FFEE ^ ((ai as u64) << 8) ^ bi as u64);
            // fires somewhere in the first ~40 emits — sometimes mid-run,
            // sometimes past the end (a schedule that never fires is a
            // valid schedule and must complete normally)
            let k = 1 + rng.gen_range(40);
            fp::clear();
            arm(fp::Site::SinkEmit, action, fp::Trigger::OnHit(k), k);
            if action == fp::Action::Panic {
                arm(
                    fp::Site::PoolDequeue,
                    action,
                    fp::Trigger::OnHit(3 + rng.gen_range(20)),
                    k,
                );
            }
            let g = g.clone();
            let report = with_watchdog(move || {
                let session = MceSession::builder()
                    .graph(g)
                    .algo(algo)
                    .threads(2)
                    .build()
                    .unwrap();
                session.count(algo)
            });
            fp::clear();
            assert_eq!(report.algo, algo);
            assert_eq!(
                report.partial.is_some(),
                report.outcome != RunOutcome::Completed,
                "{algo:?}/{action:?}: partial must accompany exactly the faulted outcomes \
                 (got {:?})",
                report.outcome
            );
            if let RunOutcome::Panicked { site, message } = &report.outcome {
                assert!(
                    site == "sink-emit" || site == "pool-dequeue",
                    "{algo:?}: panic attributed to unexpected site {site} ({message})"
                );
            }
        }
    }
}

/// Schedules that inject nothing — unarmed, armed-but-never-firing, and
/// delay-only — must leave every algorithm's clique count identical to
/// the sequential oracle.
#[test]
fn zero_fault_schedules_match_oracle() {
    let _x = fp::exclusive();
    let g = generators::gnp(24, 0.3, 17);
    let want = oracle::maximal_cliques(&g).len() as u64;
    for &algo in Algo::ALL.iter() {
        for schedule in 0..3u32 {
            fp::clear();
            match schedule {
                0 => {} // registry empty
                1 => arm(
                    // armed but out of reach: the graph has nowhere near
                    // a million cliques
                    fp::Site::SinkEmit,
                    fp::Action::Panic,
                    fp::Trigger::OnHit(1_000_000),
                    0,
                ),
                _ => arm(
                    // delay perturbs timing only, never results
                    fp::Site::SinkEmit,
                    fp::Action::Delay(1),
                    fp::Trigger::OnHit(3),
                    0,
                ),
            }
            let g = g.clone();
            let report = with_watchdog(move || {
                let session = MceSession::builder()
                    .graph(g)
                    .algo(algo)
                    .threads(2)
                    .build()
                    .unwrap();
                session.count(algo)
            });
            fp::clear();
            assert_eq!(
                report.outcome,
                RunOutcome::Completed,
                "{algo:?} schedule {schedule}"
            );
            assert!(report.partial.is_none(), "{algo:?} schedule {schedule}");
            assert_eq!(report.cliques, want, "{algo:?} schedule {schedule}");
        }
    }
}

/// ISSUE 9 acceptance: a panic injected mid-enumeration into a 4-thread
/// ParTTT run yields `RunOutcome::Panicked` with non-empty partial
/// progress — the cliques emitted before the fault survive the unwind.
#[test]
fn parttt_mid_run_panic_yields_partial_progress() {
    let _x = fp::exclusive();
    fp::clear();
    let g = generators::gnp(40, 0.3, 5);
    assert!(
        oracle::maximal_cliques(&g).len() > 20,
        "graph too sparse to panic mid-run"
    );
    // hits 1..=9 emit normally, the 10th emit unwinds its worker
    arm(
        fp::Site::SinkEmit,
        fp::Action::Panic,
        fp::Trigger::OnHit(10),
        0,
    );
    let report = with_watchdog(move || {
        let session = MceSession::builder()
            .graph(g)
            .algo(Algo::ParTtt)
            .threads(4)
            .build()
            .unwrap();
        session.count(Algo::ParTtt)
    });
    fp::clear();
    match &report.outcome {
        RunOutcome::Panicked { site, message } => {
            assert_eq!(site, "sink-emit");
            assert_eq!(message, "failpoint sink-emit: injected panic");
        }
        other => panic!("expected Panicked, got {other:?}"),
    }
    let partial = report.partial.as_ref().expect("faulted run carries partial");
    assert!(
        !partial.is_empty(),
        "nine emits preceded the fault, partial must be non-empty: {partial:?}"
    );
    assert!(partial.cliques_emitted >= 9);
    assert_eq!(partial.cliques_emitted, report.cliques);
}

/// ISSUE 9 acceptance: a panic injected into the serve-replay writer
/// (at the epoch-publish seam) ends the replay with a `Panicked` outcome
/// and a partial report — readers stop, the scope drains, nothing hangs.
#[test]
fn serve_replay_publish_panic_degrades_gracefully() {
    let _x = fp::exclusive();
    fp::clear();
    let g = generators::gnp(14, 0.4, 21);
    // the first batch publishes epoch 1 cleanly; the second publish panics
    arm(
        fp::Site::GraphPublish,
        fp::Action::Panic,
        fp::Trigger::OnHit(2),
        0,
    );
    let report = with_watchdog(move || {
        let stream = EdgeStream::permuted(&g, 3);
        let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
        let pool = ThreadPool::new(2);
        let cfg = DriverConfig {
            batch_size: 5,
            readers: 2,
            queries_per_round: 4,
            seed: 11,
            ..DriverConfig::default()
        };
        serve_replay(&mut svc, &stream, &pool, &cfg)
    });
    fp::clear();
    match &report.outcome {
        RunOutcome::Panicked { site, .. } => assert_eq!(site, "graph-publish"),
        other => panic!("expected Panicked, got {other:?}"),
    }
    let partial = report.partial.as_ref().expect("faulted replay carries partial");
    assert_eq!(
        partial.batches_applied, 1,
        "exactly the pre-fault batch was applied: {partial:?}"
    );
    assert!(!partial.is_empty());
    assert_eq!(report.updates, 1);
}

/// A `dynamic-apply` fault rejects a batch *before any mutation*: the
/// error names the exact boundary, the session still sits on it, and —
/// once the fault clears — replaying from that boundary converges to the
/// oracle clique set.
#[test]
fn dynamic_batch_fault_reports_exact_boundary() {
    let _x = fp::exclusive();
    fp::clear();
    let g = generators::gnp(16, 0.35, 13);
    let stream = EdgeStream::permuted(&g, 7);
    let want = oracle::maximal_cliques(&g).len();
    // the 3rd admission check rejects its batch
    arm(
        fp::Site::DynamicApply,
        fp::Action::ReturnError,
        fp::Trigger::OnHit(3),
        0,
    );
    let mut session = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
    let mut applied = 0usize;
    let mut pending: Vec<Vec<_>> = Vec::new();
    for batch in stream.batches(6) {
        if !pending.is_empty() {
            pending.push(batch.to_vec());
            continue;
        }
        match session.try_apply_batch(batch) {
            Ok(_) => applied += 1,
            Err(e) => {
                assert_eq!(applied, 2, "fault must strike the third batch");
                assert_eq!(
                    e.batches_applied, applied,
                    "error reports the exact pre-fault boundary"
                );
                assert_eq!(e.batches_applied, session.batches_applied());
                assert!(e.message.contains("dynamic-apply"));
                assert_eq!(
                    format!("{e}"),
                    format!("{} ({} batches already applied)", e.message, e.batches_applied)
                );
                pending.push(batch.to_vec());
            }
        }
    }
    assert!(!pending.is_empty(), "the fault must have fired");
    fp::clear();
    // resume from the reported boundary: the rejected batch mutated
    // nothing, so replaying it (and the rest) reaches the full C(G)
    for batch in &pending {
        session.apply_batch(batch);
    }
    assert_eq!(session.clique_count(), want);
    assert_eq!(session.batches_applied(), 2 + pending.len());
}

/// A sticky I/O fault at the writer's flush seam mid-run: the session
/// degrades to `RunOutcome::SinkFailed` with the pre-fault byte/clique
/// accounting instead of panicking or silently truncating output.
#[test]
fn stream_sink_flush_fault_degrades_to_sink_failed() {
    let _x = fp::exclusive();
    fp::clear();
    let g = generators::gnp(30, 0.3, 29);
    let out = std::env::temp_dir().join(format!(
        "parmce-chaos-{}-flush.ndjson",
        std::process::id()
    ));
    arm(
        fp::Site::SinkFlush,
        fp::Action::ReturnError,
        fp::Trigger::Always,
        0,
    );
    let out_cl = out.clone();
    let report = with_watchdog(move || {
        let session = MceSession::builder()
            .graph(g)
            .algo(Algo::Ttt)
            .threads(2)
            .stream(&out_cl, WriterFormat::Ndjson)
            .build()
            .unwrap();
        session.run().report
    });
    fp::clear();
    let _ = std::fs::remove_file(&out);
    match &report.outcome {
        RunOutcome::SinkFailed { message } => {
            assert!(
                message.contains("sink-flush") || message.contains("flush"),
                "sink error should name the flush fault: {message}"
            );
        }
        other => panic!("expected SinkFailed, got {other:?}"),
    }
    let partial = report.partial.as_ref().expect("sink fault carries partial");
    assert_eq!(partial.cliques_emitted, report.cliques);
    assert!(!partial.is_empty(), "cliques were emitted before the flush fault");
}
