//! L1↔L3 contract tests: the AOT Pallas artifacts executed via PJRT must
//! agree exactly with the CPU oracle on real graphs, and the manifest's
//! shape constants must match what the Rust tile scheduler assumes.
//! (Requires `make artifacts`; tests skip gracefully when missing.)

use parmce::graph::datasets::{Dataset, Scale};
use parmce::graph::{generators, triangles};
use parmce::mce::ranking::{RankStrategy, Ranking, TriangleBackend};
use parmce::runtime::engine::Engine;
use parmce::runtime::tri_rank::{PjrtTiledBackend, PjrtTriangleBackend};

fn engine() -> Option<Engine> {
    match Engine::load_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping artifact tests: {err}");
            None
        }
    }
}

#[test]
fn kernel_counts_match_cpu_on_dataset_analogs() {
    let Some(e) = engine() else { return };
    let backend = PjrtTriangleBackend::new(&e);
    for d in [Dataset::DblpLike, Dataset::WikiTalkLike, Dataset::OrkutLike] {
        let g = d.graph(Scale::Tiny);
        let got = backend.per_vertex(&g).unwrap();
        assert_eq!(got, triangles::per_vertex(&g), "{}", d.name());
    }
}

#[test]
fn tiled_schedule_exact_on_non_tile_aligned_sizes() {
    let Some(e) = engine() else { return };
    let backend = PjrtTiledBackend(PjrtTriangleBackend::new(&e));
    for n in [100usize, 257, 300] {
        let g = generators::gnp(n, 0.08, n as u64);
        assert_eq!(
            backend.per_vertex(&g).unwrap(),
            triangles::per_vertex(&g),
            "n={n}"
        );
    }
}

#[test]
fn pjrt_ranking_orders_identically_to_cpu_ranking() {
    let Some(e) = engine() else { return };
    let g = Dataset::AsSkitterLike.graph(Scale::Tiny);
    let backend = PjrtTriangleBackend::new(&e);
    let pjrt = Ranking::compute_with(&g, RankStrategy::Triangle, &backend).unwrap();
    let cpu = Ranking::compute(&g, RankStrategy::Triangle);
    for v in 0..g.n() as u32 {
        for w in 0..g.n() as u32 {
            assert_eq!(pjrt.higher(v, w), cpu.higher(v, w), "({v},{w})");
        }
    }
}

#[test]
fn manifest_constants_match_tile_scheduler_assumptions() {
    let Some(e) = engine() else { return };
    let tile_b = e.constant("TILE_B").unwrap();
    let full_n = e.constant("FULL_N").unwrap();
    assert!(tile_b.is_power_of_two());
    assert!(full_n % 128 == 0, "FULL_N must be a multiple of the kernel block");
    // the python test suite asserts the same constants from the L2 side
}

#[test]
fn empty_and_triangle_free_graphs() {
    let Some(e) = engine() else { return };
    let backend = PjrtTriangleBackend::new(&e);
    let star = parmce::graph::csr::CsrGraph::from_edges(
        64,
        &(1..64u32).map(|v| (0, v)).collect::<Vec<_>>(),
    );
    assert_eq!(backend.per_vertex(&star).unwrap(), vec![0u64; 64]);
}
