//! Ingest-pipeline equivalence suite (ISSUE 10): every parallel ingest
//! stage — edge-list parsing, CSR construction, triangle counting, core
//! decomposition, ranking — must be byte-identical to its sequential
//! reference at every thread count, on randomized graphs and on the
//! parser's awkward corners (non-contiguous ids, self-loops, comments).

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::csr::CsrGraph;
use parmce::graph::{degeneracy, edgelist, generators, triangles};
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::session::{Algo, MceSession};

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Render a graph as an edge-list document with noise the parser must
/// cope with: comments, blank lines, and sprinkled self-loops.
fn render_noisy(g: &CsrGraph, self_loop_every: usize) -> String {
    let mut text = String::from("# ingest equivalence fixture\n% percent comments too\n\n");
    for (i, (u, v)) in g.edges().into_iter().enumerate() {
        if self_loop_every > 0 && i % self_loop_every == 0 {
            text.push_str(&format!("{u} {u}\n"));
        }
        text.push_str(&format!("{u} {v}\n"));
    }
    text
}

#[test]
fn parallel_parse_matches_sequential_on_random_graphs() {
    for seed in [3u64, 17, 99] {
        let g = generators::gnp(120, 0.08, seed);
        let text = render_noisy(&g, 7);
        let seq = edgelist::parse_report(text.as_bytes()).unwrap();
        for threads in THREADS {
            let pool = ThreadPool::new(threads);
            let par = edgelist::parse_report_parallel(&text, &pool).unwrap();
            assert_eq!(par.n, seq.n, "seed={seed} threads={threads}");
            assert_eq!(par.self_loops, seq.self_loops);
            assert_eq!(par.edges, seq.edges, "seed={seed} threads={threads}");
        }
    }
}

#[test]
fn parallel_parse_preserves_first_appearance_interning() {
    // non-contiguous, descending, and repeated raw ids: interning order
    // (first appearance) decides the dense id space, so any chunk-order
    // slip would renumber vertices and change every downstream stage
    let text = "900 7\n7 900\n42 900\n5 5\n42 7\n900 1000000\n";
    let seq = edgelist::parse_report(text.as_bytes()).unwrap();
    assert_eq!(seq.n, 4, "900, 7, 42, 1000000 → four dense ids");
    assert_eq!(seq.self_loops, 1);
    for threads in THREADS {
        let pool = ThreadPool::new(threads);
        let par = edgelist::parse_report_parallel(text, &pool).unwrap();
        assert_eq!(par.edges, seq.edges, "threads={threads}");
        assert_eq!(par.n, seq.n);
        assert_eq!(par.self_loops, seq.self_loops);
    }
}

#[test]
fn csr_triangles_cores_and_rankings_agree_at_every_width() {
    let cases = [
        generators::gnp(150, 0.06, 11),
        generators::planted_cliques(140, 0.01, 6, 5, 9, 23),
        generators::barabasi_albert(130, 3, 5),
    ];
    for (ci, g) in cases.iter().enumerate() {
        let edges = g.edges();
        let tri_seq = triangles::per_vertex(g);
        let core_seq = degeneracy::core_decomposition(g);
        for threads in THREADS {
            let pool = ThreadPool::new(threads);
            let gp = CsrGraph::from_edges_parallel(g.n(), &edges, &pool);
            assert_eq!(gp.n(), g.n(), "case={ci} threads={threads}");
            assert_eq!(gp.m(), g.m());
            for v in 0..g.n() as u32 {
                assert_eq!(gp.neighbors(v), g.neighbors(v), "case={ci} v={v}");
            }
            assert_eq!(triangles::per_vertex_parallel(g, &pool), tri_seq);
            // cutoff 0 forces the parallel peeler even on small graphs
            let core_par = degeneracy::core_decomposition_parallel_with_cutoff(g, &pool, 0);
            assert_eq!(core_par.core, core_seq.core, "case={ci} threads={threads}");
            assert_eq!(core_par.degeneracy, core_seq.degeneracy);
            for s in [RankStrategy::Degree, RankStrategy::Triangle, RankStrategy::Degeneracy] {
                let a = Ranking::compute(g, s);
                let b = Ranking::compute_parallel(g, s, &pool);
                for v in 0..g.n() as u32 {
                    for w in (v + 1)..g.n() as u32 {
                        assert_eq!(a.higher(v, w), b.higher(v, w), "{s:?}");
                    }
                }
            }
        }
    }
}

#[test]
fn threaded_file_loaders_agree_with_sequential_loaders() {
    let dir = std::env::temp_dir().join("parmce_ingest_equivalence_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("noisy.edges");
    let g = generators::planted_cliques(90, 0.02, 4, 5, 8, 31);
    std::fs::write(&path, render_noisy(&g, 5)).unwrap();

    let g1 = edgelist::load_graph(&path).unwrap();
    let (s1, n1) = edgelist::load_stream(&path).unwrap();
    for threads in THREADS {
        let gt = edgelist::load_graph_threads(&path, threads).unwrap();
        assert_eq!(gt.n(), g1.n(), "threads={threads}");
        assert_eq!(gt.edges(), g1.edges(), "threads={threads}");
        let (st, nt) = edgelist::load_stream_threads(&path, threads).unwrap();
        assert_eq!(nt, n1);
        assert_eq!(st, s1, "threads={threads}");
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn sessions_with_different_ingest_widths_count_identically() {
    let g = generators::planted_cliques(120, 0.015, 5, 6, 10, 77);
    let mut counts = Vec::new();
    for ingest in [1usize, 4] {
        let s = MceSession::builder()
            .graph(g.clone())
            .threads(2)
            .ingest_threads(ingest)
            .rank_strategy(RankStrategy::Triangle)
            .build()
            .unwrap();
        let (cliques, report) = s.collect(Algo::ParMce);
        assert!(report.completed());
        counts.push((report.cliques, cliques));
    }
    assert_eq!(counts[0].0, counts[1].0);
    assert_eq!(counts[0].1, counts[1].1, "canonical clique lists must match");
}
